"""Elastic autoscaling benchmark: the cost-priced controller vs every
fixed fleet size under the deterministic bursty diurnal trace
(``repro.scale.traffic``), per CXL topology preset.

For each preset the SAME trace (one compressed day, seeded Poisson
arrivals over a diurnal sinusoid with burst trains) is served by

* every **fixed** fleet size ``1..max_engines`` — the baseline family;
* the **autoscaled** fleet — ``dsm.placement.choose_scale`` pricing
  hold/grow/shrink each tick with the emulator cost model, joins paying
  the modelled staged-transfer capital and landing only after the
  modelled join delay.

Everything is a pure function of (seed, config), so the decision counts
are bit-deterministic and exact-gated: a refactor that silently stops
scaling (or starts losing sessions) shows up as a count flip, not just
as a slower number.  The acceptance criterion — autoscaled beats the
best fixed size on priced cost AND p99 admission latency with zero lost
sessions — is gated as a boolean per preset.  Wall-clock throughput is
reported but ungated.
"""
from __future__ import annotations

import time

try:
    from benchmarks.harness import Bench
except ImportError:                      # standalone: python benchmarks/...
    from harness import Bench

SEED = 3
TOPOLOGIES = ("cxl11-direct", "cxl20-switched-pool", "cxl30-fabric")


def main():
    from repro.scale.autoscaler import (Autoscaler, AutoscaleConfig,
                                        simulate_autoscale, simulate_fixed)
    from repro.scale.traffic import TrafficConfig, offered_tokens, \
        traffic_trace

    trace = traffic_trace(TrafficConfig(seed=SEED))

    bench = Bench("autoscale")
    bench.set_config(seed=SEED, n_requests=len(trace),
                     offered_tokens=offered_tokens(trace),
                     topologies=list(TOPOLOGIES))
    bench.record("autoscale_trace_requests", len(trace),
                 "sessions in the compressed day")

    t0 = time.perf_counter()
    for topo in TOPOLOGIES:
        cfg = AutoscaleConfig(topology=topo)
        scaler = Autoscaler(cfg)
        auto = simulate_autoscale(trace, cfg, scaler=scaler)
        fixed = {n: simulate_fixed(trace, n, cfg)
                 for n in range(1, cfg.max_engines + 1)}
        best_n = min(fixed, key=lambda n: fixed[n].priced_cost_ns)
        best = fixed[best_n]
        beats = (auto.priced_cost_ns < best.priced_cost_ns
                 and auto.p99_admission_ticks < best.p99_admission_ticks
                 and auto.lost_sessions == 0)
        bench.record(f"autoscale_beats_best_fixed.{topo}", beats,
                     f"cost {auto.priced_cost_ns:.3g} < "
                     f"{best.priced_cost_ns:.3g} (n={best_n}), p99 "
                     f"{auto.p99_admission_ticks:.0f} < "
                     f"{best.p99_admission_ticks:.0f}")
        bench.record(f"autoscale_lost_sessions.{topo}",
                     auto.lost_sessions, "must be zero")
        bench.record(f"autoscale_cost_over_best_fixed.{topo}",
                     auto.priced_cost_ns / best.priced_cost_ns,
                     "priced cost ratio, lower is better", fmt=".3f")
        bench.record(f"autoscale_p99_ticks.{topo}",
                     auto.p99_admission_ticks,
                     f"vs fixed-{best_n}'s {best.p99_admission_ticks:.0f}")
        bench.record(f"autoscale_decisions.{topo}", auto.decisions,
                     "scale decisions logged (all priced alternatives)")
        bench.record(f"autoscale_grows.{topo}", auto.grows,
                     "applied scale-out events")
        bench.record(f"autoscale_shrinks.{topo}", auto.shrinks,
                     "applied scale-in events")
        bench.record(f"autoscale_engines_span.{topo}",
                     f"{auto.engines_min}-{auto.engines_max}",
                     "capacity range the controller used")
        bench.record(f"autoscale_tokens_per_tick.{topo}",
                     auto.tokens_per_tick, "served throughput",
                     fmt=".2f")
    dt = time.perf_counter() - t0
    bench.record("autoscale_sim_wall_s", dt,
                 "3 presets x (1 auto + 12 fixed) simulations", fmt=".1f")
    bench.write()


if __name__ == "__main__":
    main()
