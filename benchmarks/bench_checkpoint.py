"""DSM-runtime benchmark: durable-commit protocol throughput.

The system-scale counterpart of the paper's §6.1 performance discussion:
* sync vs async (compute/flush-overlapped) commit wall time,
* commit bytes/s into the pool,
* recovery time from pool vs peer staging.

Runs a real (small) model training loop on CPU with the FliT-protocol
committer — numbers are host-I/O bound and meant for RELATIVE comparison.
"""
from __future__ import annotations

import shutil
import tempfile
import time

import jax

from repro.configs import get_smoke_config
from repro.data.pipeline import DataPipeline, SyntheticLMSource
from repro.dsm.pool import DSMPool
from repro.dsm.recovery import RecoveryManager
from repro.dsm.tiers import TierManager
from repro.models.registry import build
from repro.train.loop import run_durable_loop
from repro.train.state import init_train_state
from repro.train.step import make_train_step

N_STEPS = 12
COMMIT_EVERY = 2


def run(mode: str, tmp: str, replicate=False, crash=None):
    cfg = get_smoke_config("olmo-1b")
    bundle = build(cfg)
    key = jax.random.PRNGKey(0)
    state = init_train_state(bundle.init_params(key), key)
    step = jax.jit(make_train_step(bundle))
    pipe = DataPipeline(SyntheticLMSource(cfg.vocab_size), 4, 64)
    pool = DSMPool(f"{tmp}/pool_{mode}_{replicate}")
    peer = TierManager(DSMPool(f"{tmp}/peer_{mode}"), worker_id=1)
    t0 = time.perf_counter()
    r = run_durable_loop(step, state, pipe, pool, n_steps=N_STEPS,
                         commit_every=COMMIT_EVERY, commit_mode=mode,
                         peer_tiers=peer if replicate else None,
                         replicate=replicate, crash_at=crash)
    wall = time.perf_counter() - t0
    return r, wall, pool


def main():
    tmp = tempfile.mkdtemp(prefix="bench_ckpt_")
    try:
        # warmup jit
        run("sync", tmp + "/warm")

        r_sync, t_sync, pool_s = run("sync", tmp)
        r_async, t_async, _ = run("async", tmp)
        commit_s_sync = sum(t.commit_s for t in r_sync.timings)
        commit_s_async = sum(t.commit_s for t in r_async.timings)
        latest = pool_s.latest_manifest()
        bytes_per_commit = sum(o["nbytes"]
                               for o in latest["objects"].values())
        print(f"ckpt_sync_wall_s,{t_sync:.3f},{N_STEPS} steps")
        print(f"ckpt_async_wall_s,{t_async:.3f},overlap hides flush")
        print(f"ckpt_sync_commit_s,{commit_s_sync:.3f},blocking flush total")
        print(f"ckpt_async_commit_s,{commit_s_async:.3f},joined in background")
        print(f"ckpt_bytes_per_commit,{bytes_per_commit},"
              f"{bytes_per_commit/1e6:.1f} MB")
        spd = commit_s_sync / max(commit_s_async, 1e-9)
        print(f"ckpt_async_commit_speedup,{spd:.2f},sync/async blocking time")

        # recovery latency: pool vs peer staging
        _, _, pool = run("sync", tmp + "/rec")
        t0 = time.perf_counter()
        r2, _, pool2 = run("sync", tmp + "/rec2", replicate=True,
                           crash={5: "before_commit"})
        print(f"ckpt_recoveries,{len(r2.recoveries)},"
              f"source={','.join(r2.recoveries)}")
    finally:
        shutil.rmtree(tmp, ignore_errors=True)


if __name__ == "__main__":
    main()
