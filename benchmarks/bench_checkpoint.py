"""DSM-runtime benchmark: durable-commit protocol throughput.

The system-scale counterpart of the paper's §6.1 performance discussion:
* sync vs async vs sharded vs sharded-async commit wall time, swept over
  shard counts — measures (not asserts) the compute/flush-overlap and
  shard-parallelism wins of the sharded-async schedule;
* commit bytes/s into the pool;
* recovery time from pool vs peer staging.

Runs a real (small) model training loop on CPU with the FliT-protocol
committer — numbers are host-I/O bound and meant for RELATIVE comparison.

Output is CSV-ish ``key,value,note`` lines; the headline comparison is
``ckpt_commit_blocking_s,<mode>,shards=<n>`` — at >= 4 shards the
sharded-async blocking time should be at or below sync.
"""
from __future__ import annotations

import os
import shutil
import tempfile
import time

# the mesh section runs on a real 2x4 device mesh — force the 8-device
# host platform before jax initialises (no-op when already forced, e.g.
# under benchmarks/run.py or the test conftest)
if "xla_force_host_platform_device_count" not in os.environ.get(
        "XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=8"
                               ).strip()

import jax
import jax.numpy as jnp
import numpy as np

try:
    from benchmarks.harness import Bench
except ImportError:                      # standalone: python benchmarks/...
    from harness import Bench

from repro.configs import get_smoke_config
from repro.data.pipeline import DataPipeline, SyntheticLMSource
from repro.dsm.api import CXL0Config, open_cxl0
from repro.dsm.pool import DSMPool
from repro.models.registry import build
from repro.train.loop import run_durable_loop
from repro.train.state import init_train_state
from repro.train.step import make_train_step

N_STEPS = 12
COMMIT_EVERY = 2
SHARD_SWEEP = (1, 2, 4, 8)


def run(mode: str, tmp: str, *, n_shards=1, replicate=False, crash=None):
    cfg = get_smoke_config("olmo-1b")
    bundle = build(cfg)
    key = jax.random.PRNGKey(0)
    state = init_train_state(bundle.init_params(key), key)
    step = jax.jit(make_train_step(bundle))
    pipe = DataPipeline(SyntheticLMSource(cfg.vocab_size), 4, 64)
    pool = DSMPool(f"{tmp}/pool_{mode}_{n_shards}_{replicate}")
    # a CXL0Context is itself a valid RStore peer (exposes .staging)
    peer = open_cxl0(f"{tmp}/peer_{mode}_{n_shards}", 1)
    t0 = time.perf_counter()
    r = run_durable_loop(step, state, pipe, pool, n_steps=N_STEPS,
                         commit_every=COMMIT_EVERY, commit_mode=mode,
                         n_shards=n_shards,
                         peer_tiers=peer if replicate else None,
                         replicate=replicate, crash_at=crash)
    wall = time.perf_counter() - t0
    return r, wall, pool


def blocking_commit_s(r) -> float:
    return sum(t.commit_s for t in r.timings)


def bench_write_object_fast_path(bench, tmp: str, *, rows=8192,
                                 row_bytes=512):
    """The PR-7 pool-write gate: ``write_object`` (streamed frame, one
    data pass, one fsync) vs ``write_object_legacy`` (np.savez + sidecar,
    three passes, two fsyncs) on a fine-grained object — embedding-row
    granularity, where the legacy per-zip-member overhead dominates.
    Asserted as a RATIO so the gate is runner-independent."""
    pool = DSMPool(f"{tmp}/fastpath")
    tree = {f"row{i}": np.random.default_rng(i).standard_normal(
                (row_bytes // 4,)).astype(np.float32)
            for i in range(rows)}
    mb = rows * row_bytes / 2**20

    def run_writer(write, base_version):
        write("emb", base_version, tree)             # warm (dirs, arena)
        best = float("inf")
        for v in (1, 2):
            t0 = time.perf_counter()
            write("emb", base_version + v, tree)
            best = min(best, time.perf_counter() - t0)
        return best

    t_new = run_writer(pool.write_object, 10)
    t_old = run_writer(pool.write_object_legacy, 20)
    speedup = t_old / t_new
    note = f"{rows} x {row_bytes} B float32 rows ({mb:.0f} MiB), fsync incl."
    bench.record("ckpt_write_object_mb_s", mb / t_new,
                 f"streamed write_object, {note}", fmt=".0f")
    bench.record("ckpt_write_object_legacy_mb_s", mb / t_old,
                 f"legacy np.savez write, {note}", fmt=".0f")
    bench.record("ckpt_write_object_speedup_x", speedup,
                 "streamed vs legacy, same object", fmt=".1f")
    assert speedup >= 5.0, (
        f"write_object fast path regressed: {speedup:.1f}x < 5x legacy")
    bench.record("ckpt_write_object_speedup_ok", True,
                 "write_object >= 5x legacy (asserted)")


def bench_mesh_commit(bench, tmp: str, *, n_leaves=8, dim=512,
                      n_commits=3):
    """The PR-9 device-local commit: sharded flush consuming per-device
    buffers (``CXL0Config.mesh``) vs the classic full-tree host gather,
    over the SAME device-sharded state on a real 2x4 mesh.  Wall times
    are measured (ungated — host-I/O bound); what IS exact-gated is the
    transport contract: identical manifests/per-shard bytes and zero
    full-tree D2H gather traffic on the device path."""
    if jax.device_count() < 8:
        bench.record("ckpt_mesh_skipped", True,
                     f"needs 8 host devices, have {jax.device_count()}")
        return
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    sh = jax.sharding.NamedSharding(
        mesh, jax.sharding.PartitionSpec("data", "model"))
    key = jax.random.PRNGKey(0)
    tree = {}
    for t in range(n_leaves):
        key, k = jax.random.split(key)
        tree[f"w{t}"] = jax.device_put(
            jax.random.normal(k, (dim, dim), jnp.float32), sh)
    mb = sum(l.nbytes for l in tree.values()) / 2**20

    def run_commits(use_mesh):
        ctx = CXL0Config(path=f"{tmp}/mesh_{bool(use_mesh)}",
                         schedule="sharded", n_shards=None,
                         mesh=mesh if use_mesh else None).open()
        best = float("inf")
        for step in range(1, n_commits + 1):
            ctx.put({"params": tree}, step=step)
            t0 = time.perf_counter()
            with ctx.commit(step):
                pass
            best = min(best, time.perf_counter() - t0)
        return ctx, best

    ctx_dev, t_dev = run_commits(True)
    ctx_hg, t_hg = run_commits(False)
    note = f"{n_leaves} x {dim}x{dim} f32 ({mb:.0f} MiB) on a 2x4 mesh"
    bench.record("ckpt_mesh_flush_device_s", t_dev,
                 f"device-local sharded commit, {note}", fmt=".3f")
    bench.record("ckpt_mesh_flush_gather_s", t_hg,
                 f"host-gather sharded commit, {note}", fmt=".3f")
    m_dev = ctx_dev.pool.latest_manifest()
    m_hg = ctx_hg.pool.latest_manifest()
    bench.record("ckpt_mesh_shards", ctx_dev.committer.n_shards,
                 "shard count derived from the mesh device layout")
    bench.record("ckpt_mesh_shard_bytes",
                 [s["nbytes"] for s in m_dev["objects"]["params"]["shards"]],
                 "per-shard bytes, device-local path")
    bench.record("ckpt_mesh_manifest_equal", bool(m_dev == m_hg),
                 "device-local manifest == host-gather manifest")
    bench.record("ckpt_mesh_d2h_gather_bytes",
                 ctx_dev.tiers.d2h_gather_bytes,
                 "full-tree D2H gathers on the device-local path "
                 f"(per-buffer copies: {ctx_dev.tiers.d2h_shard_bytes})")


def main():
    bench = Bench("checkpoint")
    bench.set_config(n_steps=N_STEPS, commit_every=COMMIT_EVERY,
                     shard_sweep=list(SHARD_SWEEP))
    tmp = tempfile.mkdtemp(prefix="bench_ckpt_")
    try:
        # warmup jit
        run("sync", tmp + "/warm")

        # -- schedule x shard-count sweep --------------------------------
        r_sync, t_sync, pool_s = run("sync", tmp)
        commit_sync = blocking_commit_s(r_sync)
        latest = pool_s.latest_manifest()
        bytes_per_commit = sum(o["nbytes"]
                               for o in latest["objects"].values())
        bench.record("ckpt_bytes_per_commit", bytes_per_commit,
                     f"{bytes_per_commit/1e6:.1f} MB")
        bench.record("ckpt_commit_blocking_s", commit_sync,
                     "mode=sync shards=1",
                     key="ckpt_commit_blocking_s.sync.1", fmt=".3f")
        bench.record("ckpt_wall_s", t_sync, "mode=sync shards=1",
                     key="ckpt_wall_s.sync.1", fmt=".3f")

        r_async, t_async, _ = run("async", tmp)
        commit_async = blocking_commit_s(r_async)
        bench.record("ckpt_commit_blocking_s", commit_async,
                     "mode=async shards=1",
                     key="ckpt_commit_blocking_s.async.1", fmt=".3f")
        bench.record("ckpt_wall_s", t_async, "mode=async shards=1",
                     key="ckpt_wall_s.async.1", fmt=".3f")

        results = {}
        for mode in ("sharded", "sharded-async"):
            for n in SHARD_SWEEP:
                r, wall, _ = run(mode, tmp, n_shards=n)
                cb = blocking_commit_s(r)
                results[(mode, n)] = cb
                bench.record("ckpt_commit_blocking_s", cb,
                             f"mode={mode} shards={n}",
                             key=f"ckpt_commit_blocking_s.{mode}.{n}",
                             fmt=".3f")
                bench.record("ckpt_wall_s", wall,
                             f"mode={mode} shards={n}",
                             key=f"ckpt_wall_s.{mode}.{n}", fmt=".3f")

        for n in SHARD_SWEEP:
            spd = commit_sync / max(results[("sharded-async", n)], 1e-9)
            bench.record("ckpt_sharded_async_speedup", spd,
                         f"sync/sharded-async blocking time at {n} shards",
                         key=f"ckpt_sharded_async_speedup.{n}", fmt=".2f")
        ok4 = results[("sharded-async", 4)] <= commit_sync
        bench.record("ckpt_sharded_async_beats_sync_at_4_shards", bool(ok4),
                     f"{results[('sharded-async', 4)]:.3f}s vs "
                     f"{commit_sync:.3f}s")

        # -- recovery latency: pool vs peer staging ----------------------
        r2, _, _ = run("sync", tmp + "/rec2", replicate=True,
                       crash={5: "before_commit"})
        bench.record("ckpt_recoveries", len(r2.recoveries),
                     f"source={','.join(r2.recoveries)}")

        # -- streamed vs legacy write_object fast path -------------------
        bench_write_object_fast_path(bench, tmp)

        # -- device-local vs host-gather mesh commit ---------------------
        bench_mesh_commit(bench, tmp)
    finally:
        shutil.rmtree(tmp, ignore_errors=True)
    bench.write()


if __name__ == "__main__":
    main()
