"""Cluster-protocol benchmark: multi-writer commit safety at speed.

Measures (not asserts, except the zero-loss invariant):
* contended multi-writer commit throughput — K committer threads share
  one pool; every commit must survive (the O_EXCL seq reservation turns
  collisions into rescans, never into overwrites) and the row reports
  commits/s and the rescan (collision) overhead vs a single writer;
* cross-process staging throughput — RStore spill-file stage + view-read
  of a multi-MB state partition (the peer-recovery data path);
* N-process cluster step rate with the full lockstep protocol (board
  all-reduce + rank records + elected cluster manifests), vs world size.

Output is CSV-ish ``key,value,note`` rows like the other benches.
"""
from __future__ import annotations

import os
import shutil
import tempfile
import threading
import time

import numpy as np

try:
    from benchmarks.harness import Bench
except ImportError:                      # standalone: python benchmarks/...
    from harness import Bench

from repro.dsm.cluster import FileStagingArea
from repro.dsm.pool import DSMPool
from repro.scenarios.cluster import spawn_worker


def bench_contended_commits(bench, tmp: str, *, writers=4, per_writer=40):
    obj_pool = DSMPool(os.path.join(tmp, "contended"))
    objs = {w: obj_pool.write_object(f"w{w}/x", 1,
                                     {"a": np.zeros(64, np.float32)})
            for w in range(writers)}

    def run_writers(n_writers) -> float:
        pool_dir = os.path.join(tmp, f"commit_{n_writers}")
        handles = {w: DSMPool(pool_dir) for w in range(n_writers)}
        for w in range(n_writers):
            handles[w].write_object(f"w{w}/x", 1,
                                    {"a": np.zeros(64, np.float32)})
        t0 = time.perf_counter()

        def work(w):
            for i in range(per_writer):
                handles[w].commit_manifest(i, {f"w{w}/x": objs[w]},
                                           meta={"w": w})

        threads = [threading.Thread(target=work, args=(w,))
                   for w in range(n_writers)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        wall = time.perf_counter() - t0
        ms = DSMPool(pool_dir).manifests_desc()
        total = n_writers * per_writer
        assert len(ms) == total, (len(ms), total)     # zero loss, always
        assert len({m["seq"] for m in ms}) == total
        return total / wall

    solo = run_writers(1)
    contended = run_writers(writers)
    bench.record("cluster_commit_rate_1_writer", solo, "commits/s",
                 fmt=".0f")
    bench.record(f"cluster_commit_rate_{writers}_writers", contended,
                 "commits/s aggregate; zero lost/overwritten commits "
                 "asserted", key="cluster_commit_rate_contended", fmt=".0f")
    bench.record("cluster_commit_contention_ratio", contended / solo,
                 "aggregate vs solo (O_EXCL rescan overhead)", fmt=".2f")
    bench.record("cluster_commits_total", (1 + writers) * per_writer,
                 "manifests across both runs; every one present and "
                 "unique (zero-loss asserted in-bench)", fmt=".0f")


def bench_staging_throughput(bench, tmp: str, *, mb=8):
    area = FileStagingArea(os.path.join(tmp, "staging"))
    tree = {"p": np.random.default_rng(0).standard_normal(
        (mb * 1024 * 1024 // 4,)).astype(np.float32)}
    t0 = time.perf_counter()
    area.proxy(1).staging["w0/params"] = (3, tree)
    t_stage = time.perf_counter() - t0
    t0 = time.perf_counter()
    view = area.view(1, {"w0/params": tree})
    t_view = time.perf_counter() - t0
    assert np.array_equal(np.asarray(view.staging["w0/params"][1]["p"]),
                          tree["p"])
    bench.record("cluster_rstore_stage_mb_s", mb / t_stage,
                 f"{mb} MiB partition -> sibling spill buffer", fmt=".0f")
    bench.record("cluster_staging_view_mb_s", mb / t_view,
                 "sibling buffer -> recovery view (read + CRC validate)",
                 fmt=".0f")
    bench.record("cluster_staged_bytes", tree["p"].nbytes,
                 "bytes per staged copy (deterministic)", fmt=".0f")


def bench_streamed_vs_legacy(bench, tmp: str, *, pages=8192, page_kib=1):
    """The PR-7 fast-path gate: stage + view throughput of the streamed
    spill format vs the PR-6 ``np.savez`` path on the SAME fine-grained
    workload (a paged KV partition — thousands of ~KiB leaves, where the
    legacy per-zip-member and double-CRC overheads dominate).  Asserted
    as a RATIO, not wall-clock, so the gate is runner-independent."""
    tree = {f"page{i}": np.random.default_rng(i).integers(
                0, 255, (page_kib * 1024,), dtype=np.uint8).astype(np.uint8)
            for i in range(pages)}
    mb = pages * page_kib / 1024

    def run(area):
        area.proxy(1).staging["w0/kv"] = (1, tree)      # warm (dirs, arena)
        stage = view = float("inf")
        for tag in (2, 3):
            t0 = time.perf_counter()
            area.proxy(1).staging["w0/kv"] = (tag, tree)
            stage = min(stage, time.perf_counter() - t0)
            t0 = time.perf_counter()
            got = area.view(1, {"w0/kv": tree})
            view = min(view, time.perf_counter() - t0)
        assert got.staging["w0/kv"][0] == 3
        return stage, view

    s_stage, s_view = run(FileStagingArea(os.path.join(tmp, "fast")))
    l_stage, l_view = run(FileStagingArea(os.path.join(tmp, "slow"),
                                          legacy_format=True))
    stage_x, view_x = l_stage / s_stage, l_view / s_view
    note = f"{pages} x {page_kib} KiB pages ({mb:.0f} MiB)"
    bench.record("cluster_stream_stage_mb_s", mb / s_stage,
                 f"streamed spill, {note}", fmt=".0f")
    bench.record("cluster_legacy_stage_mb_s", mb / l_stage,
                 f"legacy np.savez spill, {note}", fmt=".0f")
    bench.record("cluster_stream_view_mb_s", mb / s_view,
                 "streamed mmap view read + CRC", fmt=".0f")
    bench.record("cluster_legacy_view_mb_s", mb / l_view,
                 "legacy np.load view read + CRC", fmt=".0f")
    bench.record("cluster_stage_speedup_x", stage_x,
                 "streamed vs legacy stage, same workload", fmt=".1f")
    bench.record("cluster_view_speedup_x", view_x,
                 "streamed vs legacy view, same workload", fmt=".1f")
    assert stage_x >= 10.0, (
        f"staging fast path regressed: {stage_x:.1f}x < 10x legacy")
    assert view_x >= 10.0, (
        f"view fast path regressed: {view_x:.1f}x < 10x legacy")
    bench.record("cluster_stream_speedup_ok", True,
                 "stage AND view >= 10x legacy (asserted)")


def bench_cluster_step_rate(bench, tmp: str, *, steps=12, commit_every=3):
    for world in (2, 3, 4):
        pool = os.path.join(tmp, f"cluster_w{world}")
        t0 = time.perf_counter()
        procs = [spawn_worker(pool, r, world, steps=steps,
                              commit_every=commit_every, replicate=True)
                 for r in range(world)]
        ok = True
        for p in procs:
            out, err = p.communicate(timeout=600)
            ok = ok and p.returncode == 0
        wall = time.perf_counter() - t0
        assert ok, "cluster bench worker failed"
        bench.record(f"cluster_steps_per_s_world{world}", steps / wall,
                     f"{steps} lockstep steps, commit every {commit_every} "
                     f"(incl. process startup)", fmt=".2f")


def main():
    bench = Bench("cluster")
    tmp = tempfile.mkdtemp(prefix="bench_cluster_")
    try:
        bench_contended_commits(bench, tmp)
        bench_staging_throughput(bench, tmp)
        bench_streamed_vs_legacy(bench, tmp)
        bench_cluster_step_rate(bench, tmp)
    finally:
        shutil.rmtree(tmp, ignore_errors=True)
    bench.write()


if __name__ == "__main__":
    main()
