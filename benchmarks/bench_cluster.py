"""Cluster-protocol benchmark: multi-writer commit safety at speed.

Measures (not asserts, except the zero-loss invariant):
* contended multi-writer commit throughput — K committer threads share
  one pool; every commit must survive (the O_EXCL seq reservation turns
  collisions into rescans, never into overwrites) and the row reports
  commits/s and the rescan (collision) overhead vs a single writer;
* cross-process staging throughput — RStore spill-file stage + view-read
  of a multi-MB state partition (the peer-recovery data path);
* N-process cluster step rate with the full lockstep protocol (board
  all-reduce + rank records + elected cluster manifests), vs world size.

Output is CSV-ish ``key,value,note`` rows like the other benches.
"""
from __future__ import annotations

import os
import shutil
import tempfile
import threading
import time

import numpy as np

try:
    from benchmarks.harness import Bench
except ImportError:                      # standalone: python benchmarks/...
    from harness import Bench

from repro.dsm.cluster import FileStagingArea
from repro.dsm.pool import DSMPool
from repro.scenarios.cluster import spawn_worker


def bench_contended_commits(bench, tmp: str, *, writers=4, per_writer=40):
    obj_pool = DSMPool(os.path.join(tmp, "contended"))
    objs = {w: obj_pool.write_object(f"w{w}/x", 1,
                                     {"a": np.zeros(64, np.float32)})
            for w in range(writers)}

    def run_writers(n_writers) -> float:
        pool_dir = os.path.join(tmp, f"commit_{n_writers}")
        handles = {w: DSMPool(pool_dir) for w in range(n_writers)}
        for w in range(n_writers):
            handles[w].write_object(f"w{w}/x", 1,
                                    {"a": np.zeros(64, np.float32)})
        t0 = time.perf_counter()

        def work(w):
            for i in range(per_writer):
                handles[w].commit_manifest(i, {f"w{w}/x": objs[w]},
                                           meta={"w": w})

        threads = [threading.Thread(target=work, args=(w,))
                   for w in range(n_writers)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        wall = time.perf_counter() - t0
        ms = DSMPool(pool_dir).manifests_desc()
        total = n_writers * per_writer
        assert len(ms) == total, (len(ms), total)     # zero loss, always
        assert len({m["seq"] for m in ms}) == total
        return total / wall

    solo = run_writers(1)
    contended = run_writers(writers)
    bench.record("cluster_commit_rate_1_writer", solo, "commits/s",
                 fmt=".0f")
    bench.record(f"cluster_commit_rate_{writers}_writers", contended,
                 "commits/s aggregate; zero lost/overwritten commits "
                 "asserted", key="cluster_commit_rate_contended", fmt=".0f")
    bench.record("cluster_commit_contention_ratio", contended / solo,
                 "aggregate vs solo (O_EXCL rescan overhead)", fmt=".2f")


def bench_staging_throughput(bench, tmp: str, *, mb=8):
    area = FileStagingArea(os.path.join(tmp, "staging"))
    tree = {"p": np.random.default_rng(0).standard_normal(
        (mb * 1024 * 1024 // 4,)).astype(np.float32)}
    t0 = time.perf_counter()
    area.proxy(1).staging["w0/params"] = (3, tree)
    t_stage = time.perf_counter() - t0
    t0 = time.perf_counter()
    view = area.view(1, {"w0/params": tree})
    t_view = time.perf_counter() - t0
    assert np.array_equal(np.asarray(view.staging["w0/params"][1]["p"]),
                          tree["p"])
    bench.record("cluster_rstore_stage_mb_s", mb / t_stage,
                 f"{mb} MiB partition -> sibling spill buffer", fmt=".0f")
    bench.record("cluster_staging_view_mb_s", mb / t_view,
                 "sibling buffer -> recovery view (read + CRC validate)",
                 fmt=".0f")


def bench_cluster_step_rate(bench, tmp: str, *, steps=12, commit_every=3):
    for world in (2, 3, 4):
        pool = os.path.join(tmp, f"cluster_w{world}")
        t0 = time.perf_counter()
        procs = [spawn_worker(pool, r, world, steps=steps,
                              commit_every=commit_every, replicate=True)
                 for r in range(world)]
        ok = True
        for p in procs:
            out, err = p.communicate(timeout=600)
            ok = ok and p.returncode == 0
        wall = time.perf_counter() - t0
        assert ok, "cluster bench worker failed"
        bench.record(f"cluster_steps_per_s_world{world}", steps / wall,
                     f"{steps} lockstep steps, commit every {commit_every} "
                     f"(incl. process startup)", fmt=".2f")


def main():
    bench = Bench("cluster")
    tmp = tempfile.mkdtemp(prefix="bench_cluster_")
    try:
        bench_contended_commits(bench, tmp)
        bench_staging_throughput(bench, tmp)
        bench_cluster_step_rate(bench, tmp)
    finally:
        shutil.rmtree(tmp, ignore_errors=True)
    bench.write()


if __name__ == "__main__":
    main()
