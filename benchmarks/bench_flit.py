"""Paper §6 — the FliT transformation: correctness-vs-cost comparison.

Per (workload × policy):
* durability violation rate over a seed sweep with injected crashes
  (raw / original-FliT violate; Alg. 2 / MStore-all never do);
* modelled operation cost (Fig. 5 latency table) — Alg. 2's
  LStore+one-RFlush beats MStore-everything, quantifying the paper's
  §6.1 performance argument;
* simulator throughput (ops/s) as a harness health metric.
"""
from __future__ import annotations

import time

try:
    from benchmarks.harness import Bench
except ImportError:                      # standalone: python benchmarks/...
    from harness import Bench

from repro.core.flit import POLICIES
from repro.core.harness import WORKLOADS, run_once
from repro.core.latency import DEVICE, trace_cost

N_SEEDS = 150


def violation_rates():
    out = []
    for wl_name, mk in WORKLOADS.items():
        for policy in POLICIES:
            t0 = time.perf_counter()
            viol = ops = 0
            for seed in range(N_SEEDS):
                r = run_once(mk, policy, seed, p_crash=0.08, max_crashes=2)
                viol += (not r.durable)
                ops += sum(1 for e in r.history if e.kind == "res")
            dt = time.perf_counter() - t0
            out.append((f"flit_violations_{wl_name}_{policy}",
                        viol, f"{N_SEEDS} seeds; {ops/dt:.0f} ops/s checked"))
    return out


def op_cost_model():
    """Modelled ns per high-level op (device issuing, remote object)."""
    out = []
    # counter inc: raw = 1 RMW; flit = cnt-RMW + RMW + RFlush + cnt-RMW;
    # mstore_all = 1 M-RMW
    raw = [(DEVICE, "faa", "remote")]
    flit = [(DEVICE, "faa", "remote")] * 3 + [(DEVICE, "rflush", "remote")]
    mstore = [(DEVICE, "faa", "remote")]
    out.append(("flit_cost_inc_raw_ns", trace_cost(raw), "no durability"))
    out.append(("flit_cost_inc_flit_cxl0_ns",
                trace_cost(flit), "durable (Alg. 2)"))
    out.append(("flit_cost_inc_mstore_ns",
                trace_cost(mstore, flavors=["m"]),
                "durable (MStore; no counters)"))
    # 4-store op (e.g. stack push: 2 private field stores + CAS publish)
    flit4 = ([(DEVICE, "lstore", "remote")] * 3
             + [(DEVICE, "rflush", "remote")] * 3
             + [(DEVICE, "cas", "remote"), (DEVICE, "rflush", "remote")])
    mstore4 = [(DEVICE, "mstore", "remote")] * 3 + [(DEVICE, "cas", "remote")]
    out.append(("flit_cost_push_flit_cxl0_ns", trace_cost(flit4),
                "Alg. 2: LStore+RFlush per field"))
    out.append(("flit_cost_push_mstore_ns",
                trace_cost(mstore4, flavors=["l", "l", "l", "m"]),
                "MStore fields + M-CAS"))
    return out


def main():
    bench = Bench("flit")
    bench.set_config(n_seeds=N_SEEDS)
    for name, val, derived in violation_rates() + op_cost_model():
        bench.record(name, val, derived)
    bench.write()


if __name__ == "__main__":
    main()
