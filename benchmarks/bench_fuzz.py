"""Adversarial crash-fuzzer bench: a pinned slice of the fuzz suite as a
gated regression metric.

Every episode is a pure function of (seed, config, schedule), so the
counts below are bit-deterministic: the gate pins the invariant-violation
count to 0 AND the kill / torn-write / recovery counts to their exact
values — a refactor that silently stops injecting faults (or stops
recovering from them) shows up as a count drop, not just as green tests.
"""
from __future__ import annotations

import time

try:
    from benchmarks.harness import Bench
except ImportError:                      # standalone: python benchmarks/...
    from harness import Bench

EPISODES = 3
SEED = 0
#: pinned to the original three workloads — the gated counts below are
#: exact, and the newer ``scale`` workload has its own suite/tests
WORKLOADS = ("train", "serve", "cluster")


def main():
    import tempfile

    from repro.scenarios.fuzz import TOPOLOGIES, run_fuzz_suite

    t0 = time.perf_counter()
    with tempfile.TemporaryDirectory(prefix="bench-fuzz-") as d:
        s = run_fuzz_suite(d, episodes=EPISODES, seed=SEED, shrink=False,
                           workloads=WORKLOADS)
    dt = time.perf_counter() - t0

    bench = Bench("fuzz")
    bench.set_config(episodes_per_cell=EPISODES, seed=SEED,
                     workloads=list(WORKLOADS), topologies=list(TOPOLOGIES))
    bench.record("fuzz_episodes", s.episodes,
                 f"{EPISODES} x {len(WORKLOADS)} workloads x "
                 f"{len(TOPOLOGIES)} topologies")
    bench.record("fuzz_invariant_violations", s.violations,
                 "recovery != newest completed commit, or not bit-identical")
    bench.record("fuzz_kills_fired", s.kills_fired,
                 "scheduled worker deaths that actually landed")
    bench.record("fuzz_torn_writes", s.torn_writes,
                 "durable writes corrupted after their rename")
    bench.record("fuzz_recoveries", s.recoveries,
                 "checked recovery invocations (incl. forced finals)")
    bench.record("fuzz_cold_starts", s.cold_starts,
                 "episodes that legitimately had nothing recoverable")
    bench.record("fuzz_episodes_per_s", s.episodes / dt,
                 "suite wall-clock throughput", fmt=".1f")
    bench.write()


if __name__ == "__main__":
    main()
