"""Paper Fig. 5 — latency of CXL0 primitives on host and device.

Reproduces the paper's latency *trends* from the calibrated model
(exact ns are chart-read; the stated ratios hold exactly — asserted in
tests/test_latency_model.py) and prices the primitives through the
simulator so the numbers and the executable semantics stay coupled.
"""
from __future__ import annotations

try:
    from benchmarks.harness import Bench
except ImportError:                      # standalone: python benchmarks/...
    from harness import Bench

from repro.core.latency import DEVICE, HOST, LATENCY_NS, primitive_latency


def rows():
    out = []
    for node in (HOST, DEVICE):
        for prim in ("load", "lstore", "rstore", "mstore", "rflush"):
            for loc in ("local", "remote"):
                try:
                    ns = primitive_latency(node, prim, loc)
                except KeyError:
                    continue
                out.append((f"fig5_{node}_{prim}_{loc}", ns,
                            f"{node} {prim} -> {loc}"))
    # headline ratios from the paper text
    out.append(("fig5_ratio_host_remote_over_local",
                LATENCY_NS[(HOST, "load", "remote")]
                / LATENCY_NS[(HOST, "load", "local")], "paper: 2.34x"))
    out.append(("fig5_ratio_device_remote_over_local",
                LATENCY_NS[(DEVICE, "load", "remote")]
                / LATENCY_NS[(DEVICE, "load", "local")], "paper: 1.94x"))
    out.append(("fig5_ratio_dev_rstore_over_lstore",
                LATENCY_NS[(DEVICE, "rstore", "remote")]
                / LATENCY_NS[(DEVICE, "lstore", "remote")], "paper: 2.08x"))
    out.append(("fig5_ratio_dev_mstore_over_rstore",
                LATENCY_NS[(DEVICE, "mstore", "remote")]
                / LATENCY_NS[(DEVICE, "rstore", "remote")], "paper: 1.45x"))
    return out


def main():
    bench = Bench("latency")
    for name, val, derived in rows():
        bench.record(name, val, derived, fmt=".2f")
    bench.write()


if __name__ == "__main__":
    main()
