"""Vectorized-semantics benchmark: vmapped CXL0 schedule fuzzing throughput.

The JAX twin of the LTS (core/semantics_jax.py) runs thousands of random
schedules in parallel — this benchmark reports schedules/s and steps/s,
and cross-checks a sample against the Python reference.
"""
from __future__ import annotations

import time

import jax
import numpy as np

try:
    from benchmarks.harness import Bench
except ImportError:                      # standalone: python benchmarks/...
    from harness import Bench

from repro.core.semantics_jax import (
    JaxSystem, random_schedules, run_schedules,
)

SYS = JaxSystem(owner=(0, 0, 1, 1), volatile=(False, True), n_machines=2)


def main():
    key = jax.random.PRNGKey(0)
    B, T = 2048, 64
    acts = random_schedules(SYS, key, batch=B, length=T, p_crash=0.03)
    # warm up compile
    C, M, obs = run_schedules(SYS, acts)
    jax.block_until_ready(obs)
    t0 = time.perf_counter()
    n_rep = 10
    for _ in range(n_rep):
        C, M, obs = run_schedules(SYS, acts)
        jax.block_until_ready(obs)
    dt = (time.perf_counter() - t0) / n_rep
    bench = Bench("model_fuzz")
    bench.set_config(batch=B, length=T)
    bench.record("fuzz_schedules_per_s", B / dt, f"batch={B} length={T}",
                 fmt=".0f")
    bench.record("fuzz_steps_per_s", B * T / dt, "vmapped LTS steps",
                 fmt=".0f")
    # invariant check on the batch (single-valid-value)
    C = np.asarray(C)
    bad = 0
    for b in range(min(B, 256)):
        for x in range(SYS.n_locs):
            vals = {v for v in C[b, :, x] if v != -1}
            bad += len(vals) > 1
    bench.record("fuzz_invariant_violations", bad,
                 "over 256 sampled end states")
    bench.write()


if __name__ == "__main__":
    main()
