"""Placement-policy benchmark: cost-driven tier placement vs fixed
policies across the emulated CXL topology presets.

For every preset (``cxl11-direct``, ``cxl20-switched-pool``,
``cxl30-fabric``) a seeded workload of spill-then-consume objects
(log-uniform sizes, the serving eviction mix) is placed three ways:

* **always-staging** — every object RStore-staged to a peer buffer
  (volatile: a peer loss forces a replay at the policy's modelled rate);
* **always-pool**    — every object durably flushed at the policy's best
  shard count and restored from the pool;
* **policy**         — ``PlacementPolicy.choose_spill`` per object.

The scored quantity is the expected end-to-end emulated ns from the SAME
cost model the runtime's emulator prices real ops with (``dsm.emu``), so
the comparison is deterministic — the per-object argmin guarantees
``policy <= min(fixed)`` on every preset, and any preset whose workload
mixes decisions makes the policy STRICTLY better than both (the
acceptance criterion; gated in CI via ``benchmarks/baselines``).

A second section instruments a REAL TierManager over a throwaway pool
with the topology emulator and drives the policy's routed spills through
it (``attach_emulator`` + the actual lstore/rstore/rflush_sharded calls),
reporting the priced-trace totals — the injectable emulation end to end.
"""
from __future__ import annotations

import shutil
import tempfile
from typing import Dict, List

import numpy as np

try:
    from benchmarks.harness import Bench
except ImportError:                      # standalone: python benchmarks/...
    from harness import Bench

from repro.dsm.api import open_cxl0
from repro.dsm.emu import PRESETS, TopologyEmulator, attach_emulator
from repro.dsm.placement import PlacementPolicy

N_OBJECTS = 24
SIZE_RANGE = (4 << 10, 64 << 20)         # 4 KiB .. 64 MiB, log-uniform
SEED = 0


def workload_sizes(n: int = N_OBJECTS, seed: int = SEED) -> List[int]:
    rng = np.random.default_rng(seed)
    lo, hi = np.log(SIZE_RANGE[0]), np.log(SIZE_RANGE[1])
    return [int(np.exp(x)) for x in rng.uniform(lo, hi, size=n)]


def score(policy: PlacementPolicy, sizes: List[int]) -> Dict[str, float]:
    """Expected emulated ns of the whole workload under each strategy."""
    totals = {"staging": 0.0, "pool": 0.0, "policy": 0.0}
    n_staging = 0
    for i, nb in enumerate(sizes):
        costs = policy.spill_costs(nb)
        totals["staging"] += costs["staging"]
        totals["pool"] += costs["pool"]
        choice = policy.choose_spill(f"obj{i}", nb)
        totals["policy"] += costs[choice]
        n_staging += choice == "staging"
    totals["n_staging"] = n_staging
    totals["n_pool"] = len(sizes) - n_staging
    return totals


def emulated_run(preset: str, sizes: List[int]) -> Dict[str, float]:
    """Drive the policy's routed spills through a REAL TierManager with the
    topology emulator attached: staging choices rstore into a peer,
    pool choices rflush_sharded at the chosen shard count.  Returns the
    priced-trace summary (deterministic for a fixed preset + seed)."""
    policy = PlacementPolicy(preset)
    emu = TopologyEmulator(preset, seed=SEED)
    tmp = tempfile.mkdtemp(prefix=f"bench_placement_{preset}_")
    try:
        tiers = attach_emulator(open_cxl0(f"{tmp}/pool", 0).tiers, emu)
        peer = open_cxl0(f"{tmp}/peer", 1)
        for i, nb in enumerate(sizes):
            name = f"obj{i}"
            # payloads are capped at 4 KiB so the bench stays I/O-light:
            # the ROUTING is driven by the workload size nb, while the
            # priced trace reflects the bytes actually moved here (the
            # full-size comparison above is the modelled section)
            tree = {"x": np.zeros(max(1, min(nb, 1 << 12)) // 4,
                                  np.float32)}
            tiers.lstore(name, tree)
            if policy.choose_spill(name, nb) == "staging":
                tiers.rstore(name, peer)
            else:
                tiers.rflush_sharded(name, policy.choose_shards(nb, name))
        return {"ops": len(emu.trace), "total_ns": emu.total_ns(),
                **{f"{op}_ns": v for op, v in emu.per_op_ns().items()}}
    finally:
        shutil.rmtree(tmp, ignore_errors=True)


def main():
    bench = Bench("placement")
    sizes = workload_sizes()
    bench.set_config(n_objects=N_OBJECTS, size_range=list(SIZE_RANGE),
                     seed=SEED, presets=sorted(PRESETS))

    strict_wins = 0
    all_ok = True
    for preset in sorted(PRESETS):
        policy = PlacementPolicy(preset)
        t = score(policy, sizes)
        best_fixed = min(t["staging"], t["pool"])
        ratio = t["policy"] / best_fixed
        ok = (t["policy"] <= t["staging"] + 1e-9
              and t["policy"] <= t["pool"] + 1e-9)
        strict = t["policy"] < best_fixed * (1 - 1e-9)
        strict_wins += strict
        all_ok = all_ok and ok
        for strat in ("staging", "pool", "policy"):
            bench.record("placement_total_ms", t[strat] / 1e6,
                         f"preset={preset} strategy={strat}",
                         key=f"placement_total_ms.{preset}.{strat}",
                         fmt=".3f")
        bench.record("placement_policy_over_best_fixed", ratio,
                     f"preset={preset} (<= 1.0 required)",
                     key=f"placement_policy_over_best_fixed.{preset}",
                     fmt=".4f")
        bench.record("placement_decisions", f"{t['n_staging']}s/{t['n_pool']}p",
                     f"preset={preset} staging/pool split",
                     key=f"placement_decisions.{preset}")

    bench.record("placement_policy_never_worse", bool(all_ok),
                 "policy <= both fixed strategies on every preset")
    bench.record("placement_strict_win_presets", int(strict_wins),
                 "presets where the policy beats BOTH fixed strategies")

    # -- the injectable emulator end to end ---------------------------------
    for preset in sorted(PRESETS):
        r = emulated_run(preset, sizes)
        bench.record("placement_emulated_trace_ops", r["ops"],
                     f"preset={preset} priced TierManager ops",
                     key=f"placement_emulated_trace_ops.{preset}")
        bench.record("placement_emulated_trace_ms", r["total_ns"] / 1e6,
                     f"preset={preset} priced-trace occupancy",
                     key=f"placement_emulated_trace_ms.{preset}", fmt=".3f")

    bench.write()
    return all_ok and strict_wins >= 1


if __name__ == "__main__":
    # hard gate when run standalone (mirrors bench_serve): the cost-driven
    # policy must never lose to a fixed strategy and must strictly win on
    # at least one topology preset
    if not main():
        raise SystemExit("FAIL: placement policy lost to a fixed strategy "
                         "or never strictly won")
