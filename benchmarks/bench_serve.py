"""Serving benchmark: static vs continuous batching on mixed-length traces.

The workload is the one the serving refactor exists for: requests with a
common prompt length but a WIDE mix of decode budgets.  Static batching
decodes every batch until its longest sequence finishes (short requests
ride along as dead lanes); continuous batching frees a slot the tick its
sequence completes and refills it from the queue.

Measured (CPU smoke config, compile excluded via warmup):

* ``serve_tokens_per_s,<mode>`` — end-to-end emitted-token throughput;
* ``serve_decode_ticks,<mode>`` — decode steps taken (the batch-occupancy
  win, hardware-independent);
* ``serve_speedup`` — continuous over static tokens/s (acceptance floor
  1.3x on the default config);
* ``serve_commit_overhead_frac`` — wall-time cost of durable session
  commits (FliT path, sharded-async schedule, every 4 ticks) relative to
  stateless continuous serving.  I/O-bound on CPU smoke configs; for
  RELATIVE comparison only.

Emits through the shared harness: ``BENCH_serve.json`` feeds the CI
regression gate (scripts/bench_gate.py) like every other bench.
"""
from __future__ import annotations

import os
import shutil
import tempfile
import time

try:
    from benchmarks.harness import Bench
except ImportError:                      # standalone: python benchmarks/...
    from harness import Bench

from repro.serve.engine import build_serve_engine
from repro.serve.trace import synthetic_trace, trace_t_max

N_REQUESTS = 20
N_SLOTS = 4
PROMPT_LEN = 32
NEW_TOKENS = (4, 8, 16, 32, 64)
COMMIT_EVERY = 4


def _trace(vocab: int):
    return synthetic_trace(N_REQUESTS, prompt_lens=(PROMPT_LEN,),
                           new_tokens=NEW_TOKENS, vocab_size=vocab)


def _timed_run(engine, trace, mode: str):
    t0 = time.perf_counter()
    res = (engine.run(trace) if mode == "continuous"
           else engine.run_static(trace))
    return res, time.perf_counter() - t0


def main():
    bench = Bench("serve")
    t_max = trace_t_max(_trace(2))
    results = {}

    # -- static baseline ----------------------------------------------------
    eng, cfg = build_serve_engine("olmo-1b", smoke=True, n_slots=N_SLOTS,
                                  t_max=t_max)
    trace = _trace(cfg.vocab_size)
    eng.run_static(trace[:N_SLOTS])          # compile prefill+decode shapes
    res_s, dt_s = _timed_run(eng, trace, "static")
    results["static"] = {"tokens_per_s": res_s.emitted_tokens / dt_s,
                         "decode_ticks": res_s.decode_ticks,
                         "wall_s": dt_s,
                         "emitted_tokens": res_s.emitted_tokens}

    # -- continuous ---------------------------------------------------------
    eng2, _ = build_serve_engine("olmo-1b", smoke=True, n_slots=N_SLOTS,
                                 t_max=t_max)
    eng2.warmup([PROMPT_LEN])
    res_c, dt_c = _timed_run(eng2, trace, "continuous")
    results["continuous"] = {"tokens_per_s": res_c.emitted_tokens / dt_c,
                             "decode_ticks": res_c.decode_ticks,
                             "wall_s": dt_c,
                             "emitted_tokens": res_c.emitted_tokens}
    assert res_c.outputs == res_s.outputs, \
        "continuous and static batching must emit identical tokens"

    # -- continuous + durable session commits -------------------------------
    tmp = tempfile.mkdtemp(prefix="bench_serve_")
    try:
        eng3, _ = build_serve_engine(
            "olmo-1b", smoke=True, n_slots=N_SLOTS, t_max=t_max,
            pool_path=os.path.join(tmp, "pool"),
            commit_every=COMMIT_EVERY, commit_mode="sharded-async")
        eng3.warmup([PROMPT_LEN])
        res_d, dt_d = _timed_run(eng3, trace, "continuous")
        eng3.close()
        results["durable"] = {"tokens_per_s": res_d.emitted_tokens / dt_d,
                              "wall_s": dt_d, "commits": res_d.commits,
                              "commit_every": COMMIT_EVERY,
                              "commit_mode": "sharded-async"}
    finally:
        shutil.rmtree(tmp, ignore_errors=True)

    speedup = (results["continuous"]["tokens_per_s"]
               / results["static"]["tokens_per_s"])
    overhead = dt_d / dt_c - 1.0
    bench.set_config(arch="olmo-1b smoke", n_requests=N_REQUESTS,
                     n_slots=N_SLOTS, prompt_len=PROMPT_LEN,
                     new_tokens=list(NEW_TOKENS),
                     commit_every=COMMIT_EVERY,
                     commit_mode="sharded-async")

    for mode in ("static", "continuous"):
        r = results[mode]
        bench.record("serve_tokens_per_s", r["tokens_per_s"],
                     f"mode={mode}", key=f"serve_tokens_per_s.{mode}",
                     fmt=".0f")
        bench.record("serve_decode_ticks", r["decode_ticks"],
                     f"mode={mode}", key=f"serve_decode_ticks.{mode}")
    bench.record("serve_emitted_tokens", res_c.emitted_tokens,
                 "identical across modes (asserted)")
    bench.record("serve_speedup", speedup,
                 f"continuous/static tokens per s (mixed "
                 f"{min(NEW_TOKENS)}-{max(NEW_TOKENS)} tok budgets)",
                 fmt=".2f")
    bench.record("serve_speedup_ge_1.3", bool(speedup >= 1.3),
                 "acceptance floor")
    bench.record("serve_commit_overhead_frac", overhead,
                 f"durable sessions (commit every {COMMIT_EVERY} ticks) "
                 f"vs stateless", fmt=".3f")
    bench.record("serve_durable_commits", res_d.commits,
                 "commits in the durable run")
    bench.write()
    return speedup


if __name__ == "__main__":
    # the acceptance floor is a hard gate when run standalone (CI smoke
    # job); benchmarks/run.py calls main() without it so one noisy box
    # doesn't abort the whole benchmark sweep
    if main() < 1.3:
        raise SystemExit("FAIL: continuous batching below the 1.3x "
                         "tokens/s acceptance floor")
