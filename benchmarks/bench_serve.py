"""Serving benchmark: static vs continuous batching on mixed-length traces.

The workload is the one the serving refactor exists for: requests with a
common prompt length but a WIDE mix of decode budgets.  Static batching
decodes every batch until its longest sequence finishes (short requests
ride along as dead lanes); continuous batching frees a slot the tick its
sequence completes and refills it from the queue.

Measured (CPU smoke config, compile excluded via warmup):

* ``serve_tokens_per_s,<mode>`` — end-to-end emitted-token throughput;
* ``serve_decode_ticks,<mode>`` — decode steps taken (the batch-occupancy
  win, hardware-independent);
* ``serve_speedup`` — continuous over static tokens/s (acceptance floor
  1.3x on the default config);
* ``serve_commit_overhead_frac`` — wall-time cost of durable session
  commits (FliT path, sharded-async schedule, every 4 ticks) relative to
  stateless continuous serving.  I/O-bound on CPU smoke configs; for
  RELATIVE comparison only.

Fleet section (2 engines over ONE pool, shared-prefix workload —
serve.fleet + the paged KV layout):

* ``serve_fleet_speedup`` — 2-engine aggregate tokens per lockstep
  decode round over 1 engine's tokens per tick, same slot count each.
  Per-ROUND, not wall-clock: the engines of an in-process fleet tick
  sequentially, so rounds are the hardware-independent unit (exactly as
  ``serve_decode_ticks`` gates occupancy, not seconds), and the 1.6x
  floor stays meaningful on a single-core CI box where two processes
  could never beat one on wall time;
* ``serve_fleet_migration_token_loss`` — tokens lost across a forced
  live migration vs the uninterrupted single-engine run.  Gated EXACTLY
  zero, with bit-identical outputs;
* ``serve_fleet_prefix_hits`` / ``serve_fleet_prefix_prefills`` — a
  THIRD engine opened on the fleet's pool serves the identical trace
  from the content-addressed ``kvblk/`` objects alone: every admission
  a hit, zero prefills.  Both gated exact.

Emits through the shared harness: ``BENCH_serve.json`` feeds the CI
regression gate (scripts/bench_gate.py) like every other bench.
"""
from __future__ import annotations

import os
import shutil
import tempfile
import time

try:
    from benchmarks.harness import Bench
except ImportError:                      # standalone: python benchmarks/...
    from harness import Bench

from repro.serve.engine import build_serve_engine
from repro.serve.trace import synthetic_trace, trace_t_max

N_REQUESTS = 20
N_SLOTS = 4
PROMPT_LEN = 32
NEW_TOKENS = (4, 8, 16, 32, 64)
COMMIT_EVERY = 4

# fleet cells: 24 requests drawing from 2 distinct prompts (the
# shared-prefix serving workload), 2 slots per engine
N_FLEET_REQS = 24
FLEET_SLOTS = 2
FLEET_NEW_TOKENS = (4, 8, 16, 24)
FLEET_PROMPTS = 2


def _trace(vocab: int):
    return synthetic_trace(N_REQUESTS, prompt_lens=(PROMPT_LEN,),
                           new_tokens=NEW_TOKENS, vocab_size=vocab)


def _fleet_trace(vocab: int):
    return synthetic_trace(N_FLEET_REQS, prompt_lens=(PROMPT_LEN,),
                           new_tokens=FLEET_NEW_TOKENS, vocab_size=vocab,
                           n_prompts=FLEET_PROMPTS)


def _timed_run(engine, trace, mode: str):
    t0 = time.perf_counter()
    res = (engine.run(trace) if mode == "continuous"
           else engine.run_static(trace))
    return res, time.perf_counter() - t0


def _fleet_section(bundle, params, vocab: int, t_max: int) -> dict:
    """The three fleet cells (docstring up top).  One weight pytree is
    shared across every engine; compile time is excluded via warmup."""
    from repro.serve.engine import build_serve_engine
    from repro.serve.fleet import FleetController
    trace = _fleet_trace(vocab)
    tmp = tempfile.mkdtemp(prefix="bench_fleet_")
    try:
        # -- reference: ONE engine, same slot count, same workload -----------
        single, _ = build_serve_engine(
            "olmo-1b", smoke=True, n_slots=FLEET_SLOTS, t_max=t_max,
            pool_path=os.path.join(tmp, "single"),
            commit_every=COMMIT_EVERY, prefix_reuse=True,
            bundle=bundle, params=params)
        single.warmup([PROMPT_LEN])
        res1, dt1 = _timed_run(single, trace, "continuous")
        single.close()

        # -- 2-engine aggregate throughput -----------------------------------
        fl = FleetController(
            "olmo-1b", pool_path=os.path.join(tmp, "fleet"), n_engines=2,
            n_slots=FLEET_SLOTS, t_max=t_max, commit_every=COMMIT_EVERY,
            prefix_reuse=True, bundle=bundle, params=params)
        for e in fl.engines.values():
            e.warmup([PROMPT_LEN])
        t0 = time.perf_counter()
        resf = fl.run(trace)        # rebalancing on: tail imbalance is
        #                             exactly what live migration fixes
        dtf = time.perf_counter() - t0
        assert resf.outputs == res1.outputs, \
            "fleet placement must not change any token stream"
        rounds = max(r.decode_ticks for r in resf.per_engine.values())
        speedup = ((resf.emitted_tokens / rounds)
                   / (res1.emitted_tokens / res1.decode_ticks))

        # -- cross-engine prefix reuse: a 3rd engine on the SAME pool --------
        eng3, _ = build_serve_engine(
            "olmo-1b", smoke=True, n_slots=FLEET_SLOTS, t_max=t_max,
            pool_path=os.path.join(tmp, "fleet"), engine_id=3,
            commit_every=COMMIT_EVERY, prefix_reuse=True,
            bundle=bundle, params=params)
        eng3.warmup([PROMPT_LEN])
        res3 = eng3.run(trace)
        eng3.close()
        fl.close()
        assert res3.outputs == res1.outputs

        # -- forced live migration: zero token loss --------------------------
        flm = FleetController(
            "olmo-1b", pool_path=os.path.join(tmp, "mig"), n_engines=2,
            n_slots=FLEET_SLOTS, t_max=t_max, commit_every=COMMIT_EVERY,
            prefix_reuse=True, bundle=bundle, params=params)
        flm.submit(trace)
        moved = None
        while not flm.done:
            flm.tick(rebalance=False)
            if moved is None and flm.engines[1]._tick >= 3:
                src = flm.engines[1]
                moved = next((r for r in src.sched.admission_order
                              if r in src.sched.running), None)
                if moved is not None:
                    flm.migrate(moved, 1, 2)
        resm = flm.finish()
        flm.close()

        return {
            "speedup": speedup,
            "single_ticks": res1.decode_ticks,
            "fleet_rounds": rounds,
            "tokens_per_s": resf.emitted_tokens / dtf,
            "single_tokens_per_s": res1.emitted_tokens / dt1,
            "prefix_hits": res3.prefix_hits,
            "prefix_prefills": res3.prefills,
            "migrations": resm.migrations,
            "migration_token_loss":
                res1.emitted_tokens - resm.emitted_tokens,
            "migration_outputs_match": resm.outputs == res1.outputs,
        }
    finally:
        shutil.rmtree(tmp, ignore_errors=True)


def main():
    bench = Bench("serve")
    t_max = trace_t_max(_trace(2))
    results = {}

    # -- static baseline ----------------------------------------------------
    eng, cfg = build_serve_engine("olmo-1b", smoke=True, n_slots=N_SLOTS,
                                  t_max=t_max)
    trace = _trace(cfg.vocab_size)
    eng.run_static(trace[:N_SLOTS])          # compile prefill+decode shapes
    res_s, dt_s = _timed_run(eng, trace, "static")
    results["static"] = {"tokens_per_s": res_s.emitted_tokens / dt_s,
                         "decode_ticks": res_s.decode_ticks,
                         "wall_s": dt_s,
                         "emitted_tokens": res_s.emitted_tokens}

    # -- continuous ---------------------------------------------------------
    eng2, _ = build_serve_engine("olmo-1b", smoke=True, n_slots=N_SLOTS,
                                 t_max=t_max)
    eng2.warmup([PROMPT_LEN])
    res_c, dt_c = _timed_run(eng2, trace, "continuous")
    results["continuous"] = {"tokens_per_s": res_c.emitted_tokens / dt_c,
                             "decode_ticks": res_c.decode_ticks,
                             "wall_s": dt_c,
                             "emitted_tokens": res_c.emitted_tokens}
    assert res_c.outputs == res_s.outputs, \
        "continuous and static batching must emit identical tokens"

    # -- continuous + durable session commits -------------------------------
    tmp = tempfile.mkdtemp(prefix="bench_serve_")
    try:
        eng3, _ = build_serve_engine(
            "olmo-1b", smoke=True, n_slots=N_SLOTS, t_max=t_max,
            pool_path=os.path.join(tmp, "pool"),
            commit_every=COMMIT_EVERY, commit_mode="sharded-async")
        eng3.warmup([PROMPT_LEN])
        res_d, dt_d = _timed_run(eng3, trace, "continuous")
        eng3.close()
        results["durable"] = {"tokens_per_s": res_d.emitted_tokens / dt_d,
                              "wall_s": dt_d, "commits": res_d.commits,
                              "commit_every": COMMIT_EVERY,
                              "commit_mode": "sharded-async"}
    finally:
        shutil.rmtree(tmp, ignore_errors=True)

    fleet = _fleet_section(eng.bundle, eng.params, cfg.vocab_size, t_max)

    speedup = (results["continuous"]["tokens_per_s"]
               / results["static"]["tokens_per_s"])
    overhead = dt_d / dt_c - 1.0
    bench.set_config(arch="olmo-1b smoke", n_requests=N_REQUESTS,
                     n_slots=N_SLOTS, prompt_len=PROMPT_LEN,
                     new_tokens=list(NEW_TOKENS),
                     commit_every=COMMIT_EVERY,
                     commit_mode="sharded-async")

    for mode in ("static", "continuous"):
        r = results[mode]
        bench.record("serve_tokens_per_s", r["tokens_per_s"],
                     f"mode={mode}", key=f"serve_tokens_per_s.{mode}",
                     fmt=".0f")
        bench.record("serve_decode_ticks", r["decode_ticks"],
                     f"mode={mode}", key=f"serve_decode_ticks.{mode}")
    bench.record("serve_emitted_tokens", res_c.emitted_tokens,
                 "identical across modes (asserted)")
    bench.record("serve_speedup", speedup,
                 f"continuous/static tokens per s (mixed "
                 f"{min(NEW_TOKENS)}-{max(NEW_TOKENS)} tok budgets)",
                 fmt=".2f")
    bench.record("serve_speedup_ge_1.3", bool(speedup >= 1.3),
                 "acceptance floor")
    bench.record("serve_commit_overhead_frac", overhead,
                 f"durable sessions (commit every {COMMIT_EVERY} ticks) "
                 f"vs stateless", fmt=".3f")
    bench.record("serve_durable_commits", res_d.commits,
                 "commits in the durable run")
    bench.record("serve_fleet_speedup", fleet["speedup"],
                 f"2-engine aggregate tokens/round over 1 engine "
                 f"({fleet['single_ticks']} ticks -> "
                 f"{fleet['fleet_rounds']} rounds, {FLEET_SLOTS} slots "
                 f"each, shared-prefix {FLEET_PROMPTS}-prompt trace)",
                 fmt=".2f")
    bench.record("serve_fleet_speedup_ge_1.6",
                 bool(fleet["speedup"] >= 1.6), "acceptance floor")
    bench.record("serve_fleet_tokens_per_s", fleet["tokens_per_s"],
                 "in-process fleet wall-clock (engines tick "
                 "sequentially; not gated)", fmt=".0f")
    bench.record("serve_fleet_prefix_hits", fleet["prefix_hits"],
                 "3rd engine on the fleet pool: admissions served from "
                 "content-addressed blocks")
    bench.record("serve_fleet_prefix_prefills", fleet["prefix_prefills"],
                 "3rd engine on the fleet pool: prefills (0 = every "
                 "prompt restored)")
    bench.record("serve_fleet_migration_token_loss",
                 fleet["migration_token_loss"],
                 f"emitted-token delta vs uninterrupted run across "
                 f"{fleet['migrations']} live migration(s)")
    bench.record("serve_fleet_migration_outputs_match",
                 fleet["migration_outputs_match"],
                 "bit-identical token streams across the handoff")
    bench.write()
    return speedup


if __name__ == "__main__":
    # the acceptance floor is a hard gate when run standalone (CI smoke
    # job); benchmarks/run.py calls main() without it so one noisy box
    # doesn't abort the whole benchmark sweep
    if main() < 1.3:
        raise SystemExit("FAIL: continuous batching below the 1.3x "
                         "tokens/s acceptance floor")
