"""Paper Table 1 — CXL transactions observable per CXL0 primitive.

Emits the encoded mapping and the availability summary (which primitives
current hardware cannot issue — the paper's '???' rows), plus per-§4
system-configuration primitive sets.
"""
from __future__ import annotations

try:
    from benchmarks.harness import Bench
except ImportError:                      # standalone: python benchmarks/...
    from harness import Bench

from repro.core.latency import (
    CONFIG_PRIMITIVES, TABLE1, available_primitives,
)


def main():
    bench = Bench("table1")
    for r in TABLE1:
        bench.record(f"table1_{r.node}_{r.primitive}",
                     1 if r.available else 0,
                     f"op={r.operation} | HM={'/'.join(r.to_hm)} | "
                     f"HDM={'/'.join(r.to_hdm)}")
    for node in ("host", "device"):
        av = available_primitives(node)
        bench.record(f"table1_available_{node}", len(av), "/".join(av))
    for config, nodes in CONFIG_PRIMITIVES.items():
        for node, prims in nodes.items():
            bench.record(f"config_{config}_{node}", len(prims),
                         "/".join(prims))
    bench.write()


if __name__ == "__main__":
    main()
