"""Shared bench harness: every benchmark emits through one funnel.

Each bench module builds a ``Bench("name")``, ``record``s metrics (which
keeps the historical ``name,value,note`` CSV stdout format), and
``write``s ``BENCH_<name>.json`` — so the SAME numbers a human reads in
the CI log drive ``scripts/bench_gate.py``'s regression comparison
against the committed baselines in ``benchmarks/baselines/``.

JSON schema (consumed by the gate)::

    {"bench": "<name>",
     "metrics": {"<key>": {"value": <number|bool|str>, "note": "..."}},
     "config": {...}}

Metric keys must be unique per bench; ``record`` takes an explicit
``key=`` for families that print the same CSV name with distinguishing
notes (e.g. ``ckpt_commit_blocking_s`` per mode x shard count), and
suffixes ``#2``, ``#3``... on accidental collisions rather than silently
overwriting.  ``BENCH_DIR`` overrides the output directory (default cwd).
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict, Optional

import numpy as np


def _jsonable(value: Any) -> Any:
    if isinstance(value, (np.bool_,)):
        return bool(value)
    if isinstance(value, np.integer):
        return int(value)
    if isinstance(value, np.floating):
        return float(value)
    return value


class Bench:
    def __init__(self, name: str):
        self.name = name
        self.metrics: Dict[str, Dict[str, Any]] = {}
        self.config: Dict[str, Any] = {}

    def record(self, metric: str, value: Any, note: str = "", *,
               key: Optional[str] = None, fmt: Optional[str] = None) -> Any:
        """Print the historical ``metric,value,note`` CSV row and store the
        RAW value under ``key`` (default: the metric name) for the JSON
        dump.  ``fmt`` only affects the printed form."""
        display = format(value, fmt) if fmt else value
        print(f"{metric},{display},{note}", flush=True)
        k = key or metric
        if k in self.metrics:
            i = 2
            while f"{k}#{i}" in self.metrics:
                i += 1
            k = f"{k}#{i}"
        self.metrics[k] = {"value": _jsonable(value), "note": note}
        return value

    def set_config(self, **kw):
        self.config.update({k: _jsonable(v) for k, v in kw.items()})

    def write(self, out_dir: Optional[str] = None) -> str:
        out_dir = out_dir or os.environ.get("BENCH_DIR", ".")
        os.makedirs(out_dir, exist_ok=True)
        path = os.path.join(out_dir, f"BENCH_{self.name}.json")
        doc = {"bench": self.name, "metrics": self.metrics,
               "config": self.config}
        with open(path, "w") as f:
            json.dump(doc, f, indent=2)
        print(f"bench_json,{path},written", flush=True)
        return path
