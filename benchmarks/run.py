"""Benchmark runner: one module per paper table/figure + system benches.

Prints ``name,value,derived`` CSV rows (assignment format) AND — through
the shared harness (benchmarks/harness.py) — writes one
``BENCH_<name>.json`` per bench, the machine-readable results that
``scripts/bench_gate.py`` compares against the committed baselines in
``benchmarks/baselines/`` (the CI perf-regression gate).

Roofline / dry-run reporting lives in launch/dryrun.py +
roofline/report.py because it needs the 512-device environment.
"""
from __future__ import annotations

import os
import sys
import time

# the mesh bench sections need 8 host devices; the flag only works if it
# is in the environment before ANY bench module first imports jax, i.e.
# right here (an externally pinned force is left untouched)
if "xla_force_host_platform_device_count" not in os.environ.get(
        "XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=8"
                               ).strip()

# run.py is invoked as a script (``python benchmarks/run.py``): put the
# repo root on the path so ``benchmarks`` resolves as a package and the
# bench modules share one harness import
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))


def main() -> None:
    from benchmarks import (bench_autoscale, bench_latency, bench_table1,
                            bench_flit, bench_checkpoint, bench_cluster,
                            bench_fuzz, bench_model_fuzz, bench_placement,
                            bench_serve)
    modules = [
        ("fig5 latency model", bench_latency),
        ("table1 transaction mapping", bench_table1),
        ("flit transformation (violations + cost)", bench_flit),
        ("durable checkpoint protocol", bench_checkpoint),
        ("multi-writer cluster protocol", bench_cluster),
        ("continuous-batching serving (static vs slots)", bench_serve),
        ("vectorized semantics fuzzing", bench_model_fuzz),
        ("adversarial crash fuzzing (end-to-end DSM)", bench_fuzz),
        ("cost-driven placement over emulated topologies", bench_placement),
        ("elastic autoscaling vs fixed fleets", bench_autoscale),
    ]
    for title, mod in modules:
        print(f"# --- {title} ---", flush=True)
        t0 = time.perf_counter()
        mod.main()
        print(f"# ({title}: {time.perf_counter()-t0:.1f}s)", flush=True)


if __name__ == "__main__":
    main()
