"""Benchmark runner: one module per paper table/figure + system benches.

Prints ``name,value,derived`` CSV rows (assignment format).  Roofline /
dry-run reporting lives in launch/dryrun.py + roofline/report.py because it
needs the 512-device environment.
"""
from __future__ import annotations

import sys
import time


def main() -> None:
    from benchmarks import (bench_latency, bench_table1, bench_flit,
                            bench_checkpoint, bench_cluster,
                            bench_model_fuzz, bench_serve)
    modules = [
        ("fig5 latency model", bench_latency),
        ("table1 transaction mapping", bench_table1),
        ("flit transformation (violations + cost)", bench_flit),
        ("durable checkpoint protocol", bench_checkpoint),
        ("multi-writer cluster protocol", bench_cluster),
        ("continuous-batching serving (static vs slots)", bench_serve),
        ("vectorized semantics fuzzing", bench_model_fuzz),
    ]
    for title, mod in modules:
        print(f"# --- {title} ---", flush=True)
        t0 = time.perf_counter()
        mod.main()
        print(f"# ({title}: {time.perf_counter()-t0:.1f}s)", flush=True)


if __name__ == "__main__":
    main()
