"""A durably linearizable KV store over simulated disaggregated memory.

Two machines share a KV map whose keys live on both owners.  Writers on
machine 0, a reader on machine 1.  We crash machine 0 mid-run; with the
FliT-for-CXL0 transformation every completed put survives, and the checker
certifies the full history.  The same run under the raw (untransformed)
object is shown losing an acknowledged put.

Run:  PYTHONPATH=src python examples/durable_kv.py
"""
from repro.core.durable import durably_linearizable
from repro.core.flit import POLICIES
from repro.core.harness import kv_workload
from repro.core.sim import Simulator


def run(policy: str, seed: int):
    wl = kv_workload(n_machines=2, n_keys=3)
    sim = Simulator(wl.cfg, seed=seed, p_tau=0.4, p_crash=0.10,
                    max_crashes=1, crashable=list(wl.crashable))
    view = POLICIES[policy](counter_of=wl.counter_of)
    wl.spawn(sim, view)
    history = sim.run()
    ok = durably_linearizable(history, wl.spec)
    return history, ok


def main():
    print("searching for a seed where the raw object loses a committed put…")
    for seed in range(400):
        history, ok = run("raw", seed)
        if not ok:
            print(f"\n--- raw object, seed {seed}: DURABILITY VIOLATION ---")
            for e in history:
                print("   ", e)
            print("\nsame seed, FliT-for-CXL0 (Alg. 2):")
            history2, ok2 = run("flit_cxl0", seed)
            for e in history2:
                print("   ", e)
            print(f"\nraw durable: {ok}   flit_cxl0 durable: {ok2}")
            assert ok2
            return
    print("no violation found (increase seeds)")


if __name__ == "__main__":
    main()
