"""A durably linearizable KV store over the real DSM runtime — the §6
story told with the unified API (`open_cxl0`), two ways:

* **commit regions** — puts are LStored and batches commit atomically:
  `with ctx.commit(step) as txn: txn.store(...)`.  A crash ANYWHERE
  inside a region emits no completeOp, so recovery lands exactly on the
  previous commit: the torn batch is invisible, never a mixed state.

* **the §6 transformation** — `ctx.transform(KVSpec(n))` wraps the same
  linearizable KV object with FliT-for-CXL0 at op granularity (per-op
  LStore + RFlush + completeOp): EVERY acknowledged put survives, even
  the ones a batch discipline would have lost in its torn tail — the
  paper's durable-linearizability upgrade as a reusable API.

Run:  PYTHONPATH=src python examples/durable_kv.py
"""
import shutil
import tempfile

import numpy as np

from repro.core.objects import KVSpec
from repro.dsm import CrashError, open_cxl0

N_KEYS = 4


def kv_templates():
    return {f"kv/k{k}": np.zeros((), np.int64) for k in range(N_KEYS)}


def run_commit_regions(path):
    """Batch-committed KV writer that dies mid-batch."""
    ctx = open_cxl0(path, schedule="sync")
    acked = {}
    try:
        for step in range(3):
            with ctx.commit(step) as txn:
                for k in range(N_KEYS):
                    v = 10 * step + k
                    txn.store(f"kv/k{k}", np.int64(v))
                    acked[f"kv/k{k}"] = v
                    if step == 2 and k == 1:
                        raise CrashError("power loss mid-batch")
    except CrashError:
        pass
    ctx.crash()                        # volatile tiers vanish

    # a fresh incarnation: ONE recovery path, newest completed commit
    ctx2 = open_cxl0(path)
    objs, step, source = ctx2.recover(kv_templates())
    recovered = {n: int(v) for n, v in objs.items()}
    return acked, recovered, step, source


def run_transformed(path):
    """The same workload through the §6-transformed KV object."""
    ctx = open_cxl0(path, schedule="sync")
    kv = ctx.transform(KVSpec(N_KEYS), name="kv6")
    acked = {}
    try:
        for step in range(3):
            for k in range(N_KEYS):
                v = 10 * step + k
                kv.op("put", k, v)     # LStore + RFlush + completeOp
                acked[k] = v
                if step == 2 and k == 1:
                    raise CrashError("power loss mid-batch")
    except CrashError:
        pass
    ctx.crash()

    kv2 = open_cxl0(path).transform(KVSpec(N_KEYS), name="kv6")
    recovered = {k: kv2.state[k] for k in range(N_KEYS)}
    return acked, recovered, kv2.ops_done


def main():
    tmp = tempfile.mkdtemp(prefix="durable_kv_")
    try:
        print("--- commit regions: batches are atomic, torn tail invisible")
        acked, rec, step, source = run_commit_regions(f"{tmp}/regions")
        print(f"    acked before the crash: {acked}")
        print(f"    recovered (commit step {step}, source={source}): {rec}")
        lost = {n for n, v in acked.items() if rec[n] != v}
        print(f"    the torn batch rolled back atomically: lost={sorted(lost)}")
        assert all(int(v) == 10 + int(n[-1]) for n, v in rec.items())

        print("--- §6 transform: every acknowledged put survives")
        acked6, rec6, ops = run_transformed(f"{tmp}/transform")
        print(f"    acked before the crash: {acked6}")
        print(f"    recovered after {ops + 1} completed ops: {rec6}")
        assert rec6 == acked6, (rec6, acked6)
        print("    durably linearizable: recovered state == acknowledged "
              "history")
    finally:
        shutil.rmtree(tmp, ignore_errors=True)


if __name__ == "__main__":
    main()
