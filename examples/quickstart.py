"""Quickstart: the CXL0 model in 4 acts.

  1. litmus tests — what can(not) happen under partial crashes;
  2. Proposition 1 — primitive simulations, checked exhaustively;
  3. FliT-for-CXL0 — the §6 transformation making a concurrent counter
     durably linearizable, with the untransformed object as the foil;
  4. the same transformation as a one-line API over the REAL runtime:
     ``open_cxl0(...).transform(CounterSpec())`` — completed increments
     survive a worker crash, recovered through the one recovery path.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
from repro.core.litmus import LITMUS_TESTS, run_litmus
from repro.core.semantics import Variant
from repro.core.props import PROP1_ITEMS, check_prop1_item
from repro.core.state import make_config
from repro.core.harness import WORKLOADS, run_once


def act1_litmus():
    print("=" * 70)
    print("Act 1 — litmus tests (paper Fig. 3 + §3.5 + §6)")
    print("=" * 70)
    for t in LITMUS_TESTS:
        verdicts = " ".join(
            f"{v.value}:{'✓' if run_litmus(t, v) else '✗'}"
            for v in Variant)
        print(f"  {t.name:42s} {verdicts}")


def act2_prop1():
    print("=" * 70)
    print("Act 2 — Proposition 1, verified exhaustively (2 machines × 2 locs)")
    print("=" * 70)
    cfg = make_config(2, 1)
    for item in PROP1_ITEMS[:4]:        # first four (fast subset)
        res = check_prop1_item(item, cfg)
        print(f"  Prop 1.{item.idx} {item.name:45s} "
              f"checked={res.checked}  ok={res.ok}")
    print("  (items 5-8 run in tests/test_props.py)")


def act3_flit():
    print("=" * 70)
    print("Act 3 — FliT transformation: durable vs not, under crashes")
    print("=" * 70)
    mk = WORKLOADS["counter"]
    for policy in ("raw", "original_flit", "flit_cxl0", "mstore_all"):
        viol = sum(not run_once(mk, policy, seed, p_crash=0.08,
                                max_crashes=2).durable
                   for seed in range(100))
        verdict = "NOT durable" if viol else "durably linearizable"
        print(f"  {policy:15s} violations={viol:3d}/100  -> {verdict}")


def act4_context():
    print("=" * 70)
    print("Act 4 — ctx.transform: the §6 counter on the real runtime")
    print("=" * 70)
    import shutil
    import tempfile
    from repro.core.objects import CounterSpec
    from repro.dsm import open_cxl0

    tmp = tempfile.mkdtemp(prefix="quickstart_act4_")
    try:
        ctx = open_cxl0(f"{tmp}/pool", schedule="sync")
        counter = ctx.transform(CounterSpec(), name="counter")
        got = [counter.op("inc") for _ in range(5)]
        print(f"  5 increments returned {got}; live value "
              f"{counter.state}")
        ctx.crash()         # the worker's volatile tiers vanish
        revived = open_cxl0(f"{tmp}/pool").transform(CounterSpec(),
                                                     name="counter")
        print(f"  after crash + recovery ({revived.recovered_from[1]}): "
              f"value {revived.state}, {revived.ops_done + 1} completed ops")
        assert revived.state == 5
        print("  every completed op survived — durably linearizable")
    finally:
        shutil.rmtree(tmp, ignore_errors=True)


if __name__ == "__main__":
    act1_litmus()
    act2_prop1()
    act3_flit()
    act4_context()
