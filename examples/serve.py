"""Serve a small model with batched requests: prefill + decode loop.

Demonstrates the serving path every decode-shape dry-run cell lowers:
batched prompts -> prefill fills the KV/SSM caches -> token-by-token
decode with greedy sampling.  ``--arch`` selects any of the ten assigned
architectures (reduced smoke config of the same family).

Run:  PYTHONPATH=src python examples/serve.py --arch jamba-1.5-large-398b
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_smoke_config
from repro.models.registry import build


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b", choices=ARCH_IDS)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=32)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    bundle = build(cfg, dec_pos_len=args.prompt_len + args.new_tokens)
    key = jax.random.PRNGKey(0)
    params = bundle.init_params(key)

    B, S = args.batch, args.prompt_len
    t_max = S + args.new_tokens
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}
    if cfg.is_encdec:
        batch["enc_embeds"] = jax.random.normal(
            key, (B, cfg.encdec.enc_seq, cfg.d_model), jnp.bfloat16)
    caches = bundle.init_caches(key, B, t_max)

    prefill = jax.jit(lambda p, b, c: bundle.prefill(p, b, c))
    decode = jax.jit(lambda p, t, s: bundle.decode(p, t, s))

    t0 = time.perf_counter()
    logits, state = prefill(params, batch, caches)
    jax.block_until_ready(logits)
    t_prefill = time.perf_counter() - t0

    tokens = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    outputs = [tokens]
    t0 = time.perf_counter()
    for _ in range(args.new_tokens - 1):
        logits, state = decode(params, tokens, state)
        tokens = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        outputs.append(tokens)
    jax.block_until_ready(tokens)
    t_decode = time.perf_counter() - t0

    out = jnp.concatenate(outputs, axis=1)
    print(f"arch={args.arch} ({bundle.n_params()/1e6:.1f}M smoke config)")
    print(f"prefill: {B}x{S} tokens in {t_prefill*1e3:.0f} ms "
          f"(incl. compile)")
    print(f"decode:  {args.new_tokens-1} steps x {B} seqs in "
          f"{t_decode*1e3:.0f} ms "
          f"({(args.new_tokens-1)*B/t_decode:.0f} tok/s)")
    print("sampled token ids (first sequence):",
          [int(t) for t in out[0][:16]])


if __name__ == "__main__":
    main()
