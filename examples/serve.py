"""Serve a small model with continuous batching (and, optionally, durable
sessions that survive a kill).

Thin front-end over the ``repro.serve`` subsystem: a slot-based scheduler
admits requests into fixed decode lanes, a slot-masked decode step
advances every lane at its own position, finished sequences free their
lane immediately, and — when ``--pool`` is given — session state commits
through the FliT durable path so re-running the same command resumes
every committed session bit-identically.

Run:  PYTHONPATH=src python examples/serve.py --arch olmo-1b
      PYTHONPATH=src python examples/serve.py --pool /tmp/serve_pool
"""
import argparse
import time

from repro.configs import get_smoke_config
from repro.serve.engine import build_serve_engine, servable_archs
from repro.serve.trace import synthetic_trace, trace_t_max


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b", choices=servable_archs())
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--pool", default=None,
                    help="enable durable sessions in this DSM pool dir")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    trace = synthetic_trace(args.requests, prompt_lens=(args.prompt_len,),
                            vocab_size=cfg.vocab_size)
    engine, _ = build_serve_engine(
        args.arch, smoke=True, n_slots=args.slots,
        t_max=trace_t_max(trace), pool_path=args.pool,
        commit_every=4 if args.pool else 0)

    if args.pool and engine.resume() is not None:
        print(f"resumed {len(engine.results)} finished sessions "
              f"from the pool")
    t0 = time.perf_counter()
    res = engine.run(trace)
    dt = time.perf_counter() - t0
    engine.close()

    print(f"arch={args.arch} ({engine.bundle.n_params() / 1e6:.1f}M smoke "
          f"config), {args.slots} slots")
    print(f"{len(res.outputs)} requests, {res.emitted_tokens} tokens in "
          f"{dt:.2f}s ({res.emitted_tokens / dt:.0f} tok/s incl. compile); "
          f"{res.decode_ticks} decode ticks vs "
          f"{sum(r.max_new_tokens for r in trace)} static-worst-case")
    rid = trace[0].rid
    print(f"sampled token ids ({rid}):", res.outputs[rid][:16])


if __name__ == "__main__":
    main()
