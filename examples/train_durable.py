"""End-to-end driver: train a language model with durable FliT-protocol
checkpointing and injected worker crashes.

Defaults train a ~10M-param OLMo-style model for 60 steps on CPU in a few
minutes; ``--full`` selects a ~100M-param config for a few hundred steps
(the assignment's end-to-end scale — expect ~1-2 h on one CPU core; on a
real TPU slice the same driver runs via launch/train.py).

Two crashes are injected; the loop recovers from the pool (or a peer's
staged copy with --replicate) and the final state is verified IDENTICAL to
an uninterrupted run — the durable-linearizability guarantee, end to end.

Run:  PYTHONPATH=src python examples/train_durable.py [--full] [--replicate]
"""
import argparse
import shutil
import tempfile

import jax
import numpy as np

from repro.configs import get_config
from repro.data.pipeline import DataPipeline, SyntheticLMSource
from repro.dsm.pool import DSMPool
from repro.dsm.tiers import TierManager
from repro.models.registry import build
from repro.train.loop import run_durable_loop
from repro.train.state import init_train_state
from repro.train.step import make_train_step


def small_cfg(full: bool):
    base = get_config("olmo-1b")
    if full:    # ~100M params
        return base.with_(n_layers=8, d_model=768, n_heads=12, n_kv_heads=12,
                          d_ff=3072, vocab_size=32000, attn_chunk=256,
                          remat="none")
    return base.with_(n_layers=4, d_model=256, n_heads=8, n_kv_heads=8,
                      d_ff=1024, vocab_size=8192, attn_chunk=128,
                      remat="none")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--replicate", action="store_true",
                    help="RStore-stage state into a peer (faster recovery)")
    ap.add_argument("--steps", type=int, default=None)
    args = ap.parse_args()

    cfg = small_cfg(args.full)
    n_steps = args.steps or (300 if args.full else 60)
    batch, seq = (8, 512) if args.full else (4, 256)

    bundle = build(cfg)
    print(f"model: {bundle.n_params()/1e6:.1f}M params, "
          f"{cfg.n_layers}L d{cfg.d_model}")
    key = jax.random.PRNGKey(0)
    state = init_train_state(bundle.init_params(key), key)
    step = jax.jit(make_train_step(bundle, peak_lr=3e-4,
                                   total_steps=n_steps))
    tmp = tempfile.mkdtemp(prefix="train_durable_")
    try:
        pool = DSMPool(f"{tmp}/pool")
        peer = TierManager(DSMPool(f"{tmp}/peer"), worker_id=1)
        crash_at = {n_steps // 3: "before_commit",
                    2 * n_steps // 3: "after_commit"}
        pipe = DataPipeline(SyntheticLMSource(cfg.vocab_size), batch, seq)
        print(f"training {n_steps} steps, commit every 10, crashes at "
              f"{sorted(crash_at)} …")
        r = run_durable_loop(step, state, pipe, pool, n_steps=n_steps,
                             commit_every=10, commit_mode="async",
                             peer_tiers=peer if args.replicate else None,
                             replicate=args.replicate, crash_at=crash_at)
        print(f"crashes: {r.crashes}  recoveries: {r.recoveries}")
        print(f"loss: first={r.losses[0]:.3f} last={r.losses[-1]:.3f}")
        mean_compute = np.mean([t.compute_s for t in r.timings
                                if t.compute_s])
        mean_commit = np.mean([t.commit_s for t in r.timings if t.commit_s])
        print(f"step time: {mean_compute*1e3:.0f} ms;   "
              f"commit (blocking part): {mean_commit*1e3:.0f} ms")

        # verify against an uninterrupted run
        pool2 = DSMPool(f"{tmp}/pool2")
        pipe2 = DataPipeline(SyntheticLMSource(cfg.vocab_size), batch, seq)
        r2 = run_durable_loop(step, state, pipe2, pool2, n_steps=n_steps,
                              commit_every=10)
        same = all(
            np.array_equal(np.asarray(a, np.float32),
                           np.asarray(b, np.float32))
            for a, b in zip(jax.tree_util.tree_leaves(r.state.params),
                            jax.tree_util.tree_leaves(r2.state.params)))
        print(f"crash-recovered final params identical to clean run: {same}")
        assert same
    finally:
        shutil.rmtree(tmp, ignore_errors=True)


if __name__ == "__main__":
    main()
