"""End-to-end driver: train a language model durably with the unified
CXL0 programming-model API — `open_cxl0` + commit regions, nothing else.

The whole durable loop is the paper's model verbatim: every step LStores
the new state into the context (`ctx.put`), every tenth step opens a
*commit region* whose clean exit emits exactly one completeOp, and a
mid-run crash (`ctx.crash()` — the worker's volatile tiers vanish) is
healed by the ONE recovery path `ctx.recover`, which replays from the
newest completed commit.  The final state is verified IDENTICAL to an
uninterrupted run — durable linearizability, end to end.

Defaults train a ~10M-param OLMo-style model for 60 steps on CPU in a few
minutes; ``--full`` selects a ~100M-param config (expect ~1-2 h on one CPU
core; on a real TPU slice the same loop runs via launch/train.py, which
wires the identical ``CXL0Config``).

Run:  PYTHONPATH=src python examples/train_durable.py [--full] [--replicate]
"""
import argparse
import shutil
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data.pipeline import DataPipeline, SyntheticLMSource
from repro.dsm import open_cxl0
from repro.train.state import init_train_state
from repro.train.step import make_train_step


def small_cfg(full: bool):
    base = get_config("olmo-1b")
    if full:    # ~100M params
        return base.with_(n_layers=8, d_model=768, n_heads=12, n_kv_heads=12,
                          d_ff=3072, vocab_size=32000, attn_chunk=256,
                          remat="none")
    return base.with_(n_layers=4, d_model=256, n_heads=8, n_kv_heads=8,
                      d_ff=1024, vocab_size=8192, attn_chunk=128,
                      remat="none")


def state_objects(state, pipe_state):
    """The committed object set: params + optimizer moments + counters +
    data-pipeline position (so replay resumes exactly where the recovered
    step left off — no data loss or dupes)."""
    return {
        "params": state.params,
        "opt_mu": state.opt.mu,
        "opt_nu": state.opt.nu,
        "counters": {"opt_step": state.opt.step, "rng": state.rng},
        "pipeline": {"seed": np.int64(pipe_state.seed),
                     "step": np.int64(pipe_state.step)},
    }


def objects_to_state(objs, template, pipe):
    from repro.data.pipeline import PipelineState
    st = template.__class__(
        params=objs["params"],
        opt=template.opt._replace(
            mu=objs["opt_mu"], nu=objs["opt_nu"],
            step=jnp.asarray(objs["counters"]["opt_step"])),
        rng=jnp.asarray(objs["counters"]["rng"]))
    pipe.state = PipelineState(seed=int(objs["pipeline"]["seed"]),
                               step=int(objs["pipeline"]["step"]))
    return st


def train(pool_path, step_fn, init_state, pipe, *, n_steps,
          commit_every=10, crash_steps=(), peer=None):
    """The 5-line durable loop (plus crash injection): open a context,
    put + commit-region on a cadence, recover after any crash."""
    ctx = open_cxl0(pool_path, schedule="async", peers=(peer,) if peer
                    else (), replicate_to=peer)
    templates = state_objects(init_state, pipe.state)
    ctx.put(templates, step=-1)
    with ctx.commit(-1):                       # durable floor: step -1
        pass
    ctx.drain()

    state, losses, recoveries = init_state, [], []
    crash_steps = set(crash_steps)
    i = 0
    while i < n_steps:
        if i in crash_steps:
            crash_steps.discard(i)
            ctx.crash()                        # f_i: volatile tiers vanish
            objs, rec_step, source = ctx.recover(templates)
            state = objects_to_state(objs, state, pipe)
            recoveries.append(source)
            i = rec_step + 1
            continue
        batch = {k: jnp.asarray(v) for k, v in pipe.next_global().items()}
        state, metrics = step_fn(state, batch)
        losses.append(float(metrics["loss"]))
        ctx.put(state_objects(state, pipe.state), step=i)
        if (i + 1) % commit_every == 0:
            with ctx.commit(i):                # ONE completeOp on exit
                pass
        i += 1
    ctx.drain()                                # tail flush (planned GPF)
    ctx.close()
    return state, losses, recoveries


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--replicate", action="store_true",
                    help="RStore-stage state into a peer (faster recovery)")
    ap.add_argument("--steps", type=int, default=None)
    args = ap.parse_args()

    cfg = small_cfg(args.full)
    n_steps = args.steps or (300 if args.full else 60)
    batch, seq = (8, 512) if args.full else (4, 256)

    from repro.models.registry import build
    bundle = build(cfg)
    print(f"model: {bundle.n_params()/1e6:.1f}M params, "
          f"{cfg.n_layers}L d{cfg.d_model}")
    key = jax.random.PRNGKey(0)
    state = init_train_state(bundle.init_params(key), key)
    step = jax.jit(make_train_step(bundle, peak_lr=3e-4,
                                   total_steps=n_steps))
    tmp = tempfile.mkdtemp(prefix="train_durable_")
    try:
        # a peer context IS a valid RStore target / recovery source
        peer = (open_cxl0(f"{tmp}/peer", 1) if args.replicate else None)
        crashes = sorted({max(n_steps // 3, 1), max(2 * n_steps // 3, 2)})
        pipe = DataPipeline(SyntheticLMSource(cfg.vocab_size), batch, seq)
        print(f"training {n_steps} steps, commit every 10, crashes at "
              f"{crashes} …")
        final, losses, recoveries = train(
            f"{tmp}/pool", step, state, pipe, n_steps=n_steps,
            crash_steps=crashes, peer=peer)
        print(f"crashes: {len(recoveries)}  recoveries: {recoveries}")
        print(f"loss: first={losses[0]:.3f} last={losses[-1]:.3f}")

        # verify against an uninterrupted run over a fresh pool
        pipe2 = DataPipeline(SyntheticLMSource(cfg.vocab_size), batch, seq)
        clean, _, _ = train(f"{tmp}/pool2", step, state, pipe2,
                            n_steps=n_steps)
        same = all(
            np.array_equal(np.asarray(a, np.float32),
                           np.asarray(b, np.float32))
            for a, b in zip(jax.tree_util.tree_leaves(final.params),
                            jax.tree_util.tree_leaves(clean.params)))
        print(f"crash-recovered final params identical to clean run: {same}")
        assert same
    finally:
        shutil.rmtree(tmp, ignore_errors=True)


if __name__ == "__main__":
    main()
