"""Perf-regression gate: compare BENCH_*.json against committed baselines.

The ROADMAP's so-far-invisible performance trajectory, made enforceable:
every bench emits ``BENCH_<name>.json`` through the shared harness
(benchmarks/harness.py), and this comparator fails CI when a gated
metric regresses beyond its per-metric tolerance vs the baselines
committed in ``benchmarks/baselines/``.

Baseline schema — one ``<name>.json`` per bench::

    {"bench": "<name>",
     "metrics": {
        "<key>": {"value": <v>, "direction": "higher"|"lower"|"exact",
                  "rel_tol": 0.1, "abs_tol": 0.0}}}

Per metric, with ``tol = max(abs_tol, rel_tol * |value|)``:

* ``higher`` — higher is better; FAIL iff actual < value - tol
  (improvements never fail; use for throughputs, speedups, win counts);
* ``lower``  — lower is better; FAIL iff actual > value + tol
  (latencies, overheads);
* ``exact``  — FAIL iff |actual - value| > tol (deterministic model
  outputs: calibrated latencies, mapping counts, violation counts;
  non-numeric values compare by equality).

Only metrics present in a baseline are gated — noisy wall-clock metrics
simply stay out of the baseline files.  A gated metric MISSING from the
bench output fails (deleted coverage is a regression too), as does a
missing BENCH json for a baseline'd bench.

Exit status: 0 = all gates pass, 1 = any regression (the CI contract;
tests/test_bench_gate.py locks the nonzero-on-regression behaviour).

Usage::

    python scripts/bench_gate.py [--baselines benchmarks/baselines]
                                 [--bench-dir .] [--only name ...]
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import sys
from typing import List, Optional, Tuple


def _tol(spec: dict) -> float:
    value = spec["value"]
    rel = float(spec.get("rel_tol", 0.0))
    abs_ = float(spec.get("abs_tol", 0.0))
    try:
        return max(abs_, rel * abs(float(value)))
    except (TypeError, ValueError):
        return 0.0


def check_metric(key: str, spec: dict, actual) -> Optional[str]:
    """None if the gate passes, else a human-readable failure reason."""
    baseline = spec["value"]
    direction = spec.get("direction", "exact")
    if isinstance(baseline, bool) or not isinstance(baseline, (int, float)):
        mismatch = (bool(actual) != baseline if isinstance(baseline, bool)
                    else actual != baseline)
        return (f"expected {baseline!r}, got {actual!r}" if mismatch
                else None)
    try:
        a = float(actual)
    except (TypeError, ValueError):
        return f"non-numeric actual {actual!r} vs baseline {baseline}"
    tol = _tol(spec)
    if direction == "higher":
        if a < baseline - tol:
            return f"{a:g} < {baseline:g} - tol {tol:g} (higher is better)"
    elif direction == "lower":
        if a > baseline + tol:
            return f"{a:g} > {baseline:g} + tol {tol:g} (lower is better)"
    elif direction == "exact":
        if abs(a - baseline) > tol:
            return f"{a:g} != {baseline:g} (tol {tol:g})"
    else:
        return f"unknown direction {direction!r} in baseline"
    return None


def gate_bench(baseline_path: str, bench_dir: str) -> Tuple[str, List[str]]:
    """Gate one bench; returns (bench name, failure messages)."""
    with open(baseline_path) as f:
        baseline = json.load(f)
    name = baseline["bench"]
    bench_path = os.path.join(bench_dir, f"BENCH_{name}.json")
    if not os.path.exists(bench_path):
        return name, [f"missing {bench_path} (bench did not run?)"]
    with open(bench_path) as f:
        result = json.load(f)
    metrics = result.get("metrics", {})
    failures = []
    for key, spec in baseline.get("metrics", {}).items():
        if key not in metrics:
            failures.append(f"{key}: gated metric missing from bench output")
            continue
        reason = check_metric(key, spec, metrics[key].get("value"))
        if reason is not None:
            failures.append(f"{key}: {reason}")
    return name, failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--baselines", default="benchmarks/baselines",
                    help="directory of committed baseline jsons")
    ap.add_argument("--bench-dir", default=".",
                    help="directory holding the BENCH_*.json outputs")
    ap.add_argument("--only", nargs="*", default=None,
                    help="gate only these bench names")
    args = ap.parse_args(argv)

    paths = sorted(glob.glob(os.path.join(args.baselines, "*.json")))
    if not paths:
        print(f"bench-gate: no baselines under {args.baselines}",
              file=sys.stderr)
        return 1
    by_name = {}
    for p in paths:
        with open(p) as f:
            by_name[json.load(f)["bench"]] = p
    if args.only is not None:
        unknown = sorted(set(args.only) - set(by_name))
        if unknown:
            # a typo'd/renamed bench must not silently gate NOTHING
            print(f"bench-gate: no baseline for {unknown} "
                  f"(have: {sorted(by_name)})", file=sys.stderr)
            return 1
        by_name = {n: by_name[n] for n in args.only}
    total_gated = n_fail = 0
    for name, p in sorted(by_name.items()):
        name, failures = gate_bench(p, args.bench_dir)
        with open(p) as f:
            n_metrics = len(json.load(f).get("metrics", {}))
        total_gated += n_metrics
        if failures:
            n_fail += 1
            print(f"FAIL {name} ({len(failures)}/{n_metrics} gates):")
            for msg in failures:
                print(f"  - {msg}")
        else:
            print(f"PASS {name} ({n_metrics} gates)")
    print(f"bench-gate: {total_gated} gated metrics, "
          f"{n_fail} failing bench(es)")
    return 1 if n_fail else 0


if __name__ == "__main__":
    sys.exit(main())
