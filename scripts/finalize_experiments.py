"""Regenerate the §Dry-run and §Roofline sections of EXPERIMENTS.md from
results/*.json (run after the dry-run sweep)."""
import re
import subprocess
import sys

out = subprocess.run(
    [sys.executable, "-m", "repro.roofline.report", "--results", "results",
     "--csv", "results/roofline.csv"],
    capture_output=True, text=True, env={"PYTHONPATH": "src",
                                         "PATH": "/usr/bin:/bin"})
report = out.stdout
if out.returncode != 0:
    print(out.stderr)
    sys.exit(1)

dryrun = report.split("## §Roofline (single-pod baselines)")[0]
dryrun = dryrun.replace("## §Dry-run\n", "").strip()
roofline = ("## §Roofline (single-pod baselines)"
            + report.split("## §Roofline (single-pod baselines)")[1]).strip()

doc = open("EXPERIMENTS.md").read()

dry_section = f"""## §Dry-run

Meshes: single-pod 16×16 (256 chips) and multi-pod 2×16×16 (512 chips,
"pod" as a pure-DP axis). Every non-skipped cell `.lower().compile()`s with
the full sharding config; bytes/device from ``memory_analysis()`` (XLA:CPU
pipeline — an upper bound for the TPU target: the CPU SPMD pass keeps
full-size f32 gradient all-reduces that the TPU pass turns into
reduce-scatters; see §Perf/M-series). Collective columns are the
1-period probe's partitioned-HLO byte counts. ``long_500k`` is skipped for
the eight full-attention archs per the assignment and runs for
jamba + rwkv6. Multi-pod cells for the heaviest arch (jamba) and the
re-baselined small-arch train/prefill cells are compile+memory only
(probe-less): the §Roofline table is single-pod per the assignment.

{dryrun}
"""

roof_section = f"""## §Roofline

Terms per the assignment: compute = HLO_FLOPs/(chips·197 TF), memory =
HLO_bytes/(chips·819 GB/s), collective = coll_bytes/(chips·4·50 GB/s),
from the two unrolled probes extrapolated to full depth (probe2−probe1 per
period). ``t_mem(model)`` is the fused-TPU traffic cross-check
(``roofline/memory.py``); the bottleneck verdict and roofline fraction use
min(HLO, model) for the memory term. ``useful-FLOPs`` =
MODEL_FLOPS(6·N_active·D) / total HLO FLOPs — values < 1 expose remat
recompute and MoE capacity overcompute; decode values are tiny because a
single-token step is bandwidth-dominated by design.

{roofline}
"""

pat = re.compile(r"## §Dry-run.*?(?=## §Perf)", re.S)
doc = pat.sub(dry_section + "\n" + roof_section + "\n\n", doc)
open("EXPERIMENTS.md", "w").write(doc)
print("EXPERIMENTS.md updated")
