"""Architecture registry: ``get_config("<arch-id>")`` / ``--arch <id>``."""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.configs.base import (  # noqa: F401
    ModelConfig, MoEConfig, MLAConfig, MambaConfig, RWKVConfig, EncDecConfig,
    ShapeConfig, SHAPES, SHAPES_BY_NAME, shape_applicable,
)

_ARCH_MODULES: Dict[str, str] = {
    "chameleon-34b": "repro.configs.chameleon_34b",
    "olmo-1b": "repro.configs.olmo_1b",
    "yi-34b": "repro.configs.yi_34b",
    "internlm2-1.8b": "repro.configs.internlm2_1_8b",
    "phi3-medium-14b": "repro.configs.phi3_medium_14b",
    "olmoe-1b-7b": "repro.configs.olmoe_1b_7b",
    "deepseek-v2-236b": "repro.configs.deepseek_v2_236b",
    "jamba-1.5-large-398b": "repro.configs.jamba_1_5_large_398b",
    "whisper-small": "repro.configs.whisper_small",
    "rwkv6-7b": "repro.configs.rwkv6_7b",
}

ARCH_IDS: List[str] = list(_ARCH_MODULES)

# Published parameter totals (for sanity tests; +-4% tolerance).
PUBLISHED_PARAMS = {
    "chameleon-34b": 34.4e9,
    "olmo-1b": 1.18e9,
    "yi-34b": 34.4e9,
    "internlm2-1.8b": 1.89e9,
    # "14B" is the marketing name; the exact config (untied emb) is 14.66B
    "phi3-medium-14b": 14.66e9,
    "olmoe-1b-7b": 6.9e9,
    "deepseek-v2-236b": 236e9,
    "jamba-1.5-large-398b": 398e9,
    "whisper-small": 0.244e9,
    "rwkv6-7b": 7.6e9,
}


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {ARCH_IDS}")
    return importlib.import_module(_ARCH_MODULES[arch_id]).CONFIG


def get_smoke_config(arch_id: str) -> ModelConfig:
    if arch_id not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {ARCH_IDS}")
    return importlib.import_module(_ARCH_MODULES[arch_id]).SMOKE_CONFIG
