"""Configuration system.

Every architecture is described by a single frozen ``ModelConfig`` dataclass.
Configs are pure data — building params / steps happens in ``repro.models``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Sub-configs
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 0              # routed experts
    top_k: int = 0
    n_shared: int = 0               # always-on shared experts (deepseek-v2)
    d_ff_expert: int = 0            # per-expert hidden
    moe_every: int = 1              # a layer l is MoE iff l % moe_every == moe_offset
    moe_offset: int = 0
    first_dense: int = 0            # first `first_dense` layers use dense MLP
    capacity_factor: float = 1.25
    router_dtype: str = "float32"


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    """Multi-head latent attention (deepseek-v2)."""
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class MambaConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0                # 0 -> d_model // 16


@dataclasses.dataclass(frozen=True)
class RWKVConfig:
    head_dim: int = 64
    decay_lora: int = 64            # lora rank for data-dependent decay (w)
    mix_lora: int = 32              # token-shift mixing lora rank


@dataclasses.dataclass(frozen=True)
class EncDecConfig:
    n_enc_layers: int = 0
    enc_seq: int = 1500             # whisper: 30s audio -> 1500 frames
    enc_pos_embed: bool = True


# ---------------------------------------------------------------------------
# Main config
# ---------------------------------------------------------------------------

FAMILIES = ("dense", "moe", "hybrid", "ssm", "encdec", "vlm", "audio")


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    arch_id: str
    family: str                     # one of FAMILIES
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0               # 0 -> d_model // n_heads

    norm: str = "rmsnorm"           # rmsnorm | layernorm | nonparametric_ln
    act: str = "silu"               # silu (swiglu) | gelu (plain mlp)
    glu: bool = True                # gated (SwiGLU) vs plain 2-matrix MLP
    tied_embeddings: bool = False
    rope_theta: float = 10000.0
    use_rope: bool = True
    qk_norm: bool = False           # chameleon uses qk-norm
    max_seq_len: int = 524288

    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    mamba: Optional[MambaConfig] = None
    rwkv: Optional[RWKVConfig] = None
    encdec: Optional[EncDecConfig] = None

    # hybrid (jamba): layer l is attention iff l % attn_every == attn_offset,
    # else mamba. attn_every=1 -> pure attention.
    attn_every: int = 1
    attn_offset: int = 0

    # dtypes / numerics
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    moment_dtype: str = "float32"   # bf16 for >=100B archs (fits 16GB/chip)
    logit_dtype: str = "float32"

    # execution
    cache_dtype: str = ""           # "" -> compute_dtype; "float8_e4m3fn"
    #                                 halves decode cache traffic (H2)
    remat: str = "full"             # full | dots | none
    attn_chunk: int = 1024          # KV-chunk for online-softmax attention
    ssm_chunk: int = 256            # time-chunk for mamba / rwkv6
    scan_layers: bool = True        # lax.scan over (stacked) layer blocks
    use_pallas: bool = False        # Pallas kernels (TPU); jnp ref path on CPU

    # long-context capability: sub-quadratic archs can run long_500k decode
    subquadratic: bool = False

    def __post_init__(self):
        assert self.family in FAMILIES, self.family
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // max(self.n_heads, 1))
        if self.n_heads:
            assert self.n_heads % max(self.n_kv_heads, 1) == 0, (
                f"{self.arch_id}: n_heads={self.n_heads} kv={self.n_kv_heads}")

    # -- derived ------------------------------------------------------------
    @property
    def is_moe(self) -> bool:
        return self.moe is not None and self.moe.n_experts > 0

    @property
    def is_hybrid(self) -> bool:
        return self.attn_every > 1

    @property
    def is_encdec(self) -> bool:
        return self.encdec is not None and self.encdec.n_enc_layers > 0

    @property
    def is_attention_free(self) -> bool:
        return self.rwkv is not None

    def layer_kind(self, l: int) -> str:
        """'attn' | 'mamba' | 'rwkv' sequence-mixer kind of layer l."""
        if self.rwkv is not None:
            return "rwkv"
        if self.attn_every > 1:
            return "attn" if l % self.attn_every == self.attn_offset else "mamba"
        return "attn"

    def mlp_kind(self, l: int) -> str:
        """'dense' | 'moe' channel-mixer kind of layer l."""
        if not self.is_moe or l < self.moe.first_dense:
            return "dense"
        return "moe" if (l % self.moe.moe_every == self.moe.moe_offset) else "dense"

    # -- analytic parameter count (used by tests vs published sizes) --------
    def param_count(self) -> int:
        d, v = self.d_model, self.vocab_size
        total = v * d * (1 if self.tied_embeddings else 2)
        if self.is_encdec and self.encdec.enc_pos_embed:
            total += self.encdec.enc_seq * d + self.max_position_embeddings_dec() * d

        def attn_params() -> int:
            if self.mla is not None:
                m = self.mla
                h = self.n_heads
                p = d * m.q_lora_rank
                p += m.q_lora_rank * h * (m.qk_nope_head_dim + m.qk_rope_head_dim)
                p += d * (m.kv_lora_rank + m.qk_rope_head_dim)
                p += m.kv_lora_rank * h * (m.qk_nope_head_dim + m.v_head_dim)
                p += h * m.v_head_dim * d
                return p
            hd = self.head_dim
            return (d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd
                    + self.n_heads * hd * d)

        def mlp_params(ff: int) -> int:
            return d * ff * (3 if self.glu else 2)

        def moe_params() -> int:
            m = self.moe
            p = (m.n_experts + m.n_shared) * mlp_params(m.d_ff_expert)
            p += d * m.n_experts  # router
            return p

        def mamba_params() -> int:
            mc = self.mamba
            inner = mc.expand * d
            dt_rank = mc.dt_rank or d // 16
            p = d * 2 * inner                     # in_proj (x and z)
            p += mc.d_conv * inner                # depthwise conv
            p += inner * (dt_rank + 2 * mc.d_state)   # x_proj
            p += dt_rank * inner                  # dt_proj
            p += inner * mc.d_state + inner       # A_log, D
            p += inner * d                        # out_proj
            return p

        def rwkv_params() -> int:
            rc = self.rwkv
            # time-mix: r,k,v,g,o square proj + decay lora + first (u)
            p = 5 * d * d
            p += d * rc.decay_lora + rc.decay_lora * d   # decay lora
            p += 5 * (d * rc.mix_lora + rc.mix_lora * d)  # token-shift loras
            p += d                                         # bonus u
            # channel-mix
            p += d * self.d_ff + self.d_ff * d + d * d
            return p

        n_dec = self.n_layers
        for l in range(n_dec):
            kind = self.layer_kind(l)
            if kind == "attn":
                total += attn_params()
            elif kind == "mamba":
                total += mamba_params()
            elif kind == "rwkv":
                total += rwkv_params()
                continue  # rwkv_params includes channel mix
            total += moe_params() if self.mlp_kind(l) == "moe" else mlp_params(self.d_ff)
        if self.is_encdec:
            # encoder self-attn+mlp, decoder already counted; add cross-attn
            total += self.encdec.n_enc_layers * (attn_params() + mlp_params(self.d_ff))
            total += n_dec * attn_params()  # cross attention in decoder
        return total

    def active_param_count(self) -> int:
        """Params used per token (MoE: top_k + shared experts only)."""
        if not self.is_moe:
            return self.param_count()
        m = self.moe
        d = self.d_model
        per_expert = d * m.d_ff_expert * (3 if self.glu else 2)
        inactive = 0
        for l in range(self.n_layers):
            if self.mlp_kind(l) == "moe":
                inactive += (m.n_experts - m.top_k) * per_expert
        return self.param_count() - inactive

    def max_position_embeddings_dec(self) -> int:
        return 448 if self.is_encdec else 0

    def with_(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# Input shapes (assigned to every LM arch)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: Tuple[ShapeConfig, ...] = (
    ShapeConfig("train_4k", 4096, 256, "train"),
    ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    ShapeConfig("decode_32k", 32768, 128, "decode"),
    ShapeConfig("long_500k", 524288, 1, "decode"),
)

SHAPES_BY_NAME = {s.name: s for s in SHAPES}


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Whether a (arch, shape) cell is runnable; else reason for the skip."""
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, ("long_500k needs sub-quadratic attention; "
                       f"{cfg.arch_id} is full-attention (see DESIGN.md)")
    return True, ""
