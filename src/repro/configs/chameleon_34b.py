"""chameleon-34b [vlm] — early-fusion, VQ image tokens [arXiv:2405.09818].

48L d_model=8192 64H (GQA kv=8) d_ff=22016 vocab=65536.
The modality frontend (VQ-VAE image tokenizer) is a STUB: image tokens are
part of the 65536 vocab and ``input_specs()`` provides precomputed token ids.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="chameleon-34b",
    family="vlm",
    n_layers=48,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=22016,
    vocab_size=65536,
    norm="rmsnorm",
    qk_norm=True,            # chameleon stabilizes early fusion with qk-norm
    rope_theta=10000.0,
)

SMOKE_CONFIG = CONFIG.with_(
    n_layers=2, d_model=64, n_heads=8, n_kv_heads=2, d_ff=176,
    vocab_size=256, attn_chunk=32, ssm_chunk=16)
