"""deepseek-v2-236b [moe] — MLA kv_lora=512, 2 shared + 160 routed top-6
[arXiv:2405.04434].

60L d_model=5120 128H (GQA kv=128) d_ff=1536(per-expert) vocab=102400.
First layer uses a dense MLP (d_ff 12288), remaining 59 layers are MoE.
~236B total / ~21B active. Moments kept in bf16 to fit 16GB/chip (DESIGN §5).
"""
from repro.configs.base import ModelConfig, MoEConfig, MLAConfig

CONFIG = ModelConfig(
    arch_id="deepseek-v2-236b",
    family="moe",
    n_layers=60,
    d_model=5120,
    n_heads=128,
    n_kv_heads=128,
    d_ff=12288,               # dense layers' hidden (first layer)
    vocab_size=102400,
    moe=MoEConfig(n_experts=160, top_k=6, n_shared=2, d_ff_expert=1536,
                  moe_every=1, first_dense=1),
    mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512,
                  qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128),
    moment_dtype="bfloat16",
)

SMOKE_CONFIG = CONFIG.with_(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
    vocab_size=256, attn_chunk=32, ssm_chunk=16, moment_dtype="float32",
    moe=MoEConfig(n_experts=8, top_k=2, n_shared=1, d_ff_expert=32,
                  moe_every=1, first_dense=1, capacity_factor=2.0),
    mla=MLAConfig(q_lora_rank=32, kv_lora_rank=16,
                  qk_nope_head_dim=16, qk_rope_head_dim=8, v_head_dim=16))
