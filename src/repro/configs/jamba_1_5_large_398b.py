"""jamba-1.5-large-398b [hybrid] — Mamba+attn 1:7 interleave, MoE 16e top-2
[arXiv:2403.19887].

72L d_model=8192 64H (GQA kv=8) d_ff=24576 vocab=65536.
Layer l is attention iff l % 8 == 4 (1 attention : 7 mamba), MoE on every
other layer (odd layers). Sub-quadratic overall -> runs long_500k.
Moments kept in bf16 to fit 16GB/chip (DESIGN §5).
"""
from repro.configs.base import ModelConfig, MoEConfig, MambaConfig

CONFIG = ModelConfig(
    arch_id="jamba-1.5-large-398b",
    family="hybrid",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=24576,
    vocab_size=65536,
    use_rope=False,          # jamba has no positional encoding in attn layers
    attn_every=8,
    attn_offset=4,
    moe=MoEConfig(n_experts=16, top_k=2, d_ff_expert=24576,
                  moe_every=2, moe_offset=1),
    mamba=MambaConfig(d_state=16, d_conv=4, expand=2),
    moment_dtype="bfloat16",
    subquadratic=True,       # 9 attn layers; serving memory dominated by mamba
)

SMOKE_CONFIG = CONFIG.with_(
    n_layers=8, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
    vocab_size=256, attn_chunk=32, ssm_chunk=16, moment_dtype="float32",
    moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=128, moe_every=2,
                  moe_offset=1, capacity_factor=2.0),
    mamba=MambaConfig(d_state=4, d_conv=4, expand=2, dt_rank=8))
