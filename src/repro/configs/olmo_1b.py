"""olmo-1b [dense] — non-parametric LN [arXiv:2402.00838].

16L d_model=2048 16H (GQA kv=16) d_ff=8192 vocab=50304.
OLMo uses non-parametric LayerNorm (no scale/bias), SwiGLU, RoPE, and a
tied, padded embedding (50304 = 50257 padded to a multiple of 128).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="olmo-1b",
    family="dense",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=8192,
    vocab_size=50304,
    norm="nonparametric_ln",
    tied_embeddings=True,
)

SMOKE_CONFIG = CONFIG.with_(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=256,
    vocab_size=256, attn_chunk=32, ssm_chunk=16)
