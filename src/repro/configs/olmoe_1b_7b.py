"""olmoe-1b-7b [moe] — 64 experts top-8 [arXiv:2409.02060].

16L d_model=2048 16H (GQA kv=16) d_ff=1024(per-expert) vocab=50304,
MoE 64e top-8 on every layer. ~6.9B total / ~1.3B active.
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    arch_id="olmoe-1b-7b",
    family="moe",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1024,
    vocab_size=50304,
    qk_norm=True,  # OLMoE uses QK-norm
    moe=MoEConfig(n_experts=64, top_k=8, d_ff_expert=1024, moe_every=1),
)

SMOKE_CONFIG = CONFIG.with_(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=64,
    vocab_size=256, attn_chunk=32, ssm_chunk=16,
    moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=64, moe_every=1,
                  capacity_factor=2.0))
