"""phi3-medium-14b [dense] — RoPE SwiGLU GQA [arXiv:2404.14219].

40L d_model=5120 40H (GQA kv=10) d_ff=17920 vocab=100352.
40 heads / 10 kv heads are not divisible by the 16-way model axis; the
sharding layer relies on GSPMD uneven (padded) sharding for head dims
(see DESIGN.md §5).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="phi3-medium-14b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=40,
    n_kv_heads=10,
    d_ff=17920,
    vocab_size=100352,
)

SMOKE_CONFIG = CONFIG.with_(
    n_layers=2, d_model=80, n_heads=10, n_kv_heads=2, d_ff=224, head_dim=8,
    vocab_size=256, attn_chunk=32, ssm_chunk=16)
