"""rwkv6-7b [ssm] — Finch, data-dependent decay [arXiv:2404.05892].

32L d_model=4096 (attention-free) d_ff=14336 vocab=65536.
64 heads x head_dim 64; O(1) recurrent state -> the long_500k representative.
"""
from repro.configs.base import ModelConfig, RWKVConfig

CONFIG = ModelConfig(
    arch_id="rwkv6-7b",
    family="ssm",
    n_layers=32,
    d_model=4096,
    n_heads=64,              # wkv heads = d_model / head_dim
    n_kv_heads=64,
    d_ff=14336,
    vocab_size=65536,
    norm="layernorm",
    use_rope=False,
    rwkv=RWKVConfig(head_dim=64, decay_lora=64, mix_lora=32),
    subquadratic=True,
)

SMOKE_CONFIG = CONFIG.with_(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
    vocab_size=256, attn_chunk=32, ssm_chunk=16,
    rwkv=RWKVConfig(head_dim=16, decay_lora=8, mix_lora=4))
