"""whisper-small [audio] — enc-dec, conv frontend (stub) [arXiv:2212.04356].

12L(enc)+12L(dec) d_model=768 12H d_ff=3072 vocab=51865.
The conv1d+mel frontend is a STUB: ``input_specs()`` provides precomputed
frame embeddings (batch, 1500, 768). Whisper uses learned positional
embeddings (no RoPE) and pre-LN LayerNorm, plain GELU MLP.
decode shapes are lowered mechanically with a 32k decoder self-attn cache;
the model's trained decoder context is 448 tokens (DESIGN §6).
"""
from repro.configs.base import ModelConfig, EncDecConfig

CONFIG = ModelConfig(
    arch_id="whisper-small",
    family="audio",
    n_layers=12,                 # decoder layers
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    d_ff=3072,
    vocab_size=51865,
    norm="layernorm",
    act="gelu",
    glu=False,
    use_rope=False,
    tied_embeddings=True,
    encdec=EncDecConfig(n_enc_layers=12, enc_seq=1500),
)

SMOKE_CONFIG = CONFIG.with_(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=256,
    vocab_size=256, attn_chunk=32, ssm_chunk=16,
    encdec=EncDecConfig(n_enc_layers=2, enc_seq=48))
