"""yi-34b [dense] — llama-arch GQA [arXiv:2403.04652].

60L d_model=7168 56H (GQA kv=8) d_ff=20480 vocab=64000.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="yi-34b",
    family="dense",
    n_layers=60,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=20480,
    vocab_size=64000,
    rope_theta=5000000.0,
)

SMOKE_CONFIG = CONFIG.with_(
    n_layers=2, d_model=56, n_heads=7, n_kv_heads=1, d_ff=160, head_dim=8,
    vocab_size=250, attn_chunk=32, ssm_chunk=16)
