"""CXL0 — the paper's contribution: a programming model for disaggregated
memory over CXL, as an executable artifact.

Layers:
* ``state`` / ``semantics``   — the operational semantics (LTS) + variants
* ``explore`` / ``refine``    — bounded model checking, trace inclusion
* ``litmus`` / ``props``      — the paper's litmus tests and Proposition 1
* ``flit`` / ``objects`` / ``sim`` / ``durable`` / ``harness``
                              — the FliT-for-CXL0 transformation (Alg. 2)
                                and the durable-linearizability checker
* ``latency``                 — Fig. 5 latency model + Table 1 mapping
* ``semantics_jax``           — vectorized JAX twin (vmapped fuzzing)
"""
from repro.core.state import (  # noqa: F401
    BOT, State, SystemConfig, initial_state, make_config, check_invariant,
)
from repro.core.semantics import (  # noqa: F401
    Variant, Label, LStore, RStore, MStore, Load, LFlush, RFlush, GPF, Crash,
    RMW, apply_label, step_with_tau,
)
from repro.core.explore import trace_feasible, reachable  # noqa: F401
from repro.core.flit import (  # noqa: F401
    POLICIES, DURABLE_POLICIES, NON_DURABLE_POLICIES,
)
from repro.core.durable import durably_linearizable, linearizable  # noqa: F401
