"""Durable linearizability checking (paper §6, after Izraelevitz et al.).

A history is *durably linearizable* iff it is well formed and linearizable
once all crash events are removed (the paper keeps Herlihy–Wing
happens-before as is).  Pending invocations (threads killed by a crash
mid-operation) may be completed with any result or dropped — the standard
linearizability treatment.

``linearizable(history, spec)`` implements the Wing & Gong search with
memoization on (linearized-op frozenset, spec state): at each step any op
whose invocation precedes the first response of the remaining *completed*
ops may linearize next; completed ops must reproduce their observed result,
pending ops are unconstrained and optional.

Small histories only (≲ 25 ops) — exactly the regime our simulator
produces; the search is exact, not sampled.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Set, Tuple

from repro.core.sim import Event, History
from repro.core.objects import SeqSpec


@dataclasses.dataclass(frozen=True)
class OpRecord:
    op_id: int
    thread: int
    op: str
    args: Tuple
    inv_index: int
    res_index: Optional[int]          # None = pending (crashed mid-op)
    result: object = None

    @property
    def completed(self) -> bool:
        return self.res_index is not None


def strip_crashes(history: History) -> List[Event]:
    return [e for e in history if e.kind != "crash"]


def collect_ops(history: History) -> List[OpRecord]:
    inv: Dict[int, Tuple[int, Event]] = {}
    res: Dict[int, Tuple[int, Event]] = {}
    events = strip_crashes(history)
    for i, e in enumerate(events):
        if e.kind == "inv":
            inv[e.op_id] = (i, e)
        elif e.kind == "res":
            res[e.op_id] = (i, e)
    ops = []
    for op_id, (i, e) in sorted(inv.items()):
        r = res.get(op_id)
        ops.append(OpRecord(op_id, e.thread, e.op, e.args, i,
                            r[0] if r else None,
                            r[1].result if r else None))
    return ops


def well_formed(history: History) -> bool:
    """Each thread's local history alternates inv/res (possibly ending with
    a pending inv killed by a crash)."""
    open_op: Dict[int, Optional[int]] = {}
    for e in history:
        if e.kind == "crash":
            continue
        if e.kind == "inv":
            if open_op.get(e.thread) is not None:
                return False
            open_op[e.thread] = e.op_id
        elif e.kind == "res":
            if open_op.get(e.thread) != e.op_id:
                return False
            open_op[e.thread] = None
    return True


def linearizable(history: History, spec: SeqSpec,
                 max_nodes: int = 2_000_000) -> bool:
    """Exact linearizability check of the crash-stripped history."""
    assert well_formed(history), "history is not well formed"
    ops = collect_ops(history)
    completed = [o for o in ops if o.completed]
    by_id = {o.op_id: o for o in ops}
    all_completed_ids = frozenset(o.op_id for o in completed)

    seen: Set[Tuple[frozenset, object]] = set()
    nodes = 0

    def first_response_bound(done: frozenset) -> float:
        rs = [o.res_index for o in completed if o.op_id not in done]
        return min(rs) if rs else float("inf")

    def dfs(done: frozenset, state) -> bool:
        nonlocal nodes
        if all_completed_ids <= done:
            return True
        key = (done, state)
        if key in seen:
            return False
        seen.add(key)
        nodes += 1
        if nodes > max_nodes:
            raise RuntimeError("linearizability search exceeded bound")
        bound = first_response_bound(done)
        for o in ops:
            if o.op_id in done or o.inv_index > bound:
                continue
            state2, result = spec.apply(state, o.op, o.args)
            if o.completed and result != o.result:
                continue
            if dfs(done | {o.op_id}, state2):
                return True
        return False

    return dfs(frozenset(), spec.initial())


def durably_linearizable(history: History, spec: SeqSpec) -> bool:
    """The paper's criterion: well formed + linearizable after removing
    crash events."""
    return well_formed(history) and linearizable(history, spec)


# ---------------------------------------------------------------------------
# Convenience: run a workload under a policy and check durability
# ---------------------------------------------------------------------------

def explain_violation(history: History) -> str:
    return "\n".join(repr(e) for e in history)
