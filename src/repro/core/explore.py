"""Bounded exploration of the CXL0 LTS.

Two entry points:

* ``trace_feasible(cfg, trace)`` — is a *serialized* sequence of labels (the
  paper's litmus-test presentation, §3.4) realizable when interleaved with
  arbitrary silent τ propagation steps?  BFS over τ-closures.

* ``reachable(cfg, ...)`` — the full bounded reachable state space (for
  Proposition-1 checking and variant refinement), with the action alphabet
  restricted to a small value set.

State spaces here are tiny (≤ 3 machines × ≤ 3 locations × ≤ 3 values), as in
the paper's FDR4 experiments; plain BFS with hashing suffices.
"""
from __future__ import annotations

from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

from repro.core.state import State, SystemConfig, initial_state, check_invariant
from repro.core.semantics import (
    Label, Variant, apply_label, enabled_labels, step_with_tau, tau_closure,
)


def trace_feasible(cfg: SystemConfig, trace: Sequence[Label],
                   variant: Variant = Variant.BASE,
                   start: Optional[State] = None) -> bool:
    """Can ``trace`` be executed from the initial state (τ-interleaved)?"""
    frontier: Set[State] = {start or initial_state(cfg)}
    for lab in trace:
        nxt: Set[State] = set()
        for s in frontier:
            nxt.update(step_with_tau(cfg, s, lab, variant))
        if not nxt:
            return False
        frontier = nxt
    return True


def trace_final_states(cfg: SystemConfig, trace: Sequence[Label],
                       variant: Variant = Variant.BASE,
                       start: Optional[State] = None) -> List[State]:
    """All (τ-closed) states after executing ``trace`` (empty = infeasible)."""
    frontier: Set[State] = set(tau_closure(cfg, start or initial_state(cfg)))
    for lab in trace:
        nxt: Set[State] = set()
        for s in frontier:
            for s2 in step_with_tau(cfg, s, lab, variant):
                nxt.update(tau_closure(cfg, s2))
        frontier = nxt
        if not frontier:
            return []
    return list(frontier)


def reachable(cfg: SystemConfig, values: Tuple[int, ...] = (0, 1),
              variant: Variant = Variant.BASE, crashes: bool = True,
              max_states: int = 200_000) -> Set[State]:
    """Bounded reachable set under the full action alphabet (incl. τ)."""
    s0 = initial_state(cfg)
    seen: Set[State] = {s0}
    frontier = [s0]
    while frontier:
        nxt = []
        for s in frontier:
            succs = [s2 for _, s2 in enabled_labels(cfg, s, values, variant,
                                                    crashes)]
            succs.extend(tau_closure(cfg, s))
            for s2 in succs:
                if s2 not in seen:
                    assert check_invariant(s2), ("cache invariant violated",
                                                 s, s2)
                    seen.add(s2)
                    nxt.append(s2)
                    if len(seen) > max_states:
                        raise RuntimeError("state space exceeds bound")
        frontier = nxt
    return seen


# ---------------------------------------------------------------------------
# Observable-trace languages (for variant refinement, §3.5)
# ---------------------------------------------------------------------------

def traces_up_to(cfg: SystemConfig, depth: int,
                 values: Tuple[int, ...] = (0, 1),
                 variant: Variant = Variant.BASE,
                 crashes: bool = True,
                 label_filter=None) -> Set[Tuple[str, ...]]:
    """The set of observable traces (repr'd labels) of length ≤ depth.

    τ steps are silent: each visible step is taken from the τ-closure.
    ``label_filter(label) -> bool`` restricts the alphabet (keeps the
    language finite and comparison meaningful across variants).
    """
    out: Set[Tuple[str, ...]] = {()}
    frontier: Dict[Tuple[str, ...], Set[State]] = {
        (): set(tau_closure(cfg, initial_state(cfg)))}
    for _ in range(depth):
        nxt: Dict[Tuple[str, ...], Set[State]] = {}
        for prefix, states in frontier.items():
            for s in states:
                for lab, s2 in enabled_labels(cfg, s, values, variant,
                                              crashes):
                    if label_filter is not None and not label_filter(lab):
                        continue
                    tr = prefix + (repr(lab),)
                    out.add(tr)
                    nxt.setdefault(tr, set()).update(tau_closure(cfg, s2))
        frontier = nxt
    return out
