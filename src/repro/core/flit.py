"""FliT for CXL0 (paper §6, Alg. 2) and the policies it is compared against.

A *memory view* wraps raw CXL0 primitives behind the four FliT methods
(``private_load`` / ``private_store`` / ``shared_load`` / ``shared_store``
plus RMW variants and ``completeOp``).  Object implementations
(``repro.core.objects``) are written once against this interface; swapping
the view swaps the persistence discipline:

* ``RawView``       — no flushes at all (the untransformed linearizable
                      object).  NOT durable under crashes — the negative
                      control our durability checker must catch.
* ``OriginalFliT``  — Wei et al.'s Alg. 1 translated naively: ``Flush`` is
                      taken as *local* flush (next hierarchy level only).
                      Correct in the full-system-crash model, WRONG under
                      CXL0's partial crashes (paper §6 motivating example).
* ``FliTCXL0``      — the paper's Alg. 2: all stores are LStore, all
                      persistence flushes are RFlush, completeOp is empty.
* ``MStoreAll``     — every tagged store is an MStore (always durable, no
                      counters needed, works without coherence — the
                      paper's "inferior performance" strawman, §6.1).

Object code runs inside the concurrent simulator (``repro.core.sim``) as
generators: every primitive is ``yield``-ed as a request and the simulator
returns its result, so arbitrary interleavings and crash points between
primitives are explored.

All views implement the same generator protocol; primitives are tuples
``(op, *args)`` with op ∈ {load, lstore, rstore, mstore, lflush, rflush,
faa, cas, gpf}.
"""
from __future__ import annotations

from typing import Iterator, Optional, Tuple


class MemView:
    """Base view: FliT interface over yielded CXL0 primitives.

    ``counter_of``: data location -> FliT-counter location (or None if this
    policy needs no counters).
    """

    name = "abstract"
    uses_counters = False

    def __init__(self, counter_of=None):
        self.counter_of = counter_of or (lambda x: None)

    # -- raw primitive helpers (generators) --------------------------------
    def _load(self, x):
        return (yield ("load", x))

    def _lstore(self, x, v):
        yield ("lstore", x, v)

    def _mstore(self, x, v):
        yield ("mstore", x, v)

    def _lflush(self, x):
        yield ("lflush", x)

    def _rflush(self, x):
        yield ("rflush", x)

    def _faa(self, x, d, flavor="l"):
        return (yield ("faa", x, d, flavor))

    def _cas(self, x, old, new, flavor="l"):
        return (yield ("cas", x, old, new, flavor))

    def _atomic_begin(self):
        # Models the paper's synchronous-flush assumption (§B Condition 2):
        # the store→flush window is failure-atomic — the scheduler does not
        # inject crashes inside it (Simulator(respect_atomic=True)).  With
        # respect_atomic=False the window is exposed; see the FINDING tests.
        yield ("atomic_begin",)

    def _atomic_end(self):
        yield ("atomic_end",)

    # -- FliT interface (override in subclasses) ----------------------------
    def private_load(self, x):
        return (yield from self._load(x))

    def private_store(self, x, v, pflag=True):
        raise NotImplementedError

    def shared_load(self, x, pflag=True):
        raise NotImplementedError

    def shared_store(self, x, v, pflag=True):
        raise NotImplementedError

    def shared_cas(self, x, old, new, pflag=True):
        raise NotImplementedError

    def shared_faa(self, x, d, pflag=True):
        raise NotImplementedError

    def complete_op(self):
        if False:
            yield  # pragma: no cover
        return None


class RawView(MemView):
    """The untransformed linearizable object: plain stores, no flushes."""

    name = "raw"

    def private_store(self, x, v, pflag=True):
        yield from self._lstore(x, v)

    def shared_load(self, x, pflag=True):
        return (yield from self._load(x))

    def shared_store(self, x, v, pflag=True):
        yield from self._lstore(x, v)

    def shared_cas(self, x, old, new, pflag=True):
        return (yield from self._cas(x, old, new, "l"))

    def shared_faa(self, x, d, pflag=True):
        return (yield from self._faa(x, d, "l"))


class OriginalFliT(MemView):
    """Wei et al. Alg. 1 ported naively: Flush == LFlush (next level only).

    In the single-machine full-system-crash model this is FliT; under CXL0
    an LFlush only reaches the *owner's volatile cache*, so a completed
    operation can still be lost when the owner machine crashes.
    """

    name = "original_flit"
    uses_counters = True

    def private_store(self, x, v, pflag=True):
        yield from self._atomic_begin()
        yield from self._lstore(x, v)
        if pflag:
            yield from self._lflush(x)
        yield from self._atomic_end()

    def shared_load(self, x, pflag=True):
        v = yield from self._load(x)
        c = self.counter_of(x)
        if pflag and c is not None:
            if (yield from self._load(c)) > 0:
                yield from self._lflush(x)
        return v

    def shared_store(self, x, v, pflag=True):
        if not pflag:
            yield from self._lstore(x, v)
            return
        c = self.counter_of(x)
        yield from self._faa(c, 1, "l")
        yield from self._atomic_begin()
        yield from self._lstore(x, v)
        yield from self._lflush(x)
        yield from self._atomic_end()
        yield from self._faa(c, -1, "l")

    def shared_cas(self, x, old, new, pflag=True):
        if not pflag:
            return (yield from self._cas(x, old, new, "l"))
        c = self.counter_of(x)
        yield from self._faa(c, 1, "l")
        yield from self._atomic_begin()
        ok = yield from self._cas(x, old, new, "l")
        yield from self._lflush(x)
        yield from self._atomic_end()
        yield from self._faa(c, -1, "l")
        return ok

    def shared_faa(self, x, d, pflag=True):
        if not pflag:
            return (yield from self._faa(x, d, "l"))
        c = self.counter_of(x)
        yield from self._faa(c, 1, "l")
        yield from self._atomic_begin()
        old = yield from self._faa(x, d, "l")
        yield from self._lflush(x)
        yield from self._atomic_end()
        yield from self._faa(c, -1, "l")
        return old


class FliTCXL0(OriginalFliT):
    """The paper's Alg. 2: LStore everywhere, RFlush for persistence,
    empty completeOp.  Provides durable linearizability under partial
    crashes (§B of the paper; checked by our simulator + checker)."""

    name = "flit_cxl0"
    uses_counters = True

    def private_store(self, x, v, pflag=True):
        yield from self._atomic_begin()
        yield from self._lstore(x, v)
        if pflag:
            yield from self._rflush(x)
        yield from self._atomic_end()

    def shared_load(self, x, pflag=True):
        v = yield from self._load(x)
        c = self.counter_of(x)
        if pflag and c is not None:
            if (yield from self._load(c)) > 0:
                yield from self._rflush(x)
        return v

    def shared_store(self, x, v, pflag=True):
        if not pflag:
            yield from self._lstore(x, v)
            return
        c = self.counter_of(x)
        yield from self._faa(c, 1, "l")
        yield from self._atomic_begin()
        yield from self._lstore(x, v)
        yield from self._rflush(x)
        yield from self._atomic_end()
        yield from self._faa(c, -1, "l")

    def shared_cas(self, x, old, new, pflag=True):
        if not pflag:
            return (yield from self._cas(x, old, new, "l"))
        c = self.counter_of(x)
        yield from self._faa(c, 1, "l")
        yield from self._atomic_begin()
        ok = yield from self._cas(x, old, new, "l")
        yield from self._rflush(x)
        yield from self._atomic_end()
        yield from self._faa(c, -1, "l")
        return ok

    def shared_faa(self, x, d, pflag=True):
        if not pflag:
            return (yield from self._faa(x, d, "l"))
        c = self.counter_of(x)
        yield from self._faa(c, 1, "l")
        yield from self._atomic_begin()
        old = yield from self._faa(x, d, "l")
        yield from self._rflush(x)
        yield from self._atomic_end()
        yield from self._faa(c, -1, "l")
        return old


class MStoreAll(MemView):
    """Every tagged store/RMW goes straight to physical memory (M-flavor).

    Durable by construction (Prop. 1.8: MStore ≈ LStore·RFlush) and needs no
    coherence or counters — the paper's high-cost alternative (§6.1).
    Loads may still observe unpersisted values written by *other* policies;
    within a homogeneous MStoreAll run every write is persistent.
    """

    name = "mstore_all"

    def private_store(self, x, v, pflag=True):
        if pflag:
            yield from self._mstore(x, v)
        else:
            yield from self._lstore(x, v)

    def shared_load(self, x, pflag=True):
        return (yield from self._load(x))

    def shared_store(self, x, v, pflag=True):
        if pflag:
            yield from self._mstore(x, v)
        else:
            yield from self._lstore(x, v)

    def shared_cas(self, x, old, new, pflag=True):
        return (yield from self._cas(x, old, new, "m" if pflag else "l"))

    def shared_faa(self, x, d, pflag=True):
        return (yield from self._faa(x, d, "m" if pflag else "l"))


POLICIES = {v.name: v for v in (RawView, OriginalFliT, FliTCXL0, MStoreAll)}

#: policies expected to be durably linearizable under CXL0 partial crashes
DURABLE_POLICIES = ("flit_cxl0", "mstore_all")
#: policies expected to exhibit durability violations (negative controls)
NON_DURABLE_POLICIES = ("raw", "original_flit")
