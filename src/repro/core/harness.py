"""Workload harness: objects × policies × schedules × crashes → verdicts.

Builds small concurrent workloads over the simulator and checks durable
linearizability of the produced histories.  Used by tests, the hypothesis
property suite, and ``benchmarks/bench_flit.py``:

* durable policies (``flit_cxl0``, ``mstore_all``) must yield durably
  linearizable histories on EVERY schedule/seed;
* negative controls (``raw``, ``original_flit``) must exhibit at least one
  durability violation across a seed sweep (the §6 motivating example).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Tuple

from repro.core.state import SystemConfig
from repro.core.semantics import Variant
from repro.core.sim import Simulator, History
from repro.core.flit import POLICIES, MemView
from repro.core.objects import (
    Counter, KVMap, Layout, Register, TreiberStack,
)
from repro.core.durable import durably_linearizable


@dataclasses.dataclass
class Workload:
    name: str
    cfg: SystemConfig
    spec: object
    # (sim, view) -> None: spawns threads on the simulator
    spawn: Callable[[Simulator, MemView], None]
    crashable: Tuple[int, ...]
    counter_of: Callable[[int], Optional[int]] = (lambda x: None)


def _sys(layout: Layout, n_machines: int) -> SystemConfig:
    return SystemConfig(n_machines=n_machines, owner=tuple(layout.owners),
                        volatile=tuple(False for _ in range(n_machines)))


# ---------------------------------------------------------------------------
# Workload builders
# ---------------------------------------------------------------------------

def counter_workload(n_machines: int = 2, incs_per_thread: int = 2) -> Workload:
    """Counter owned by machine 0; machines 0..n-1 each inc; machine n-1
    (never crashed) reads at the end."""
    layout = Layout()
    counter = Counter(layout, owner=0)
    cfg = _sys(layout, n_machines)

    def spawn(sim: Simulator, mv: MemView):
        for m in range(n_machines):
            ops = [("inc", lambda mv=mv: counter.inc(mv), ())
                   for _ in range(incs_per_thread)]
            sim.spawn(m, ops)
        sim.spawn(n_machines - 1,
                  [("read", lambda mv=mv: counter.read(mv), ())] * 2)

    return Workload("counter", cfg, counter.spec(), spawn,
                    crashable=tuple(range(n_machines - 1)),
                    counter_of=layout.counter_of)


def register_workload(n_machines: int = 2) -> Workload:
    layout = Layout()
    reg = Register(layout, owner=0)
    cfg = _sys(layout, n_machines)

    def spawn(sim: Simulator, mv: MemView):
        for m in range(n_machines):
            sim.spawn(m, [("write", (lambda v, mv=mv: reg.write(mv, v)),
                           (10 * (m + 1) + j,)) for j in range(2)])
        sim.spawn(n_machines - 1,
                  [("read", lambda mv=mv: reg.read(mv), ())] * 2)

    return Workload("register", cfg, reg.spec(), spawn,
                    crashable=tuple(range(n_machines - 1)),
                    counter_of=layout.counter_of)


def stack_workload(n_machines: int = 2, pushes: int = 2) -> Workload:
    layout = Layout()
    n_threads = n_machines
    stack = TreiberStack(layout, owner=0, n_slots=2 * pushes * n_threads,
                         n_threads=n_threads)
    cfg = _sys(layout, n_machines)

    def spawn(sim: Simulator, mv: MemView):
        for m in range(n_machines):
            ops = [("push", (lambda v, mv=mv, t=m: stack.push(mv, v, t)),
                    (10 * (m + 1) + j,)) for j in range(pushes)]
            sim.spawn(m, ops)
        sim.spawn(n_machines - 1,
                  [("pop", lambda mv=mv, t=n_machines - 1:
                    stack.pop(mv, t), ())] * (pushes + 1))

    return Workload("stack", cfg, stack.spec(), spawn,
                    crashable=tuple(range(n_machines - 1)),
                    counter_of=layout.counter_of)


def kv_workload(n_machines: int = 2, n_keys: int = 2) -> Workload:
    layout = Layout()
    kv = KVMap(layout, n_keys, n_machines)
    cfg = _sys(layout, n_machines)

    def spawn(sim: Simulator, mv: MemView):
        for m in range(n_machines):
            ops = []
            for k in range(n_keys):
                ops.append(("put", (lambda k, v, mv=mv: kv.put(mv, k, v)),
                            (k, 10 * (m + 1) + k)))
            sim.spawn(m, ops)
        sim.spawn(n_machines - 1,
                  [("get", (lambda k, mv=mv: kv.get(mv, k)), (k,))
                   for k in range(n_keys)])

    return Workload("kv", cfg, kv.spec(), spawn,
                    crashable=tuple(range(n_machines - 1)),
                    counter_of=layout.counter_of)


WORKLOADS: Dict[str, Callable[[], Workload]] = {
    "counter": counter_workload,
    "register": register_workload,
    "stack": stack_workload,
    "kv": kv_workload,
}


# ---------------------------------------------------------------------------
# Runner
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class RunResult:
    workload: str
    policy: str
    seed: int
    crashed: int
    durable: bool
    history: History


def run_once(make_workload: Callable[[], Workload], policy: str, seed: int,
             *, variant: Variant = Variant.BASE, p_crash: float = 0.05,
             max_crashes: int = 1, p_tau: float = 0.3,
             respect_atomic: bool = True) -> RunResult:
    wl = make_workload()        # fresh object state per run
    view_cls = POLICIES[policy]
    sim = Simulator(wl.cfg, variant=variant, seed=seed, p_tau=p_tau,
                    p_crash=p_crash, max_crashes=max_crashes,
                    crashable=list(wl.crashable),
                    respect_atomic=respect_atomic)
    view = view_cls(counter_of=wl.counter_of)
    wl.spawn(sim, view)
    history = sim.run()
    ok = durably_linearizable(history, wl.spec)
    return RunResult(wl.name, policy, seed, sim.n_crashes, ok, history)


def sweep(make_workload: Callable[[], Workload], policy: str,
          seeds: range, **kw) -> List[RunResult]:
    return [run_once(make_workload, policy, s, **kw) for s in seeds]
