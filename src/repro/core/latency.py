"""Latency model (paper Fig. 5) and CXL transaction mapping (Table 1).

The paper measures CXL0 primitives on a real x86 CPU + FPGA pair over
CXL 1.1.  Exact nanosecond values are read off a bar chart, so this module
stores a *calibrated* table: absolute numbers are representative of
published CXL 1.1 measurements, and the paper's stated ratios hold exactly:

* host: local Read/MStore 2.34x faster than to HDM (remote);
* device: local (device-bias HDM) 1.94x faster than to HM (remote);
* device→HM: MStore = 1.45x RStore; RStore = 2.08x LStore;
* RFlush latency ≈ MStore latency (both reach physical memory);
* host and device remote accesses have approximately equal latency.

``trace_cost`` prices a trace of CXL0 primitives — used by the FliT
benchmark (Alg. 2's LStore+RFlush vs. the MStore-everything strawman) and
by the DSM runtime's tier cost model.

Table 1 is encoded verbatim: the many-to-one mapping from CXL.cache /
CXL.mem transactions to CXL0 primitives, including the primitives that are
*unavailable* ("???" in the paper) on current hardware.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

HOST, DEVICE = "host", "device"
HM, HDM = "HM", "HDM"          # Host-attached Memory / Host-managed Device Mem

# ---------------------------------------------------------------------------
# Fig. 5 — latency (ns) per (node, primitive, target-locality)
# target is "local" or "remote" from the issuing node's perspective:
#   host:   local = HM,  remote = HDM
#   device: local = HDM (device-bias), remote = HM
# ---------------------------------------------------------------------------

_R_HOST = 2.34      # host remote/local ratio (Read, MStore)
_R_DEV = 1.94       # device remote/local ratio
_R_RS_LS = 2.08     # device→HM: RStore vs LStore
_R_MS_RS = 1.45     # device→HM: MStore vs RStore

#: ns. Base calibration points (order-of-magnitude from CXL 1.1 literature).
LATENCY_NS: Dict[Tuple[str, str, str], float] = {}


def _build():
    host_read_local = 105.0           # DRAM load
    host_read_remote = host_read_local * _R_HOST
    dev_read_local = 201.0            # device-bias HDM
    dev_read_remote = dev_read_local * _R_DEV

    # host rows -------------------------------------------------------------
    LATENCY_NS[(HOST, "load", "local")] = host_read_local
    LATENCY_NS[(HOST, "load", "remote")] = host_read_remote
    # LStore retires into the store buffer: fast and locality-independent
    LATENCY_NS[(HOST, "lstore", "local")] = 12.0
    LATENCY_NS[(HOST, "lstore", "remote")] = 12.0
    LATENCY_NS[(HOST, "mstore", "local")] = 125.0
    LATENCY_NS[(HOST, "mstore", "remote")] = 125.0 * _R_HOST
    # RFlush ≈ MStore (paper §5.2)
    LATENCY_NS[(HOST, "rflush", "local")] = LATENCY_NS[(HOST, "mstore", "local")]
    LATENCY_NS[(HOST, "rflush", "remote")] = LATENCY_NS[(HOST, "mstore", "remote")]

    # device rows ------------------------------------------------------------
    LATENCY_NS[(DEVICE, "load", "local")] = dev_read_local
    LATENCY_NS[(DEVICE, "load", "remote")] = dev_read_remote
    # device LStore: single cache level, no write buffer; the cache used for
    # HM targets is slower than the HDM one (two separate caches in the IP)
    dev_lstore_remote = 90.0           # to HM (green bar — slower)
    LATENCY_NS[(DEVICE, "lstore", "local")] = 62.0
    LATENCY_NS[(DEVICE, "lstore", "remote")] = dev_lstore_remote
    dev_rstore_remote = dev_lstore_remote * _R_RS_LS
    LATENCY_NS[(DEVICE, "rstore", "remote")] = dev_rstore_remote
    LATENCY_NS[(DEVICE, "rstore", "local")] = LATENCY_NS[(DEVICE, "lstore", "local")]
    LATENCY_NS[(DEVICE, "mstore", "remote")] = dev_rstore_remote * _R_MS_RS
    LATENCY_NS[(DEVICE, "mstore", "local")] = (
        LATENCY_NS[(DEVICE, "mstore", "remote")] / _R_DEV)
    LATENCY_NS[(DEVICE, "rflush", "remote")] = LATENCY_NS[(DEVICE, "mstore", "remote")]
    LATENCY_NS[(DEVICE, "rflush", "local")] = LATENCY_NS[(DEVICE, "mstore", "local")]


_build()

#: RMW ≈ load + store on an EXCLUSIVE line (paper §3.3); approximated as the
#: sum of the load and the flavored store.
def rmw_latency(node: str, flavor: str, locality: str) -> float:
    store = {"l": "lstore", "r": "rstore", "m": "mstore"}[flavor]
    key = (node, store, locality)
    if key not in LATENCY_NS:           # host RStore unavailable — price as M
        key = (node, "mstore", locality)
    return LATENCY_NS[(node, "load", locality)] + LATENCY_NS[key]


def primitive_latency(node: str, prim: str, locality: str,
                      flavor: str = "l") -> float:
    if prim in ("faa", "cas", "rmw"):
        return rmw_latency(node, flavor, locality)
    if prim == "lflush":
        # evict to the next level: priced like a local store-and-forward
        return LATENCY_NS[(node, "lstore", locality)] * 2.0
    key = (node, prim, locality)
    if key not in LATENCY_NS:
        raise KeyError(f"primitive {prim} unavailable on {node} ({locality})")
    return LATENCY_NS[key]


def trace_cost(trace: Sequence[Tuple[str, str, str]],
               flavors: Optional[Sequence[str]] = None) -> float:
    """Σ latency over (node, primitive, locality) records, in ns."""
    total = 0.0
    for i, (node, prim, locality) in enumerate(trace):
        fl = flavors[i] if flavors else "l"
        total += primitive_latency(node, prim, locality, fl)
    return total


# ---------------------------------------------------------------------------
# Table 1 — CXL transactions observable per CXL0 primitive
# ---------------------------------------------------------------------------

UNAVAILABLE = "???"


@dataclasses.dataclass(frozen=True)
class MappingRow:
    primitive: str
    node: str
    operation: str                   # ISA / device operation that triggers it
    to_hm: Tuple[str, ...]           # CXL transactions targeting HM
    to_hdm: Tuple[str, ...]          # CXL transactions targeting HDM (host bias)

    @property
    def available(self) -> bool:
        return self.operation != UNAVAILABLE


TABLE1: Tuple[MappingRow, ...] = (
    # --- host rows (x86 instructions; CXL.cache H2D / CXL.mem M2S) ---------
    MappingRow("load", HOST, "Load", ("None", "SnpInv"), ("None", "MemRdData")),
    MappingRow("lstore", HOST, "Store", ("None", "SnpInv"),
               ("None", "MemRdData", "MemRd")),
    MappingRow("rstore", HOST, UNAVAILABLE, (UNAVAILABLE,), (UNAVAILABLE,)),
    MappingRow("mstore", HOST, "Non-Temporal Store + Fence", ("SnpInv",),
               ("MemWr",)),
    MappingRow("lflush", HOST, UNAVAILABLE, (UNAVAILABLE,), (UNAVAILABLE,)),
    MappingRow("rflush", HOST, "CLFlush", ("None", "SnpInv"),
               ("None", "MemInv", "MemWr")),
    # --- device rows (CXL.cache D2H / CXL.cache & CXL.mem) ------------------
    MappingRow("load", DEVICE, "Caching Read", ("None", "RdShared"),
               ("None", "RdShared")),
    MappingRow("lstore", DEVICE, "Caching Write", ("None", "RdOwn"),
               ("None", "RdOwn")),
    MappingRow("rstore", DEVICE, "HM: ItoMWr / HDM: Caching Write",
               ("ItoMWr",), ("None", "RdOwn")),
    MappingRow("mstore", DEVICE, "Caching Write + CLFlush",
               ("(RdOwn +) DirtyEvict", "WOWrInv/F", "WrInv"),
               ("None", "MemRd")),
    MappingRow("lflush", DEVICE, UNAVAILABLE, (UNAVAILABLE,), (UNAVAILABLE,)),
    MappingRow("rflush", DEVICE, "CLFlush", ("CleanEvict", "DirtyEvict"),
               ("None", "MemRd")),
)


def table1_row(primitive: str, node: str) -> MappingRow:
    for r in TABLE1:
        if r.primitive == primitive and r.node == node:
            return r
    raise KeyError((primitive, node))


def available_primitives(node: str) -> List[str]:
    return [r.primitive for r in TABLE1 if r.node == node and r.available]


#: §4 — which CXL0 primitives each *system configuration* admits
CONFIG_PRIMITIVES: Dict[str, Dict[str, Tuple[str, ...]]] = {
    "host_device_pair": {
        HOST: ("load", "lstore", "mstore", "rflush", "gpf", "l-rmw"),
        DEVICE: ("load", "lstore", "rstore", "mstore", "rflush", "l-rmw"),
    },
    "partitioned_pool": {
        HOST: ("load", "lstore", "mstore", "lflush", "rflush", "gpf",
               "l-rmw", "m-rmw"),
    },
    "shared_pool_coherent": {
        HOST: ("load", "lstore", "mstore", "rflush", "gpf", "l-rmw",
               "m-rmw"),
    },
    # non-coherent realistic pool: cache-bypassing subset only (§4)
    "shared_pool_noncoherent": {
        HOST: ("load_m", "mstore", "m-rmw"),
    },
}
