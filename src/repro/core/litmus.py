"""The paper's litmus tests (§3.4 Fig. 3, §3.5 tests 10–12, §6 test 13).

Each test is a serialized trace of CXL0 labels plus the expected verdict:
``True`` = the behavior is allowed (✓), ``False`` = illegal (✗).  Verdicts
are *per variant* for the §3.5 tests.  Machine/location indices are
0-based here; the paper's ``x^i`` notation (location on machine i) appears
in comments with the paper's 1-based numbering.

All memories are non-volatile (as the paper assumes for these tests).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Sequence, Tuple

from repro.core.state import SystemConfig, make_config
from repro.core.semantics import (
    Crash, Label, LFlush, LStore, Load, MStore, RFlush, RStore, Variant,
)
from repro.core.explore import trace_feasible


@dataclasses.dataclass(frozen=True)
class LitmusTest:
    name: str
    description: str
    cfg: SystemConfig
    trace: Tuple[Label, ...]
    # verdict per variant: True = allowed (✓), False = illegal (✗)
    expected: Dict[Variant, bool]


def _expect(base: bool, lwb=None, psn=None) -> Dict[Variant, bool]:
    return {Variant.BASE: base,
            Variant.LWB: base if lwb is None else lwb,
            Variant.PSN: base if psn is None else psn}


# two machines, one location each: loc 0 = x^1 (machine 0), loc 1 = x^2
CFG2 = make_config(2, 1)
# three machines for test 7: loc i on machine i
CFG3 = make_config(3, 1)
# two locations on machine 1 plus one on machine 0 for tests 8/9
CFG_89 = SystemConfig(n_machines=2, owner=(0, 1), volatile=(False, False))


LITMUS_TESTS: Tuple[LitmusTest, ...] = (
    # ---------------- single machine (tests 1–3) --------------------------
    LitmusTest(
        "test1_rstore_lost",
        "A value stored with RStore may be lost on crash: it completes in "
        "the owner's cache, which is volatile. (paper: ✓)",
        CFG2,
        (RStore(0, 0, 1), Crash(0), Load(0, 0, 0)),
        _expect(True)),
    LitmusTest(
        "test2_mstore_survives",
        "MStore persists before returning, so the post-crash load cannot "
        "observe the initial value. (paper: ✗)",
        CFG2,
        (MStore(0, 0, 1), Crash(0), Load(0, 0, 0)),
        _expect(False)),
    LitmusTest(
        "test3_lflush_persists_local",
        "LStore + LFlush by the owner forces vertical propagation to local "
        "persistent memory; the value cannot be lost. (paper: ✗)",
        CFG2,
        (LStore(0, 0, 1), LFlush(0, 0), Crash(0), Load(0, 0, 0)),
        _expect(False)),

    # ---------------- multiple machines (tests 4–7) -----------------------
    LitmusTest(
        "test4_remote_rstore_lost",
        "RStore to a remote location completes in the remote owner's cache; "
        "if the owner crashes before write-back the value is lost. "
        "(paper: ✓)",
        CFG2,
        (RStore(0, 1, 1), Crash(1), Load(0, 1, 0)),
        _expect(True)),
    LitmusTest(
        "test5_rflush_prevents_loss",
        "RFlush blocks until no cache holds the line (∀j. C_j = ⊥), i.e. the "
        "value reached the owner's memory; the crash cannot lose it. "
        "(paper: ✗)",
        CFG2,
        (RStore(0, 1, 1), RFlush(0, 1), Crash(1), Load(0, 1, 0)),
        _expect(False)),
    LitmusTest(
        "test6_load_copy_saves_value",
        "Loading copies the value into the loader's cache, so after the "
        "writer crashes the reader still observes it from C_2. (paper: ✗ "
        "for the loss; under LWB the first remote load is instead served "
        "after a forced write-back, which also prevents the loss.)",
        CFG2,
        # machine 0 LStores to x^2 (remote); machine 1 loads it (copy into
        # C_2); machine 0 crashes; the value must still be visible.
        (LStore(0, 1, 1), Load(1, 1, 1), Crash(0), Load(1, 1, 0)),
        _expect(False)),
    LitmusTest(
        "test7_flush_moves_to_third_cache",
        "Machine 1's LFlush pushes its copy toward the owner's (machine 3) "
        "cache, so the value survives the writer's crash in C_3. (paper: ✗)",
        CFG3,
        # x^3 = loc 2 owned by machine 2. machine 0 writes, machine 1 loads
        # and flushes (copy moves to owner cache), machine 0 crashes.
        (LStore(0, 2, 1), Load(1, 2, 1), LFlush(1, 2), Crash(0),
         Load(1, 2, 0)),
        _expect(False)),

    # ---------------- multiple variables (tests 8–9) ----------------------
    LitmusTest(
        "test8_observed_then_lost",
        "A stored value that another operation already observed (and "
        "propagated into its own write) can be lost: recovery shows the "
        "later operation's effect without the first. (paper: ✓)",
        CFG_89,
        # y^1 = loc 0 (machine 0), x^2 = loc 1 (machine 1).
        # RStore_2(y^1, x^2) shorthand: machine 1 reads x^2 then RStores to
        # y^1. Machine 1 crashes; x is lost but y survived at machine 0.
        (RStore(0, 1, 1),            # machine 0 writes x^2 := 1 (owner cache)
         Load(1, 1, 1),              # machine 1 reads x^2 == 1
         RStore(1, 0, 1),            # ... and RStores it into y^1
         Crash(1),                   # machine 1 crashes: x^2 lost
         Load(0, 0, 1),              # y survived (machine 0's cache/memory)
         Load(0, 1, 0)),             # but x is back to 0 — inconsistent ✓
        # LWB too: machine 1 OWNS x^2, so its load is an own-cache hit and
        # does not force a write-back.
        _expect(True)),
    LitmusTest(
        "test9_mstore_prevents_inconsistency",
        "Using MStore for the first write persists x before it can be "
        "observed, so the inconsistent recovery of test 8 is impossible. "
        "(paper: ✗)",
        CFG_89,
        (MStore(0, 1, 1), Load(1, 1, 1), RStore(1, 0, 1), Crash(1),
         Load(0, 0, 1), Load(0, 1, 0)),
        _expect(False)),

    # ---------------- §3.5 variant-distinguishing tests 10–12 -------------
    LitmusTest(
        "test10_variants",
        "RStore_2(x^1,1); Load_2(x^1,1); f_1; Load_2(x^1,0) — the copy in "
        "C_2 may propagate home before the crash (BASE/PSN ✓); LWB forces "
        "the remote load through memory, so the value persisted (✗).",
        CFG2,
        (RStore(1, 0, 1), Load(1, 0, 1), Crash(0), Load(1, 0, 0)),
        {Variant.BASE: True, Variant.LWB: False, Variant.PSN: True}),
    LitmusTest(
        "test11_variants",
        "LStore_1(x^1,1); Load_2(x^1,1); f_1; Load_1(x^1,0) — same loss "
        "pattern with the writer being the owner. (✓, ✗, ✓)",
        CFG2,
        (LStore(0, 0, 1), Load(1, 0, 1), Crash(0), Load(0, 0, 0)),
        {Variant.BASE: True, Variant.LWB: False, Variant.PSN: True}),
    LitmusTest(
        "test12_variants",
        "LStore_2(x^1,1); f_1; Load_1(x^1,1); f_1; Load_2(x^1,0) — under "
        "LWB the owner's load can hit its OWN cache after a C-C propagation "
        "without touching memory, so a second crash still loses the value "
        "(✓); PSN poisons x^1 in C_2 at the first crash, making the "
        "intermediate Load_1(x^1,1) impossible (✗).",
        CFG2,
        (LStore(1, 0, 1), Crash(0), Load(0, 0, 1), Crash(0), Load(1, 0, 0)),
        {Variant.BASE: True, Variant.LWB: True, Variant.PSN: False}),

    # ---------------- §6 motivating example (test 13) ---------------------
    LitmusTest(
        "test13_remote_crash_breaks_local_program",
        "§6: x ∈ Loc_M2; M1 runs x=1; r1=x; r2=x. A crash of the REMOTE "
        "machine M2 between the two loads can make r1 ≠ r2 — impossible in "
        "any single-machine model. (✓ = assertion can fail; under LWB the "
        "first load hits M1's own cache, and the copy can still be evicted "
        "toward M2 and lost, so the behavior remains allowed)",
        CFG2,
        (LStore(0, 1, 1), Load(0, 1, 1), Crash(1), Load(0, 1, 0)),
        _expect(True)),
    LitmusTest(
        "test13b_lflush_insufficient",
        "§6: an LFlush between the store and the loads does NOT fix test 13 "
        "— it only moves the value into M2's (volatile) cache. (✓)",
        CFG2,
        (LStore(0, 1, 1), LFlush(0, 1), Load(0, 1, 1), Crash(1),
         Load(0, 1, 0)),
        _expect(True, lwb=False)),
    LitmusTest(
        "test13c_rflush_fixes",
        "§6: an RFlush (reaches physical memory) makes the assertion always "
        "hold. (✗)",
        CFG2,
        (LStore(0, 1, 1), RFlush(0, 1), Load(0, 1, 1), Crash(1),
         Load(0, 1, 0)),
        _expect(False)),
)


def run_litmus(test: LitmusTest, variant: Variant) -> bool:
    """True iff the behavior is allowed under ``variant``."""
    return trace_feasible(test.cfg, test.trace, variant)


def run_all(variants: Sequence[Variant] = tuple(Variant)):
    """-> list of (test, variant, allowed, expected) rows."""
    rows = []
    for t in LITMUS_TESTS:
        for v in variants:
            rows.append((t, v, run_litmus(t, v), t.expected[v]))
    return rows
