"""Concurrent objects: sequential specifications + linearizable
implementations written against the FliT memory-view interface.

Implementations are generator functions (see ``repro.core.flit``): every
memory primitive is yielded to the simulator, so crashes and interleavings
can hit *between* any two primitives.  All implementations are linearizable
in the crash-free sequentially-consistent semantics of CXL0 (the paper:
"Without crashes, CXL0 has simple, sequentially consistent semantics");
wrapping them with ``FliTCXL0`` upgrades them to durable linearizability.

Objects:
* ``Register``     — read/write register.
* ``Counter``      — FAA counter (inc returns old value).
* ``TreiberStack`` — the classic lock-free stack: CAS on ``top``, nodes in
                     a preallocated per-thread pool (value, next fields).
* ``KVMap``        — fixed-key map of registers.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

EMPTY = -1        # sentinel "empty" result for pop
NULL = 0          # null node pointer (slot ids start at 1)


# ---------------------------------------------------------------------------
# Sequential specifications (pure, hashable states)
# ---------------------------------------------------------------------------

class SeqSpec:
    """apply(state, op, args) -> (state', result); initial() -> state."""

    def initial(self):
        raise NotImplementedError

    def apply(self, state, op: str, args: Tuple):
        raise NotImplementedError


class RegisterSpec(SeqSpec):
    def initial(self):
        return 0

    def apply(self, state, op, args):
        if op == "write":
            return args[0], None
        if op == "read":
            return state, state
        raise ValueError(op)


class CounterSpec(SeqSpec):
    def initial(self):
        return 0

    def apply(self, state, op, args):
        if op == "inc":
            return state + 1, state          # returns old value (FAA)
        if op == "read":
            return state, state
        raise ValueError(op)


class StackSpec(SeqSpec):
    def initial(self):
        return ()

    def apply(self, state, op, args):
        if op == "push":
            return state + (args[0],), None
        if op == "pop":
            if not state:
                return state, EMPTY
            return state[:-1], state[-1]
        raise ValueError(op)


class KVSpec(SeqSpec):
    def __init__(self, n_keys: int):
        self.n_keys = n_keys

    def initial(self):
        return (0,) * self.n_keys

    def apply(self, state, op, args):
        if op == "put":
            k, v = args
            return state[:k] + (v,) + state[k + 1:], None
        if op == "get":
            return state, state[args[0]]
        raise ValueError(op)


# ---------------------------------------------------------------------------
# Layouts: how an object's locations are placed on machines
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Layout:
    """Assigns shared locations (and their FliT counters) to owners.

    ``alloc(owner)`` hands out the next location on ``owner``; after all
    allocations, ``n_locs`` / ``owner`` describe the SystemConfig and
    ``counter_of`` maps data locations to their counter locations.
    """
    owners: List[int] = dataclasses.field(default_factory=list)
    counters: Dict[int, int] = dataclasses.field(default_factory=dict)

    def alloc(self, owner: int) -> int:
        self.owners.append(owner)
        return len(self.owners) - 1

    def alloc_with_counter(self, owner: int) -> int:
        x = self.alloc(owner)
        self.counters[x] = self.alloc(owner)
        return x

    def counter_of(self, x: int) -> Optional[int]:
        return self.counters.get(x)

    @property
    def n_locs(self) -> int:
        return len(self.owners)


# ---------------------------------------------------------------------------
# Implementations
# ---------------------------------------------------------------------------

class Register:
    """Single shared location; write = shared_store, read = shared_load."""

    spec_cls = RegisterSpec

    def __init__(self, layout: Layout, owner: int = 0):
        self.x = layout.alloc_with_counter(owner)

    def spec(self):
        return RegisterSpec()

    def write(self, mv, v):
        yield from mv.shared_store(self.x, v, True)
        yield from mv.complete_op()
        return None

    def read(self, mv):
        v = yield from mv.shared_load(self.x, True)
        yield from mv.complete_op()
        return v

    OPS = {"write": "write", "read": "read"}


class Counter:
    """FAA counter; inc returns the old value."""

    def __init__(self, layout: Layout, owner: int = 0):
        self.x = layout.alloc_with_counter(owner)

    def spec(self):
        return CounterSpec()

    def inc(self, mv):
        old = yield from mv.shared_faa(self.x, 1, True)
        yield from mv.complete_op()
        return old

    def read(self, mv):
        v = yield from mv.shared_load(self.x, True)
        yield from mv.complete_op()
        return v


class TreiberStack:
    """Lock-free Treiber stack over preallocated node slots.

    Node slot ``s`` (1-based) has two shared locations: ``val[s]`` and
    ``next[s]``.  ``top`` holds a slot id (0 = empty).  Slots are handed to
    threads round-robin (one private free-list each) so allocation needs no
    synchronization; node fields are written with *private* stores before
    the node is published by the CAS on ``top`` (the FliT private/shared
    distinction, §6).
    """

    def __init__(self, layout: Layout, owner: int = 0, n_slots: int = 8,
                 n_threads: int = 2):
        self.top = layout.alloc_with_counter(owner)
        self.val = [None]   # 1-based
        self.next = [None]
        for _ in range(n_slots):
            self.val.append(layout.alloc_with_counter(owner))
            self.next.append(layout.alloc_with_counter(owner))
        self.n_slots = n_slots
        # per-thread free lists (round-robin slot assignment)
        self.free: Dict[int, List[int]] = {
            t: [s for s in range(1, n_slots + 1) if (s - 1) % n_threads == t]
            for t in range(n_threads)}

    def spec(self):
        return StackSpec()

    def push(self, mv, v, thread_id: int = 0):
        free = self.free.get(thread_id)
        if not free:
            raise RuntimeError("node pool exhausted — size the workload so "
                               "each thread pushes at most its pool share")
        s = free.pop()
        yield from mv.private_store(self.val[s], v, True)
        while True:
            h = yield from mv.shared_load(self.top, True)
            yield from mv.private_store(self.next[s], h, True)
            ok = yield from mv.shared_cas(self.top, h, s, True)
            if ok:
                break
        yield from mv.complete_op()
        return None

    def pop(self, mv, thread_id: int = 0):
        while True:
            h = yield from mv.shared_load(self.top, True)
            if h == NULL:
                yield from mv.complete_op()
                return EMPTY
            n = yield from mv.shared_load(self.next[h], True)
            v = yield from mv.shared_load(self.val[h], True)
            ok = yield from mv.shared_cas(self.top, h, n, True)
            if ok:
                yield from mv.complete_op()
                return v


class KVMap:
    """Fixed-key map; every key is an independent register (keys may live
    on different owners — exercises multi-machine layouts)."""

    def __init__(self, layout: Layout, n_keys: int, n_machines: int = 1):
        self.keys = [layout.alloc_with_counter(k % n_machines)
                     for k in range(n_keys)]
        self.n_keys = n_keys

    def spec(self):
        return KVSpec(self.n_keys)

    def put(self, mv, k, v):
        yield from mv.shared_store(self.keys[k], v, True)
        yield from mv.complete_op()
        return None

    def get(self, mv, k):
        v = yield from mv.shared_load(self.keys[k], True)
        yield from mv.complete_op()
        return v
