"""Proposition 1 (paper §3.4): eight simulation facts between primitives.

The paper proves these in Rocq; we verify them *exhaustively* over bounded
universes: for every reachable state γ of a small system and all machine /
location / value choices, the set of states reachable via the left-hand
label sequence (τ-interleaved) is contained in the right-hand one.

``γ →^{α1..αn} γ'`` is read as: transitions labeled α1..αn possibly
interleaved with silent τ steps (before, between, after) — implemented by
``explore.trace_final_states``.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Callable, List, Optional, Sequence, Set, Tuple

from repro.core.state import State, SystemConfig
from repro.core.semantics import (
    Label, LFlush, LStore, MStore, RFlush, RStore, Variant,
)
from repro.core.explore import reachable, trace_final_states


def _targets(cfg: SystemConfig, s: State, labels: Sequence[Label],
             variant: Variant) -> Set[State]:
    return set(trace_final_states(cfg, labels, variant, start=s))


@dataclasses.dataclass(frozen=True)
class PropItem:
    idx: int
    name: str
    # (cfg, i, j, k, x, v) -> (lhs labels, rhs labels) or None if inapplicable
    make: Callable


def _items() -> Tuple[PropItem, ...]:
    def item1(cfg, i, j, k, x, v):
        # RStore is stronger than LStore (any i)
        return [RStore(i, x, v)], [LStore(i, x, v)]

    def item2(cfg, i, j, k, x, v):
        # LStore by the OWNER is simulated by RStore by the owner
        return [LStore(k, x, v)], [RStore(k, x, v)]

    def item3(cfg, i, j, k, x, v):
        return [MStore(i, x, v)], [RStore(i, x, v)]

    def item4(cfg, i, j, k, x, v):
        return [RFlush(i, x)], [LFlush(i, x)]

    def item5(cfg, i, j, k, x, v):
        # LFlush after RStore by NON-owner is redundant
        if j == k:
            return None
        return [RStore(j, x, v)], [RStore(j, x, v), LFlush(j, x)]

    def item6(cfg, i, j, k, x, v):
        return [MStore(i, x, v)], [MStore(i, x, v), RFlush(i, x)]

    def item7(cfg, i, j, k, x, v):
        # RStore by non-owner is simulated by LStore + LFlush
        if j == k:
            return None
        return [LStore(j, x, v), LFlush(j, x)], [RStore(j, x, v)]

    def item8(cfg, i, j, k, x, v):
        return [LStore(i, x, v), RFlush(i, x)], [MStore(i, x, v)]

    return (
        PropItem(1, "RStore stronger than LStore", item1),
        PropItem(2, "owner LStore ≡ owner RStore", item2),
        PropItem(3, "MStore stronger than RStore", item3),
        PropItem(4, "RFlush stronger than LFlush", item4),
        PropItem(5, "LFlush after non-owner RStore redundant", item5),
        PropItem(6, "RFlush after MStore redundant", item6),
        PropItem(7, "non-owner RStore ≈ LStore·LFlush", item7),
        PropItem(8, "MStore ≈ LStore·RFlush", item8),
    )


PROP1_ITEMS = _items()


@dataclasses.dataclass
class PropResult:
    item: PropItem
    checked: int
    counterexample: Optional[Tuple[State, Sequence[Label], Sequence[Label],
                                   State]]

    @property
    def ok(self) -> bool:
        return self.counterexample is None


def check_prop1_item(item: PropItem, cfg: SystemConfig,
                     values: Tuple[int, ...] = (0, 1),
                     variant: Variant = Variant.BASE,
                     states: Optional[Set[State]] = None,
                     crashes_in_universe: bool = True) -> PropResult:
    """Exhaustively check one Proposition-1 item over reachable states."""
    if states is None:
        states = reachable(cfg, values, variant, crashes=crashes_in_universe)
    n, L = cfg.n_machines, cfg.n_locs
    checked = 0
    for s in states:
        for x in range(L):
            k = cfg.owner[x]
            for i, j in itertools.product(range(n), range(n)):
                for v in values:
                    pair = item.make(cfg, i, j, k, x, v)
                    if pair is None:
                        continue
                    lhs, rhs = pair
                    lhs_t = _targets(cfg, s, lhs, variant)
                    if not lhs_t:
                        continue
                    rhs_t = _targets(cfg, s, rhs, variant)
                    checked += 1
                    bad = lhs_t - rhs_t
                    if bad:
                        return PropResult(item, checked,
                                          (s, lhs, rhs, next(iter(bad))))
    return PropResult(item, checked, None)


def check_all(cfg: SystemConfig, values: Tuple[int, ...] = (0, 1),
              variant: Variant = Variant.BASE) -> List[PropResult]:
    states = reachable(cfg, values, variant)
    return [check_prop1_item(it, cfg, values, variant, states)
            for it in PROP1_ITEMS]
