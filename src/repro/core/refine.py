"""Variant refinement (paper §3.5): trace inclusion between CXL0 models.

The paper encodes the models as CSP processes and uses the FDR4 refinement
checker.  Our stand-in is the textbook construction FDR itself uses:
determinize both LTSs over the observable alphabet (subset construction,
τ-closed) and BFS the product — a trace of ``sub`` escapes ``sup`` iff some
reachable pair has a label enabled in ``sub``'s subset but not ``sup``'s.
This decides full trace inclusion (all depths, to fixpoint), not a bounded
approximation.

Expected results (paper §3.5):
* traces(PSN) ⊆ traces(BASE) and traces(LWB) ⊆ traces(BASE);
* PSN ⊄ LWB (witness: litmus test 10) and LWB ⊄ PSN (witness: test 12),
  i.e. the two hardware variants are incomparable.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, FrozenSet, List, Optional, Tuple

from repro.core.state import State, SystemConfig, make_config, initial_state
from repro.core.semantics import (
    Crash, Label, LFlush, Load, LStore, MStore, RFlush, RStore, Variant,
    apply_label,
)
from repro.core.explore import tau_closure

Subset = FrozenSet[State]


def default_alphabet(cfg: SystemConfig,
                     values: Tuple[int, ...] = (0, 1)) -> List[Label]:
    """Observable alphabet: stores / loads (with observed value) / flushes /
    crashes.  Loads carry the observed value so the DFA is deterministic."""
    labs: List[Label] = []
    ms, locs = range(cfg.n_machines), range(cfg.n_locs)
    for i, x in itertools.product(ms, locs):
        for v in values:
            labs.append(LStore(i, x, v))
            labs.append(RStore(i, x, v))
            labs.append(MStore(i, x, v))
            labs.append(Load(i, x, v))
        labs.append(LFlush(i, x))
        labs.append(RFlush(i, x))
    for i in ms:
        labs.append(Crash(i))
    return labs


class _DetLTS:
    """τ-closed subset-construction view of one CXL0 variant."""

    def __init__(self, cfg: SystemConfig, variant: Variant):
        self.cfg, self.variant = cfg, variant
        self._closure_cache: Dict[State, FrozenSet[State]] = {}

    def closure(self, s: State) -> FrozenSet[State]:
        got = self._closure_cache.get(s)
        if got is None:
            got = frozenset(tau_closure(self.cfg, s))
            self._closure_cache[s] = got
        return got

    def initial(self) -> Subset:
        return self.closure(initial_state(self.cfg))

    def post(self, sub: Subset, lab: Label) -> Subset:
        out = set()
        for s in sub:
            s2 = apply_label(self.cfg, s, lab, self.variant)
            if s2 is not None:
                out.update(self.closure(s2))
        return frozenset(out)


@dataclasses.dataclass
class RefinementResult:
    sub: Variant
    sup: Variant
    explored_pairs: int
    witness: Optional[Tuple[str, ...]]        # a trace of sub not in sup

    @property
    def refines(self) -> bool:
        return self.witness is None


def check_refinement(sub: Variant, sup: Variant,
                     cfg: Optional[SystemConfig] = None,
                     values: Tuple[int, ...] = (0, 1),
                     max_pairs: int = 500_000) -> RefinementResult:
    """Full trace-language inclusion traces(sub) ⊆ traces(sup)."""
    cfg = cfg or make_config(2, 1)
    alphabet = default_alphabet(cfg, values)
    A, B = _DetLTS(cfg, sub), _DetLTS(cfg, sup)
    start = (A.initial(), B.initial())
    seen = {start}
    frontier: List[Tuple[Tuple[Subset, Subset], Tuple[str, ...]]] = [
        (start, ())]
    explored = 0
    while frontier:
        nxt = []
        for (sa, sb), trace in frontier:
            explored += 1
            if explored > max_pairs:
                raise RuntimeError("refinement product exceeds bound")
            for lab in alphabet:
                pa = A.post(sa, lab)
                if not pa:
                    continue
                pb = B.post(sb, lab)
                tr = trace + (repr(lab),)
                if not pb:
                    return RefinementResult(sub, sup, explored, tr)
                pair = (pa, pb)
                if pair not in seen:
                    seen.add(pair)
                    nxt.append((pair, tr))
        frontier = nxt
    return RefinementResult(sub, sup, explored, None)


def check_all_refinements(cfg: Optional[SystemConfig] = None) -> dict:
    """The paper's comparison matrix: variants ⊑ BASE; PSN vs LWB both ways."""
    cfg = cfg or make_config(2, 1)
    out = {}
    for sub, sup in [(Variant.PSN, Variant.BASE), (Variant.LWB, Variant.BASE),
                     (Variant.BASE, Variant.PSN), (Variant.BASE, Variant.LWB),
                     (Variant.PSN, Variant.LWB), (Variant.LWB, Variant.PSN)]:
        out[(sub.value, sup.value)] = check_refinement(sub, sup, cfg)
    return out
