"""CXL0 operational semantics (paper §3.3, Fig. 2) + variants (§3.5).

Every step of the labeled transition system is a function
``State -> Optional[State]`` (None = the step is not enabled).  Labels:

* machine actions:  LStore_i(x,v)  RStore_i(x,v)  MStore_i(x,v)
                    Load_i(x,v)    LFlush_i(x)    RFlush_i(x)   GPF_i
                    {L,R,M}-RMW_i(x, old, new)
* silent internal propagation τ:  PropCC(i,x)  (cache→owner-cache) and
                                  PropCM(x)    (owner-cache→memory)
* crash:  f_i

Variants:
* ``Variant.BASE`` — the CXL0 model of §3.3.
* ``Variant.PSN``  — crash poisons the crashed machine's addresses in all
  caches (CXL Isolation / MemData-NXM, §3.5).
* ``Variant.LWB``  — remote loads with implicit write-back: LOAD-from-C is
  restricted to the *own* cache; any other load must wait until no cache
  holds the line and read memory (§3.5).

Flushes are modeled as *blocking* preconditions (the MFENCE-in-TSO trick the
paper cites): ``LFlush_i(x)`` is enabled only once ``C_i(x) = ⊥``,
``RFlush_i(x)`` once no cache holds ``x``; nondeterministic τ steps do the
actual draining.  ``step_with_tau`` resolves the blocking by scheduling the
necessary propagation, which is what program-level simulators use.
"""
from __future__ import annotations

import dataclasses
import enum
import itertools
from typing import Iterator, List, Optional, Tuple

from repro.core.state import BOT, State, SystemConfig


class Variant(enum.Enum):
    BASE = "base"
    PSN = "psn"        # crash with cache-line poisoning
    LWB = "lwb"        # remote loads with implicit write-back


# ---------------------------------------------------------------------------
# Labels
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Label:
    kind: str                       # lstore|rstore|mstore|load|lflush|rflush|
    #                                 gpf|rmw|tau_cc|tau_cm|crash
    machine: Optional[int] = None
    loc: Optional[int] = None
    val: Optional[int] = None       # store value / observed load value
    old: Optional[int] = None       # rmw expected value
    rmw_store: Optional[str] = None  # 'l'|'r'|'m' for RMW store flavor

    def __repr__(self):
        a = [k for k in ("machine", "loc", "val", "old") if getattr(self, k) is not None]
        args = ",".join(f"{k}={getattr(self, k)}" for k in a)
        tag = f"{self.kind}" + (f"[{self.rmw_store}]" if self.rmw_store else "")
        return f"{tag}({args})"


def LStore(i, x, v):  return Label("lstore", i, x, v)
def RStore(i, x, v):  return Label("rstore", i, x, v)
def MStore(i, x, v):  return Label("mstore", i, x, v)
def Load(i, x, v=None): return Label("load", i, x, v)
def LFlush(i, x):     return Label("lflush", i, x)
def RFlush(i, x):     return Label("rflush", i, x)
def GPF(i):           return Label("gpf", i)
def Crash(i):         return Label("crash", i)
def RMW(i, x, old, new, flavor="l"):
    return Label("rmw", i, x, new, old, rmw_store=flavor)
def TauCC(i, x):      return Label("tau_cc", i, x)
def TauCM(x):         return Label("tau_cm", None, x)


# ---------------------------------------------------------------------------
# Individual steps (Fig. 2)
# ---------------------------------------------------------------------------

def step_lstore(cfg: SystemConfig, s: State, i: int, x: int, v: int) -> State:
    """LStore_i(x,v): C_i(x) := v; invalidate x in all other caches."""
    return s.invalidate_others(i, x).set_cache(i, x, v)


def step_rstore(cfg: SystemConfig, s: State, i: int, x: int, v: int) -> State:
    """RStore_i(x,v): C_k(x) := v for the owner k; invalidate elsewhere."""
    k = cfg.owner[x]
    return s.invalidate_others(k, x).set_cache(k, x, v)


def step_mstore(cfg: SystemConfig, s: State, i: int, x: int, v: int) -> State:
    """MStore_i(x,v): M_k(x) := v; invalidate x in ALL caches."""
    return s.invalidate_others(None, x).set_mem(x, v)


def step_load(cfg: SystemConfig, s: State, i: int, x: int,
              variant: Variant = Variant.BASE) -> Optional[Tuple[State, int]]:
    """Load_i(x): returns (state', observed value) or None if blocked (LWB).

    BASE/PSN — LOAD-from-C: if any cache holds x, read that value and copy it
    into C_i (enables a future LFlush_i); LOAD-from-M otherwise (no state
    change).  LWB — own-cache hit reads without copying; otherwise blocked
    until no cache holds x, then LOAD-from-M.
    """
    if variant is Variant.LWB:
        own = s.C[i][x]
        if own is not BOT:
            return s, own
        if s.cached_anywhere(x):
            return None                       # blocked: must drain first
        return s, s.M[x]
    v = s.cached_value(x)
    if v is not BOT:
        return s.set_cache(i, x, v), v
    return s, s.M[x]


def step_lflush(cfg: SystemConfig, s: State, i: int, x: int) -> Optional[State]:
    """LFlush_i(x): enabled once C_i(x) = ⊥ (blocking-precondition model)."""
    return s if s.C[i][x] is BOT else None


def step_rflush(cfg: SystemConfig, s: State, i: int, x: int) -> Optional[State]:
    """RFlush_i(x): enabled once no cache holds x."""
    return s if not s.cached_anywhere(x) else None


def step_gpf(cfg: SystemConfig, s: State, i: int) -> Optional[State]:
    """GPF_i: enabled once ALL caches are fully drained (global RFlush)."""
    all_empty = all(v is BOT for row in s.C for v in row)
    return s if all_empty else None


def step_tau_cc(cfg: SystemConfig, s: State, i: int, x: int) -> Optional[State]:
    """Horizontal propagation: C_i(x) moves to the owner's cache, i ≠ owner."""
    k = cfg.owner[x]
    if i == k or s.C[i][x] is BOT:
        return None
    v = s.C[i][x]
    return s.set_cache(i, x, BOT).set_cache(k, x, v)


def step_tau_cm(cfg: SystemConfig, s: State, x: int) -> Optional[State]:
    """Vertical propagation: owner's cached value reaches owner's memory and
    is removed from ALL caches."""
    k = cfg.owner[x]
    if s.C[k][x] is BOT:
        return None
    v = s.C[k][x]
    return s.invalidate_others(None, x).set_mem(x, v)


def step_crash(cfg: SystemConfig, s: State, i: int,
               variant: Variant = Variant.BASE) -> State:
    """f_i: machine i loses its cache; volatile M_i resets to 0.
    PSN additionally poisons (⊥) i's addresses in every other cache."""
    C = list(s.C)
    C[i] = tuple(BOT for _ in range(cfg.n_locs))
    if variant is Variant.PSN:
        for j in range(cfg.n_machines):
            if j == i:
                continue
            C[j] = tuple(BOT if cfg.owner[x] == i else v
                         for x, v in enumerate(C[j]))
    M = s.M
    if cfg.volatile[i]:
        M = tuple(0 if cfg.owner[x] == i else v for x, v in enumerate(M))
    return State(tuple(C), M)


def step_rmw(cfg: SystemConfig, s: State, i: int, x: int, old: int, new: int,
             flavor: str = "l",
             variant: Variant = Variant.BASE) -> Optional[Tuple[State, bool]]:
    """Atomic load+store (§3.3).  Returns (state', success) or None (blocked).

    The load half observes the cached value if one exists, else memory (under
    LWB a non-own cached value blocks, as for Load).  On CAS failure
    (observed ≠ old) the RMW degenerates to a plain read.  On success the
    store half is an {L,R,M}Store of ``new`` according to ``flavor``.
    """
    loaded = step_load(cfg, s, i, x, variant)
    if loaded is None:
        return None
    _, v = loaded
    if v != old:
        # failed CAS ≡ plain read (paper §3.3) — incl. the load's cache copy
        return loaded[0], False
    if flavor == "l":
        return step_lstore(cfg, s, i, x, new), True
    if flavor == "r":
        return step_rstore(cfg, s, i, x, new), True
    if flavor == "m":
        return step_mstore(cfg, s, i, x, new), True
    raise ValueError(flavor)


def step_faa(cfg: SystemConfig, s: State, i: int, x: int, delta: int,
             flavor: str = "l",
             variant: Variant = Variant.BASE) -> Optional[Tuple[State, int]]:
    """Fetch-and-add, an always-succeeding RMW. Returns (state', old value)."""
    loaded = step_load(cfg, s, i, x, variant)
    if loaded is None:
        return None
    _, v = loaded
    new = v + delta
    if flavor == "l":
        return step_lstore(cfg, s, i, x, new), v
    if flavor == "r":
        return step_rstore(cfg, s, i, x, new), v
    if flavor == "m":
        return step_mstore(cfg, s, i, x, new), v
    raise ValueError(flavor)


# ---------------------------------------------------------------------------
# Generic transition application + enumeration
# ---------------------------------------------------------------------------

def apply_label(cfg: SystemConfig, s: State, lab: Label,
                variant: Variant = Variant.BASE) -> Optional[State]:
    """Apply one labeled transition; None if not enabled / not observable.

    For ``load`` labels with ``val`` set, the step is enabled only when the
    observed value matches (litmus-test style); with ``val=None`` any
    observation is allowed.
    """
    k = lab.kind
    if k == "lstore":
        return step_lstore(cfg, s, lab.machine, lab.loc, lab.val)
    if k == "rstore":
        return step_rstore(cfg, s, lab.machine, lab.loc, lab.val)
    if k == "mstore":
        return step_mstore(cfg, s, lab.machine, lab.loc, lab.val)
    if k == "load":
        r = step_load(cfg, s, lab.machine, lab.loc, variant)
        if r is None:
            return None
        s2, v = r
        if lab.val is not None and v != lab.val:
            return None
        return s2
    if k == "lflush":
        return step_lflush(cfg, s, lab.machine, lab.loc)
    if k == "rflush":
        return step_rflush(cfg, s, lab.machine, lab.loc)
    if k == "gpf":
        return step_gpf(cfg, s, lab.machine)
    if k == "crash":
        return step_crash(cfg, s, lab.machine, variant)
    if k == "rmw":
        r = step_rmw(cfg, s, lab.machine, lab.loc, lab.old, lab.val,
                     lab.rmw_store or "l", variant)
        return None if r is None else r[0]
    if k == "tau_cc":
        return step_tau_cc(cfg, s, lab.machine, lab.loc)
    if k == "tau_cm":
        return step_tau_cm(cfg, s, lab.loc)
    raise ValueError(k)


def tau_steps(cfg: SystemConfig, s: State) -> Iterator[Tuple[Label, State]]:
    """All enabled silent propagation steps from s."""
    for x in range(cfg.n_locs):
        for i in range(cfg.n_machines):
            s2 = step_tau_cc(cfg, s, i, x)
            if s2 is not None:
                yield TauCC(i, x), s2
        s2 = step_tau_cm(cfg, s, x)
        if s2 is not None:
            yield TauCM(x), s2


def tau_closure(cfg: SystemConfig, s: State) -> List[State]:
    """All states reachable from s via τ* (BFS; state spaces here are small)."""
    seen = {s}
    frontier = [s]
    while frontier:
        nxt = []
        for st in frontier:
            for _, st2 in tau_steps(cfg, st):
                if st2 not in seen:
                    seen.add(st2)
                    nxt.append(st2)
        frontier = nxt
    return list(seen)


def step_with_tau(cfg: SystemConfig, s: State, lab: Label,
                  variant: Variant = Variant.BASE) -> List[State]:
    """All states reachable by τ* · lab  (the paper's ⟶^{α} with silent steps).

    This is how blocking flushes actually execute: the scheduler interleaves
    the propagation steps needed to satisfy the precondition.
    """
    out = []
    seen = set()
    for st in tau_closure(cfg, s):
        s2 = apply_label(cfg, st, lab, variant)
        if s2 is not None and s2 not in seen:
            seen.add(s2)
            out.append(s2)
    return out


def enabled_labels(cfg: SystemConfig, s: State, values: Tuple[int, ...],
                   variant: Variant = Variant.BASE,
                   crashes: bool = True) -> Iterator[Tuple[Label, State]]:
    """Enumerate every enabled non-silent transition over a small value set.

    Used by the bounded explorer (props / refinement). ``values`` bounds the
    store-value alphabet.
    """
    n, L = cfg.n_machines, cfg.n_locs
    for i, x in itertools.product(range(n), range(L)):
        for v in values:
            yield LStore(i, x, v), step_lstore(cfg, s, i, x, v)
            yield RStore(i, x, v), step_rstore(cfg, s, i, x, v)
            yield MStore(i, x, v), step_mstore(cfg, s, i, x, v)
        r = step_load(cfg, s, i, x, variant)
        if r is not None:
            s2, v = r
            yield Load(i, x, v), s2
        s2 = step_lflush(cfg, s, i, x)
        if s2 is not None:
            yield LFlush(i, x), s2
        s2 = step_rflush(cfg, s, i, x)
        if s2 is not None:
            yield RFlush(i, x), s2
    for i in range(n):
        s2 = step_gpf(cfg, s, i)
        if s2 is not None:
            yield GPF(i), s2
        if crashes:
            yield Crash(i), step_crash(cfg, s, i, variant)
