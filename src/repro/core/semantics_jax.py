"""CXL0 as a composable JAX module: vectorized executable semantics.

The Python LTS (``core.semantics``) is the reference; this module is its
JAX twin for *scale*: states are arrays, one scheduler step is a pure
``jax.lax``-branched function, whole schedules run under ``lax.scan`` and
thousands of random schedules run in parallel under ``vmap`` (the fuzzing
rig used by the property tests, and the engine behind
``benchmarks/bench_model_fuzz.py``).

Encoding
--------
* ``C``: (N, L) int32, value or ``BOT = -1``
* ``M``: (L,) int32 (owner map is static)
* actions: (5,) int32 ``[kind, machine, loc, val, flavor]`` with kinds from
  ``ACT``.  Disabled/blocked actions are no-ops (deterministic *effective*
  semantics: flushes drain eagerly — the same executable interpretation the
  Python ``Simulator`` uses; the blocking LTS view lives in
  ``core.semantics``).

Loads write their observed value into the per-step output so schedules
return full observation traces.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp

BOT = -1

ACT = dict(noop=0, lstore=1, rstore=2, mstore=3, load=4, lflush=5, rflush=6,
           tau_cc=7, tau_cm=8, crash=9, faa=10)
FLAVOR = dict(l=0, r=1, m=2)


@dataclasses.dataclass(frozen=True)
class JaxSystem:
    """Static system description (owner map, volatility)."""
    owner: Tuple[int, ...]
    volatile: Tuple[bool, ...]
    n_machines: int

    @property
    def n_locs(self) -> int:
        return len(self.owner)

    def owner_arr(self):
        return jnp.asarray(self.owner, jnp.int32)

    def volatile_arr(self):
        return jnp.asarray(self.volatile, jnp.bool_)


def initial_arrays(sys: JaxSystem):
    C = jnp.full((sys.n_machines, sys.n_locs), BOT, jnp.int32)
    M = jnp.zeros((sys.n_locs,), jnp.int32)
    return C, M


# ---------------------------------------------------------------------------
# Primitive steps (pure functions on (C, M))
# ---------------------------------------------------------------------------

def _invalidate_others(C, keep_machine, x):
    col = C[:, x]
    keep = jnp.arange(C.shape[0]) == keep_machine
    return C.at[:, x].set(jnp.where(keep, col, BOT))


def _lstore(sys, C, M, i, x, v):
    C = _invalidate_others(C, i, x)
    return C.at[i, x].set(v), M


def _rstore(sys, C, M, i, x, v):
    k = sys.owner_arr()[x]
    C = _invalidate_others(C, k, x)
    return C.at[k, x].set(v), M


def _mstore(sys, C, M, i, x, v):
    C = _invalidate_others(C, -1, x)            # -1 matches no machine
    return C, M.at[x].set(v)


def _cached_value(C, x):
    col = C[:, x]
    any_valid = jnp.any(col != BOT)
    val = jnp.max(jnp.where(col != BOT, col, jnp.iinfo(jnp.int32).min))
    return any_valid, val


def _load(sys, C, M, i, x):
    any_valid, val = _cached_value(C, x)
    out = jnp.where(any_valid, val, M[x])
    # LOAD-from-C copies the value into C_i
    C = jnp.where(any_valid, C.at[i, x].set(out), C)
    return C, M, out


def _drain_to_owner(sys, C, M, x):
    """Move any cached value of x fully to the owner's memory (rflush)."""
    any_valid, val = _cached_value(C, x)
    C = _invalidate_others(C, -1, x)
    M = jnp.where(any_valid, M.at[x].set(val), M)
    return C, M


def _lflush(sys, C, M, i, x):
    """Eager LFlush: push C_i(x) one level (owner cache, or memory if owner)."""
    k = sys.owner_arr()[x]
    v = C[i, x]
    has = v != BOT
    is_owner = i == k
    # non-owner: value moves to owner's cache
    C_cc = C.at[i, x].set(BOT).at[k, x].set(v)
    # owner: value moves to memory, all caches invalidated
    C_cm = _invalidate_others(C, -1, x)
    M_cm = M.at[x].set(v)
    C2 = jnp.where(has, jnp.where(is_owner, C_cm, C_cc), C)
    M2 = jnp.where(has & is_owner, M_cm, M)
    return C2, M2


def _rflush(sys, C, M, i, x):
    return _drain_to_owner(sys, C, M, x)


def _tau_cc(sys, C, M, i, x):
    k = sys.owner_arr()[x]
    v = C[i, x]
    ok = (v != BOT) & (i != k)
    C2 = C.at[i, x].set(BOT).at[k, x].set(v)
    return jnp.where(ok, C2, C), M


def _tau_cm(sys, C, M, i, x):
    k = sys.owner_arr()[x]
    v = C[k, x]
    ok = v != BOT
    C2 = _invalidate_others(C, -1, x)
    M2 = M.at[x].set(v)
    return jnp.where(ok, C2, C), jnp.where(ok, M2, M)


def _crash(sys, C, M, i, x):
    C = C.at[i, :].set(BOT)
    owned = sys.owner_arr() == i
    M = jnp.where(owned & sys.volatile_arr()[i], jnp.zeros_like(M), M)
    return C, M


def _faa(sys, C, M, i, x, d, flavor):
    """FAA: atomic load + flavored store. Returns (C, M, old)."""
    _, _, old = _load(sys, C, M, i, x)       # (no cache copy for RMW load)
    new = old + d
    Cl, Ml = _lstore(sys, C, M, i, x, new)
    Cr, Mr = _rstore(sys, C, M, i, x, new)
    Cm, Mm = _mstore(sys, C, M, i, x, new)
    C2 = jnp.where(flavor == 0, Cl, jnp.where(flavor == 1, Cr, Cm))
    M2 = jnp.where(flavor == 0, Ml, jnp.where(flavor == 1, Mr, Mm))
    return C2, M2, old


# ---------------------------------------------------------------------------
# One scheduler step + schedule runner
# ---------------------------------------------------------------------------

def step(sys: JaxSystem, C, M, action):
    """action: (5,) int32 [kind, machine, loc, val, flavor] -> (C, M, obs)."""
    kind, i, x, v, fl = (action[0], action[1], action[2], action[3],
                         action[4])
    obs0 = jnp.int32(BOT)

    # branches as index-switched pure functions
    def b_noop(_):   return C, M, obs0
    def b_lstore(_): C2, M2 = _lstore(sys, C, M, i, x, v); return C2, M2, obs0
    def b_rstore(_): C2, M2 = _rstore(sys, C, M, i, x, v); return C2, M2, obs0
    def b_mstore(_): C2, M2 = _mstore(sys, C, M, i, x, v); return C2, M2, obs0
    def b_load(_):   C2, M2, o = _load(sys, C, M, i, x); return C2, M2, o
    def b_lflush(_): C2, M2 = _lflush(sys, C, M, i, x); return C2, M2, obs0
    def b_rflush(_): C2, M2 = _rflush(sys, C, M, i, x); return C2, M2, obs0
    def b_taucc(_):  C2, M2 = _tau_cc(sys, C, M, i, x); return C2, M2, obs0
    def b_taucm(_):  C2, M2 = _tau_cm(sys, C, M, i, x); return C2, M2, obs0
    def b_crash(_):  C2, M2 = _crash(sys, C, M, i, x); return C2, M2, obs0
    def b_faa(_):    C2, M2, o = _faa(sys, C, M, i, x, v, fl); return C2, M2, o

    return jax.lax.switch(
        jnp.clip(kind, 0, 10), [b_noop, b_lstore, b_rstore, b_mstore, b_load,
                                b_lflush, b_rflush, b_taucc, b_taucm,
                                b_crash, b_faa], None)


@partial(jax.jit, static_argnums=0)
def run_schedule(sys: JaxSystem, actions):
    """actions: (T, 5) int32. Returns final (C, M) and per-step observations."""
    C, M = initial_arrays(sys)

    def body(carry, a):
        C, M = carry
        C, M, obs = step(sys, C, M, a)
        return (C, M), obs

    (C, M), obs = jax.lax.scan(body, (C, M), actions)
    return C, M, obs


@partial(jax.jit, static_argnums=0)
def run_schedules(sys: JaxSystem, batched_actions):
    """(B, T, 5) → vmapped runs: final Cs, Ms, observations (B, T)."""
    return jax.vmap(lambda a: run_schedule(sys, a))(batched_actions)


def random_schedules(sys: JaxSystem, key, batch: int, length: int,
                     max_val: int = 4, p_crash: float = 0.02):
    """Random action tensors for fuzzing (kind-weighted)."""
    ks = jax.random.split(key, 5)
    kinds = jax.random.choice(
        ks[0], jnp.asarray([ACT["lstore"], ACT["rstore"], ACT["mstore"],
                            ACT["load"], ACT["lflush"], ACT["rflush"],
                            ACT["tau_cc"], ACT["tau_cm"], ACT["faa"]],
                           jnp.int32),
        (batch, length),
        p=jnp.asarray([.2, .1, .1, .25, .05, .05, .1, .05, .1]))
    crash_mask = jax.random.bernoulli(ks[1], p_crash, (batch, length))
    kinds = jnp.where(crash_mask, ACT["crash"], kinds)
    machines = jax.random.randint(ks[2], (batch, length), 0, sys.n_machines)
    locs = jax.random.randint(ks[3], (batch, length), 0, sys.n_locs)
    vals = jax.random.randint(ks[4], (batch, length), 0, max_val)
    flavors = jnp.zeros((batch, length), jnp.int32)
    return jnp.stack([kinds, machines, locs, vals, flavors],
                     axis=-1).astype(jnp.int32)
