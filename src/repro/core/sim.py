"""Concurrent simulator: threads over the CXL0 LTS with crash injection.

Threads are generators yielding primitive requests (see ``core.flit``); the
simulator owns the ``State`` and drives a randomized schedule:

* pick a runnable thread, execute its next primitive;
* with probability ``p_tau`` interleave a random silent propagation step
  (nondeterministic cache eviction — the dotted lines of the paper's Fig. 1);
* with probability ``p_crash`` (bounded by ``max_crashes``) crash a machine:
  its cache is lost, its memory reset if volatile, and every thread homed on
  it dies mid-operation (the op stays *pending* in the history);
* ``respect_atomic=True`` (default) honors the views' store→flush
  failure-atomic sections — the paper's synchronous-flush assumption (§B
  Condition 2): crashes are deferred while any thread is inside one.
  ``respect_atomic=False`` exposes the window (see the FINDING tests:
  Alg. 2 is NOT durable under unrestricted partial crashes);
* crashed machines recover after ``recovery_delay`` scheduler ticks and then
  run their remaining operations on fresh thread ids (the paper's "new
  threads with new and distinct identifiers").

Blocking primitives (LFlush/RFlush/GPF, LWB loads) are resolved by forcing
the required propagation steps — semantically these are just the τ steps the
blocking precondition waits for.

The output is a ``History`` of invocation/response/crash events for the
durable-linearizability checker (``repro.core.durable``).
"""
from __future__ import annotations

import dataclasses
import random
from typing import Callable, Dict, Generator, List, Optional, Tuple

from repro.core.state import BOT, State, SystemConfig, initial_state
from repro.core.semantics import (
    Variant, step_crash, step_faa, step_load, step_lstore, step_mstore,
    step_rmw, step_rstore, step_tau_cc, step_tau_cm, tau_steps,
)


# ---------------------------------------------------------------------------
# History events
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Event:
    kind: str                  # "inv" | "res" | "crash"
    thread: Optional[int] = None
    op_id: Optional[int] = None
    op: Optional[str] = None
    args: Tuple = ()
    result: object = None
    machine: Optional[int] = None

    def __repr__(self):
        if self.kind == "crash":
            return f"crash(m{self.machine})"
        if self.kind == "inv":
            return f"inv[{self.op_id}] t{self.thread}.{self.op}{self.args}"
        return f"res[{self.op_id}] -> {self.result}"


History = List[Event]


# ---------------------------------------------------------------------------
# The simulator
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ThreadCtx:
    thread_id: int
    machine: int
    ops: List[Tuple[str, Callable, Tuple]]   # (op name, generator fn, args)
    gen: Optional[Generator] = None
    pending_result: object = None            # result to send into gen
    cur_op: int = 0
    cur_op_id: Optional[int] = None
    done: bool = False
    atomic_depth: int = 0                    # inside a store→flush section


class Simulator:
    def __init__(self, cfg: SystemConfig, *, variant: Variant = Variant.BASE,
                 seed: int = 0, p_tau: float = 0.3, p_crash: float = 0.0,
                 max_crashes: int = 0, recovery_delay: int = 4,
                 crashable=None, respect_atomic: bool = True):
        self.cfg = cfg
        self.variant = variant
        self.rng = random.Random(seed)
        self.state = initial_state(cfg)
        self.p_tau = p_tau
        self.p_crash = p_crash
        self.max_crashes = max_crashes
        self.recovery_delay = recovery_delay
        self.crashable = (list(crashable) if crashable is not None
                          else list(range(cfg.n_machines)))
        self.respect_atomic = respect_atomic
        self.history: History = []
        self.threads: List[ThreadCtx] = []
        self.n_crashes = 0
        self._op_counter = 0
        self._thread_counter = 0
        self._recovering: List[Tuple[int, ThreadCtx]] = []  # (ready_tick, ctx)
        self._tick = 0

    # -- thread management ---------------------------------------------------
    def spawn(self, machine: int, ops) -> ThreadCtx:
        ctx = ThreadCtx(self._thread_counter, machine, list(ops))
        self._thread_counter += 1
        self.threads.append(ctx)
        return ctx

    # -- primitive execution --------------------------------------------------
    def _force_drain_one(self, x: int):
        """Apply one propagation step moving x toward its owner's memory."""
        k = self.cfg.owner[x]
        holders = self.state.holders(x)
        non_owner = [i for i in holders if i != k]
        if non_owner:
            self.state = step_tau_cc(self.cfg, self.state,
                                     self.rng.choice(non_owner), x)
        elif k in holders:
            self.state = step_tau_cm(self.cfg, self.state, x)

    def _exec(self, machine: int, req, ctx: Optional[ThreadCtx] = None) -> object:
        op = req[0]
        if op == "atomic_begin":
            if ctx is not None:
                ctx.atomic_depth += 1
            return None
        if op == "atomic_end":
            if ctx is not None:
                ctx.atomic_depth = max(0, ctx.atomic_depth - 1)
            return None
        s = self.state
        if op == "load":
            x = req[1]
            if self.variant is Variant.LWB:
                # drain until the LWB load is enabled
                while True:
                    r = step_load(self.cfg, s, machine, x, self.variant)
                    if r is not None:
                        break
                    self._force_drain_one(x)
                    s = self.state
            else:
                r = step_load(self.cfg, s, machine, x, self.variant)
            self.state, v = r
            return v
        if op == "lstore":
            self.state = step_lstore(self.cfg, s, machine, req[1], req[2])
            return None
        if op == "rstore":
            self.state = step_rstore(self.cfg, s, machine, req[1], req[2])
            return None
        if op == "mstore":
            self.state = step_mstore(self.cfg, s, machine, req[1], req[2])
            return None
        if op == "lflush":
            x = req[1]
            while self.state.C[machine][x] is not BOT:
                self._force_drain_one(x)
            return None
        if op == "rflush":
            x = req[1]
            while self.state.cached_anywhere(x):
                self._force_drain_one(x)
            return None
        if op == "gpf":
            for x in range(self.cfg.n_locs):
                while self.state.cached_anywhere(x):
                    self._force_drain_one(x)
            return None
        if op == "faa":
            _, x, d, flavor = req
            while True:
                r = step_faa(self.cfg, self.state, machine, x, d, flavor,
                             self.variant)
                if r is not None:
                    break
                self._force_drain_one(x)
            self.state, old = r
            return old
        if op == "cas":
            _, x, old, new, flavor = req
            while True:
                r = step_rmw(self.cfg, self.state, machine, x, old, new,
                             flavor, self.variant)
                if r is not None:
                    break
                self._force_drain_one(x)
            self.state, ok = r
            return ok
        raise ValueError(req)

    # -- crash / recovery ------------------------------------------------------
    def crash_machine(self, m: int):
        self.state = step_crash(self.cfg, self.state, m, self.variant)
        self.history.append(Event("crash", machine=m))
        self.n_crashes += 1
        for ctx in self.threads:
            if ctx.machine == m and not ctx.done:
                if ctx.gen is not None:
                    ctx.gen.close()
                # ops from cur_op (+1 if mid-op: that op stays pending) resume
                # on a NEW thread id after recovery
                resume_from = ctx.cur_op + (1 if ctx.gen is not None else 0)
                ctx.done = True
                remaining = ctx.ops[resume_from:]
                if remaining:
                    new_ctx = ThreadCtx(self._thread_counter, m, remaining)
                    self._thread_counter += 1
                    self._recovering.append(
                        (self._tick + self.recovery_delay, new_ctx))

    def _maybe_recover(self):
        still = []
        for ready, ctx in self._recovering:
            if ready <= self._tick:
                self.threads.append(ctx)
            else:
                still.append((ready, ctx))
        self._recovering = still

    # -- one scheduling tick ----------------------------------------------------
    def _runnable(self) -> List[ThreadCtx]:
        return [t for t in self.threads if not t.done]

    def step_thread(self, ctx: ThreadCtx):
        if ctx.gen is None:
            if ctx.cur_op >= len(ctx.ops):
                ctx.done = True
                return
            name, fn, args = ctx.ops[ctx.cur_op]
            ctx.cur_op_id = self._op_counter
            self._op_counter += 1
            self.history.append(Event("inv", ctx.thread_id, ctx.cur_op_id,
                                      name, tuple(args)))
            ctx.gen = fn(*args)
            ctx.pending_result = None
        try:
            req = ctx.gen.send(ctx.pending_result)
        except StopIteration as fin:
            self.history.append(Event("res", ctx.thread_id, ctx.cur_op_id,
                                      result=fin.value))
            ctx.gen = None
            ctx.cur_op += 1
            if ctx.cur_op >= len(ctx.ops):
                ctx.done = True
            return
        ctx.pending_result = self._exec(ctx.machine, req, ctx)

    def run(self, max_ticks: int = 100_000):
        while True:
            self._tick += 1
            self._maybe_recover()
            runnable = self._runnable()
            if not runnable and not self._recovering:
                break
            if self._tick > max_ticks:
                raise RuntimeError("simulation did not terminate")
            # random silent eviction (nondeterministic propagation)
            if self.rng.random() < self.p_tau:
                taus = list(tau_steps(self.cfg, self.state))
                if taus:
                    _, self.state = self.rng.choice(taus)
            # random crash (deferred while a store→flush section is open
            # when respect_atomic — the paper's synchronous-flush assumption)
            atomic_open = self.respect_atomic and any(
                t.atomic_depth > 0 for t in self.threads if not t.done)
            if (self.n_crashes < self.max_crashes and not atomic_open
                    and self.rng.random() < self.p_crash and self.crashable):
                self.crash_machine(self.rng.choice(self.crashable))
                continue
            if runnable:
                self.step_thread(self.rng.choice(runnable))
        return self.history
