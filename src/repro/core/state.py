"""CXL0 system states (paper §3.3).

A state is a pair ``(C, M)``:

* ``C`` maps each machine ``i`` to its local cache ``C_i : Loc -> Val ⊎ {⊥}``
* ``M`` maps each machine ``i`` to its local memory ``M_i : Loc_i -> Val``

Locations are integers ``0..n_locs-1``; each is owned by exactly one machine
(``SystemConfig.owner``).  Values are small ints; ``BOT = None`` stands for ⊥.
States are immutable and hashable so the explorer can enumerate state spaces.

The global cache invariant (paper §3.3) is checked by ``check_invariant``:

    ∀ i, j, x.  C_i(x) ≠ ⊥ ∧ C_j(x) ≠ ⊥  ⇒  C_i(x) = C_j(x)
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

BOT = None            # ⊥ — the invalid cache value
INIT_VAL = 0          # the distinguished initial value "0" (paper §3.3)

Val = int
CacheRow = Tuple[Optional[Val], ...]     # one machine's cache over all locs


@dataclasses.dataclass(frozen=True)
class SystemConfig:
    """Static topology: who owns which location, which memories persist."""
    n_machines: int
    owner: Tuple[int, ...]               # owner[x] = machine owning loc x
    volatile: Tuple[bool, ...]           # volatile[i] -> M_i lost on crash

    def __post_init__(self):
        assert len(self.volatile) == self.n_machines
        assert all(0 <= o < self.n_machines for o in self.owner)

    @property
    def n_locs(self) -> int:
        return len(self.owner)

    def locs_of(self, i: int) -> Tuple[int, ...]:
        return tuple(x for x, o in enumerate(self.owner) if o == i)


def make_config(n_machines: int, locs_per_machine, volatile=None) -> SystemConfig:
    """``locs_per_machine``: int (same for all) or per-machine list."""
    if isinstance(locs_per_machine, int):
        locs_per_machine = [locs_per_machine] * n_machines
    owner = tuple(i for i, k in enumerate(locs_per_machine) for _ in range(k))
    if volatile is None:
        volatile = tuple(False for _ in range(n_machines))
    return SystemConfig(n_machines, owner, tuple(volatile))


@dataclasses.dataclass(frozen=True)
class State:
    """An immutable CXL0 state γ = (C, M)."""
    C: Tuple[CacheRow, ...]              # C[i][x] ∈ Val ⊎ {BOT}
    M: Tuple[Val, ...]                   # M[x]; owner implied by config

    # -- functional updates -------------------------------------------------
    def set_cache(self, i: int, x: int, v: Optional[Val]) -> "State":
        row = self.C[i][:x] + (v,) + self.C[i][x + 1:]
        return State(self.C[:i] + (row,) + self.C[i + 1:], self.M)

    def invalidate_others(self, i: Optional[int], x: int) -> "State":
        """Set C_j(x) = ⊥ for every j ≠ i (i=None -> every j)."""
        C = tuple(
            row if j == i or row[x] is BOT
            else row[:x] + (BOT,) + row[x + 1:]
            for j, row in enumerate(self.C))
        return State(C, self.M)

    def set_mem(self, x: int, v: Val) -> "State":
        return State(self.C, self.M[:x] + (v,) + self.M[x + 1:])

    # -- queries -------------------------------------------------------------
    def cached_value(self, x: int) -> Optional[Val]:
        """The unique valid cached value of x, or BOT (uses the invariant)."""
        for row in self.C:
            if row[x] is not BOT:
                return row[x]
        return BOT

    def cached_anywhere(self, x: int) -> bool:
        return any(row[x] is not BOT for row in self.C)

    def holders(self, x: int) -> Tuple[int, ...]:
        return tuple(i for i, row in enumerate(self.C) if row[x] is not BOT)

    def read_value(self, cfg: SystemConfig, x: int) -> Val:
        """The value a Load would observe (cache wins over memory)."""
        v = self.cached_value(x)
        return self.M[x] if v is BOT else v


def initial_state(cfg: SystemConfig) -> State:
    """Empty caches, zero-initialized memories (paper §3.3)."""
    empty: CacheRow = tuple(BOT for _ in range(cfg.n_locs))
    return State(C=tuple(empty for _ in range(cfg.n_machines)),
                 M=tuple(INIT_VAL for _ in range(cfg.n_locs)))


def check_invariant(s: State) -> bool:
    n_locs = len(s.M)
    for x in range(n_locs):
        vals = {row[x] for row in s.C if row[x] is not BOT}
        if len(vals) > 1:
            return False
    return True
