from repro.data.pipeline import (  # noqa: F401
    DataPipeline, PipelineState, SyntheticLMSource, MemmapSource,
)
