"""Deterministic, shardable, resumable data pipeline.

Requirements at 1000-node scale:
* **determinism** — batch ``i`` is a pure function of (seed, i), so any
  worker can recompute any shard (backup-shard straggler mitigation);
* **sharding** — each data-parallel rank reads only its slice;
* **resumability** — the pipeline state is one small ``PipelineState``
  (seed + step) that the DSM runtime persists as a durable object; restart
  resumes mid-epoch with no data loss/duplication;
* **rebalancing** — ``shard_plan`` can reassign shards when the worker set
  changes (elastic scaling) or a straggler is detected.

Sources: ``SyntheticLMSource`` (hash-based token stream, used by tests and
examples) and ``MemmapSource`` (binary token file via ``np.memmap``).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class PipelineState:
    """The durable pipeline position (a FliT-protocol object in the DSM
    runtime — persisted with every checkpoint commit)."""
    seed: int
    step: int

    def advance(self, n: int = 1) -> "PipelineState":
        return PipelineState(self.seed, self.step + n)


class SyntheticLMSource:
    """Deterministic pseudo-random token stream: token[j] of sequence i is a
    hash of (seed, i, j).  Cheap, reproducible anywhere, no files."""

    def __init__(self, vocab_size: int):
        self.vocab_size = vocab_size

    def sequence_batch(self, seed: int, start_seq: int, n_seqs: int,
                       seq_len: int) -> np.ndarray:
        i = np.arange(start_seq, start_seq + n_seqs, dtype=np.uint64)[:, None]
        j = np.arange(seq_len, dtype=np.uint64)[None, :]
        h = (i * np.uint64(2654435761) ^ j * np.uint64(40503)
             ^ np.uint64(seed) * np.uint64(97))
        h ^= h >> np.uint64(13)
        h = (h * np.uint64(0x9E3779B97F4A7C15)) & np.uint64(0xFFFFFFFFFFFFFFFF)
        h ^= h >> np.uint64(29)
        return (h % np.uint64(self.vocab_size)).astype(np.int32)


class MemmapSource:
    """Flat binary int32 token file; sequence i = tokens[i*L:(i+1)*L]."""

    def __init__(self, path: str, vocab_size: int):
        self.tokens = np.memmap(path, dtype=np.int32, mode="r")
        self.vocab_size = vocab_size

    def sequence_batch(self, seed: int, start_seq: int, n_seqs: int,
                       seq_len: int) -> np.ndarray:
        n_total = len(self.tokens) // seq_len
        out = np.empty((n_seqs, seq_len), np.int32)
        for r, i in enumerate(range(start_seq, start_seq + n_seqs)):
            # seeded permutation over sequence index space (epoch shuffle)
            k = (i * 2654435761 + seed * 97) % max(n_total, 1)
            out[r] = self.tokens[k * seq_len:(k + 1) * seq_len]
        return out


def shard_plan(global_batch: int, n_ranks: int,
               weights: Optional[List[float]] = None) -> List[Tuple[int, int]]:
    """(start, count) per rank.  ``weights`` rebalances away from stragglers
    (straggler mitigation: a slow worker gets a smaller shard)."""
    if weights is None:
        weights = [1.0] * n_ranks
    total_w = sum(weights)
    counts = [int(round(global_batch * w / total_w)) for w in weights]
    # fix rounding drift
    drift = global_batch - sum(counts)
    for i in range(abs(drift)):
        counts[i % n_ranks] += 1 if drift > 0 else -1
    plan, start = [], 0
    for c in counts:
        plan.append((start, c))
        start += c
    return plan


class DataPipeline:
    def __init__(self, source, global_batch: int, seq_len: int,
                 state: Optional[PipelineState] = None):
        self.source = source
        self.global_batch = global_batch
        self.seq_len = seq_len
        self.state = state or PipelineState(seed=0, step=0)

    def global_batch_at(self, step: int) -> np.ndarray:
        """The full (global_batch, seq_len+1) token block of one step
        (+1 so targets are the shifted tokens)."""
        start = step * self.global_batch
        return self.source.sequence_batch(self.state.seed, start,
                                          self.global_batch,
                                          self.seq_len + 1)

    def shard_at(self, step: int, rank: int, n_ranks: int,
                 weights=None) -> np.ndarray:
        """Rank-local slice of batch ``step`` — recomputable by ANY worker
        (deterministic), which is what backup shards rely on."""
        s, c = shard_plan(self.global_batch, n_ranks, weights)[rank]
        start = step * self.global_batch + s
        return self.source.sequence_batch(self.state.seed, start, c,
                                          self.seq_len + 1)

    def next_global(self) -> Dict[str, np.ndarray]:
        block = self.global_batch_at(self.state.step)
        self.state = self.state.advance()
        return {"tokens": block[:, :-1], "targets": block[:, 1:]}
