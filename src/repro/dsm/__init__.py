"""Disaggregated-memory runtime: the paper's CXL0 tier semantics + FliT
commit protocol applied to distributed training state.

Mapping (DESIGN.md §2):
    machine i            -> training worker
    local cache C_i      -> device HBM state (volatile)
    owner cache C_k      -> host-DRAM staging buffer (volatile, survives
                            peer crashes but not its own host's)
    owner memory M_k     -> the persistent pool (checkpoint store)
    LStore               -> in-HBM update (every step)
    RStore               -> async stage into a peer host's buffer
    MStore / RFlush      -> durable commit into the pool (fsync + CRC)
    completeOp           -> atomic manifest rename
    FliT counter         -> per-object dirty counter consulted by joiners
    crash f_i            -> worker preemption; peers uninterrupted

Multi-process scale-out lives in ``repro.dsm.cluster``: per-worker object
namespaces (``w<i>/...``), the multi-writer-safe manifest protocol (rank
records + ONE elected cluster completeOp per step), and the spill-file
staging area that makes the RStore peer-recovery path work across
processes.

The public programming-model surface is ``repro.dsm.api``: ``open_cxl0``
returns a ``CXL0Context`` that owns the whole stack behind one
``CXL0Config`` — durable object handles, commit regions, the §6
transformation and ONE recovery path.  The constructors below remain for
primitive-level access; every subsystem now wires itself through the
context.
"""
from repro.dsm.pool import DSMPool, PoolObject  # noqa: F401
from repro.dsm.tiers import TierManager  # noqa: F401
from repro.dsm.flit_runtime import DurableCommitter  # noqa: F401
from repro.dsm.recovery import (ColdStartError, CrashError,  # noqa: F401
                                RecoveryManager)
from repro.dsm.api import (CXL0Config, CXL0Context,  # noqa: F401
                           CommitRegion, DurableHandle, TransformedObject,
                           open_cxl0)
from repro.dsm.faults import (FaultInjector, FaultSchedule,  # noqa: F401
                              FaultyPool, InjectedCrash, KillSpec,
                              StragglerSpec, TornSpec, attach_faults,
                              corrupt_file)

__all__ = [
    # the unified programming-model API (use this)
    "open_cxl0", "CXL0Context", "CXL0Config", "CommitRegion",
    "DurableHandle", "TransformedObject",
    # primitive-level building blocks (the context owns these for you)
    "DSMPool", "PoolObject", "TierManager", "DurableCommitter",
    "RecoveryManager", "CrashError", "ColdStartError",
    # injectable fault layer (the adversarial crash fuzzer's substrate)
    "FaultyPool", "FaultSchedule", "KillSpec", "TornSpec", "StragglerSpec",
    "FaultInjector", "attach_faults", "InjectedCrash", "corrupt_file",
]
