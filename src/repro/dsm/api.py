"""CXL0Context — the unified programming-model API over the DSM runtime.

The paper's contribution is a *programming model*: a small vocabulary of
primitives (LStore / RStore / RFlush / MStore / completeOp) plus the §6
transformation that makes any linearizable object durably linearizable.
Before this module, using that model meant hand-wiring five classes
(``DSMPool`` → ``TierManager`` → ``DurableCommitter`` → ``RecoveryManager``
+ optional ``PlacementPolicy``) and re-implementing tier construction,
committer kwargs and the staging-beats-pool recovery precedence at every
call site.  ``open_cxl0`` collapses that to one call:

    from repro.dsm import open_cxl0

    ctx = open_cxl0("/tmp/pool", worker_id=0, topology="cxl20-switched-pool")
    with ctx.commit(step, meta={"tag": "demo"}) as txn:
        txn.store("params", params)          # LStore (+ RStore replication)
    objs, step, source = ctx.recover(templates)   # staging-beats-pool, always

Three abstractions ride on the context:

* **durable object handles** — ``h = ctx.durable(name, init=tree)`` with
  the primitive vocabulary verbatim: ``h.lstore(tree)``, ``h.rstore(peer)``,
  ``h.rflush()``, ``h.mstore(tree)``.  A handle is sugar over the context's
  tier stack; completeOp stays with commit regions and ``ctx.transform``.

* **commit regions** — ``with ctx.commit(step, meta=...) as txn:`` stores
  route through the configured placement policy, async/sharded flushes are
  joined, and exactly one completeOp (atomic manifest rename) is emitted on
  clean exit.  An exception anywhere inside the region emits NO completeOp:
  recovery lands on the previous commit — the crash-anywhere contract.
  (Under the ``async`` / ``sharded-async`` schedules the completeOp emitted
  at exit publishes the PREVIOUS region, whose flushes overlapped compute —
  the double-buffered protocol of ``repro.dsm.flit_runtime``.)

* **§6 transformation** — ``ctx.transform(spec)`` wraps ANY linearizable
  object given as a sequential spec (the ``repro.core.objects.SeqSpec``
  interface: ``initial()`` + ``apply(state, op, args) -> (state', result)``)
  with the paper's FliT-for-CXL0 discipline at op granularity: every
  operation LStores the post-state, RFlushes it durably and completeOps.
  A crash loses at most the in-flight op; recovery reuses the SAME
  ``ctx.recover`` path as every other subsystem.

``CXL0Config`` is the one dataclass all knobs live in; every legacy
constructor (``run_durable_loop``, ``SessionStore``, ``build_serve_engine``,
the cluster worker, the ``launch/*`` front-ends) now routes through it, so
there is exactly one wiring path and one recovery path in the repo.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import numpy as np

from repro.dsm.flit_runtime import (AUTO_MODE, COMMIT_MODES, CommitStats,
                                    DurableCommitter)
from repro.dsm.pool import DSMPool, PoolObject
from repro.dsm.recovery import ColdStartError, RecoveryManager
from repro.dsm.tiers import TierManager

#: the production default flush schedule when ``schedule="auto"`` and no
#: topology/placement is configured (matches the training launcher default)
DEFAULT_SCHEDULE = "sharded-async"

#: ``schedule=`` accepts any of these; "auto" resolves at open time (to the
#: placement policy's choice when a topology is configured, else the
#: production default)
SCHEDULES = COMMIT_MODES + (AUTO_MODE,)


@dataclasses.dataclass
class CXL0Config:
    """Every wiring knob of the tier stack in one (round-trippable) place.

    ``path``/``worker_id`` locate the pool and name the worker;
    ``topology`` builds a cost-driven ``PlacementPolicy`` (or pass one
    directly via ``placement``); ``schedule`` is a commit mode or "auto";
    ``peers`` are recovery sources (anything with a ``.staging`` mapping —
    a TierManager, a ``CXL0Context``, a cluster staging view);
    ``replicate_to`` is the RStore replication target; ``fault_hook`` and
    ``complete_fn`` are the scenario/cluster extension points (callables —
    excluded from ``to_dict`` round-trips)."""

    path: Optional[str] = None
    worker_id: int = 0
    topology: Optional[str] = None
    schedule: str = AUTO_MODE
    n_shards: Optional[int] = None
    retention: Optional[int] = None
    peers: Tuple[Any, ...] = ()
    replicate_to: Optional[Any] = None
    placement: Optional[Any] = None           # PlacementPolicy override
    #: a jax ``Mesh`` makes the sharded schedules device-native: shard
    #: pipelines consume per-device buffers directly (no host gather of
    #: the full tree), counts/pricing derive from the device layout.  A
    #: live object — excluded from ``to_dict`` round-trips.
    mesh: Optional[Any] = None
    fault_hook: Optional[Callable[[str, int], None]] = None
    complete_fn: Optional[Callable] = None

    #: the serializable subset (callables / live objects excluded)
    SERIALIZED = ("path", "worker_id", "topology", "schedule", "n_shards",
                  "retention")

    def __post_init__(self):
        if self.schedule not in SCHEDULES:
            raise ValueError(f"schedule={self.schedule!r} not in "
                             f"{SCHEDULES}")

    # -- resolution ---------------------------------------------------------
    def resolved_placement(self):
        """The PlacementPolicy this stack runs under: an explicit policy
        wins; else one is built from ``topology``; else None."""
        if self.placement is not None:
            return self.placement
        if self.topology is not None:
            from repro.dsm.placement import PlacementPolicy
            return PlacementPolicy(self.topology)
        return None

    def resolved_schedule(self, placement=None) -> str:
        """"auto" defers to the placement policy when one is configured
        (the committer prices the flush at first commit) and otherwise
        picks the production default; explicit modes pass through."""
        if self.schedule != AUTO_MODE:
            return self.schedule
        if placement is not None or self.placement is not None \
                or self.topology is not None:
            return AUTO_MODE
        return DEFAULT_SCHEDULE

    # -- round trip ---------------------------------------------------------
    def to_dict(self) -> Dict[str, Any]:
        return {k: getattr(self, k) for k in self.SERIALIZED}

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "CXL0Config":
        return cls(**{k: d[k] for k in cls.SERIALIZED if k in d})

    def open(self, pool: Optional[DSMPool] = None) -> "CXL0Context":
        """Build the live context (the one wiring path)."""
        return CXL0Context(self, pool=pool)


class CommitRegion:
    """``with ctx.commit(step, meta=...) as txn:`` — the Alg. 2 commit
    window as a scope.  ``txn.store`` LStores (and RStore-replicates when
    the context has a replication target); on clean exit the committer
    flushes every HBM object under the configured schedule/placement and
    emits one completeOp.  On an exception NO completeOp happens — the
    step simply is not durable and recovery lands on the previous commit."""

    def __init__(self, ctx: "CXL0Context", step: int,
                 meta: Optional[dict] = None):
        self._ctx = ctx
        self.step = step
        self.meta = meta
        #: pre-region HBM value per name stored THROUGH this region —
        #: restored on an aborted exit, so a caller that survives the
        #: exception in-process cannot have the torn batch published by a
        #: LATER commit (version counters only ever rise, so the undo can
        #: never collide with files a manifest references)
        self._undo: Dict[str, Tuple[bool, Any]] = {}
        #: CommitStats of the completeOp emitted at exit (async schedules:
        #: the PREVIOUS region's, None on the very first commit)
        self.stats: Optional[CommitStats] = None

    def store(self, name: str, tree: Any):
        """LStore one object for this commit (+ RStore replication when the
        context has a replication target) — the committer's own update
        path, so region stores and ``ctx.put`` stores never diverge."""
        if name not in self._undo:
            hbm = self._ctx.tiers.hbm
            self._undo[name] = (name in hbm, hbm.get(name))
        self._ctx.committer.update({name: tree}, step=self.step)

    def store_all(self, objects: Dict[str, Any]):
        for name, tree in objects.items():
            self.store(name, tree)

    def __enter__(self) -> "CommitRegion":
        return self

    def __exit__(self, exc_type, exc, tb):
        if exc_type is not None:
            # crash inside the region: no completeOp, and the region's own
            # stores leave the volatile tier again — a torn batch must be
            # invisible even to a process that catches the exception and
            # keeps committing
            hbm = self._ctx.tiers.hbm
            for name, (had, prev) in self._undo.items():
                if had:
                    hbm[name] = prev
                else:
                    hbm.pop(name, None)
            return False
        self.stats = self._ctx.committer.commit(self.step, meta=self.meta)
        return False


@dataclasses.dataclass
class DurableHandle:
    """A named durable object: the paper's primitive vocabulary, verbatim,
    over the context's tier stack.  completeOp is not a handle method —
    it belongs to commit regions (``ctx.commit``) and the §6 transform,
    which is exactly the paper's split between stores/flushes (per
    location) and operation completion (per high-level op)."""

    ctx: "CXL0Context"
    name: str

    def lstore(self, tree: Any) -> "DurableHandle":
        """Update the volatile HBM tier (completes immediately)."""
        self.ctx.tiers.lstore(self.name, tree)
        return self

    def rstore(self, peer: Any = None, tag: Optional[int] = None):
        """Stage the current value into a peer's host buffer (survives OUR
        crash).  ``peer`` defaults to the context's replication target."""
        peer = peer if peer is not None else self.ctx.committer.replicate_to
        if peer is None:
            raise ValueError(f"rstore({self.name!r}): no peer given and the "
                             f"context has no replicate_to target")
        self.ctx.tiers.rstore(self.name, peer, tag=tag)

    def rflush(self) -> PoolObject:
        """Durable write into the pool; returns once on storage."""
        return self.ctx.tiers.rflush(self.name)

    def mstore(self, tree: Any) -> PoolObject:
        """lstore + rflush fused (Prop. 1.8)."""
        return self.ctx.tiers.mstore(self.name, tree)

    @property
    def value(self) -> Any:
        return self.ctx.tiers.hbm.get(self.name)

    @property
    def version(self) -> int:
        return self.ctx.tiers.versions.get(self.name, 0)


# -- §6 transformation at object granularity --------------------------------

def _encode_state(state) -> Dict[str, np.ndarray]:
    """Spec states (ints / nested tuples) as a pool-storable pytree."""
    raw = json.dumps(state).encode()
    return {"state": np.frombuffer(raw, np.uint8).copy()}


def _decode_state(tree) -> Any:
    def tup(x):
        return tuple(tup(i) for i in x) if isinstance(x, list) else x
    return tup(json.loads(np.asarray(tree["state"]).tobytes().decode()))


_STATE_TEMPLATE = {"state": np.zeros(0, np.uint8)}


class TransformedObject:
    """The paper's §6 FliT-for-CXL0 transformation applied to any
    linearizable object, as a reusable API (previously only the checkpoint
    path embodied it).  The object is given as a sequential spec
    (``initial()`` + ``apply(state, op, args) -> (state', result)`` — the
    ``repro.core.objects.SeqSpec`` interface); every ``op()`` runs Alg. 2:

        flit_counter++ ; LStore(state') ; RFlush(state') ; flit_counter-- ;
        completeOp  (atomic manifest rename)

    so an op that returned to its caller survives any crash, and a crash
    mid-op is invisible — recovery (the shared ``ctx.recover`` path) lands
    on the newest COMPLETED op.  The op index is the commit step, so the
    recovered ``ops_done`` tells the caller exactly how many ops are in
    the durable history."""

    def __init__(self, ctx: "CXL0Context", spec: Any, name: str = "object",
                 recover: bool = True):
        self.ctx = ctx
        self.spec = spec
        self.name = name
        self.state = spec.initial()
        self.ops_done = -1                    # step of the newest completeOp
        self.recovered_from: Optional[Tuple[int, str]] = None
        if recover:
            got = ctx.try_recover({name: _STATE_TEMPLATE}, exact=False)
            if got is not None:
                objs, step, source = got
                self.state = _decode_state(objs[name])
                self.ops_done = step
                self.recovered_from = (step, source)

    def op(self, op: str, *args) -> Any:
        """Apply one operation durably (Alg. 2 at op granularity)."""
        new_state, result = self.spec.apply(self.state, op, args)
        step = self.ops_done + 1
        self.ctx.tiers.lstore(self.name, _encode_state(new_state))  # LStore
        obj = self.ctx.tiers.rflush(self.name)                      # RFlush
        self.ctx.pool.commit_manifest(                              # completeOp
            step, {self.name: obj},
            meta={"kind": "flit-object", "object": self.name})
        self.state = new_state
        self.ops_done = step
        return result


class CXL0Context:
    """The façade: owns pool / tiers / committer / recovery / placement
    behind one ``CXL0Config``.  Exposes the legacy objects as attributes
    (``.pool``, ``.tiers``, ``.committer``, ``.recovery``, ``.placement``)
    for code that needs primitive access, and the programming-model surface
    (``durable`` / ``commit`` / ``transform`` / ``recover``) for everything
    else.  A context is itself a valid RStore peer / recovery source (it
    exposes ``.staging``), so ``open_cxl0(peer_path, worker_id=1)`` IS the
    peer object the committer replicates into."""

    def __init__(self, config: CXL0Config, *, pool: Optional[DSMPool] = None):
        if pool is None and config.path is None:
            raise ValueError("CXL0Config needs a pool path (or pass an "
                             "already-open DSMPool)")
        self.config = config
        self.pool = pool if pool is not None else DSMPool(config.path)
        self.placement = config.resolved_placement()
        self.tiers = TierManager(self.pool, config.worker_id)
        self.peers: Tuple[Any, ...] = tuple(config.peers)
        self.committer = DurableCommitter(
            self.tiers,
            mode=config.resolved_schedule(self.placement),
            replicate_to=config.replicate_to,
            n_shards=config.n_shards,
            retention=config.retention,
            fault_hook=config.fault_hook,
            placement=self.placement,
            mesh=config.mesh,
            complete_fn=config.complete_fn)
        self.recovery = RecoveryManager(self.pool)

    # -- peer interop --------------------------------------------------------
    @property
    def staging(self) -> Dict[str, Tuple[int, Any]]:
        """Peer-staged copies held BY this worker — makes a context usable
        anywhere a ``.staging``-bearing peer is expected (rstore targets,
        recovery sources)."""
        return self.tiers.staging

    @property
    def worker_id(self) -> int:
        return self.config.worker_id

    # -- the programming-model surface --------------------------------------
    def durable(self, name: str, init: Any = None) -> DurableHandle:
        """A named durable-object handle; ``init`` LStores an initial value
        if the object is not already in the HBM tier."""
        if init is not None and name not in self.tiers.hbm:
            self.tiers.lstore(name, init)
        return DurableHandle(self, name)

    def transform(self, spec: Any, name: str = "object",
                  recover: bool = True) -> TransformedObject:
        """Apply the §6 transformation to a linearizable object (see
        ``TransformedObject``)."""
        return TransformedObject(self, spec, name=name, recover=recover)

    def put(self, objects: Dict[str, Any], step: Optional[int] = None):
        """Per-step LStore of new state (+ RStore replication when
        configured) WITHOUT committing — the hot-path half of the loop;
        a later ``commit`` region makes it durable."""
        self.committer.update(objects, step=step)

    def commit(self, step: int, meta: Optional[dict] = None) -> CommitRegion:
        """Open a commit region for ``step`` (see ``CommitRegion``).
        Objects already ``put`` are included; extra stores go through
        ``txn.store``.  Exactly one completeOp on clean exit."""
        return CommitRegion(self, step, meta)

    def drain(self, meta: Optional[dict] = None) -> Optional[CommitStats]:
        """Join + completeOp any pending async commit (planned shutdown —
        the paper's sanctioned GPF use case)."""
        return self.committer.drain(meta)

    def recover(self, templates: Dict[str, Any],
                peers: Optional[Sequence[Any]] = None, *,
                exact: bool = True) -> Tuple[Dict[str, Any], int, str]:
        """THE recovery path: a surviving peer's RStore-staged copy beats
        the pool when newer; else the newest fully-CRC-valid manifest.
        ``peers`` defaults to the context's configured peers; raises
        ``ColdStartError`` when nothing is recoverable."""
        use = tuple(peers) if peers is not None else self.peers
        return self.recovery.recover(templates, use, exact=exact)

    def try_recover(self, templates: Dict[str, Any],
                    peers: Optional[Sequence[Any]] = None, *,
                    exact: bool = True
                    ) -> Optional[Tuple[Dict[str, Any], int, str]]:
        """``recover`` that returns None on a cold pool instead of raising
        (any OTHER failure still propagates — a real runtime error during
        recovery must never be mistaken for a cold start)."""
        try:
            return self.recover(templates, peers, exact=exact)
        except ColdStartError:
            return None

    # -- lifecycle -----------------------------------------------------------
    def abort_pending(self):
        """Crash path: discard the pending commit WITHOUT completing it
        (outstanding writes are joined so no stale write can land later)."""
        self.committer.abort_pending()

    def crash(self):
        """f_i: this worker's volatile tiers vanish (pending commits are
        aborted first).  The pool and peers are uninterrupted."""
        self.committer.abort_pending()
        self.tiers.crash()

    def close(self):
        """Release flush resources (idempotent).  Does NOT drain: call
        ``drain()`` first if a pending async commit should become durable."""
        self.tiers.close()

    def __enter__(self) -> "CXL0Context":
        return self

    def __exit__(self, exc_type, exc, tb):
        self.close()
        return False


def open_cxl0(path, worker_id: int = 0, *,
              topology: Optional[str] = None,
              placement: Optional[Any] = None,
              schedule: str = AUTO_MODE,
              n_shards: Optional[int] = None,
              retention: Optional[int] = None,
              peers: Sequence[Any] = (),
              replicate_to: Optional[Any] = None,
              mesh: Optional[Any] = None,
              fault_hook: Optional[Callable[[str, int], None]] = None,
              complete_fn: Optional[Callable] = None) -> CXL0Context:
    """Open a CXL0 programming-model context over a pool.

    ``path`` is the pool directory (or an already-open ``DSMPool``).  All
    other knobs land in one ``CXL0Config`` — see its docstring.  Typical
    whole programs are now ~5 lines:

        ctx = open_cxl0("/tmp/pool")
        ctx.put(state_objects, step=0)
        with ctx.commit(0):
            pass
        objs, step, source = ctx.recover(templates)
    """
    pool = path if isinstance(path, DSMPool) else None
    cfg = CXL0Config(
        path=path if pool is None else path.path,
        worker_id=worker_id, topology=topology, placement=placement,
        schedule=schedule, n_shards=n_shards, retention=retention,
        peers=tuple(peers), replicate_to=replicate_to, mesh=mesh,
        fault_hook=fault_hook, complete_fn=complete_fn)
    return cfg.open(pool=pool)
