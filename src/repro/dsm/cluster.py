"""Multi-writer cluster protocol: N worker processes over ONE shared pool.

The paper's setting is several hosts sharing one CXL pool, where a crash
takes out a single host's caches and everything else keeps running.  This
module is that setting at process scale — the pieces every scale-out layer
(elastic training, sharded serving, multi-backend) stands on:

* **per-worker namespaces** — rank *i* commits its objects as
  ``w<i>/<name>`` (``rank_ns``), so N writers never collide on object
  files; version counters per name are seeded from the shared pool
  (``TierManager.lstore`` / ``DSMPool.max_version``), so even a rank's
  torn leftovers are never overwritten;
* **rank records + elected cluster completeOp** (``ClusterProtocol``) —
  each rank's flush ends with an atomic *rank record*
  (``records/g<gen>/s<step>/r<i>.json``) listing its objects' manifest
  entries; the LAST rank to record sees the full set and commits ONE
  cluster manifest referencing every rank's objects at that step.  The
  manifest sequence number is reserved via O_EXCL
  (``DSMPool.commit_manifest``), and at most one rank wins the per-step
  O_EXCL commit marker, so concurrent committers never clobber a
  completed commit;
* **cross-process staging** (``FileStagingArea``) — the spill-file
  realization of RStore's peer host buffer: rank *i* stages its state
  into sibling ``(i+1) mod N``'s buffer directory on every step.  A
  ``StagingProxy`` plugs into ``TierManager.rstore`` /
  ``DurableCommitter(replicate_to=...)`` as the write side; a
  ``view(...)`` is the read side that ``RecoveryManager.recover`` accepts
  as a peer — so the peer-staging recovery path works ACROSS processes,
  not just in-process.  The buffer is volatile by contract: the owner's
  crash wipes it (the scenario runner deletes the victim's directory);
* **membership + shrink plumbing** (``ControlPlane``,
  ``ScalarReduceBoard``) — a lockstep all-reduce board doubles as the
  failure detector: survivors blocked on a dead rank's contribution learn
  the new membership from the control file and raise
  ``MembershipChange``, which the worker loop turns into the elastic
  shrink protocol (see ``repro.scenarios.cluster_worker``).

Recovery-source precedence for a victim's partition (same rule as
single-worker recovery, now across processes): the sibling's staged copy
wins iff its step tag is NEWER than the newest cluster manifest that
references the victim's objects; otherwise the pool wins — and if the
pool's copy is older than the survivors' live step, every survivor rolls
back to that manifest so the cluster never mixes steps.
"""
from __future__ import annotations

import dataclasses
import json
import os
import shutil
import tempfile
import time
from typing import Any, Dict, Optional, Sequence, Tuple

import numpy as np

import jax

from repro.dsm import stream
from repro.dsm.pool import (DSMPool, _crc_of_arrays, decode_arrays,
                            encode_arrays, manifest_entry)

#: polling period of the file-based rendezvous primitives (seconds)
POLL_S = 0.02


def rank_ns(rank: int, name: str) -> str:
    """The per-worker object namespace: ``w<i>/<name>``."""
    return f"w{rank}/{name}"


def ring_sibling(rank: int, live: Sequence[int]) -> int:
    """The staging target of ``rank`` in the ring over the live rank set:
    each rank RStore-stages its state into the next live rank's host
    buffer, so any single crash leaves the victim's newest state in a
    SURVIVOR's buffer."""
    live = sorted(live)
    return live[(live.index(rank) + 1) % len(live)]


class MembershipChange(Exception):
    """Raised out of a blocking rendezvous when the control plane reports a
    membership change affecting the live set: the caller must run the
    matching protocol leg (``kind="shrink"`` — a worker died, run the
    shrink protocol; the grow leg is planned-only and handled at step
    boundaries, so a raised change is always a death today)."""

    def __init__(self, victim: int, kind: str = "shrink"):
        super().__init__(f"worker {victim} left the cluster"
                         if kind == "shrink"
                         else f"worker {victim} membership change ({kind})")
        self.victim = victim
        self.member = victim
        self.kind = kind


def _atomic_json(path: str, doc: dict, *, fsync: bool = True):
    """Write-fsync-rename, same discipline as every other durable file.
    ``fsync=False`` keeps only the rename atomicity (readers never see a
    partial document) and skips the storage flush — correct for files
    that are VOLATILE by contract, like staging-buffer metas: they only
    need to survive the writer process, not a host crash."""
    os.makedirs(os.path.dirname(path), exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path))
    try:
        with os.fdopen(fd, "w") as f:
            json.dump(doc, f)
            if fsync:
                f.flush()
                os.fsync(f.fileno())
        os.replace(tmp, path)
    except BaseException:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise


def _read_json(path: str) -> Optional[dict]:
    """None on missing OR torn (a concurrent writer's rename not yet
    visible / a reader outracing the replace) — callers poll."""
    try:
        with open(path) as f:
            return json.load(f)
    except (OSError, ValueError):
        return None


def _prune_gen_step_dirs(root: str, gen: int, step: int):
    """Remove ``g<j>/`` trees of stale generations (j < gen) and
    ``g<gen>/s<k>/`` subtrees of superseded steps (k < step) — the shared
    bounded-growth sweep of the record and all-reduce directories.
    rmtree races between concurrent pruners are harmless."""
    if not os.path.isdir(root):
        return
    for gdir in os.listdir(root):
        g = gdir[1:]
        if not (gdir.startswith("g") and g.isdigit()):
            continue
        if int(g) < gen:
            shutil.rmtree(os.path.join(root, gdir), ignore_errors=True)
            continue
        if int(g) != gen:
            continue
        for sdir in os.listdir(os.path.join(root, gdir)):
            s = sdir[1:]
            if (sdir.startswith("s") and s.lstrip("-").isdigit()
                    and int(s) < step):
                shutil.rmtree(os.path.join(root, gdir, sdir),
                              ignore_errors=True)


# ---------------------------------------------------------------------------
# control plane: membership changes
# ---------------------------------------------------------------------------

class ControlPlane:
    """An ordered log of SIGNED membership changes (grow and shrink).

    Each posting is one immutable file ``changes/c<idx>.json`` —
    ``{"idx": i, "kind": "grow"|"shrink", "member": m, "planned": p,
    "at_step": s}`` — so a planned grow followed by a crash shrink of
    the very member it admitted never overwrites it (the single-file
    predecessor could only hold ONE change).  Postings come from the
    launcher/orchestrator, a single writer by construction, exactly as
    the legacy ``shrink.json`` did.

    * planned change (elastic scale in either direction): posted BEFORE
      the step — every rank executes the matching protocol leg at the
      top of step ``at_step``;
    * crash shrink: posted by the orchestrator AFTER it observes a
      worker death (``planned=False``); survivors notice while blocked
      on the dead rank in a rendezvous (``check_crash``).

    ``post``/``read`` keep the legacy shrink-only shapes for existing
    callers; new code posts through ``post_change`` and consumes the
    ordered ``changes()`` list.
    """

    def __init__(self, root: str):
        self.root = root
        self.changes_dir = os.path.join(root, "changes")
        os.makedirs(self.changes_dir, exist_ok=True)

    def post_change(self, kind: str, member: int, *, planned: bool = False,
                    at_step: Optional[int] = None) -> dict:
        assert kind in ("grow", "shrink"), kind
        assert kind == "shrink" or planned, "grow changes are planned-only"
        idx = len(self.changes())
        doc = {"idx": idx, "kind": kind, "member": int(member),
               "planned": bool(planned), "at_step": at_step}
        _atomic_json(os.path.join(self.changes_dir, f"c{idx:04d}.json"), doc)
        return doc

    def changes(self) -> list:
        """Every posted change, oldest first."""
        out = []
        for fn in sorted(os.listdir(self.changes_dir)):
            if fn.startswith("c") and fn.endswith(".json"):
                doc = _read_json(os.path.join(self.changes_dir, fn))
                if doc is not None:
                    out.append(doc)
        return out

    # -- legacy shrink-only shapes -------------------------------------------
    def post(self, victim: int, *, planned: bool = False,
             at_step: Optional[int] = None):
        self.post_change("shrink", victim, planned=planned, at_step=at_step)

    def read(self) -> Optional[dict]:
        """Newest change in the legacy single-doc shape (plus ``kind``)."""
        ch = self.changes()
        if not ch:
            return None
        d = ch[-1]
        return {"victim": d["member"], "planned": d["planned"],
                "at_step": d["at_step"], "kind": d["kind"]}

    def check_crash(self, live: Sequence[int]):
        """Raise MembershipChange if a CRASH change affecting ``live`` has
        been posted (planned changes are handled at step boundaries, not
        mid-rendezvous).  A change whose member already left ``live`` is
        spent and never re-raises."""
        for d in self.changes():
            if not d["planned"] and d["member"] in live:
                raise MembershipChange(d["member"], d.get("kind", "shrink"))

    # shrink rendezvous: the adopter publishes the recovery decision ------
    def post_shrink_result(self, gen: int, doc: dict):
        _atomic_json(os.path.join(self.root, f"shrink_g{gen}.json"), doc)

    def wait_shrink_result(self, gen: int, *, timeout: float = 120.0) -> dict:
        deadline = time.monotonic() + timeout
        path = os.path.join(self.root, f"shrink_g{gen}.json")
        while True:
            doc = _read_json(path)
            if doc is not None:
                return doc
            if time.monotonic() > deadline:
                raise TimeoutError(f"no shrink result for gen {gen}")
            time.sleep(POLL_S)


# ---------------------------------------------------------------------------
# lockstep scalar all-reduce (the data-parallel gradient combine)
# ---------------------------------------------------------------------------

class ScalarReduceBoard:
    """File-based all-reduce of one scalar per (generation, step, rank).

    Bit-exact: contributions are written as ``float.hex()`` and summed in
    sorted-rank order, so every rank computes the identical float64 — and
    a re-run with the same membership history reproduces it exactly.
    Keyed by generation so contributions from before a shrink can never
    leak into the re-executed step after it.  ``combine`` doubles as the
    failure detector: while blocked on a missing contribution it polls the
    control plane and raises ``MembershipChange`` when a death is posted.
    """

    def __init__(self, root: str):
        self.root = root
        os.makedirs(root, exist_ok=True)

    def _path(self, gen: int, step: int, rank: int) -> str:
        return os.path.join(self.root, f"g{gen}", f"s{step}",
                            f"r{rank}.json")

    def contribute(self, gen: int, step: int, rank: int, value: float):
        _atomic_json(self._path(gen, step, rank),
                     {"v": float(value).hex()})

    def combine(self, gen: int, step: int, ranks: Sequence[int], *,
                control: Optional[ControlPlane] = None,
                timeout: float = 120.0) -> float:
        ranks = sorted(ranks)
        deadline = time.monotonic() + timeout
        while True:
            vals = {}
            for r in ranks:
                doc = _read_json(self._path(gen, step, r))
                if doc is None:
                    break
                vals[r] = float.fromhex(doc["v"])
            if len(vals) == len(ranks):
                total = 0.0
                for r in ranks:         # fixed order -> bit-exact
                    total += vals[r]
                # every rank has contributed to `step`, so every rank is
                # past combine(step - 1) — older dirs are dead weight
                _prune_gen_step_dirs(self.root, gen, step)
                return total
            if control is not None:
                control.check_crash(ranks)
            if time.monotonic() > deadline:
                raise TimeoutError(
                    f"all-reduce g{gen}/s{step}: missing "
                    f"{sorted(set(ranks) - set(vals))}")
            time.sleep(POLL_S)


# ---------------------------------------------------------------------------
# cross-process RStore staging (the peer host buffer as spill files)
# ---------------------------------------------------------------------------

def _mangle(name: str) -> str:
    return name.replace("/", "__")


class _StagingBuffer:
    """The write side of one worker's host buffer: a mapping facade whose
    ``buf[name] = (tag, tree)`` writes the staged copy through to spill
    files.  Payload and meta are two atomic renames, so a crash between
    them CAN leave the previous meta next to a new payload — the meta
    therefore carries a CRC of the payload it describes, and ``view``
    discards any pair that does not match (recovery then falls back to
    the pool, never adopts a mislabeled copy).

    Spills are streamed frames (``repro.dsm.stream``) and are NOT
    fsync'd: the staging tier is peer host memory, volatile by contract
    — it must survive the WRITER's crash (the completed writes + renames
    do, the owner process keeps running) but is expected to vanish with
    the owner host.  Skipping the two fsyncs of the legacy path is the
    single biggest win of the staging fast path.  Leaves are
    materialized (device→host) HERE, per leaf, as the frame streams —
    ``TierManager.rstore`` no longer pays a whole-tree ``_to_host`` up
    front (see ``materializes_leaves``)."""

    #: tells ``TierManager.rstore`` it may hand over device-backed trees
    #: as-is: this buffer copies each leaf to host only as it streams out
    materializes_leaves = True

    def __init__(self, path: str, arena: Optional[stream.SpillArena] = None,
                 legacy: bool = False):
        self.path = path
        self.arena = arena
        self.legacy = legacy

    def __setitem__(self, name: str, value: Tuple[int, Any]):
        tag, tree = value
        try:
            os.makedirs(self.path, exist_ok=True)
            leaves = [np.asarray(l)
                      for l in jax.tree_util.tree_leaves(tree)]
            base = os.path.join(self.path, _mangle(name))
            if self.legacy:
                self._write_legacy(name, base, int(tag), leaves)
                return
            fd, tmp = tempfile.mkstemp(dir=self.path)
            try:
                with os.fdopen(fd, "wb") as f:
                    crc, _, _ = stream.write_frame(f, leaves, self.arena)
                os.replace(tmp, base + stream.SUFFIX)
            except BaseException:
                try:
                    os.unlink(tmp)
                except OSError:
                    pass
                raise
            _atomic_json(base + ".json",
                         {"name": name, "tag": int(tag), "n": len(leaves),
                          "crc": crc, "format": "cxl0"},
                         fsync=False)
        except FileNotFoundError:
            # the buffer owner crashed and its volatile buffer was wiped
            # out from under this store: an RStore into a dead peer's
            # cache simply does not land — the crash semantics, not an
            # error of ours
            return

    def _write_legacy(self, name: str, base: str, tag: int, leaves):
        """The PR-6 spill format (``np.savez`` + fsync'd meta): kept so
        backward-compat tests can fabricate old staging areas and as the
        in-bench comparison baseline for the streamed path."""
        raw, dtypes, shapes = encode_arrays(leaves)
        fd, tmp = tempfile.mkstemp(dir=self.path)
        try:
            with os.fdopen(fd, "wb") as f:
                np.savez(f, **{f"a{i}": a for i, a in enumerate(raw)})
                f.flush()
                os.fsync(f.fileno())
            os.replace(tmp, base + ".npz")
        except BaseException:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise
        _atomic_json(base + ".json",
                     {"name": name, "tag": tag, "n": len(leaves),
                      "crc": _crc_of_arrays(leaves),
                      "dtypes": dtypes, "shapes": shapes})


@dataclasses.dataclass
class StagingProxy:
    """RStore target for a remote sibling: quacks like a TierManager as far
    as ``rstore`` / ``DurableCommitter(replicate_to=...)`` care (exposes
    ``.staging``), but lands the copy in the sibling's buffer directory."""
    staging: _StagingBuffer


@dataclasses.dataclass
class StagedView:
    """Read side, shaped exactly like a TierManager peer for
    ``RecoveryManager.recover``: ``.staging = {name: (tag, host tree)}``."""
    staging: Dict[str, Tuple[int, Any]]


class FileStagingArea:
    """Per-worker spill-file buffers emulating RStore's peer host memory.

    ``root/w<i>/`` is worker *i*'s buffer: copies staged INTO it by peers.
    It is volatile by contract — worker *i*'s crash loses it (the
    orchestrator wipes the directory), exactly the CXL0 cache-loss model;
    the copies OF worker *i* living in a sibling's buffer survive.
    """

    def __init__(self, root: str, *, legacy_format: bool = False):
        self.root = root
        self.legacy_format = legacy_format
        self._arena = stream.SpillArena()
        os.makedirs(root, exist_ok=True)

    def area(self, rank: int) -> str:
        return os.path.join(self.root, f"w{rank}")

    def payload_path(self, rank: int, name: str) -> str:
        """Path of ``name``'s spill payload in ``rank``'s buffer — the
        streamed frame if present, else the legacy ``.npz``."""
        base = os.path.join(self.area(rank), _mangle(name))
        if os.path.exists(base + stream.SUFFIX):
            return base + stream.SUFFIX
        if os.path.exists(base + ".npz"):
            return base + ".npz"
        return base + stream.SUFFIX

    def proxy(self, rank: int) -> StagingProxy:
        """Write INTO ``rank``'s buffer (the rstore/replicate_to target)."""
        return StagingProxy(_StagingBuffer(self.area(rank), self._arena,
                                           legacy=self.legacy_format))

    def view(self, rank: int, templates: Dict[str, Any]) -> StagedView:
        """Read ``rank``'s OWN buffer: the staged copies this worker holds
        for its peers, unflattened against ``templates`` (only requested
        names are loaded).  Torn, missing, or meta/payload-mismatched
        entries (CRC check) are simply absent — recovery then falls back
        to the pool."""
        staged: Dict[str, Tuple[int, Any]] = {}
        for name, template in templates.items():
            base = os.path.join(self.area(rank), _mangle(name))
            meta = _read_json(base + ".json")
            if meta is None:
                continue
            if meta.get("format") == "cxl0":
                # streamed frame: mmap-backed zero-copy read; the frame's
                # own footer CRC is folded during the read and must also
                # match the meta's CRC (a writer that died between the
                # payload and meta renames leaves a meta describing a
                # DIFFERENT payload)
                try:
                    arrays, crc, hdr = stream.read_frame(base + stream.SUFFIX)
                except (stream.FrameError, OSError):
                    continue        # torn spill: not a usable copy
                if crc != meta.get("crc") or len(arrays) != meta.get("n"):
                    continue
            else:
                try:
                    with np.load(base + ".npz") as z:
                        arrays = [z[f"a{i}"] for i in range(meta["n"])]
                    arrays = decode_arrays(arrays, meta["dtypes"],
                                           meta["shapes"])
                except Exception:
                    continue        # torn spill: not a usable copy
                if _crc_of_arrays(arrays) != meta.get("crc"):
                    continue  # meta/payload mismatch — see above
            _, treedef = jax.tree_util.tree_flatten(template)
            staged[name] = (meta["tag"],
                            jax.tree_util.tree_unflatten(treedef, arrays))
        return StagedView(staged)

    def wipe(self, rank: int):
        """Worker ``rank`` crashed: its host buffer is gone."""
        shutil.rmtree(self.area(rank), ignore_errors=True)


# ---------------------------------------------------------------------------
# rank records + elected cluster completeOp
# ---------------------------------------------------------------------------

class ClusterProtocol:
    """Per-rank handle for the multi-writer commit protocol over one pool.

    A cluster commit of step ``s`` (generation ``g``)::

        every rank:   flush its w<i>/ objects (any schedule)
                      -> atomic rank record records/g<g>/s<s>/r<i>.json
        last to record (sees all N records, wins the O_EXCL marker):
                      -> ONE cluster manifest referencing every rank's
                         objects at step s  (completeOp)

    ``cluster_complete`` is shaped as a ``DurableCommitter`` complete_fn,
    so each rank's committer keeps its schedules, shard pipelines and
    fault-injection hooks and only the completeOp changes.  With
    ``confirm=True`` the call additionally blocks until the cluster
    manifest for the step is visible — used by the fault-injected victim
    (so a ``post_completeOp`` kill really is after the CLUSTER commit) and
    by the shrink/final barrier commits.
    """

    def __init__(self, pool: DSMPool, rank: int, live: Sequence[int], *,
                 gen: int = 0, confirm: bool = False,
                 retention: Optional[int] = None,
                 timeout: float = 120.0):
        self.pool = pool
        self.rank = rank
        self.live = sorted(live)
        self.gen = gen
        self.confirm = confirm
        #: manifests kept by the ELECTED committer's post-commit gc.
        #: Running gc from the winner right after its commit is the one
        #: multi-writer-safe point: every live rank's objects for this
        #: step are already referenced by the manifest just committed, and
        #: the lockstep all-reduce bounds rank skew to one step, so no
        #: rank can have flushed objects for a LATER commit yet.
        self.retention = retention
        self.timeout = timeout
        self.records_root = os.path.join(pool.path, "records")
        #: filename -> parsed manifest doc.  Manifest files are immutable
        #: once their rename made them parseable, so successful parses can
        #: be cached — the polling paths (wait_manifest) then cost
        #: O(listdir + unseen files) instead of re-parsing every manifest
        #: in the pool every 20 ms.
        self._manifest_cache: Dict[str, dict] = {}

    def set_membership(self, gen: int, live: Sequence[int]):
        self.gen = gen
        self.live = sorted(live)

    # -- rank records --------------------------------------------------------
    def _rec_dir(self, step: int) -> str:
        return os.path.join(self.records_root, f"g{self.gen}", f"s{step}")

    def write_record(self, step: int, entries: Dict[str, dict]):
        _atomic_json(os.path.join(self._rec_dir(step),
                                  f"r{self.rank}.json"),
                     {"rank": self.rank, "objects": entries})

    def read_records(self, step: int) -> Optional[Dict[str, dict]]:
        """Merged object entries of EVERY live rank's record for ``step``,
        or None while any record is still missing."""
        merged: Dict[str, dict] = {}
        for r in self.live:
            doc = _read_json(os.path.join(self._rec_dir(step),
                                          f"r{r}.json"))
            if doc is None:
                return None
            merged.update(doc["objects"])
        return merged

    # -- the elected completeOp ---------------------------------------------
    def _win_commit_marker(self, step: int) -> bool:
        """At most one rank per (gen, step) performs the completeOp — the
        O_EXCL marker makes the election atomic, so a stalled also-ran can
        never rename a DUPLICATE manifest for an old step after newer
        steps committed."""
        try:
            fd = os.open(os.path.join(self._rec_dir(step), ".commit"),
                         os.O_CREAT | os.O_EXCL | os.O_WRONLY)
        except FileExistsError:
            return False
        os.close(fd)
        return True

    def meta_for(self, **extra) -> dict:
        doc = {"kind": "cluster", "gen": self.gen, "live": self.live}
        doc.update(extra)
        return doc

    def _manifests_desc(self) -> list:
        """Like ``pool.manifests_desc`` but with the immutable-parse cache
        (see ``_manifest_cache``); entries for deleted files are dropped."""
        docs, seen = [], set()
        for fn in os.listdir(self.pool.path):
            if not (fn.startswith("manifest.") and fn.endswith(".json")):
                continue
            mid = fn[len("manifest."):-len(".json")]
            if not mid.isdigit():
                continue
            seen.add(fn)
            doc = self._manifest_cache.get(fn)
            if doc is None:
                doc = _read_json(os.path.join(self.pool.path, fn))
                if doc is None:
                    continue        # reservation still empty: poll again
                self._manifest_cache[fn] = doc
            docs.append(doc)
        for fn in list(self._manifest_cache):
            if fn not in seen:      # gc'd manifest
                del self._manifest_cache[fn]
        return sorted(docs, key=lambda d: (-d["step"], -d["seq"]))

    def find_manifest(self, step: int,
                      gen: Optional[int] = None) -> Optional[dict]:
        """Newest cluster manifest for ``step`` (optionally of one
        generation)."""
        for m in self._manifests_desc():
            if m["step"] != step:
                continue
            if gen is not None and m["meta"].get("gen") != gen:
                continue
            return m
        return None

    def wait_manifest(self, step: int, *,
                      control: Optional[ControlPlane] = None) -> dict:
        """Block until the cluster manifest for ``step`` is visible.

        Failover: if the marker winner died between winning the election
        and renaming the manifest, nobody would ever commit — so after a
        grace period any waiter whose record set is complete commits
        DIRECTLY, bypassing the marker.  The worst case is a duplicate
        manifest for the same step with identical content (merged from
        the same records), which is benign: seq numbers are reserved
        atomically and readers order by (step, seq)."""
        deadline = time.monotonic() + self.timeout
        takeover_at = time.monotonic() + min(5.0, self.timeout / 4)
        while True:
            m = self.find_manifest(step, gen=self.gen)
            if m is not None:
                return m
            if control is not None:
                control.check_crash(self.live)
            now = time.monotonic()
            if now > takeover_at:
                takeover_at = float("inf")
                merged = self.read_records(step)
                if merged is not None:
                    self.pool.commit_manifest(step, merged,
                                              self.meta_for())
                    self._prune_records(step)
                    if self.retention:
                        self.pool.gc(keep=self.retention)
                    continue        # our own commit is now findable
            if now > deadline:
                raise TimeoutError(
                    f"cluster manifest g{self.gen}/s{step} never appeared")
            time.sleep(POLL_S)

    def _prune_records(self, step: int):
        """Drop record dirs of committed-and-superseded steps (and stale
        generations) so a long run does not accumulate one dir per step
        forever — the same pathology gc's emptied-object-dir cleanup
        removes.  Lockstep guarantees no live rank still needs a record
        for a step older than the one just committed; a straggler's
        re-created dir is a harmless orphan swept by the next commit."""
        _prune_gen_step_dirs(self.records_root, self.gen, step)

    def try_commit(self, step: int, meta: Optional[dict] = None) -> int:
        """Commit the cluster manifest for ``step`` iff every live rank has
        recorded AND this rank wins the commit marker.  Returns the new
        manifest seq, or -1 when someone else is (or will be) the
        committer."""
        merged = self.read_records(step)
        if merged is None or not self._win_commit_marker(step):
            return -1
        seq = self.pool.commit_manifest(step, merged,
                                        meta or self.meta_for())
        self._prune_records(step)
        if self.retention:
            self.pool.gc(keep=self.retention)
        return seq

    def cluster_complete(self, step: int, written: Dict[str, Any],
                         meta: Optional[dict] = None) -> int:
        """The DurableCommitter ``complete_fn``: rank record + elected
        cluster commit (+ confirmation barrier when configured)."""
        entries = {name: manifest_entry(o) for name, o in written.items()}
        self.write_record(step, entries)
        seq = self.try_commit(step, meta)
        if self.confirm:
            seq = self.wait_manifest(step)["seq"]
        return seq
