"""Emulated CXL topologies: price every tier primitive from the calibrated
latency table (core/latency.py) under an injectable topology model.

The paper calibrates CXL0 primitive latencies on ONE real CXL 1.1
host+device pair (Fig. 5) but argues the model "captures a wide range of
current and future CXL setups".  Following emucxl (arXiv:2404.08311) —
emulated latency injection is enough to study placement policies — and the
CXL survey taxonomy (arXiv:2412.20249: 1.1 direct-attach, 2.0 switched
pool, 3.0 fabric), this module makes the runtime *feel* a topology:

* a ``Topology`` names the knobs that differ across CXL generations —
  a remote-access latency multiplier over the 1.1 calibration, a per-hop
  switch/fabric latency, per-link bandwidth caps, the number of parallel
  links to the pool (shard fan-out), and a per-stream contention factor
  when concurrent flush pipelines share links;
* three presets span the survey's taxonomy: ``cxl11-direct``,
  ``cxl20-switched-pool``, ``cxl30-fabric``;
* ``TopologyEmulator`` prices one op (latency from Fig. 5, scaled by the
  topology; transfer from the bandwidth model; deterministic seeded
  queueing jitter) and records a ``PricedOp`` trace;
* ``attach_emulator(tiers, emu)`` instruments a live ``TierManager``
  in place: every ``lstore`` / ``rstore`` / ``rflush`` / ``mstore`` /
  ``rload`` — the sharded and async variants included — is priced at call
  time (so the trace order is the program order, deterministic) and then
  delegated unchanged.  Behaviour is untouched; only the trace grows.

The same pricing functions are the cost model behind the placement policy
(repro.dsm.placement): decisions and emulation can never drift apart.

Unit convenience: 1 GB/s == 1 byte/ns, so ``nbytes / bw_gbps`` is ns.
"""
from __future__ import annotations

import dataclasses
import functools
import threading
import time
from typing import Any, Dict, List, Optional

import numpy as np

import jax

from repro.core.latency import DEVICE, HOST, LATENCY_NS


@dataclasses.dataclass(frozen=True)
class Topology:
    """One emulated CXL setup.  Latencies are multipliers/offsets over the
    Fig. 5 calibration (which IS the 1.1 direct-attach measurement);
    bandwidths are per-link caps in GB/s (== bytes/ns)."""
    name: str
    generation: str             # "1.1" | "2.0" | "3.0"
    #: scales every REMOTE-locality latency vs the 1.1 calibration
    remote_multiplier: float
    #: fixed per-access switch/fabric traversal cost (ns; 0 = direct)
    switch_hop_ns: float
    #: one pool link's bandwidth cap (GB/s)
    link_bw_gbps: float
    #: parallel links to the pool — the useful shard fan-out
    n_links: int
    #: fractional per-extra-stream slowdown when concurrent flush
    #: pipelines contend for links (0 = perfect isolation)
    contention_per_stream: float
    #: peer host-buffer (RStore staging) path bandwidth (GB/s)
    staging_bw_gbps: float
    #: local HBM/DRAM tier bandwidth for LStore (GB/s)
    local_bw_gbps: float = 100.0
    #: serial submit/bookkeeping cost per extra shard pipeline (ns)
    shard_setup_ns: float = 2_000.0
    #: fixed manifest+CRC validation cost of a pool restore (ns)
    pool_restore_overhead_ns: float = 20_000.0

    def aggregate_bw_gbps(self, n_streams: int) -> float:
        """Effective aggregate pool bandwidth of ``n_streams`` concurrent
        flush pipelines: streams beyond ``n_links`` share links, and every
        active link pair pays the contention tax."""
        active = max(1, min(n_streams, self.n_links))
        return (self.link_bw_gbps * active
                / (1.0 + self.contention_per_stream * (active - 1)))


#: The survey taxonomy as concrete presets.  cxl11-direct IS the paper's
#: measured pair (multiplier 1.0, no hop); the 2.0/3.0 numbers follow the
#: survey's qualitative ordering: each switch/fabric hop adds latency,
#: pools add links (fan-out bandwidth) but cross-host staging paths
#: lengthen.
PRESETS: Dict[str, Topology] = {t.name: t for t in (
    Topology("cxl11-direct", "1.1",
             remote_multiplier=1.0, switch_hop_ns=0.0,
             link_bw_gbps=12.0, n_links=1, contention_per_stream=0.0,
             staging_bw_gbps=32.0),
    Topology("cxl20-switched-pool", "2.0",
             remote_multiplier=1.4, switch_hop_ns=80.0,
             link_bw_gbps=16.0, n_links=4, contention_per_stream=0.35,
             staging_bw_gbps=10.0),
    Topology("cxl30-fabric", "3.0",
             remote_multiplier=2.2, switch_hop_ns=150.0,
             link_bw_gbps=20.0, n_links=8, contention_per_stream=0.15,
             staging_bw_gbps=8.0),
)}


def get_topology(name_or_topology) -> Topology:
    if isinstance(name_or_topology, Topology):
        return name_or_topology
    try:
        return PRESETS[name_or_topology]
    except KeyError:
        raise KeyError(f"unknown topology {name_or_topology!r}; presets: "
                       f"{sorted(PRESETS)}") from None


def tree_nbytes(tree: Any) -> int:
    """Total payload bytes of a pytree (jax or numpy leaves)."""
    total = 0
    for l in jax.tree_util.tree_leaves(tree):
        nb = getattr(l, "nbytes", None)
        if nb is None:
            nb = int(np.prod(np.shape(l))) * np.dtype(
                getattr(l, "dtype", np.float64)).itemsize
        total += int(nb)
    return total


# ---------------------------------------------------------------------------
# pricing (pure functions — shared by the emulator and the placement policy)
# ---------------------------------------------------------------------------

def _remote_lat(topo: Topology, node: str, prim: str) -> float:
    return (LATENCY_NS[(node, prim, "remote")] * topo.remote_multiplier
            + topo.switch_hop_ns)


def lstore_ns(topo: Topology, nbytes: int) -> float:
    """LStore: local volatile tier — locality-independent, no topology
    effects beyond the local-tier bandwidth."""
    return LATENCY_NS[(HOST, "lstore", "local")] + nbytes / topo.local_bw_gbps


def rstore_ns(topo: Topology, nbytes: int) -> float:
    """RStore into a PEER's host buffer: the cache-to-cache propagation
    path.  Host RStore is unavailable on real 1.1 hardware (Table 1), so
    like ``rmw_latency`` the latency point is the device-issued RStore."""
    return _remote_lat(topo, DEVICE, "rstore") + nbytes / topo.staging_bw_gbps


def rload_staging_ns(topo: Topology, nbytes: int) -> float:
    """Read back a copy a peer staged into OUR host buffer."""
    return (LATENCY_NS[(HOST, "load", "local")]
            + nbytes / topo.staging_bw_gbps)


def rflush_ns(topo: Topology, nbytes: int, n_streams: int = 1) -> float:
    """One durable flush stream into the pool (RFlush ≈ MStore latency,
    paper §5.2) carrying ``nbytes``, with ``n_streams`` total pipelines
    contending for the links."""
    return (_remote_lat(topo, HOST, "rflush")
            + nbytes * n_streams / topo.aggregate_bw_gbps(n_streams))


def mstore_ns(topo: Topology, nbytes: int) -> float:
    return _remote_lat(topo, HOST, "mstore") + nbytes / topo.link_bw_gbps


def rload_pool_ns(topo: Topology, nbytes: int) -> float:
    """Pool restore: remote load + manifest/CRC validation overhead."""
    return (_remote_lat(topo, HOST, "load") + topo.pool_restore_overhead_ns
            + nbytes / topo.aggregate_bw_gbps(1))


def sharded_flush_ns(topo: Topology, nbytes: int, n_shards: int) -> float:
    """Emulated wall time of a sharded durable flush: shards run in
    parallel across links (transfer divides by the aggregate bandwidth),
    but each extra pipeline costs serial setup — so the optimum shard
    count is topology- AND size-dependent."""
    k = max(1, n_shards)
    return (_remote_lat(topo, HOST, "rflush")
            + topo.shard_setup_ns * (k - 1)
            + nbytes / topo.aggregate_bw_gbps(k))


def sharded_flush_device_ns(topo: Topology, device_bytes, n_shards: int
                            ) -> float:
    """Emulated wall time of a DEVICE-sharded durable flush: the real
    per-device byte loads (``meshio.per_device_nbytes``) are packed onto
    ``n_shards`` pipelines largest-first, and the wall time is the
    heaviest pipeline's transfer at its per-pipeline share of the
    aggregate bandwidth — skewed device layouts price worse than the
    balanced-blob model, which is exactly why the placement policy wants
    the real vector.  Reduces to ``sharded_flush_ns`` when the loads are
    balanced."""
    loads = sorted((int(b) for b in device_bytes), reverse=True)
    if not loads:
        return sharded_flush_ns(topo, 0, n_shards)
    k = max(1, min(n_shards, len(loads)))
    lanes = [0] * k
    for b in loads:                      # greedy LPT onto the lightest lane
        lanes[lanes.index(min(lanes))] += b
    return (_remote_lat(topo, HOST, "rflush")
            + topo.shard_setup_ns * (k - 1)
            + max(lanes) / (topo.aggregate_bw_gbps(k) / k))


def join_transfer_ns(topo: Topology, nbytes: int, n_shards: int = 1
                     ) -> float:
    """Emulated cost of a grow-by-repartition join moving ``nbytes`` of
    state to the joiner: the survivors RStore the joiner's partition into
    its staging buffer, the joiner reads it back, and the gen+1 manifest
    re-flushes the moved objects durably under the new owner.  This is
    the capital cost an autoscale grow decision pays up front — cheap on
    fabric (GFAM staging bandwidth), expensive over a 1.1 direct link —
    which is exactly why scale decisions must flip per preset."""
    return (rstore_ns(topo, nbytes)
            + rload_staging_ns(topo, nbytes)
            + sharded_flush_ns(topo, nbytes, n_shards))


# ---------------------------------------------------------------------------
# the emulator: a priced-trace recorder
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PricedOp:
    """One priced primitive in program order."""
    seq: int
    op: str                  # lstore/rstore/rflush/rflush_shard/mstore/rload
    name: str
    nbytes: int
    n_streams: int
    cost_ns: float


class TopologyEmulator:
    """Prices ops under one topology and records the trace.

    Deterministic by construction: the queueing jitter is drawn from a
    seeded generator in record order, and ``attach_emulator`` prices at
    CALL time (program order), so the same (topology, seed, op sequence)
    always yields the identical priced trace — asserted in
    tests/test_emu.py and relied on by the CI bench gate.

    ``fault_model`` is an optional straggler/slow-writer model (anything
    with ``perturb(seq, op, name) -> (cost_multiplier, sleep_seconds)``,
    e.g. ``repro.dsm.faults.StragglerSpec``): the multiplier scales the
    priced cost — seeded by trace position, so still deterministic — and
    the sleep is a real capped stall applied OUTSIDE the trace lock, so
    concurrent flush pipelines genuinely reorder under the perturbation
    without perturbing the trace itself.
    """

    #: max fractional queueing jitter applied per op (+/-)
    JITTER = 0.02

    def __init__(self, topology, *, seed: int = 0, fault_model=None):
        self.topology = get_topology(topology)
        self.seed = seed
        self.fault_model = fault_model
        self.trace: List[PricedOp] = []
        self._rng = np.random.default_rng(seed)
        self._lock = threading.Lock()

    # -- pricing -------------------------------------------------------------
    def _base_ns(self, op: str, nbytes: int, n_streams: int) -> float:
        t = self.topology
        if op == "lstore":
            return lstore_ns(t, nbytes)
        if op == "rstore":
            return rstore_ns(t, nbytes)
        if op == "rload":
            return rload_staging_ns(t, nbytes)
        if op in ("rflush", "rflush_shard"):
            return rflush_ns(t, nbytes, n_streams)
        if op == "mstore":
            return mstore_ns(t, nbytes)
        raise KeyError(f"unpriceable op {op!r}")

    def record(self, op: str, name: str, nbytes: int,
               n_streams: int = 1) -> PricedOp:
        """Price one op and append it to the trace (thread-safe; jitter is
        consumed under the lock so trace order defines the draw order)."""
        sleep_s = 0.0
        with self._lock:
            jitter = 1.0 + self.JITTER * float(self._rng.uniform(-1.0, 1.0))
            cost = self._base_ns(op, nbytes, n_streams) * jitter
            if self.fault_model is not None:
                mult, sleep_s = self.fault_model.perturb(
                    len(self.trace), op, name)
                cost *= mult
            po = PricedOp(len(self.trace), op, name, int(nbytes),
                          n_streams, cost)
            self.trace.append(po)
        if sleep_s > 0.0:
            time.sleep(sleep_s)    # a real stall, outside the trace lock
        return po

    # -- summaries -----------------------------------------------------------
    def total_ns(self) -> float:
        return float(sum(p.cost_ns for p in self.trace))

    def per_op_ns(self) -> Dict[str, float]:
        out: Dict[str, float] = {}
        for p in self.trace:
            out[p.op] = out.get(p.op, 0.0) + p.cost_ns
        return out

    def reset(self):
        """Clear the trace AND re-seed the jitter stream — after reset the
        emulator reprices identically to a fresh one."""
        self.trace = []
        self._rng = np.random.default_rng(self.seed)


def attach_emulator(tiers, emu: TopologyEmulator):
    """Instrument a live TierManager in place: price every tier primitive
    through ``emu`` at call time, then delegate unchanged.  Returns
    ``tiers`` (with ``tiers.emulator`` set).  Sharded flushes are priced
    one ``rflush_shard`` op per shard with ``n_streams`` = the clamped
    shard count, BEFORE submission — program order, not completion order,
    so the trace stays deterministic under the thread pool."""
    from repro.dsm.pool import partition_leaves

    # a fused primitive (mstore = lstore + rflush) delegates to other
    # WRAPPED methods on the same instance: only the outermost call is
    # priced, so the fused op is charged once, not once plus its parts
    nesting = threading.local()

    def _hbm_nbytes(name: str) -> int:
        return tree_nbytes(tiers.hbm.get(name, ()))

    def _priced_call(record, orig, args, kwargs):
        if getattr(nesting, "depth", 0) == 0:
            record()
        nesting.depth = getattr(nesting, "depth", 0) + 1
        try:
            return orig(*args, **kwargs)
        finally:
            nesting.depth -= 1

    def _wrap(op, orig, nbytes_of):
        @functools.wraps(orig)
        def priced(*args, **kwargs):
            return _priced_call(
                lambda: emu.record(op, args[0] if args else "?",
                                   nbytes_of(*args, **kwargs)),
                orig, args, kwargs)
        return priced

    def _shard_assignment(name, n_shards):
        # metadata-only (leaf ``nbytes``): pricing a device-sharded flush
        # must not itself gather the tree to host — and a jax leaf's
        # nbytes equals its gathered nbytes, so the priced assignment is
        # the same one both flush paths actually write
        from repro.dsm.meshio import leaf_nbytes
        sizes = [leaf_nbytes(l)
                 for l in jax.tree_util.tree_leaves(tiers.hbm[name])]
        return [sum(sizes[i] for i in idxs) for idxs in
                partition_leaves(sizes, n_shards)]

    def _wrap_sharded(orig):
        @functools.wraps(orig)
        def priced(name, n_shards, *args, **kwargs):
            def record():
                shard_bytes = _shard_assignment(name, n_shards)
                for nb in shard_bytes:
                    emu.record("rflush_shard", name, nb, len(shard_bytes))
            return _priced_call(record, orig, (name, n_shards) + args,
                                kwargs)
        return priced

    tiers.lstore = _wrap("lstore", tiers.lstore,
                         lambda name, tree: tree_nbytes(tree))
    tiers.rstore = _wrap("rstore", tiers.rstore,
                         lambda name, *a, **k: _hbm_nbytes(name))
    tiers.rflush = _wrap("rflush", tiers.rflush,
                         lambda name: _hbm_nbytes(name))
    tiers.flush_async = _wrap("rflush", tiers.flush_async,
                              lambda name: _hbm_nbytes(name))
    tiers.mstore = _wrap("mstore", tiers.mstore,
                         lambda name, tree: tree_nbytes(tree))
    tiers.rload = _wrap("rload", tiers.rload,
                        lambda name: tree_nbytes(
                            (tiers.staging.get(name) or (0, ()))[1]))
    tiers.rflush_sharded = _wrap_sharded(tiers.rflush_sharded)
    tiers.flush_async_sharded = _wrap_sharded(tiers.flush_async_sharded)
    tiers.emulator = emu
    return tiers
