"""Injectable fault layer: seeded kill schedules, torn durable writes and
straggler/slow-writer perturbation for the adversarial crash fuzzer
(repro.scenarios.fuzz).

The kill-point suites enumerate three hand-picked commit-window points;
this module makes the *whole* primitive surface killable:

* ``KillSpec`` — one scheduled death: a worker, a primitive boundary
  (any ``lstore``/``rstore``/``rflush``/``mstore``/``completeOp`` call
  index, before or after the call), or — for the legacy corpus — one of
  the three commit-window points at a given step.
* ``TornSpec`` — torn-write emulation ("Barely Distributed and Almost
  Persistent": partial visibility is the failure mode CXL shared memory
  actually exhibits): a seeded per-(object, version) decision to
  truncate, bit-flip or zero a payload file AFTER its atomic rename, so
  the write is *visible* but *wrong* and the CRC/manifest path must
  reject it.
* ``StragglerSpec`` — seeded per-op delay multipliers routed through the
  ``TopologyEmulator`` pricing hook (``attach_emulator``), optionally
  with a real capped ``time.sleep`` so async flush pipelines genuinely
  reorder.
* ``FaultyPool`` — a ``DSMPool`` that applies the torn-write spec on
  every durable write and records exactly which ``(name, version)``
  payloads it corrupted — the fuzzer's independent oracle reads this
  ledger to compute the expected recovery point.
* ``FaultInjector`` / ``attach_faults`` — per-worker op counting and
  kill firing, wrapped around a live ``CXL0Context``'s tier methods the
  same way ``attach_emulator`` wraps them (faults outermost: a killed op
  is never priced).

Every decision is a pure hash of (salt, identity) — never wall clock,
never thread timing — so the same (schedule, program) always injects the
identical faults; the fuzzer's determinism property rests on this.
"""
from __future__ import annotations

import dataclasses
import functools
import os
import threading
import zlib
from typing import Any, Dict, List, Optional, Tuple

from repro.dsm import stream
from repro.dsm.flit_runtime import KILL_POINTS
from repro.dsm.pool import DSMPool
from repro.dsm.recovery import CrashError

#: phase boundaries of the grow-by-repartition join protocol
#: (scenarios/cluster_worker.py, scale suite).  After ``join_staged`` the
#: joiner's partition sits in its staging buffer; after ``join_committed``
#: the gen+1 manifest is elected; after ``join_adopted`` every rank runs
#: the new membership.  A kill at any of them must recover to either the
#: old or the new membership bit-identically — never a torn one.
JOIN_POINTS = ("join_staged", "join_committed", "join_adopted")

#: the primitive vocabulary a kill can target (async/sharded flush
#: variants count as ``rflush``; ``completeOp`` is the manifest commit)
PRIMITIVES = ("lstore", "rstore", "rflush", "mstore", "completeOp")

#: ways a torn write can mangle a payload file it leaves visible
TORN_MODES = ("truncate", "bitflip", "zero")


def _hash01(*parts: Any) -> float:
    """Deterministic uniform-ish [0, 1) from arbitrary identity parts."""
    h = zlib.crc32("|".join(str(p) for p in parts).encode()) & 0xFFFFFFFF
    return h / 2.0 ** 32


class InjectedCrash(CrashError):
    """A scheduled worker death fired at a primitive boundary.  Subclasses
    ``CrashError`` so the existing crash/recover paths treat it exactly
    like any other injected worker loss."""

    def __init__(self, worker: int, op: str, index: int, phase: str,
                 name: str = ""):
        super().__init__(
            f"injected crash: worker {worker} {phase} {op}[{index}]"
            + (f" ({name})" if name else ""))
        self.worker = worker
        self.op = op
        self.index = index
        self.phase = phase
        self.name = name


@dataclasses.dataclass(frozen=True)
class KillSpec:
    """One scheduled death.  Two addressing modes:

    * **primitive boundary** (the fuzzer's random mode): ``op`` is a
      primitive kind or ``"any"``; ``index`` is the 0-based call index
      (per kind, or global for ``"any"``); ``phase`` picks before/after
      the call — "before" models dying with the op never issued,
      "after" with the op complete but nothing that follows.
    * **commit-window point** (the legacy corpus): ``point`` is one of
      ``KILL_POINTS`` and the kill fires at the first such hook whose
      commit step is >= ``at_step`` — exactly the addressing of the
      process-kill suites, now expressible as a pinned schedule.
    """

    worker: int = 0
    op: Optional[str] = None
    index: int = 0
    phase: str = "before"
    point: Optional[str] = None
    at_step: int = 0

    def __post_init__(self):
        if (self.op is None) == (self.point is None):
            raise ValueError("KillSpec needs exactly one of op= / point=")
        if self.op is not None and self.op not in PRIMITIVES + ("any",):
            raise ValueError(f"unknown op {self.op!r}")
        if (self.point is not None
                and self.point not in KILL_POINTS + JOIN_POINTS):
            raise ValueError(f"unknown point {self.point!r}")
        if self.phase not in ("before", "after"):
            raise ValueError(f"phase must be before/after, got {self.phase!r}")

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "KillSpec":
        return cls(**d)


@dataclasses.dataclass(frozen=True)
class TornSpec:
    """Seeded torn-write model: each durable write of ``(name, version)``
    is independently corrupted with probability ``rate``, mode drawn from
    ``modes``.  Decisions hash the identity, not the call order, so they
    are stable across threads, retries and incarnations."""

    rate: float
    salt: int = 0
    modes: Tuple[str, ...] = TORN_MODES

    def decide(self, name: str, version: int) -> Optional[str]:
        if _hash01("torn", self.salt, name, version) >= self.rate:
            return None
        pick = _hash01("torn-mode", self.salt, name, version)
        return self.modes[int(pick * len(self.modes)) % len(self.modes)]


@dataclasses.dataclass(frozen=True)
class StragglerSpec:
    """Seeded slow-writer model: with probability ``rate`` an op's priced
    cost is multiplied by up to ``max_mult`` and the caller stalls for a
    real (capped) sleep, so async flush pipelines genuinely reorder under
    the perturbation.  Plugged into ``TopologyEmulator(fault_model=...)``
    — the delay rides the same pricing hook as the topology model."""

    rate: float
    max_mult: float = 8.0
    sleep_s: float = 0.0005
    max_sleep_s: float = 0.005
    salt: int = 0

    def perturb(self, seq: int, op: str, name: str) -> Tuple[float, float]:
        """(cost multiplier, real sleep seconds) for trace entry ``seq``."""
        if _hash01("straggler", self.salt, seq, op, name) >= self.rate:
            return 1.0, 0.0
        mult = 1.0 + (self.max_mult - 1.0) * _hash01(
            "straggler-mult", self.salt, seq, op, name)
        return mult, min(self.sleep_s * mult, self.max_sleep_s)


@dataclasses.dataclass(frozen=True)
class FaultSchedule:
    """One episode's complete fault plan: any number of kills plus
    optional torn-write and straggler models.  Fully JSON-serializable —
    the minimal-reproducer format is (config, schedule)."""

    kills: Tuple[KillSpec, ...] = ()
    torn: Optional[TornSpec] = None
    straggler: Optional[StragglerSpec] = None

    def to_dict(self) -> dict:
        return {
            "kills": [k.to_dict() for k in self.kills],
            "torn": dataclasses.asdict(self.torn) if self.torn else None,
            "straggler": (dataclasses.asdict(self.straggler)
                          if self.straggler else None),
        }

    @classmethod
    def from_dict(cls, d: dict) -> "FaultSchedule":
        torn = d.get("torn")
        strag = d.get("straggler")
        if torn is not None:
            torn = TornSpec(**{**torn, "modes": tuple(torn["modes"])})
        if strag is not None:
            strag = StragglerSpec(**strag)
        return cls(kills=tuple(KillSpec.from_dict(k)
                               for k in d.get("kills", ())),
                   torn=torn, straggler=strag)


# ---------------------------------------------------------------------------
# torn durable writes
# ---------------------------------------------------------------------------

def _payload_span(path: str) -> Tuple[int, int]:
    """(offset, length) of the largest member's DATA bytes — the region
    the content CRC provably covers.  Corrupting here guarantees the read
    path must reject the file (a flip in e.g. a central-directory
    timestamp could otherwise go unnoticed and desynchronize the fuzzer's
    oracle).  Sniffs the payload format: streamed ``.cxl0`` frames are
    targeted via their header's leaf table (the largest leaf's bytes),
    legacy ``.npz`` payloads via the zip local-file-header walk."""
    with open(path, "rb") as f:
        magic = f.read(len(stream.MAGIC))
    if magic == stream.MAGIC:
        return stream.payload_span(path)
    import zipfile
    with zipfile.ZipFile(path) as z:
        info = max(z.infolist(), key=lambda i: i.file_size)
    with open(path, "rb") as f:
        f.seek(info.header_offset)
        hdr = f.read(30)            # local file header: sizes at 26/28
    n_name = int.from_bytes(hdr[26:28], "little")
    n_extra = int.from_bytes(hdr[28:30], "little")
    return info.header_offset + 30 + n_name + n_extra, info.file_size


def corrupt_file(path: str, mode: str):
    """Mangle a payload file IN PLACE, deterministically, leaving it
    visible (the rename already happened): ``truncate`` keeps a prefix,
    ``bitflip`` inverts one byte of array data, ``zero`` XOR-smears a
    64-byte window of array data (any nonzero burst under 32 bits — and
    any fixed nonzero XOR pattern — changes a CRC32, so detection is
    guaranteed, never probabilistic).  The CRC / structure validation of
    the read path must reject all three for BOTH payload formats."""
    size = os.path.getsize(path)
    if mode == "truncate":
        # legacy zip: the central directory lives at the tail — a prefix
        # can never parse as a complete archive.  Streamed frame: the
        # size equation (header + payload + footer == file size) fails
        # and the footer magic is gone
        os.truncate(path, max(1, size // 3))
        return
    off, length = _payload_span(path)
    length = max(1, length)
    with open(path, "r+b") as f:
        if mode == "bitflip":
            pos = off + length // 2
            f.seek(pos)
            b = f.read(1)
            f.seek(pos)
            f.write(bytes([(b[0] if b else 0) ^ 0xFF]))
        elif mode == "zero":
            span = min(64, length)
            f.seek(off)
            window = f.read(span)
            f.seek(off)
            f.write(bytes(c ^ 0xA5 for c in window))
        else:
            raise ValueError(f"unknown torn mode {mode!r}")
        f.flush()
        os.fsync(f.fileno())


class FaultyPool(DSMPool):
    """A DSMPool whose durable writes can be torn: after the payload's
    atomic rename (so the write IS visible), the payload file is
    corrupted per the ``TornSpec`` (or a forced per-write override).  The
    frame footer / ``.crc`` sidecar and the manifest entry keep
    describing the ORIGINAL bytes — exactly the mislabeled-but-visible
    state a writer dying mid-update leaves on CXL shared memory.  Every
    corruption is recorded in ``injected`` so an oracle can compute which
    commits must be rejected.

    The injection rides ``DSMPool._finalize_write`` (the post-rename
    hook) rather than a ``write_object`` override: the split-phase
    pipelined shard writes (``start_write``/``finish``) and the legacy
    ``.npz`` writer all funnel through that hook, so every durable-write
    flavor stays corruptible and the fuzzer's oracle stays in sync."""

    def __init__(self, path: str, *, torn: Optional[TornSpec] = None,
                 injected: Optional[List[Tuple[str, int, str]]] = None):
        self.torn = torn
        #: ledger of (name, version, mode) actually corrupted — may be a
        #: shared list when several pool handles cover one directory
        self.injected: List[Tuple[str, int, str]] = (
            injected if injected is not None else [])
        self._forced: Dict[Tuple[str, int], str] = {}
        self._faults_lock = threading.Lock()
        super().__init__(path)

    def force_corrupt(self, name: str, version: int, mode: str):
        """Pin the NEXT write of ``(name, version)`` to be torn with
        ``mode`` regardless of the spec (targeted tests)."""
        if mode not in TORN_MODES:
            raise ValueError(f"unknown torn mode {mode!r}")
        with self._faults_lock:
            self._forced[(name, version)] = mode

    def _finalize_write(self, name: str, version: int, payload_path: str):
        super()._finalize_write(name, version, payload_path)
        with self._faults_lock:
            mode = self._forced.pop((name, version), None)
        if mode is None and self.torn is not None:
            mode = self.torn.decide(name, version)
        if mode is not None:
            corrupt_file(payload_path, mode)
            with self._faults_lock:
                self.injected.append((name, version, mode))


# ---------------------------------------------------------------------------
# kill firing
# ---------------------------------------------------------------------------

class FaultInjector:
    """Per-worker kill machinery: counts primitive boundaries, fires the
    schedule's kills for THIS worker (each spec at most once, in schedule
    order), and doubles as the ``CXL0Context`` ``fault_hook`` so
    commit-window (point-based) kills ride the existing plumbing.

    One injector persists across a worker's incarnations — counters keep
    rising through crash + recovery, so a second kill later in the
    schedule still lands at a well-defined global index."""

    def __init__(self, schedule: FaultSchedule, worker: int = 0):
        self.schedule = schedule
        self.worker = worker
        self.counts: Dict[str, int] = {k: 0 for k in PRIMITIVES}
        self.total = 0
        self.fired: List[dict] = []
        self.last_window: Optional[Tuple[str, int]] = None
        self._done: set = set()
        self._lock = threading.Lock()

    # -- the armed spec ------------------------------------------------------
    def _next_spec(self) -> Optional[Tuple[int, KillSpec]]:
        for i, s in enumerate(self.schedule.kills):
            if s.worker == self.worker and i not in self._done:
                return i, s
        return None

    def _fire(self, slot: int, op: str, name: str, index: int, phase: str):
        self._done.add(slot)
        self.fired.append({"worker": self.worker, "op": op, "index": index,
                           "phase": phase, "name": name})
        raise InjectedCrash(self.worker, op, index, phase, name)

    # -- primitive-boundary addressing ---------------------------------------
    def begin(self, op: str, name: str) -> Tuple[int, int]:
        """Count one primitive call and maybe die BEFORE it.  Returns the
        (per-kind, global) indices for the matching ``end``."""
        with self._lock:
            my, g = self.counts[op], self.total
            self.counts[op] += 1
            self.total += 1
        self._maybe_fire(op, name, my, g, "before")
        return my, g

    def end(self, op: str, name: str, my: int, g: int):
        """Maybe die AFTER a counted call."""
        self._maybe_fire(op, name, my, g, "after")

    def _maybe_fire(self, op: str, name: str, my: int, g: int, phase: str):
        armed = self._next_spec()
        if armed is None:
            return
        slot, s = armed
        if s.point is not None or s.phase != phase:
            return
        if (s.op == "any" and g == s.index) or (s.op == op and my == s.index):
            self._fire(slot, op, name, my, phase)

    def call(self, op: str, name: str, fn, *args, **kwargs):
        """Bracket an arbitrary call as one primitive boundary — used for
        completeOps that do not go through a wrapped pool method (the
        cluster's elected manifest commit)."""
        my, g = self.begin(op, name)
        out = fn(*args, **kwargs)
        self.end(op, name, my, g)
        return out

    # -- commit-window (point) addressing ------------------------------------
    def window(self, point: str, step: int):
        """The ``fault_hook`` signature: fires point-based kills exactly
        like the process-kill workers did (first hook of the point whose
        commit step is >= ``at_step``)."""
        self.last_window = (point, step)
        armed = self._next_spec()
        if armed is None:
            return
        slot, s = armed
        if s.point == point and step >= s.at_step:
            self._fire(slot, point, f"step{step}", step, "at")


def attach_faults(ctx, injector: FaultInjector, *, wrap_pool: bool = True):
    """Instrument a live ``CXL0Context`` in place: every tier primitive
    passes through ``injector`` boundaries (async/sharded flush variants
    count as ``rflush``) and — unless ``wrap_pool=False`` (shared-pool
    cluster setups bracket the elected completeOp themselves via
    ``injector.call``) — so does ``pool.commit_manifest`` as
    ``completeOp``.  Apply AFTER ``attach_emulator`` so the kill check is
    outermost: a killed op is never priced.  Nested primitives (mstore =
    lstore + rflush) only count once, mirroring the emulator's rule.
    Returns ``ctx`` (with ``ctx.fault_injector`` set)."""
    tiers = ctx.tiers
    nesting = threading.local()

    def _wrap(kind, orig):
        @functools.wraps(orig)
        def guarded(*args, **kwargs):
            if getattr(nesting, "depth", 0):
                return orig(*args, **kwargs)
            name = str(args[0]) if args else "?"
            my, g = injector.begin(kind, name)
            nesting.depth = 1
            try:
                out = orig(*args, **kwargs)
            finally:
                nesting.depth = 0
            injector.end(kind, name, my, g)
            return out
        return guarded

    tiers.lstore = _wrap("lstore", tiers.lstore)
    tiers.rstore = _wrap("rstore", tiers.rstore)
    tiers.mstore = _wrap("mstore", tiers.mstore)
    for meth in ("rflush", "rflush_sharded", "flush_async",
                 "flush_async_sharded"):
        setattr(tiers, meth, _wrap("rflush", getattr(tiers, meth)))
    if wrap_pool and getattr(ctx.pool, "_fault_injector", None) is not injector:
        orig_commit = ctx.pool.commit_manifest

        @functools.wraps(orig_commit)
        def commit_manifest(step, objects, meta=None):
            return injector.call("completeOp", f"manifest@{step}",
                                 orig_commit, step, objects, meta)

        ctx.pool.commit_manifest = commit_manifest
        ctx.pool._fault_injector = injector
    ctx.fault_injector = injector
    return ctx
