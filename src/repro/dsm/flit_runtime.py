"""The FliT-protocol durable commit for training state (paper Alg. 2 at
checkpoint granularity).

One *commit* of step ``s`` = the high-level operation; the state objects
(param shards, optimizer moments, data-pipeline state, RNG) are the shared
locations.  Following Alg. 2:

    for each object X:  flit_counter(X)++ ; LStore(X) ; RFlush(X) ;
                        flit_counter(X)--
    completeOp()  =  atomic manifest rename

Durable linearizability of the step history follows exactly as in the
paper's §B: a commit whose completeOp (manifest rename) finished survives
any single-worker crash; recovery always lands on SOME completed commit —
never a torn mixture of steps (test: tests/test_dsm.py).

Two schedules:
* ``sync``  — rflush every object, then completeOp (simple, blocking);
* ``async`` — overlap: flushes of step s run in the background while step
  s+1 computes; the next commit joins them first.  This is the
  compute/flush overlap lever measured in benchmarks/bench_checkpoint.py.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, Optional

from repro.dsm.pool import DSMPool, PoolObject
from repro.dsm.tiers import TierManager


@dataclasses.dataclass
class CommitStats:
    step: int
    seq: int
    n_objects: int
    bytes_written: int
    wall_s: float
    mode: str


class DurableCommitter:
    def __init__(self, tiers: TierManager, *, mode: str = "sync",
                 replicate_to: Optional[TierManager] = None):
        assert mode in ("sync", "async")
        self.tiers = tiers
        self.mode = mode
        self.replicate_to = replicate_to     # peer for RStore staging
        self._pending: Optional[Dict[str, Any]] = None
        self.stats: list = []

    # -- the Alg. 2 protocol over training state -----------------------------
    def update(self, objects: Dict[str, Any], step: Optional[int] = None):
        """Per-step LStore of the new state into HBM (always happens).
        If a peer is configured, also RStore-stage (cheap replication),
        tagged with the training step for recovery comparability."""
        for name, tree in objects.items():
            self.tiers.lstore(name, tree)
            if self.replicate_to is not None:
                self.tiers.rstore(name, self.replicate_to, tag=step)

    def commit(self, step: int, meta: Optional[dict] = None) -> CommitStats:
        """Durable commit of the current HBM state (blocking)."""
        t0 = time.perf_counter()
        if self.mode == "async":
            return self._commit_async(step, meta, t0)
        written: Dict[str, PoolObject] = {}
        for name in self.tiers.hbm:
            written[name] = self.tiers.rflush(name)
        seq = self.tiers.pool.commit_manifest(step, written, meta)
        st = CommitStats(step, seq, len(written),
                         sum(o.nbytes for o in written.values()),
                         time.perf_counter() - t0, "sync")
        self.stats.append(st)
        return st

    def _commit_async(self, step: int, meta, t0) -> CommitStats:
        """Join the previous async flushes, completeOp them, then launch
        flushes of the CURRENT state in the background."""
        st = None
        if self._pending is not None:
            prev_step, names = self._pending
            written = {n: self.tiers.flush_wait(n) for n in names}
            seq = self.tiers.pool.commit_manifest(prev_step, written, meta)
            st = CommitStats(prev_step, seq, len(written),
                             sum(o.nbytes for o in written.values()),
                             time.perf_counter() - t0, "async")
            self.stats.append(st)
        names = list(self.tiers.hbm)
        for name in names:
            self.tiers.flush_async(name)
        self._pending = (step, names)
        return st

    def drain(self, meta: Optional[dict] = None) -> Optional[CommitStats]:
        """Flush any pending async commit (planned shutdown — the paper's
        sanctioned GPF use case)."""
        if self.mode == "async" and self._pending is not None:
            t0 = time.perf_counter()
            prev_step, names = self._pending
            written = {n: self.tiers.flush_wait(n) for n in names}
            seq = self.tiers.pool.commit_manifest(prev_step, written, meta)
            self._pending = None
            st = CommitStats(prev_step, seq, len(written),
                             sum(o.nbytes for o in written.values()),
                             time.perf_counter() - t0, "drain")
            self.stats.append(st)
            return st
        return None


def gpf_snapshot(committers, step: int, meta: Optional[dict] = None):
    """Global Persistent Flush (paper §3.2): drain EVERY worker's volatile
    tiers into the pool and commit a synchronized manifest.

    The paper deems GPF too blocking/fragile for the hot path but sanctions
    it for planned shutdown/snapshot; that is exactly this API's contract —
    the launcher calls it on SIGTERM or before elastic re-meshing.  Returns
    the per-worker commit stats."""
    stats = []
    for c in committers:
        c.drain(meta)
        stats.append(c.commit(step, meta))
        c.drain(meta)
    return stats
