"""The FliT-protocol durable commit for training state (paper Alg. 2 at
checkpoint granularity).

One *commit* of step ``s`` = the high-level operation; the state objects
(param shards, optimizer moments, data-pipeline state, RNG) are the shared
locations.  Following Alg. 2:

    for each object X:  flit_counter(X)++ ; LStore(X) ; RFlush(X) ;
                        flit_counter(X)--
    completeOp()  =  atomic manifest rename

Durable linearizability of the step history follows exactly as in the
paper's §B: a commit whose completeOp (manifest rename) finished survives
any single-worker crash; recovery always lands on SOME completed commit —
never a torn mixture of steps (tests: tests/test_dsm.py and the
process-kill suite in repro.scenarios).

Four schedules:

* ``sync``          — rflush every object serially, then completeOp
                      (simple, blocking; the baseline);
* ``async``         — overlap: one background flush thread per object runs
                      while step s+1 computes; the next commit joins them
                      before its completeOp;
* ``sharded``       — each object's pytree is split into ``n_shards``
                      byte-balanced leaf groups and written in PARALLEL
                      (one LStore/RFlush pipeline per shard on a thread
                      pool), then completeOp.  Blocking, but the flush
                      wall-time divides by the shard-level parallelism;
* ``sharded-async`` — the production default: sharded writes of step s are
                      double-buffered behind compute of step s+1; commit(s)
                      first joins + completeOps the PREVIOUS step's shards,
                      then launches step s's shard pipelines and returns.
                      The blocking cost is just the join of flushes that
                      already overlapped compute.

Retention: when ``retention=k`` is set, every completeOp is followed by
``pool.gc(keep=k)`` — old manifests and the shard versions only they
reference are deleted, bounding pool growth for long runs.

Fault injection: ``fault_hook(point, step)`` is called at the three
commit-window points ``pre_flush`` (state about to be flushed),
``mid_flush`` (first shard/object durable, manifest NOT yet written — a
kill here leaves a torn write) and ``post_completeOp`` (manifest rename
done).  The scenario runner (repro.scenarios) uses it to ``os._exit`` a
real worker process at each point and assert recovery lands on a completed
commit.  In ``*-async`` modes ``post_completeOp`` reports the PREVIOUS
step — the one whose manifest was just renamed.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax

from repro.dsm import meshio
from repro.dsm.tiers import TierManager

COMMIT_MODES = ("sync", "async", "sharded", "sharded-async")

#: not a schedule itself: a committer built with ``mode=AUTO_MODE`` defers
#: to its PlacementPolicy at the first commit, which prices the flush
#: under the active topology and resolves to one of COMMIT_MODES
AUTO_MODE = "auto"

#: fault-injection points inside the commit window
KILL_POINTS = ("pre_flush", "mid_flush", "post_completeOp")


@dataclasses.dataclass
class CommitStats:
    step: int
    seq: int
    n_objects: int
    bytes_written: int
    wall_s: float
    mode: str
    n_shards: int = 1


def auto_shard_count(total_bytes: int, *,
                     min_shard_bytes: int = 1 << 20,
                     n_devices: Optional[int] = None) -> int:
    """THE default shard-count heuristic (single source of truth; the
    launcher re-uses it via train/step.py): one flush pipeline per local
    device, capped so no shard falls under ``min_shard_bytes`` — tiny
    states degrade gracefully to fewer pipelines.  ``n_devices`` pins the
    device term to a configured Mesh's size (a mesh-slice rank must size
    its pipelines from ITS sub-grid, not the whole process's devices)."""
    per_device = max(n_devices if n_devices is not None
                     else jax.local_device_count(), 1)
    by_bytes = max(total_bytes // min_shard_bytes, 1)
    return max(1, min(per_device, by_bytes))


class DurableCommitter:
    def __init__(self, tiers: TierManager, *, mode: str = "sync",
                 replicate_to: Optional[Any] = None,
                 n_shards: Optional[int] = None,
                 retention: Optional[int] = None,
                 fault_hook: Optional[Callable[[str, int], None]] = None,
                 placement: Optional[Any] = None,
                 mesh: Optional[Any] = None,
                 complete_fn: Optional[
                     Callable[[int, Dict[str, Any], Optional[dict]],
                              int]] = None):
        assert mode in COMMIT_MODES + (AUTO_MODE,), mode
        assert mode != AUTO_MODE or placement is not None, \
            "mode='auto' needs a PlacementPolicy to resolve the schedule"
        self.tiers = tiers
        self.mode = mode
        #: device-sharded commit: with a ``Mesh`` configured, the sharded
        #: schedules consume each device's buffer inside its own shard
        #: pipeline (tiers.rflush_sharded(device_local=True)) — no host
        #: gather of the full tree — and the shard count is derived from
        #: the mesh/sharding layout instead of a gathered-pytree balance.
        #: Shard FILES stay bit-identical to the host-gather path (the
        #: assignment is computed from the same per-leaf bytes), so
        #: recovery is format-compatible in both directions.
        self.mesh = mesh
        #: cost-driven placement (repro.dsm.placement).  When set, the
        #: shard count comes from ``placement.choose_shards`` (sized by
        #: the actual state bytes under the active topology) instead of
        #: the device-count heuristic, and ``mode="auto"`` resolves to
        #: the policy's schedule choice at the first commit.
        self.placement = placement
        self.replicate_to = replicate_to     # peer for RStore staging (a
        #                                      TierManager or any object
        #                                      with a .staging mapping, e.g.
        #                                      a cluster StagingProxy)
        self.n_shards = n_shards or None     # None = auto at first commit
        self.retention = retention
        self.fault_hook = fault_hook
        #: delegated completeOp: ``complete_fn(step, written, meta) -> seq``
        #: replaces the default single-writer ``pool.commit_manifest``.
        #: The cluster protocol (repro.dsm.cluster) uses this to turn a
        #: rank's flush into a rank-record + elected CLUSTER manifest
        #: commit; the flush machinery (schedules, shard pipelines, fault
        #: hooks) is reused unchanged.
        self.complete_fn = complete_fn
        #: (step, object names, meta) of the in-flight async commit.  meta
        #: is captured at LAUNCH so the manifest always describes the state
        #: that was actually flushed — a later commit's meta (e.g. a newer
        #: serving session table) must never pair with these objects.
        self._pending: Optional[Tuple[int, List[str], Optional[dict]]] = None
        self.stats: list = []

    def _hook(self, point: str, step: int):
        if self.fault_hook is not None:
            self.fault_hook(point, step)

    def _hbm_bytes(self) -> int:
        # emu.tree_nbytes is THE byte-counting used everywhere the
        # placement policy is fed sizes (kvcache.spill_auto, cluster
        # ranks) — one definition, so the same state never prices
        # differently across call sites
        from repro.dsm.emu import tree_nbytes
        return tree_nbytes(dict(self.tiers.hbm))

    def _resolve_shards(self) -> int:
        """Lazy auto shard count: sized from the actual HBM state volume
        at the first sharded flush — by the placement policy's cost model
        when one is configured, else the device-count heuristic.  With a
        Mesh, the policy prices from the REAL per-device byte loads
        (``meshio.per_device_nbytes``, metadata-only) and the heuristic's
        device term is the mesh's device count."""
        if self.n_shards is None:
            total = self._hbm_bytes()
            if self.placement is not None:
                device_bytes = (meshio.per_device_nbytes(
                    dict(self.tiers.hbm)) if self.mesh is not None else None)
                self.n_shards = self.placement.choose_shards(
                    total, device_bytes=device_bytes)
            else:
                self.n_shards = auto_shard_count(
                    total, n_devices=(meshio.mesh_device_count(self.mesh)
                                      if self.mesh is not None else None))
        return self.n_shards

    @property
    def _device_local(self) -> bool:
        """Sharded flushes consume device buffers directly iff a Mesh is
        configured — the host-gather path stays the default."""
        return self.mesh is not None

    def _resolve_mode(self) -> str:
        """``mode="auto"`` defers the schedule choice until the first
        commit, when the real state volume is known: the placement policy
        prices the flush under its topology and picks sync vs
        sharded-async (logged as a ``schedule`` decision)."""
        if self.mode == AUTO_MODE:
            self.mode = self.placement.choose_schedule(self._hbm_bytes())
        return self.mode

    def _complete_op(self, step: int, written: Dict[str, Any],
                     meta, t0, label: str) -> CommitStats:
        """completeOp = atomic manifest rename (or the delegated
        cluster-level completeOp), then retention GC."""
        if self.complete_fn is not None:
            seq = self.complete_fn(step, written, meta)
        else:
            seq = self.tiers.pool.commit_manifest(step, written, meta)
        # retention GC only in the single-committer configuration:
        # pool.gc deletes every version no kept manifest references, so
        # running it from one rank of a multi-writer pool would delete a
        # concurrent rank's flushed-but-not-yet-committed objects.  With a
        # delegated completeOp, retention is the cluster layer's job.
        if self.retention is not None and self.complete_fn is None:
            self.tiers.pool.gc(keep=self.retention)
        st = CommitStats(step, seq, len(written),
                         sum(o.nbytes for o in written.values()),
                         time.perf_counter() - t0, label,
                         (self.n_shards or 1) if "sharded" in self.mode
                         else 1)
        self.stats.append(st)
        self._hook("post_completeOp", step)
        return st

    # -- the Alg. 2 protocol over training state -----------------------------
    def update(self, objects: Dict[str, Any], step: Optional[int] = None):
        """Per-step LStore of the new state into HBM (always happens).
        If a peer is configured, also RStore-stage (cheap replication),
        tagged with the training step for recovery comparability."""
        for name, tree in objects.items():
            self.tiers.lstore(name, tree)
            if self.replicate_to is not None:
                self.tiers.rstore(name, self.replicate_to, tag=step)

    def commit(self, step: int, meta: Optional[dict] = None
               ) -> Optional[CommitStats]:
        """Durable commit of the current HBM state.  Blocking modes return
        the stats of THIS step; async modes return the stats of the
        PREVIOUS step whose flushes were just joined (None on the first
        call)."""
        t0 = time.perf_counter()
        self._resolve_mode()
        if self.mode == "async":
            return self._commit_async(step, meta, t0)
        if self.mode == "sharded-async":
            return self._commit_sharded_async(step, meta, t0)
        self._hook("pre_flush", step)
        written: Dict[str, Any] = {}
        first = True
        for name in self.tiers.hbm:
            if self.mode == "sharded":
                written[name] = self.tiers.rflush_sharded(
                    name, self._resolve_shards(),
                    post_first_shard=self._mid_flush_probe(first, step),
                    device_local=self._device_local)
            else:
                written[name] = self.tiers.rflush(name)
                if first:
                    self._hook("mid_flush", step)
            first = False
        return self._complete_op(step, written, meta, t0, self.mode)

    def _mid_flush_probe(self, first: bool, step: int):
        """The mid-flush fault-injection callback — ONLY materialized when a
        fault hook is installed, because the tiers layer must synchronously
        wait on the first shard to fire it (which would serialize shard 0
        and block the async launch in normal operation)."""
        if not first or self.fault_hook is None:
            return None
        return lambda: self._hook("mid_flush", step)

    def _commit_async(self, step: int, meta, t0) -> Optional[CommitStats]:
        """Join the previous async flushes, completeOp them, then launch
        flushes of the CURRENT state in the background."""
        st = self._join_pending(t0, "async")
        self._hook("pre_flush", step)
        names = list(self.tiers.hbm)
        for i, name in enumerate(names):
            self.tiers.flush_async(name)
            if i == 0:
                # first object's durable write is in flight, manifest absent
                self._hook("mid_flush", step)
        self._pending = (step, names, meta)
        return st

    def _commit_sharded_async(self, step: int, meta, t0
                              ) -> Optional[CommitStats]:
        """Double-buffered sharded commit: join + completeOp step s-1's
        shard pipelines (they overlapped compute of step s), then launch
        step s's pipelines and return immediately."""
        st = self._join_pending(t0, "sharded-async")
        self._hook("pre_flush", step)
        names = list(self.tiers.hbm)
        first = True
        for name in names:
            self.tiers.flush_async_sharded(
                name, self._resolve_shards(),
                post_first_shard=self._mid_flush_probe(first, step),
                device_local=self._device_local)
            first = False
        self._pending = (step, names, meta)
        return st

    def _join_pending(self, t0, label: str) -> Optional[CommitStats]:
        if self._pending is None:
            return None
        prev_step, names, meta = self._pending
        self._pending = None        # cleared FIRST: a failed join must not
        #                             leave already-popped names re-joinable
        written: Dict[str, Any] = {}
        first_err: Optional[BaseException] = None
        for n in names:
            try:
                written[n] = self.tiers.flush_wait(n)
            except Exception as e:   # join the rest, then surface the first
                if first_err is None:
                    first_err = e
        if first_err is not None:
            raise first_err          # step simply not durable; no manifest
        return self._complete_op(prev_step, written, meta, t0, label)

    def drain(self, meta: Optional[dict] = None) -> Optional[CommitStats]:
        """Flush any pending async commit (planned shutdown — the paper's
        sanctioned GPF use case).  The manifest carries the meta captured
        when the pending commit LAUNCHED; ``meta`` is only a fallback for
        pre-capture callers."""
        if self._pending is not None:
            if self._pending[2] is None and meta is not None:
                self._pending = (*self._pending[:2], meta)
            return self._join_pending(time.perf_counter(), "drain")
        return None

    def abort_pending(self):
        """Crash path: discard the pending commit WITHOUT completing it.
        Outstanding writes are joined (so no stale write can land after the
        next incarnation starts) but no manifest is written — the step is
        simply not durable, exactly the partial-crash semantics."""
        self._pending = None
        self.tiers.abort_flushes()


def gpf_snapshot(committers, step: int, meta: Optional[dict] = None):
    """Global Persistent Flush (paper §3.2): drain EVERY worker's volatile
    tiers into the pool and commit a synchronized manifest.

    The paper deems GPF too blocking/fragile for the hot path but sanctions
    it for planned shutdown/snapshot; that is exactly this API's contract —
    the launcher calls it on SIGTERM or before elastic re-meshing.  Returns
    the per-worker commit stats."""
    stats = []
    for c in committers:
        c.drain(meta)
        stats.append(c.commit(step, meta))
        c.drain(meta)
    return stats
