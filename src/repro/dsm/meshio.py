"""Device-local buffer access for mesh-sharded durable commits.

The host-gather flush path materializes a WHOLE pytree on host
(``np.asarray`` per leaf) before any shard pipeline starts — on a real
multi-device mesh that is one big D2H gather whose peak host footprint is
the full state, and it serializes in front of every pipeline.  This
module is the device-native alternative the sharded schedules use when a
``Mesh`` is configured:

* shard ASSIGNMENT is computed from array METADATA only (``leaf_nbytes``
  reads ``.nbytes`` off the jax array, no transfer) — and because a jax
  leaf's ``nbytes`` equals its gathered ``np.asarray(leaf).nbytes``, the
  byte-balanced ``partition_leaves`` assignment is IDENTICAL to the
  host-gather path's at the same shard count.  Same assignment + same
  leaf bytes + same frame writer = bit-identical shard files, CRCs and
  manifests (equivalence-locked by tests/test_mesh_commit.py);
* leaf MATERIALIZATION happens inside each shard's flush pipeline
  (``assemble_leaf``): every per-device buffer is copied host-side
  individually (``np.asarray(shard.data)`` — the device-local view the
  ``.cxl0`` frame writer consumes via ``stream._leaf_view``) and placed
  at its ``Shard.index``, so the full tree never exists on host at once
  and the copies overlap across pipelines;
* ``per_device_nbytes`` exposes the real per-device byte loads (again
  metadata-only) so the placement policy can price shard counts from the
  actual device layout instead of pretending the state is one host blob.

D2H accounting: ``TierManager`` counts gather-path conversions in
``d2h_gather_bytes`` and device-path per-buffer copies in
``d2h_shard_bytes`` — a device-sharded commit must leave
``d2h_gather_bytes`` untouched (asserted in tests), which is the
"no host gather of the full tree" contract in a checkable form.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, List, Optional

import numpy as np

import jax


def leaf_nbytes(leaf: Any) -> int:
    """Payload bytes of one leaf from metadata only — no transfer, and
    numerically identical to ``np.asarray(leaf).nbytes`` (what the
    host-gather path feeds ``partition_leaves``)."""
    nb = getattr(leaf, "nbytes", None)
    if nb is None:
        nb = int(np.prod(np.shape(leaf))) * np.dtype(
            getattr(leaf, "dtype", np.float64)).itemsize
    return int(nb)


def _unique_shards(leaf) -> List[Any]:
    """This process's addressable shards, replicas deduplicated (one copy
    per distinct index — replica 0, so every process picks the same)."""
    return [s for s in leaf.addressable_shards if s.replica_id == 0]


def assemble_leaf(leaf: Any, count: Optional[Callable[[int], None]] = None
                  ) -> np.ndarray:
    """Materialize ONE leaf on host from its per-device buffers.

    Called inside a shard pipeline thread, never on the commit path's
    critical section.  A plain ``np.ndarray`` passes through untouched
    (post-recovery state is host-resident); an unsharded / fully
    replicated jax array is one device buffer copied whole; a
    device-sharded array is assembled block-by-block at each
    ``Shard.index`` — each ``np.asarray(shard.data)`` is a single
    device-to-host copy of that device's buffer.  ``count`` (when given)
    receives the copied byte total — the ``d2h_shard_bytes`` feed."""
    if type(leaf) is np.ndarray:
        return leaf
    shards = getattr(leaf, "addressable_shards", None)
    if not shards:                       # np scalar / python number / ...
        a = np.asarray(leaf)
        if count is not None:
            count(a.nbytes)
        return a
    shards = _unique_shards(leaf)
    if len(shards) == 1 and shards[0].data.shape == leaf.shape:
        a = np.asarray(shards[0].data)
        if count is not None:
            count(a.nbytes)
        return a
    out = np.empty(leaf.shape, leaf.dtype)
    copied = 0
    for s in shards:
        block = np.asarray(s.data)       # ONE device buffer -> host
        out[s.index] = block
        copied += block.nbytes
    if count is not None:
        count(copied)
    return out


def per_device_nbytes(tree: Any) -> List[int]:
    """Real per-device byte loads of a flush of ``tree``, from sharding
    metadata only: for every leaf, each deduplicated shard's bytes are
    charged to its device; host-resident leaves (post-recovery numpy,
    counters) are pooled on one pseudo-device.  Sorted by device id so
    every caller derives the same vector — the ``device_bytes`` input of
    ``placement.choose_shards``."""
    per: Dict[int, int] = {}
    for leaf in jax.tree_util.tree_leaves(tree):
        shards = getattr(leaf, "addressable_shards", None)
        if not shards:
            per[-1] = per.get(-1, 0) + leaf_nbytes(leaf)
            continue
        for s in _unique_shards(leaf):
            d = int(s.device.id)
            per[d] = per.get(d, 0) + int(s.data.nbytes)
    return [per[k] for k in sorted(per)]


def mesh_device_count(mesh: Any) -> int:
    """Total devices of a Mesh (the device-derived shard-count ceiling)."""
    return int(np.prod(list(mesh.shape.values()))) if mesh is not None else 1
