"""Cost-driven tier placement under an emulated CXL topology.

The runtime used to hard-code its placement choices: the committer's
shard count came from ``auto_shard_count`` (device count, topology-blind),
the KV-cache manager spilled wherever the caller said, and cluster ranks
ring-staged unconditionally.  ``PlacementPolicy`` replaces those choices
with cost-model decisions priced by the SAME functions the topology
emulator uses (repro.dsm.emu), so under ``cxl11-direct`` the policy
behaves like the calibrated paper pair and under ``cxl30-fabric`` it
exploits link fan-out — and every decision is logged and assertable.

Three decisions, all per object size under the active topology:

* ``choose_spill``    — host RStore-staging vs pool for an evicted
  object.  Staging is cheap (cache-to-cache path) but volatile: with
  probability ``p_peer_loss`` the peer holding the copy crashes and the
  object must be REPLAYED (recomputed) at ``replay_ns_per_byte``.  The
  pool is durable but pays remote flush + restore (+ fixed manifest/CRC
  overhead).  The policy picks the lower EXPECTED cost;
* ``choose_shards``   — argmin over shard counts of the modelled sharded
  flush wall time (``emu.sharded_flush_ns``): setup cost per extra
  pipeline vs link fan-out.  Direct-attach (1 link) collapses to 1;
  fabric picks up to its 8 links for large states;
* ``choose_schedule`` — ``sync`` when the modelled blocking flush is
  below ``sync_threshold_ns`` (double-buffering would buy nothing),
  ``sharded-async`` otherwise.

Wiring (each opt-in, defaults unchanged):

* ``DurableCommitter(placement=...)`` resolves its shard count — and,
  with ``mode="auto"``, its schedule — from the policy at first commit;
* ``TieredKVCache(placement=...)`` gains ``spill_auto`` which routes an
  evicted session cache to staging or (sharded) pool per decision;
* cluster ranks call ``plan_rank_staging`` to decide whether ring
  RStore-staging their partition every step is worth its cost
  (``scenarios/cluster_worker.py --topology``);
* the fleet controller (``serve.fleet``) prices ``choose_admission``
  (which engine serves a new request: queue-depth decode latency plus
  prefill replay vs pool block restore when a shared prefix is
  reusable) and ``choose_migration`` (is rebalancing an in-flight
  session worth the RStore+adopt traffic vs staying put);
* the autoscaler (``scale.autoscaler``) prices ``choose_scale``
  (hold / grow / shrink the fleet: join capital — staged state transfer
  + gen+1 re-flush — vs the projected queueing cost over the decision
  window), so capacity follows demand per topology preset.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List

from repro.dsm.emu import (Topology, get_topology, join_transfer_ns,
                           rload_pool_ns, rload_staging_ns, rstore_ns,
                           sharded_flush_device_ns, sharded_flush_ns)


@dataclasses.dataclass(frozen=True)
class Decision:
    """One logged placement decision: what was chosen for which object,
    and the modelled cost of every alternative (ns) — so tests and the
    bench can assert WHY, not just what."""
    # "spill" | "shards" | "schedule" | "staging" | "admit" | "migrate"
    # | "scale"
    kind: str
    name: str
    nbytes: int
    choice: Any
    costs: Dict[str, float]
    topology: str


class PlacementPolicy:
    def __init__(self, topology, *,
                 p_peer_loss: float = 0.05,
                 replay_ns_per_byte: float = 0.2,
                 sync_threshold_ns: float = 1e6,
                 max_shards: int = 16,
                 restore_fraction: float = 1.0,
                 decode_tick_ns: float = 5e5):
        """``p_peer_loss``: probability the peer holding a staged-only copy
        crashes before the copy is consumed (the CXL0 cache-loss model);
        ``replay_ns_per_byte``: recompute cost of a lost copy;
        ``restore_fraction``: fraction of spilled objects later read back
        (1.0 = every spill is restored, the serving eviction pattern);
        ``decode_tick_ns``: modelled wall time of one slot-batched decode
        tick — converts an engine's queue depth into the wait a newly
        admitted (or rebalanced) request pays before its slot frees."""
        self.topology: Topology = get_topology(topology)
        self.p_peer_loss = p_peer_loss
        self.replay_ns_per_byte = replay_ns_per_byte
        self.sync_threshold_ns = sync_threshold_ns
        self.max_shards = max_shards
        self.restore_fraction = restore_fraction
        self.decode_tick_ns = decode_tick_ns
        self.decisions: List[Decision] = []

    def _log(self, kind: str, name: str, nbytes: int, choice,
             costs: Dict[str, float]) -> Decision:
        d = Decision(kind, name, int(nbytes), choice, dict(costs),
                     self.topology.name)
        self.decisions.append(d)
        return d

    def decisions_for(self, kind: str) -> List[Decision]:
        return [d for d in self.decisions if d.kind == kind]

    # -- spill tier ----------------------------------------------------------
    def spill_costs(self, nbytes: int) -> Dict[str, float]:
        """Expected end-to-end ns of evicting + later consuming one object
        per tier.  Staging: RStore now; with p_peer_loss the peer dies and
        the object is replayed, else it is read back from the buffer.
        Pool: best-shard-count durable flush now, remote restore later."""
        t = self.topology
        staging = (rstore_ns(t, nbytes)
                   + self.p_peer_loss * self.replay_ns_per_byte * nbytes
                   + (1.0 - self.p_peer_loss) * self.restore_fraction
                   * rload_staging_ns(t, nbytes))
        k = self.choose_shards(nbytes, log=False)
        pool = (sharded_flush_ns(t, nbytes, k)
                + self.restore_fraction * rload_pool_ns(t, nbytes))
        return {"staging": staging, "pool": pool}

    def choose_spill(self, name: str, nbytes: int) -> str:
        costs = self.spill_costs(nbytes)
        choice = min(costs, key=costs.get)
        self._log("spill", name, nbytes, choice, costs)
        return choice

    # -- shard count ---------------------------------------------------------
    def choose_shards(self, nbytes: int, name: str = "state", *,
                      log: bool = True, device_bytes=None) -> int:
        """Argmin of the modelled sharded-flush wall time.  Candidates stop
        at 2x the link count (beyond that streams only share links and pay
        setup) capped by ``max_shards``.  ``device_bytes`` (the real
        per-device byte loads of a mesh-sharded state, from
        ``meshio.per_device_nbytes``) switches the cost model to
        ``sharded_flush_device_ns`` — per-candidate costs then reflect
        the heaviest pipeline under the actual device layout, and the
        candidate range is additionally capped at the device count (a
        pipeline with no device buffer to drain buys nothing)."""
        t = self.topology
        hi = max(1, min(self.max_shards, 2 * t.n_links))
        if device_bytes is not None:
            hi = max(1, min(hi, len(device_bytes)))
            costs = {k: sharded_flush_device_ns(t, device_bytes, k)
                     for k in range(1, hi + 1)}
        else:
            costs = {k: sharded_flush_ns(t, nbytes, k)
                     for k in range(1, hi + 1)}
        best = min(costs, key=costs.get)
        if log:
            self._log("shards", name, nbytes, best,
                      {f"k{k}": v for k, v in costs.items()})
        return best

    # -- flush schedule ------------------------------------------------------
    def choose_schedule(self, nbytes: int, name: str = "state") -> str:
        """``sync`` when the modelled blocking flush is too small for
        double-buffering to pay for its join bookkeeping, else the
        production ``sharded-async`` schedule."""
        k = self.choose_shards(nbytes, name, log=False)
        flush = sharded_flush_ns(self.topology, nbytes, k)
        choice = "sync" if flush < self.sync_threshold_ns else "sharded-async"
        self._log("schedule", name, nbytes, choice,
                  {"flush_ns": flush,
                   "sync_threshold_ns": self.sync_threshold_ns})
        return choice


    # -- fleet admission -----------------------------------------------------
    def admission_costs(self, queue_depths: Dict[int, int], nbytes: int,
                        reusable: Dict[int, bool]) -> Dict[str, float]:
        """Expected ns until a new request's first token, per engine.
        Two terms: the queue wait (depth x modelled decode tick) and the
        prefill — replayed from the prompt at ``replay_ns_per_byte``
        unless this engine can restore a shared-prefix block set from
        the pool (``reusable``), which costs a pool RLoad instead."""
        t = self.topology
        out: Dict[str, float] = {}
        for eid, depth in queue_depths.items():
            fill = (rload_pool_ns(t, nbytes) if reusable.get(eid)
                    else self.replay_ns_per_byte * nbytes)
            out[f"e{eid}"] = depth * self.decode_tick_ns + fill
        return out

    def choose_admission(self, rid: str, queue_depths: Dict[int, int],
                         nbytes: int,
                         reusable: Dict[int, bool] = {}) -> int:
        """Pick the engine a new request is routed to (lowest expected
        time-to-first-token; ties break to the lowest engine id, which
        keeps the decision deterministic).  Logged as ``admit``."""
        costs = self.admission_costs(queue_depths, nbytes, reusable)
        choice = min(sorted(costs), key=costs.get)
        self._log("admit", rid, nbytes, choice, costs)
        return int(choice[1:])

    # -- fleet rebalancing ---------------------------------------------------
    def migration_costs(self, nbytes: int, imbalance: int
                        ) -> Dict[str, float]:
        """``move``: RStore the session's dirty blocks into the target's
        staging buffer + the target's adoption read.  ``stay``: the
        queue-depth gap keeps costing the session one decode-tick wait
        per tick of imbalance.  Clean pool-resident blocks move zero
        bytes either way (the block table carries them by reference)."""
        t = self.topology
        return {"move": rstore_ns(t, nbytes) + rload_staging_ns(t, nbytes),
                "stay": max(0, imbalance) * self.decode_tick_ns}

    def choose_migration(self, rid: str, nbytes: int,
                         imbalance: int) -> bool:
        """Is migrating ``rid``'s ``nbytes`` of dirty blocks to the less
        loaded engine worth the transfer, given the queue-depth
        ``imbalance`` (source depth minus target depth)?  Logged as
        ``migrate``."""
        costs = self.migration_costs(nbytes, imbalance)
        choice = costs["move"] < costs["stay"]
        self._log("migrate", rid, nbytes, choice, costs)
        return choice

    # -- fleet scaling -------------------------------------------------------
    def _queue_wait_ns(self, queue_depth: int, lanes: int,
                       session_ticks: float) -> float:
        """Total modelled wait of a ``queue_depth``-deep FIFO draining
        through ``lanes`` decode lanes: a lane is HELD for a whole
        session (~``session_ticks`` ticks), so the drain rate is
        lanes/session_ticks sessions per tick and the i-th queued
        session waits ~i*session_ticks/lanes ticks — summing to
        Q(Q+1)/2 * session_ticks/lanes ticks of wait."""
        if lanes <= 0:
            return float("inf")
        q = max(0, queue_depth)
        return (q * (q + 1) / 2.0 * session_ticks / lanes
                * self.decode_tick_ns)

    def scale_costs(self, queue_depth: int, n_engines: int,
                    slots_per_engine: int, state_nbytes: int, *,
                    busy_lanes: int = 0,
                    session_ticks: float = 16.0,
                    session_nbytes: int = 0,
                    window_ticks: int = 32,
                    engine_tick_ns: float = 2e5,
                    min_engines: int = 1,
                    max_engines: int = 8) -> Dict[str, float]:
        """Modelled ns of each scale action over the next decision window.
        Every alternative pays capacity rent (engines x ``engine_tick_ns``
        x window) plus the projected queue wait at the resulting lane
        count; ``grow`` additionally pays the join capital — the staged
        state transfer + re-flush (``emu.join_transfer_ns``) — and
        ``shrink`` pays draining a closing engine's live sessions to
        peers (RStore + adoption read per slot) AND the wait of the load
        the lost lanes displace (``busy_lanes`` — shrinking a busy fleet
        queues what no longer fits).  The controller scales out only
        when the queueing relief beats the join capital within the
        window — the inequality documented in ARCHITECTURE §12."""
        t = self.topology
        lanes = n_engines * slots_per_engine
        rent = engine_tick_ns * window_ticks
        wait = lambda q, l: self._queue_wait_ns(q, l, session_ticks)
        costs = {"hold": wait(queue_depth, lanes) + n_engines * rent}
        if n_engines < max_engines:
            k = self.choose_shards(state_nbytes, log=False)
            costs["grow"] = (join_transfer_ns(t, state_nbytes, k)
                            + wait(queue_depth, lanes + slots_per_engine)
                            + (n_engines + 1) * rent)
        if n_engines > min_engines:
            drain = slots_per_engine * (rstore_ns(t, session_nbytes)
                                        + rload_staging_ns(t, session_nbytes))
            lanes_after = lanes - slots_per_engine
            displaced = queue_depth + max(0, busy_lanes - lanes_after)
            costs["shrink"] = (drain + wait(displaced, lanes_after)
                              + (n_engines - 1) * rent)
        return costs

    def choose_scale(self, name: str, queue_depth: int, n_engines: int,
                     slots_per_engine: int, state_nbytes: int, *,
                     busy_lanes: int = 0, session_ticks: float = 16.0,
                     session_nbytes: int = 0, window_ticks: int = 32,
                     engine_tick_ns: float = 2e5, min_engines: int = 1,
                     max_engines: int = 8) -> str:
        """Pick hold / grow / shrink for the fleet (ties break to
        ``hold`` — scaling must strictly pay for itself).  Logged as
        ``scale`` with every priced alternative, so the decision log
        shows WHY capacity moved, per topology."""
        costs = self.scale_costs(
            queue_depth, n_engines, slots_per_engine, state_nbytes,
            busy_lanes=busy_lanes, session_ticks=session_ticks,
            session_nbytes=session_nbytes, window_ticks=window_ticks,
            engine_tick_ns=engine_tick_ns, min_engines=min_engines,
            max_engines=max_engines)
        choice = min(sorted(costs), key=lambda a: (costs[a], a != "hold"))
        if costs[choice] >= costs["hold"]:
            choice = "hold"
        self._log("scale", name, state_nbytes, choice, costs)
        return choice


def plan_rank_staging(policy: PlacementPolicy, nbytes: int,
                      name: str = "partition") -> bool:
    """Should a cluster rank RStore-stage its ``nbytes`` partition into its
    ring sibling every step?  Yes iff the policy's spill model prefers the
    staging tier for this size under the active topology — otherwise the
    per-step RStore is dead weight and recovery should come from the pool
    (which the commit cadence already feeds).  Logged as a ``staging``
    decision."""
    costs = policy.spill_costs(nbytes)
    choice = costs["staging"] <= costs["pool"]
    policy._log("staging", name, nbytes, choice, costs)
    return choice
