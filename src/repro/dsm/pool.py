"""Persistent object pool — the ``M_k`` tier (owner memory) of the runtime.

On-disk layout (one directory per pool, usually on shared storage):

    pool/
      objects/<object>/<version>.cxl0      # streamed, self-validating frame
      objects/<object>.s<k>/<version>.cxl0 # shard k of a SHARDED write
      objects/<object>/<version>.npz       # LEGACY payload (+ .crc sidecar)
      manifest.json                        # CURRENT committed versions
      manifest.<n>.json                    # history (GC-bounded)

Write protocol (the MStore/RFlush realization):
  1. stream ``<version>.cxl0`` to a temp name — one pass, folding the
     CRC32 chunk-by-chunk as the bytes go out (``repro.dsm.stream``);
  2. fsync, then atomically rename into place.
The frame is self-validating (header CRC + folded payload CRC in the
footer), so no sidecar write/fsync is needed — half the fsyncs of the
legacy ``.npz`` + ``.crc`` pair, which the read path still accepts for
pools written before the streamed format existed.
A *commit* (``completeOp``) atomically renames a new ``manifest.json``
listing every object's version + CRC.  Readers validate CRCs; a torn or
bit-flipped shard fails validation and recovery falls back to the previous
manifest — the recovered state is always SOME completed commit (never torn),
which is exactly durable linearizability of the step history.  Reads are
mmap-backed and zero-copy: ``read_object`` returns ``np.frombuffer`` views
into private copy-on-write pages (``read_frame``), never an intermediate
deserialization buffer.

Multi-writer safety: a pool is a SHARED resource — several worker
processes (the cluster protocol, ``repro.dsm.cluster``) or a restarted
incarnation of the same committer may commit concurrently.
``commit_manifest`` therefore reserves its sequence number atomically: it
``O_EXCL``-creates ``manifest.<n>.json`` (re-scanning and retrying on
``FileExistsError``) and only then atomically renames the full document
over the reservation.  A reservation whose writer died before the rename
is an unparseable (empty) file that every reader skips; no completed
commit is ever overwritten.  Object names may be namespaced with ``/``
(the cluster protocol uses ``w<i>/<name>`` per worker); nested
directories are handled by ``max_version`` and ``gc``.

Sharded writes (the sharded/sharded-async commit schedules): a pytree's
leaves are partitioned into ``n_shards`` byte-balanced groups
(``partition_leaves``) and each group is written — usually in parallel, one
LStore/RFlush pipeline per shard — as an independent object
``<name>.s<k>``.  The manifest entry for a sharded object records every
shard's (name, version, crc) plus the leaf->shard ``assignment`` so readers
can reassemble the pytree (``read_entry``).  Durability is unchanged: no
shard is visible until the manifest rename, and a missing/corrupt shard
fails CRC validation of the WHOLE object, forcing fallback to the previous
manifest.  Manifest history is bounded by ``gc(keep=...)``, which retains
the newest ``keep`` manifests and deletes versions (plain or sharded) that
no retained manifest references.
"""
from __future__ import annotations

import dataclasses
import json
import os
import tempfile
import zipfile
import zlib
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

import jax

from repro.dsm import stream
from repro.dsm.stream import SpillArena  # noqa: F401  (re-export)


@dataclasses.dataclass
class PoolObject:
    name: str
    version: int
    crc: int
    nbytes: int


@dataclasses.dataclass
class ShardedObject:
    """One logical object written as ``len(shards)`` independent pool
    objects (``<name>.s<k>``).  ``assignment[k]`` lists the flattened-leaf
    indices stored in shard k."""
    name: str
    version: int
    nbytes: int
    n_leaves: int
    shards: List[PoolObject]
    assignment: List[List[int]]

    def to_entry(self) -> dict:
        return {
            "name": self.name, "version": self.version,
            "nbytes": self.nbytes, "n_leaves": self.n_leaves,
            "sharded": True,
            "shards": [dataclasses.asdict(s) for s in self.shards],
            "assignment": self.assignment,
        }


def manifest_entry(obj) -> dict:
    """Serialize a PoolObject / ShardedObject / ready-made dict for the
    manifest."""
    if isinstance(obj, ShardedObject):
        return obj.to_entry()
    if dataclasses.is_dataclass(obj):
        return dataclasses.asdict(obj)
    return dict(obj)


def shard_family(name: str) -> str:
    """The logical object a (possibly shard) name belongs to:
    ``params.s3`` -> ``params``, anything else unchanged.  gc's in-flight
    watermark is per FAMILY, because one committer may write an object
    plain while another manifest references it sharded (or with a
    different shard count) — they share one version counter."""
    base, dot, suffix = name.rpartition(".s")
    if dot and suffix.isdigit():
        return base
    return name


def partition_leaves(nbytes: List[int], n_shards: int) -> List[List[int]]:
    """Byte-balanced partition of leaf indices into ``<= n_shards`` groups
    (greedy: biggest leaf onto the lightest shard).  Never returns an empty
    shard — the shard count is clamped to the leaf count."""
    n_shards = max(1, min(n_shards, len(nbytes)))
    order = sorted(range(len(nbytes)), key=lambda i: -nbytes[i])
    loads = [0] * n_shards
    groups: List[List[int]] = [[] for _ in range(n_shards)]
    for i in order:
        k = min(range(n_shards), key=lambda j: loads[j])
        groups[k].append(i)
        loads[k] += nbytes[i]
    for g in groups:
        g.sort()
    return groups


def _flatten(tree) -> Tuple[List[np.ndarray], Any]:
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return [np.asarray(l) for l in leaves], treedef


def _crc_of_arrays(arrays: List[np.ndarray]) -> int:
    crc = 0
    for a in arrays:
        crc = zlib.crc32(np.ascontiguousarray(a).tobytes(), crc)
    return crc


class CorruptObjectError(Exception):
    pass


#: dtypes numpy's npz round-trips natively; everything else (bfloat16,
#: float8 variants, ...) is stored as a raw byte view + sidecar dtype
_NATIVE_DTYPES = {
    "bool", "int8", "int16", "int32", "int64", "uint8", "uint16", "uint32",
    "uint64", "float16", "float32", "float64", "complex64", "complex128",
}


def encode_arrays(arrays: List[np.ndarray]
                  ) -> Tuple[List[np.ndarray], List[str], List[List[int]]]:
    """npz cannot round-trip ml_dtypes (bfloat16 etc.): return raw uint8
    views for non-native dtypes plus the (dtype, shape) sidecar data needed
    to reverse the view on read.  Shared by the pool write path and the
    cross-process staging area (``repro.dsm.cluster``)."""
    dtypes = [str(a.dtype) for a in arrays]
    raw = [np.ascontiguousarray(a).view(np.uint8)
           if d not in _NATIVE_DTYPES else a
           for a, d in zip(arrays, dtypes)]
    shapes = [list(a.shape) for a in arrays]
    return raw, dtypes, shapes


def decode_arrays(arrays: List[np.ndarray], dtypes: List[str],
                  shapes: List[List[int]]) -> List[np.ndarray]:
    """Reverse of ``encode_arrays``."""
    import ml_dtypes  # noqa: F401  (registers bfloat16 et al.)
    return [a if d in _NATIVE_DTYPES
            else a.view(np.dtype(d)).reshape(shape)
            for a, d, shape in zip(arrays, dtypes, shapes)]


class PendingWrite:
    """A streamed-but-not-yet-durable object write.  ``start_write``
    already pushed the whole frame (CRC folded during the stream) onto a
    temp file; ``finish`` pays the fsync and performs the atomic rename.
    Splitting the two lets the sharded flush pipelines stream shard k+1's
    bytes while shard k sits in its fsync — serialize/write and fsync
    overlap instead of queueing (``TierManager._shard_submit``)."""

    __slots__ = ("_pool", "name", "version", "crc", "nbytes",
                 "_file", "_tmp", "_dst")

    def __init__(self, pool: "DSMPool", name: str, version: int,
                 crc: int, nbytes: int, file, tmp: str, dst: str):
        self._pool = pool
        self.name = name
        self.version = version
        self.crc = crc
        self.nbytes = nbytes
        self._file = file
        self._tmp = tmp
        self._dst = dst

    def finish(self) -> PoolObject:
        """Make the write durable (fsync) and visible (atomic rename).
        MStore semantics: returns only once the object is on storage."""
        f, self._file = self._file, None
        try:
            f.flush()
            os.fsync(f.fileno())
        finally:
            f.close()
        os.replace(self._tmp, self._dst)
        self._pool._finalize_write(self.name, self.version, self._dst)
        return PoolObject(self.name, self.version, self.crc, self.nbytes)

    def abort(self):
        """Drop an unfinished write (nothing became visible)."""
        if self._file is not None:
            try:
                self._file.close()
            finally:
                self._file = None
        try:
            os.unlink(self._tmp)
        except OSError:
            pass


class DSMPool:
    def __init__(self, path: str):
        self.path = path
        self.obj_dir = os.path.join(path, "objects")
        os.makedirs(self.obj_dir, exist_ok=True)
        self._manifest_seq = self._latest_manifest_seq()
        #: reusable spill-buffer arena of this pool's streamed writes
        #: (per-thread slots inside; sharded pipelines pass their
        #: TierManager's own arena via ``start_write(..., arena=)``)
        self._arena = stream.SpillArena()

    # -- low-level object IO -------------------------------------------------
    def _obj_path(self, name: str, version: int) -> str:
        d = os.path.join(self.obj_dir, name)
        os.makedirs(d, exist_ok=True)
        return os.path.join(d, f"{version:08d}")

    def payload_path(self, name: str, version: int) -> str:
        """The on-disk payload file of ``(name, version)`` — streamed
        ``.cxl0`` if present, else the legacy ``.npz`` (tests and the
        fault layer corrupt payloads through this)."""
        base = self._obj_path(name, version)
        if os.path.exists(base + stream.SUFFIX):
            return base + stream.SUFFIX
        if os.path.exists(base + ".npz"):
            return base + ".npz"
        return base + stream.SUFFIX

    def _mkstemp(self, base: str) -> Tuple[int, str]:
        try:
            return tempfile.mkstemp(dir=os.path.dirname(base))
        except FileNotFoundError:
            # a concurrent gc() rmdir'd the (momentarily empty) object dir
            # between our makedirs and mkstemp — recreate and retry once
            os.makedirs(os.path.dirname(base), exist_ok=True)
            return tempfile.mkstemp(dir=os.path.dirname(base))

    def start_write(self, name: str, version: int, tree,
                    arena: Optional[stream.SpillArena] = None
                    ) -> PendingWrite:
        """Stream one object version onto a temp file — the CPU half of a
        durable write (serialize + write + incremental CRC, single pass,
        no fsync).  Durability and visibility happen in the returned
        handle's ``finish()``."""
        arrays, _ = _flatten(tree)
        base = self._obj_path(name, version)
        tmp_fd, tmp_name = self._mkstemp(base)
        f = os.fdopen(tmp_fd, "wb")
        try:
            crc, nbytes, _ = stream.write_frame(
                f, arrays, arena or self._arena)
        except BaseException:
            f.close()
            try:
                os.unlink(tmp_name)
            except OSError:
                pass
            raise
        return PendingWrite(self, name, version, crc, nbytes, f,
                            tmp_name, base + stream.SUFFIX)

    def write_object(self, name: str, version: int, tree) -> PoolObject:
        """Durable write of one object version (MStore semantics: complete
        only once on physical storage).  One pass over the data: each
        leaf's buffer is streamed via memoryview in CHUNK-sized slices
        with the CRC32 folded as it goes — no ``np.savez`` zip walk, no
        second ``tobytes()`` CRC pass, no sidecar fsync."""
        pending = self.start_write(name, version, tree)
        try:
            return pending.finish()
        except BaseException:
            pending.abort()
            raise

    def _finalize_write(self, name: str, version: int, payload_path: str):
        """Hook: runs after a payload's atomic rename made it visible, in
        BOTH the one-shot and split-phase write paths.  The fault layer
        (``FaultyPool``) tears payloads here — keeping the injection on
        this hook rather than on ``write_object`` means pipelined shard
        writes stay corruptible and the fuzzer's oracle stays in sync."""

    def write_object_legacy(self, name: str, version: int,
                            tree) -> PoolObject:
        """The PR-6 write path: ``np.savez`` payload + JSON ``.crc``
        sidecar, two fsyncs, three passes over the data.  Kept (a) so
        backward-compat tests can fabricate old pools and (b) as the
        in-bench comparison baseline for the streamed fast path."""
        arrays, treedef = _flatten(tree)
        crc = _crc_of_arrays(arrays)
        base = self._obj_path(name, version)
        tmp_fd, tmp_name = self._mkstemp(base)
        os.close(tmp_fd)
        raw, dtypes, shapes = encode_arrays(arrays)
        with open(tmp_name, "wb") as f:
            np.savez(f, **{f"a{i}": a for i, a in enumerate(raw)})
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp_name, base + ".npz")
        meta = {"crc": crc, "treedef": str(treedef),
                "n": len(arrays), "dtypes": dtypes, "shapes": shapes}
        with open(base + ".crc.tmp", "w") as f:
            json.dump(meta, f)
            f.flush()
            os.fsync(f.fileno())
        os.replace(base + ".crc.tmp", base + ".crc")
        nbytes = sum(a.nbytes for a in arrays)
        self._finalize_write(name, version, base + ".npz")
        return PoolObject(name, version, crc, nbytes)

    def max_version(self, name: str) -> int:
        """Highest version present on disk for ``name`` INCLUDING its shard
        objects (``name.s<k>``) and torn/unreferenced files.  A fresh worker
        incarnation seeds its version counter above this so it can never
        overwrite a file an existing manifest still references.  Handles
        namespaced names (``w<i>/<name>``): the object dir and its shard
        sibling dirs live under the namespace directory."""
        best = 0
        parent = os.path.dirname(os.path.join(self.obj_dir, name))
        base = os.path.basename(name)
        prefix = base + ".s"
        if not os.path.isdir(parent):
            return 0
        for d in os.listdir(parent):
            if d != base and not (d.startswith(prefix)
                                  and d[len(prefix):].isdigit()):
                continue
            p = os.path.join(parent, d)
            if not os.path.isdir(p):
                continue
            for fn in os.listdir(p):
                stem = fn.split(".")[0]
                if stem.isdigit():
                    best = max(best, int(stem))
        return best

    def read_object(self, name: str, version: int, treedef_like,
                    expected_crc: Optional[int] = None) -> Any:
        """Read + CRC-validate one object version; raises CorruptObjectError
        on mismatch (recovery then falls back to an older manifest).
        ``expected_crc`` (the MANIFEST-recorded crc) additionally guards
        against the payload having been atomically replaced by a
        different write since the manifest committed.

        Streamed objects are mmap'd and returned as zero-copy
        ``np.frombuffer`` views (private copy-on-write pages); the CRC
        fold is one pass over the page cache.  Legacy ``.npz`` + sidecar
        pairs written by older pools take the original decode path."""
        base = self._obj_path(name, version)
        if os.path.exists(base + stream.SUFFIX):
            try:
                arrays, crc, _ = stream.read_frame(base + stream.SUFFIX)
            except (stream.FrameError, OSError) as e:
                raise CorruptObjectError(f"{name}@{version}: {e}") from e
            if expected_crc is not None and crc != expected_crc:
                raise CorruptObjectError(
                    f"{name}@{version}: content does not match the "
                    f"manifest (overwritten by a later write?)")
            _, treedef = jax.tree_util.tree_flatten(treedef_like)
            return jax.tree_util.tree_unflatten(treedef, arrays)
        try:
            with open(base + ".crc") as f:
                meta = json.load(f)
            with np.load(base + ".npz") as z:
                arrays = [z[f"a{i}"] for i in range(meta["n"])]
            if "dtypes" in meta:
                arrays = decode_arrays(arrays, meta["dtypes"], meta["shapes"])
        except (OSError, KeyError, ValueError, TypeError, EOFError,
                zipfile.BadZipFile, zlib.error) as e:
            raise CorruptObjectError(f"{name}@{version}: {e}") from e
        if _crc_of_arrays(arrays) != meta["crc"]:
            raise CorruptObjectError(f"{name}@{version}: CRC mismatch")
        if expected_crc is not None and meta["crc"] != expected_crc:
            raise CorruptObjectError(
                f"{name}@{version}: content does not match the manifest "
                f"(overwritten by a later write?)")
        _, treedef = jax.tree_util.tree_flatten(treedef_like)
        return jax.tree_util.tree_unflatten(treedef, arrays)

    # -- manifests (completeOp) ----------------------------------------------
    def _latest_manifest_seq(self) -> int:
        best = -1
        for fn in os.listdir(self.path):
            if fn.startswith("manifest.") and fn.endswith(".json"):
                mid = fn[len("manifest."):-len(".json")]
                if mid.isdigit():
                    best = max(best, int(mid))
        return best

    def _reserve_manifest_seq(self) -> Tuple[int, str]:
        """Atomically reserve the next manifest sequence number: O_EXCL
        create of ``manifest.<n>.json`` at n = newest-on-disk + 1, re-scan
        and retry on collision.  Two committers (concurrent workers, or a
        restarted incarnation racing a stale one) can therefore never pick
        the same n — the init-time cached ``_manifest_seq`` is only a hint
        and is NEVER trusted for the reservation."""
        while True:
            seq = max(self._latest_manifest_seq(), self._manifest_seq) + 1
            dst = os.path.join(self.path, f"manifest.{seq}.json")
            try:
                fd = os.open(dst, os.O_CREAT | os.O_EXCL | os.O_WRONLY)
            except FileExistsError:
                self._manifest_seq = seq    # lost the race: scan past it
                continue
            os.close(fd)
            return seq, dst

    def commit_manifest(self, step: int, objects: Dict[str, Any],
                        meta: Optional[dict] = None) -> int:
        """Atomic commit: the step is durable iff the full manifest document
        replaced its reservation.  ``objects`` values may be PoolObject
        (plain), ShardedObject, or ready-made manifest-entry dicts.

        Multi-writer safe: the sequence number is reserved via O_EXCL
        create (see ``_reserve_manifest_seq``); the document is then
        written to a temp file, fsync'd, and atomically renamed OVER the
        reservation.  Readers either see the empty reservation (skipped as
        unparseable) or the complete document — a concurrent or restarted
        committer can never clobber a completed commit.

        The document is serialized and fsync'd ONCE: the convenience head
        pointer (``manifest.json``) is a hardlink to the same already-
        durable inode, atomically renamed into place — half the fsyncs of
        writing the document twice.  On filesystems without hardlinks the
        head falls back to a second write."""
        seq, dst = self._reserve_manifest_seq()
        self._manifest_seq = seq
        doc = {
            "seq": seq,
            "step": step,
            "objects": {name: manifest_entry(o)
                        for name, o in objects.items()},
            "meta": meta or {},
        }
        tmp = os.path.join(self.path, f".manifest.tmp.{seq}")
        with open(tmp, "w") as f:
            json.dump(doc, f)
            f.flush()
            os.fsync(f.fileno())
        # link the head's temp name to the fsync'd inode BEFORE the rename
        # consumes ``tmp`` — no second serialize, no second fsync
        head = os.path.join(self.path, "manifest.json")
        tmp2 = os.path.join(self.path, f".manifest.head.tmp.{seq}")
        try:
            os.link(tmp, tmp2)
        except OSError:
            tmp2 = None                 # no hardlinks here: write it twice
        os.replace(tmp, dst)
        # update the convenience head pointer last (also atomic; with
        # concurrent committers last-writer-wins — readers that need the
        # true newest manifest use manifests_desc())
        if tmp2 is None:
            tmp2 = os.path.join(self.path, f".manifest.head.tmp.{seq}")
            with open(tmp2, "w") as f:
                json.dump(doc, f)
                f.flush()
                os.fsync(f.fileno())
        os.replace(tmp2, head)
        return seq

    def read_entry(self, name: str, entry: dict, treedef_like) -> Any:
        """Read + validate one manifest entry, plain or sharded, checking
        content against the manifest-recorded CRCs.  For a sharded entry
        every shard must validate — the shards are read in parallel,
        mirroring the write pipelines — and any torn or corrupt shard
        raises CorruptObjectError for the WHOLE object (recovery then falls
        back to an older manifest)."""
        if not entry.get("sharded"):
            return self.read_object(name, entry["version"], treedef_like,
                                    expected_crc=entry.get("crc"))
        leaves: List[Any] = [None] * entry["n_leaves"]
        shards = list(zip(entry["shards"], entry["assignment"]))
        from concurrent.futures import ThreadPoolExecutor
        with ThreadPoolExecutor(max_workers=len(shards)) as ex:
            parts = list(ex.map(
                lambda sa: self.read_object(sa[0]["name"], sa[0]["version"],
                                            [0] * len(sa[1]),
                                            expected_crc=sa[0].get("crc")),
                shards))
        for (sh, idxs), part in zip(shards, parts):
            for i, a in zip(idxs, part):
                leaves[i] = a
        if any(l is None for l in leaves):
            raise CorruptObjectError(
                f"{name}@{entry['version']}: incomplete shard assignment")
        _, treedef = jax.tree_util.tree_flatten(treedef_like)
        return jax.tree_util.tree_unflatten(treedef, leaves)

    def manifests_desc(self) -> List[dict]:
        """All manifests, newest first — ordered by (step, seq), so logical
        time dominates.  With a single committer seq order IS step order;
        with concurrent committers a straggler may rename a manifest for an
        older step after a newer step's manifest landed (its seq is higher
        but its step is older), and recovery must still prefer the newest
        STEP.  Unparseable files (reservations whose writer died before the
        rename) are skipped."""
        out = []
        for fn in os.listdir(self.path):
            if fn.startswith("manifest.") and fn.endswith(".json"):
                mid = fn[len("manifest."):-len(".json")]
                if not mid.isdigit():
                    continue
                try:
                    with open(os.path.join(self.path, fn)) as f:
                        out.append(json.load(f))
                except (OSError, ValueError):
                    continue
        return sorted(out, key=lambda d: (-d["step"], -d["seq"]))

    def latest_manifest(self) -> Optional[dict]:
        ms = self.manifests_desc()
        return ms[0] if ms else None

    def gc(self, keep: int = 3):
        """Drop all but the newest ``keep`` manifests + unreferenced
        versions (the committer's retention policy calls this after every
        completeOp).  Handles sharded entries (every referenced shard stays
        live), namespaced objects (``w<i>/<name>`` — the walk is
        recursive), and skips files it cannot parse — e.g. tempfiles left
        by an incarnation that crashed mid-write — rather than aborting.

        Emptied object directories are removed: a long-lived pool that
        retires objects (e.g. serving's ``kv/<rid>`` with ``--retire-done``)
        must not accumulate thousands of stale ``objects/<name>/`` dirs
        forever.  A dir holding a tempfile of an in-flight write is not
        empty, so rmdir (which fails on non-empty dirs) never races a
        completed write; the one-in-a-million makedirs/mkstemp window is
        covered by write_object's retry.

        Dead manifest reservations (unparseable ``manifest.<n>.json`` whose
        writer crashed between reserve and rename) older than every kept
        manifest are deleted too — they can never become valid.

        Multi-writer tolerance: version counters are monotone per object
        (seeded above the on-disk max), so an unreferenced version NEWER
        than the newest kept reference of its object may be a concurrent
        writer's flushed-but-not-yet-committed file — gc never deletes
        those (once a later manifest references a higher version, a
        genuinely dead one falls behind the watermark and is collected).
        Versions of an object no kept manifest mentions at all are
        retired (e.g. a finished serving session's ``kv/<rid>``) and are
        deleted entirely, directory included."""
        keep = max(1, keep)
        ms = self.manifests_desc()
        keep_ms, drop_ms = ms[:keep], ms[keep:]
        live = set()
        #: family -> newest version any kept manifest references (the
        #: in-flight watermark; plain and sharded writes of one object
        #: share a version counter, so the family is the right key)
        watermark: Dict[str, int] = {}

        def _mark(name: str, version: int):
            fam = shard_family(name)
            watermark[fam] = max(watermark.get(fam, 0), version)

        for m in keep_ms:
            for n, o in m["objects"].items():
                if o.get("sharded"):
                    for s in o["shards"]:
                        live.add((s["name"], s["version"]))
                        _mark(s["name"], s["version"])
                else:
                    live.add((n, o["version"]))
                    _mark(n, o["version"])
        for m in drop_ms:
            try:
                os.unlink(os.path.join(self.path,
                                       f"manifest.{m['seq']}.json"))
            except OSError:
                pass
        if keep_ms:
            min_kept = min(m["seq"] for m in keep_ms)
            parsed = {m["seq"] for m in ms}
            for fn in os.listdir(self.path):
                if not (fn.startswith("manifest.") and fn.endswith(".json")):
                    continue
                mid = fn[len("manifest."):-len(".json")]
                if mid.isdigit() and int(mid) < min_kept \
                        and int(mid) not in parsed:
                    try:
                        os.unlink(os.path.join(self.path, fn))
                    except OSError:
                        pass
        for dirpath, dirnames, filenames in os.walk(self.obj_dir,
                                                    topdown=False):
            name = os.path.relpath(dirpath, self.obj_dir).replace(os.sep, "/")
            for fn in filenames:
                stem = fn.split(".")[0]
                if not stem.isdigit():
                    continue        # tempfile from a crashed write
                v = int(stem)
                if (name, v) in live:
                    continue
                fam = shard_family(name)
                if fam in watermark and v > watermark[fam]:
                    continue    # newer than every kept reference of this
                    #             object: may be a concurrent writer's
                    #             in-flight commit
                try:
                    os.unlink(os.path.join(dirpath, fn))
                except OSError:
                    pass
            if dirpath != self.obj_dir:
                try:
                    os.rmdir(dirpath)       # fails (harmlessly) if non-empty
                except OSError:
                    pass
