"""Persistent object pool — the ``M_k`` tier (owner memory) of the runtime.

On-disk layout (one directory per pool, usually on shared storage):

    pool/
      objects/<object>/<version>.npz       # flattened pytree + CRC32 sidecar
      objects/<object>/<version>.crc
      objects/<object>.s<k>/<version>.npz  # shard k of a SHARDED write
      manifest.json                        # CURRENT committed versions
      manifest.<n>.json                    # history (GC-bounded)

Write protocol (the MStore/RFlush realization):
  1. write ``<version>.npz`` to a temp name, fsync;
  2. write the CRC sidecar, fsync;
  3. atomically rename both into place.
A *commit* (``completeOp``) atomically renames a new ``manifest.json``
listing every object's version + CRC.  Readers validate CRCs; a torn or
bit-flipped shard fails validation and recovery falls back to the previous
manifest — the recovered state is always SOME completed commit (never torn),
which is exactly durable linearizability of the step history.

Sharded writes (the sharded/sharded-async commit schedules): a pytree's
leaves are partitioned into ``n_shards`` byte-balanced groups
(``partition_leaves``) and each group is written — usually in parallel, one
LStore/RFlush pipeline per shard — as an independent object
``<name>.s<k>``.  The manifest entry for a sharded object records every
shard's (name, version, crc) plus the leaf->shard ``assignment`` so readers
can reassemble the pytree (``read_entry``).  Durability is unchanged: no
shard is visible until the manifest rename, and a missing/corrupt shard
fails CRC validation of the WHOLE object, forcing fallback to the previous
manifest.  Manifest history is bounded by ``gc(keep=...)``, which retains
the newest ``keep`` manifests and deletes versions (plain or sharded) that
no retained manifest references.
"""
from __future__ import annotations

import dataclasses
import json
import os
import tempfile
import zipfile
import zlib
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

import jax


@dataclasses.dataclass
class PoolObject:
    name: str
    version: int
    crc: int
    nbytes: int


@dataclasses.dataclass
class ShardedObject:
    """One logical object written as ``len(shards)`` independent pool
    objects (``<name>.s<k>``).  ``assignment[k]`` lists the flattened-leaf
    indices stored in shard k."""
    name: str
    version: int
    nbytes: int
    n_leaves: int
    shards: List[PoolObject]
    assignment: List[List[int]]

    def to_entry(self) -> dict:
        return {
            "name": self.name, "version": self.version,
            "nbytes": self.nbytes, "n_leaves": self.n_leaves,
            "sharded": True,
            "shards": [dataclasses.asdict(s) for s in self.shards],
            "assignment": self.assignment,
        }


def manifest_entry(obj) -> dict:
    """Serialize a PoolObject / ShardedObject / ready-made dict for the
    manifest."""
    if isinstance(obj, ShardedObject):
        return obj.to_entry()
    if dataclasses.is_dataclass(obj):
        return dataclasses.asdict(obj)
    return dict(obj)


def partition_leaves(nbytes: List[int], n_shards: int) -> List[List[int]]:
    """Byte-balanced partition of leaf indices into ``<= n_shards`` groups
    (greedy: biggest leaf onto the lightest shard).  Never returns an empty
    shard — the shard count is clamped to the leaf count."""
    n_shards = max(1, min(n_shards, len(nbytes)))
    order = sorted(range(len(nbytes)), key=lambda i: -nbytes[i])
    loads = [0] * n_shards
    groups: List[List[int]] = [[] for _ in range(n_shards)]
    for i in order:
        k = min(range(n_shards), key=lambda j: loads[j])
        groups[k].append(i)
        loads[k] += nbytes[i]
    for g in groups:
        g.sort()
    return groups


def _flatten(tree) -> Tuple[List[np.ndarray], Any]:
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return [np.asarray(l) for l in leaves], treedef


def _crc_of_arrays(arrays: List[np.ndarray]) -> int:
    crc = 0
    for a in arrays:
        crc = zlib.crc32(np.ascontiguousarray(a).tobytes(), crc)
    return crc


class CorruptObjectError(Exception):
    pass


#: dtypes numpy's npz round-trips natively; everything else (bfloat16,
#: float8 variants, ...) is stored as a raw byte view + sidecar dtype
_NATIVE_DTYPES = {
    "bool", "int8", "int16", "int32", "int64", "uint8", "uint16", "uint32",
    "uint64", "float16", "float32", "float64", "complex64", "complex128",
}


class DSMPool:
    def __init__(self, path: str):
        self.path = path
        self.obj_dir = os.path.join(path, "objects")
        os.makedirs(self.obj_dir, exist_ok=True)
        self._manifest_seq = self._latest_manifest_seq()

    # -- low-level object IO -------------------------------------------------
    def _obj_path(self, name: str, version: int) -> str:
        d = os.path.join(self.obj_dir, name)
        os.makedirs(d, exist_ok=True)
        return os.path.join(d, f"{version:08d}")

    def write_object(self, name: str, version: int, tree) -> PoolObject:
        """Durable write of one object version (MStore semantics: complete
        only once on physical storage)."""
        arrays, treedef = _flatten(tree)
        crc = _crc_of_arrays(arrays)
        base = self._obj_path(name, version)
        tmp_fd, tmp_name = tempfile.mkstemp(dir=os.path.dirname(base))
        os.close(tmp_fd)
        # npz cannot round-trip ml_dtypes (bfloat16 etc.): store a raw view
        # and record the true dtype in the sidecar
        dtypes = [str(a.dtype) for a in arrays]
        raw = [np.ascontiguousarray(a).view(np.uint8)
               if d not in _NATIVE_DTYPES else a
               for a, d in zip(arrays, dtypes)]
        shapes = [list(a.shape) for a in arrays]
        with open(tmp_name, "wb") as f:
            np.savez(f, **{f"a{i}": a for i, a in enumerate(raw)})
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp_name, base + ".npz")
        meta = {"crc": crc, "treedef": str(treedef),
                "n": len(arrays), "dtypes": dtypes, "shapes": shapes}
        with open(base + ".crc.tmp", "w") as f:
            json.dump(meta, f)
            f.flush()
            os.fsync(f.fileno())
        os.replace(base + ".crc.tmp", base + ".crc")
        nbytes = sum(a.nbytes for a in arrays)
        return PoolObject(name, version, crc, nbytes)

    def max_version(self, name: str) -> int:
        """Highest version present on disk for ``name`` INCLUDING its shard
        objects (``name.s<k>``) and torn/unreferenced files.  A fresh worker
        incarnation seeds its version counter above this so it can never
        overwrite a file an existing manifest still references."""
        best = 0
        prefix = name + ".s"
        for d in os.listdir(self.obj_dir):
            if d != name and not (d.startswith(prefix)
                                  and d[len(prefix):].isdigit()):
                continue
            for fn in os.listdir(os.path.join(self.obj_dir, d)):
                stem = fn.split(".")[0]
                if stem.isdigit():
                    best = max(best, int(stem))
        return best

    def read_object(self, name: str, version: int, treedef_like,
                    expected_crc: Optional[int] = None) -> Any:
        """Read + CRC-validate one object version; raises CorruptObjectError
        on mismatch (recovery then falls back to an older manifest).
        ``expected_crc`` (the MANIFEST-recorded crc) additionally guards
        against the file+sidecar pair having been atomically replaced by a
        different write since the manifest committed."""
        base = self._obj_path(name, version)
        try:
            with open(base + ".crc") as f:
                meta = json.load(f)
            with np.load(base + ".npz") as z:
                arrays = [z[f"a{i}"] for i in range(meta["n"])]
            if "dtypes" in meta:
                import ml_dtypes  # noqa: F401  (registers bfloat16 et al.)
                arrays = [
                    a if d in _NATIVE_DTYPES
                    else a.view(np.dtype(d)).reshape(shape)
                    for a, d, shape in zip(arrays, meta["dtypes"],
                                           meta["shapes"])]
        except (OSError, KeyError, ValueError, TypeError, EOFError,
                zipfile.BadZipFile, zlib.error) as e:
            raise CorruptObjectError(f"{name}@{version}: {e}") from e
        if _crc_of_arrays(arrays) != meta["crc"]:
            raise CorruptObjectError(f"{name}@{version}: CRC mismatch")
        if expected_crc is not None and meta["crc"] != expected_crc:
            raise CorruptObjectError(
                f"{name}@{version}: content does not match the manifest "
                f"(overwritten by a later write?)")
        _, treedef = jax.tree_util.tree_flatten(treedef_like)
        return jax.tree_util.tree_unflatten(treedef, arrays)

    # -- manifests (completeOp) ----------------------------------------------
    def _latest_manifest_seq(self) -> int:
        best = -1
        for fn in os.listdir(self.path):
            if fn.startswith("manifest.") and fn.endswith(".json"):
                mid = fn[len("manifest."):-len(".json")]
                if mid.isdigit():
                    best = max(best, int(mid))
        return best

    def commit_manifest(self, step: int, objects: Dict[str, Any],
                        meta: Optional[dict] = None) -> int:
        """Atomic commit: the step is durable iff this rename completed.
        ``objects`` values may be PoolObject (plain) or ShardedObject."""
        self._manifest_seq += 1
        doc = {
            "seq": self._manifest_seq,
            "step": step,
            "objects": {name: manifest_entry(o)
                        for name, o in objects.items()},
            "meta": meta or {},
        }
        tmp = os.path.join(self.path, f".manifest.tmp.{self._manifest_seq}")
        with open(tmp, "w") as f:
            json.dump(doc, f)
            f.flush()
            os.fsync(f.fileno())
        dst = os.path.join(self.path, f"manifest.{self._manifest_seq}.json")
        os.replace(tmp, dst)
        # update the convenience head pointer last (also atomic)
        head = os.path.join(self.path, "manifest.json")
        tmp2 = os.path.join(self.path, ".manifest.head.tmp")
        with open(tmp2, "w") as f:
            json.dump(doc, f)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp2, head)
        return self._manifest_seq

    def read_entry(self, name: str, entry: dict, treedef_like) -> Any:
        """Read + validate one manifest entry, plain or sharded, checking
        content against the manifest-recorded CRCs.  For a sharded entry
        every shard must validate — the shards are read in parallel,
        mirroring the write pipelines — and any torn or corrupt shard
        raises CorruptObjectError for the WHOLE object (recovery then falls
        back to an older manifest)."""
        if not entry.get("sharded"):
            return self.read_object(name, entry["version"], treedef_like,
                                    expected_crc=entry.get("crc"))
        leaves: List[Any] = [None] * entry["n_leaves"]
        shards = list(zip(entry["shards"], entry["assignment"]))
        from concurrent.futures import ThreadPoolExecutor
        with ThreadPoolExecutor(max_workers=len(shards)) as ex:
            parts = list(ex.map(
                lambda sa: self.read_object(sa[0]["name"], sa[0]["version"],
                                            [0] * len(sa[1]),
                                            expected_crc=sa[0].get("crc")),
                shards))
        for (sh, idxs), part in zip(shards, parts):
            for i, a in zip(idxs, part):
                leaves[i] = a
        if any(l is None for l in leaves):
            raise CorruptObjectError(
                f"{name}@{entry['version']}: incomplete shard assignment")
        _, treedef = jax.tree_util.tree_flatten(treedef_like)
        return jax.tree_util.tree_unflatten(treedef, leaves)

    def manifests_desc(self) -> List[dict]:
        """All manifests, newest first."""
        out = []
        for fn in os.listdir(self.path):
            if fn.startswith("manifest.") and fn.endswith(".json"):
                mid = fn[len("manifest."):-len(".json")]
                if not mid.isdigit():
                    continue
                try:
                    with open(os.path.join(self.path, fn)) as f:
                        out.append(json.load(f))
                except (OSError, ValueError):
                    continue
        return sorted(out, key=lambda d: -d["seq"])

    def latest_manifest(self) -> Optional[dict]:
        ms = self.manifests_desc()
        return ms[0] if ms else None

    def gc(self, keep: int = 3):
        """Drop all but the newest ``keep`` manifests + unreferenced
        versions (the committer's retention policy calls this after every
        completeOp).  Handles sharded entries (every referenced shard stays
        live) and skips files it cannot parse — e.g. tempfiles left by an
        incarnation that crashed mid-write — rather than aborting."""
        keep = max(1, keep)
        ms = self.manifests_desc()
        keep_ms, drop_ms = ms[:keep], ms[keep:]
        live = set()
        for m in keep_ms:
            for n, o in m["objects"].items():
                if o.get("sharded"):
                    live.update((s["name"], s["version"])
                                for s in o["shards"])
                else:
                    live.add((n, o["version"]))
        for m in drop_ms:
            try:
                os.unlink(os.path.join(self.path,
                                       f"manifest.{m['seq']}.json"))
            except OSError:
                pass
        for name in os.listdir(self.obj_dir):
            d = os.path.join(self.obj_dir, name)
            for fn in os.listdir(d):
                stem = fn.split(".")[0]
                if not stem.isdigit():
                    continue        # tempfile from a crashed write
                if (name, int(stem)) not in live:
                    try:
                        os.unlink(os.path.join(d, fn))
                    except OSError:
                        pass
