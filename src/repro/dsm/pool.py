"""Persistent object pool — the ``M_k`` tier (owner memory) of the runtime.

On-disk layout (one directory per pool, usually on shared storage):

    pool/
      objects/<object>/<version>.npz     # flattened pytree + CRC32 sidecar
      objects/<object>/<version>.crc
      manifest.json                      # CURRENT committed versions
      manifest.<n>.json                  # history (GC-bounded)

Write protocol (the MStore/RFlush realization):
  1. write ``<version>.npz`` to a temp name, fsync;
  2. write the CRC sidecar, fsync;
  3. atomically rename both into place.
A *commit* (``completeOp``) atomically renames a new ``manifest.json``
listing every object's version + CRC.  Readers validate CRCs; a torn or
bit-flipped shard fails validation and recovery falls back to the previous
manifest — the recovered state is always SOME completed commit (never torn),
which is exactly durable linearizability of the step history.
"""
from __future__ import annotations

import dataclasses
import json
import os
import tempfile
import zipfile
import zlib
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

import jax


@dataclasses.dataclass
class PoolObject:
    name: str
    version: int
    crc: int
    nbytes: int


def _flatten(tree) -> Tuple[List[np.ndarray], Any]:
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return [np.asarray(l) for l in leaves], treedef


def _crc_of_arrays(arrays: List[np.ndarray]) -> int:
    crc = 0
    for a in arrays:
        crc = zlib.crc32(np.ascontiguousarray(a).tobytes(), crc)
    return crc


class CorruptObjectError(Exception):
    pass


#: dtypes numpy's npz round-trips natively; everything else (bfloat16,
#: float8 variants, ...) is stored as a raw byte view + sidecar dtype
_NATIVE_DTYPES = {
    "bool", "int8", "int16", "int32", "int64", "uint8", "uint16", "uint32",
    "uint64", "float16", "float32", "float64", "complex64", "complex128",
}


class DSMPool:
    def __init__(self, path: str):
        self.path = path
        self.obj_dir = os.path.join(path, "objects")
        os.makedirs(self.obj_dir, exist_ok=True)
        self._manifest_seq = self._latest_manifest_seq()

    # -- low-level object IO -------------------------------------------------
    def _obj_path(self, name: str, version: int) -> str:
        d = os.path.join(self.obj_dir, name)
        os.makedirs(d, exist_ok=True)
        return os.path.join(d, f"{version:08d}")

    def write_object(self, name: str, version: int, tree) -> PoolObject:
        """Durable write of one object version (MStore semantics: complete
        only once on physical storage)."""
        arrays, treedef = _flatten(tree)
        crc = _crc_of_arrays(arrays)
        base = self._obj_path(name, version)
        tmp_fd, tmp_name = tempfile.mkstemp(dir=os.path.dirname(base))
        os.close(tmp_fd)
        # npz cannot round-trip ml_dtypes (bfloat16 etc.): store a raw view
        # and record the true dtype in the sidecar
        dtypes = [str(a.dtype) for a in arrays]
        raw = [np.ascontiguousarray(a).view(np.uint8)
               if d not in _NATIVE_DTYPES else a
               for a, d in zip(arrays, dtypes)]
        shapes = [list(a.shape) for a in arrays]
        with open(tmp_name, "wb") as f:
            np.savez(f, **{f"a{i}": a for i, a in enumerate(raw)})
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp_name, base + ".npz")
        meta = {"crc": crc, "treedef": str(treedef),
                "n": len(arrays), "dtypes": dtypes, "shapes": shapes}
        with open(base + ".crc.tmp", "w") as f:
            json.dump(meta, f)
            f.flush()
            os.fsync(f.fileno())
        os.replace(base + ".crc.tmp", base + ".crc")
        nbytes = sum(a.nbytes for a in arrays)
        return PoolObject(name, version, crc, nbytes)

    def read_object(self, name: str, version: int, treedef_like) -> Any:
        """Read + CRC-validate one object version; raises CorruptObjectError
        on mismatch (recovery then falls back to an older manifest)."""
        base = self._obj_path(name, version)
        try:
            with open(base + ".crc") as f:
                meta = json.load(f)
            with np.load(base + ".npz") as z:
                arrays = [z[f"a{i}"] for i in range(meta["n"])]
            if "dtypes" in meta:
                import ml_dtypes  # noqa: F401  (registers bfloat16 et al.)
                arrays = [
                    a if d in _NATIVE_DTYPES
                    else a.view(np.dtype(d)).reshape(shape)
                    for a, d, shape in zip(arrays, meta["dtypes"],
                                           meta["shapes"])]
        except (OSError, KeyError, ValueError, TypeError, EOFError,
                zipfile.BadZipFile, zlib.error) as e:
            raise CorruptObjectError(f"{name}@{version}: {e}") from e
        if _crc_of_arrays(arrays) != meta["crc"]:
            raise CorruptObjectError(f"{name}@{version}: CRC mismatch")
        _, treedef = jax.tree_util.tree_flatten(treedef_like)
        return jax.tree_util.tree_unflatten(treedef, arrays)

    # -- manifests (completeOp) ----------------------------------------------
    def _latest_manifest_seq(self) -> int:
        best = -1
        for fn in os.listdir(self.path):
            if fn.startswith("manifest.") and fn.endswith(".json"):
                mid = fn[len("manifest."):-len(".json")]
                if mid.isdigit():
                    best = max(best, int(mid))
        return best

    def commit_manifest(self, step: int, objects: Dict[str, PoolObject],
                        meta: Optional[dict] = None) -> int:
        """Atomic commit: the step is durable iff this rename completed."""
        self._manifest_seq += 1
        doc = {
            "seq": self._manifest_seq,
            "step": step,
            "objects": {name: dataclasses.asdict(o)
                        for name, o in objects.items()},
            "meta": meta or {},
        }
        tmp = os.path.join(self.path, f".manifest.tmp.{self._manifest_seq}")
        with open(tmp, "w") as f:
            json.dump(doc, f)
            f.flush()
            os.fsync(f.fileno())
        dst = os.path.join(self.path, f"manifest.{self._manifest_seq}.json")
        os.replace(tmp, dst)
        # update the convenience head pointer last (also atomic)
        head = os.path.join(self.path, "manifest.json")
        tmp2 = os.path.join(self.path, ".manifest.head.tmp")
        with open(tmp2, "w") as f:
            json.dump(doc, f)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp2, head)
        return self._manifest_seq

    def manifests_desc(self) -> List[dict]:
        """All manifests, newest first."""
        out = []
        for fn in os.listdir(self.path):
            if fn.startswith("manifest.") and fn.endswith(".json"):
                mid = fn[len("manifest."):-len(".json")]
                if not mid.isdigit():
                    continue
                try:
                    with open(os.path.join(self.path, fn)) as f:
                        out.append(json.load(f))
                except (OSError, ValueError):
                    continue
        return sorted(out, key=lambda d: -d["seq"])

    def latest_manifest(self) -> Optional[dict]:
        ms = self.manifests_desc()
        return ms[0] if ms else None

    def gc(self, keep: int = 3):
        """Drop all but the newest ``keep`` manifests + unreferenced versions."""
        ms = self.manifests_desc()
        keep_ms, drop_ms = ms[:keep], ms[keep:]
        live = {(n, o["version"]) for m in keep_ms
                for n, o in m["objects"].items()}
        for m in drop_ms:
            os.unlink(os.path.join(self.path, f"manifest.{m['seq']}.json"))
        for name in os.listdir(self.obj_dir):
            d = os.path.join(self.obj_dir, name)
            for fn in os.listdir(d):
                ver = int(fn.split(".")[0])
                if (name, ver) not in live:
                    os.unlink(os.path.join(d, fn))
