"""Crash injection + recovery (partial-crash model, per the paper §3.1).

A worker crash loses its HBM and host-staging tiers; the pool and OTHER
workers are uninterrupted.  Recovery sources, best first:

1. **peer staging** — if a surviving peer holds an RStore-staged copy NEWER
   than the pool's manifest (CXL0 cache-to-cache propagation), adopt it.
   The peer may be an in-process ``TierManager`` or a cross-process
   staging view (``repro.dsm.cluster.FileStagingArea``) — anything with a
   ``.staging`` mapping of ``name -> (tag, host tree)``;
2. **pool manifest** — newest manifest whose every object CRC-validates;
   torn/corrupt shards trigger fallback to the previous manifest.  Works
   for plain AND sharded manifest entries: a sharded object validates only
   if EVERY shard validates, so a commit torn mid-shard-write is invisible.

``RecoveryManager.recover`` returns (state_objects, step, source).

Reads go through ``DSMPool.read_entry``, so recovery gets the streamed
format's mmap-backed zero-copy loads for free — and still reads legacy
``.npz`` objects written by older incarnations (the pool sniffs the
payload per object), so a fleet can recover across the format change.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Optional, Tuple

from repro.dsm.pool import CorruptObjectError, DSMPool


class CrashError(Exception):
    """Raised by fault-injection hooks to simulate a worker loss."""


class ColdStartError(RuntimeError):
    """No recoverable state exists anywhere (empty pool, no peer staging).
    Subclasses RuntimeError for backward compatibility; resume paths catch
    THIS and never a broader class, so a real runtime failure during
    recovery cannot be mistaken for a cold start (which would shadow the
    pool with a fresh step -1 manifest)."""


class RecoveryManager:
    def __init__(self, pool: DSMPool):
        self.pool = pool

    def recover_from_pool(self, templates: Dict[str, Any], *,
                          exact: bool = True
                          ) -> Optional[Tuple[Dict[str, Any], int, int]]:
        """Newest fully-valid manifest -> (objects, step, seq).

        ``exact=True`` (default): the manifest's object set must equal the
        template set — the whole-state recovery of the training loop.
        ``exact=False``: the manifest may contain MORE objects than asked
        for (subset recovery) — e.g. a surviving cluster worker recovering
        only the victim rank's ``w<v>/...`` objects out of a cluster
        manifest that references every rank's."""
        for m in self.pool.manifests_desc():
            entries = m["objects"]
            if exact and set(entries) != set(templates):
                continue
            if not set(templates) <= set(entries):
                continue
            try:
                objs = {
                    name: self.pool.read_entry(name, entries[name],
                                               templates[name])
                    for name in templates}
            except (CorruptObjectError, KeyError, ValueError):
                # torn commit, or an object whose pytree structure no
                # longer matches the template (e.g. a pre-shrink manifest
                # read with post-repartition templates — tree_unflatten
                # raises ValueError): fall back to an older manifest
                continue
            return objs, m["step"], m["seq"]
        return None

    def recover_latest(self, template_for: Callable[[str, dict], Any]
                       ) -> Optional[Tuple[Dict[str, Any], dict]]:
        """Newest fully-CRC-valid manifest for a DYNAMIC object set.

        Unlike ``recover_from_pool`` (fixed training-state objects known up
        front), the object set here varies per manifest — e.g. one KV-cache
        object per live serving session.  ``template_for(name, entry)``
        returns the pytree prototype used to unflatten that object (the
        manifest's ``meta`` describes the set; the session store derives
        templates from it).  Returns ``(objects, manifest)`` for the newest
        manifest whose EVERY object validates, or None — torn commits fall
        back to older manifests exactly as in the fixed-set path."""
        for m in self.pool.manifests_desc():
            try:
                objs = {
                    name: self.pool.read_entry(
                        name, entry, template_for(name, entry))
                    for name, entry in m["objects"].items()}
            except (CorruptObjectError, KeyError, ValueError):
                continue            # torn commit (or template/structure
                #                     mismatch): fall back to older manifest
            return objs, m
        return None

    def recover(self, templates: Dict[str, Any],
                peers: Tuple[Any, ...] = (), *,
                exact: bool = True,
                ) -> Tuple[Dict[str, Any], int, str]:
        """Full recovery path: peer staging beats the pool if newer.

        ``templates``: pytree prototypes (for unflattening) per object.
        ``peers``: anything exposing a ``.staging`` mapping of
        ``name -> (tag, host tree)`` — an in-process TierManager, or a
        cross-process ``FileStagingArea.view(...)`` (repro.dsm.cluster)
        backed by a sibling worker's spill-file buffer.  Peer staging is
        only adopted if it covers ALL requested objects at one consistent
        version (else it could mix steps — not linearizable).
        ``exact=False`` allows subset recovery from the pool (see
        ``recover_from_pool``)."""
        pool_state = self.recover_from_pool(templates, exact=exact)
        best_peer: Optional[Dict[str, Any]] = None
        best_ver = -1
        for peer in peers:
            if not set(templates) <= set(peer.staging):
                continue
            staged = {n: peer.staging[n] for n in templates}
            vers = {v for v, _ in staged.values()}
            if len(vers) != 1:      # mixed-step staging: not consistent
                continue
            v = vers.pop()
            if v > best_ver:
                best_ver = v
                best_peer = {n: t for n, (_, t) in staged.items()}
        if pool_state is None and best_peer is None:
            raise ColdStartError("no recoverable state (cold start)")
        if best_peer is not None:
            # staged copies are tagged with the training step (see
            # DurableCommitter.update); newest wins against the manifest
            if pool_state is None or best_ver > pool_state[1]:
                return best_peer, best_ver, "peer-staging"
        objs, step, _ = pool_state
        return objs, step, "pool"
