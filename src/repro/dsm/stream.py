"""Zero-copy streaming frame I/O — the pool/staging data-path fast path.

The PR-6 write path serialized a pytree three times: ``np.savez`` walked
every leaf through Python's zipfile (one CRC pass + one copy per member),
``_crc_of_arrays`` made a SECOND full pass over ``tobytes()`` copies, and
every commit allocated fresh buffers.  This module replaces all of that
with a single-pass framed-binary protocol:

* each leaf's buffer is streamed via ``memoryview`` (no ``tobytes()``
  copy) in fixed-size ``CHUNK`` slices, folding ``zlib.crc32``
  incrementally as the bytes go out — one pass over the data;
* leaves smaller than ``PACK_LIMIT`` are coalesced into a reusable
  ``SpillArena`` buffer so a fine-grained pytree (a paged KV cache, an
  embedding table's row shards) costs a handful of large writes instead
  of thousands of tiny syscalls;
* the reader ``mmap``s the frame (``ACCESS_COPY``: private copy-on-write
  pages) and returns ``np.frombuffer`` views directly into the page
  cache — zero-copy loads, validated by the same incremental CRC fold.

Frame layout (all integers little-endian)::

    0            MAGIC        b"CXL0FR1\\n"                     8 bytes
    8            header_len   u32
    12           header_crc   u32  (zlib.crc32 of the header JSON)
    16           header JSON  {"n": N, "dtypes": [...],
                               "shapes": [[...]], "nbytes": [...]}
    hdr_end      payload      every leaf's raw C-order bytes, tightly
                              concatenated (offsets = running sums)
    hdr_end+P    FOOTER       b"CXL0END\\n"                     8 bytes
    +8           payload_crc  u32  (zlib.crc32 folded over the payload)
    +12          payload_len  u64
    total file size == hdr_end + P + 20

``payload_crc`` is ``zlib.crc32`` folded over each leaf's raw contiguous
bytes in order — by construction the SAME value as the legacy
``pool._crc_of_arrays``, so manifests, staging metas and fault oracles
written against either format validate against the other.

Torn-write detection (the crash-consistency contract this frame must
uphold — see ``repro.dsm.faults``):

* ``truncate``  — the total-size equation fails (and the footer magic is
  gone): structural reject before any data is read;
* ``bitflip``   — a sub-32-bit burst in the payload: CRC32 detection is
  guaranteed, never probabilistic;
* ``zero``      — a fixed nonzero XOR smear of array data: the folded
  CRC changes (same guarantee the legacy format relied on);
* header damage — ``header_crc`` / JSON parse / size-equation reject, so
  a flipped dtype token can never silently re-type the data.
"""
from __future__ import annotations

import json
import mmap
import os
import struct
import threading
import zlib
from typing import Any, BinaryIO, Dict, List, Optional, Tuple

import numpy as np

MAGIC = b"CXL0FR1\n"
FOOTER = b"CXL0END\n"
#: payload suffix of streamed pool objects / staging spills (the legacy
#: ``.npz`` + ``.crc`` sidecar pair remains readable for old pools)
SUFFIX = ".cxl0"
#: CRC/write granularity for large leaves: big enough that zlib.crc32's
#: per-call overhead vanishes, small enough to keep the fold incremental
CHUNK = 1 << 20
#: leaves below this are coalesced into the arena before hitting the file
PACK_LIMIT = 256 << 10
_FOOTER_LEN = len(FOOTER) + 4 + 8        # magic + u32 crc + u64 payload_len
_HDR_FIXED = len(MAGIC) + 4 + 4          # magic + u32 len + u32 crc


class FrameError(Exception):
    """Any structural or CRC validation failure of a frame — the caller
    (pool read path, staging view) treats it exactly like a torn write."""


class SpillArena:
    """Reusable spill-buffer arena: one geometrically-grown scratch buffer
    per thread, checked out by the frame writer to coalesce small leaves
    (and to compact the rare non-contiguous one) instead of allocating
    per commit.  Thread-safety is by construction — each worker thread of
    a sharded flush pipeline gets its own slot via ``threading.local``."""

    #: floor for the first checkout; grown geometrically after that
    MIN_BYTES = 1 << 20

    def __init__(self):
        self._local = threading.local()
        self.allocations = 0         # observability (tests assert reuse)

    def checkout(self, nbytes: int) -> memoryview:
        """A writable scratch buffer of at least ``nbytes`` — the SAME
        underlying buffer on every call from one thread unless it had to
        grow."""
        buf = getattr(self._local, "buf", None)
        if buf is None or len(buf) < nbytes:
            size = max(self.MIN_BYTES,
                       len(buf) * 2 if buf is not None else 0, nbytes)
            buf = bytearray(size)
            self._local.buf = buf
            self.allocations += 1
        return memoryview(buf)


#: process-wide fallback arena for callers that do not carry their own
_DEFAULT_ARENA = SpillArena()


def _leaf_view(a: np.ndarray) -> memoryview:
    """The raw bytes of ``a`` as a memoryview WITHOUT copying when the
    array is already C-contiguous (the overwhelmingly common case: host
    snapshots of training state / KV pages).  Non-contiguous leaves are
    compacted first — the one copy the format cannot avoid; dtypes the
    buffer protocol refuses (bfloat16 et al.) go out as uint8 views."""
    if not a.flags.c_contiguous:
        a = np.ascontiguousarray(a)
    if not a.ndim or not a.size:
        # 0-d and empty arrays can't be view-cast; tobytes() of ≤ itemsize
        # bytes is not a copy worth avoiding
        return memoryview(a.tobytes())
    try:
        return memoryview(a).cast("B")
    except (TypeError, ValueError, BufferError):
        return memoryview(a.view(np.uint8)).cast("B")


def frame_header(leaves: List[np.ndarray]) -> Dict[str, Any]:
    """One pass, with the dtype-token stringification memoized: a paged
    KV spill has thousands of same-dtype leaves, and ``str(dtype)`` per
    leaf was a measurable share of the whole write at 2 KiB pages."""
    dtypes: List[str] = []
    shapes: List[List[int]] = []
    nbytes: List[int] = []
    memo: Dict[Any, str] = {}
    for a in leaves:
        dt = a.dtype
        tok = memo.get(dt)
        if tok is None:
            tok = memo[dt] = str(dt)
        dtypes.append(tok)
        shapes.append(list(a.shape))
        nbytes.append(a.nbytes)
    return {"n": len(leaves), "dtypes": dtypes,
            "shapes": shapes, "nbytes": nbytes}


def write_frame(f: BinaryIO, leaves: List[np.ndarray],
                arena: Optional[SpillArena] = None
                ) -> Tuple[int, int, Dict[str, Any]]:
    """Stream ``leaves`` into ``f`` as one frame; single pass, CRC folded
    chunk-by-chunk as the bytes are written.  Returns
    ``(payload_crc, payload_nbytes, header)``.  The caller owns fsync /
    rename — staging (volatile by contract) skips the fsync entirely,
    the pool does not."""
    arena = arena or _DEFAULT_ARENA
    header = frame_header(leaves)
    hdr = json.dumps(header, separators=(",", ":")).encode()
    f.write(MAGIC)
    f.write(struct.pack("<II", len(hdr), zlib.crc32(hdr)))
    f.write(hdr)
    crc = 0
    total = 0
    pack = arena.checkout(max(PACK_LIMIT * 2, CHUNK))
    pack_cap = len(pack) - PACK_LIMIT
    pos = 0
    for a in leaves:
        mv = _leaf_view(a)
        n = len(mv)
        total += n
        if n >= PACK_LIMIT:
            if pos:                             # flush the packed run
                crc = _fold(pack, pos, crc)
                f.write(pack[:pos])
                pos = 0
            for lo in range(0, n, CHUNK):
                part = mv[lo:lo + CHUNK]
                crc = zlib.crc32(part, crc)
                f.write(part)
        else:
            pack[pos:pos + n] = mv
            pos += n
            if pos >= pack_cap:
                crc = _fold(pack, pos, crc)
                f.write(pack[:pos])
                pos = 0
    if pos:
        crc = _fold(pack, pos, crc)
        f.write(pack[:pos])
    f.write(FOOTER)
    f.write(struct.pack("<IQ", crc, total))
    return crc, total, header


def _fold(mv: memoryview, end: int, crc: int) -> int:
    """Fold ``mv[:end]`` into ``crc`` in CHUNK slices.  CRC32 of a
    concatenation equals the fold of its pieces, so batching small packed
    leaves into spans changes nothing about the resulting checksum."""
    for lo in range(0, end, CHUNK):
        crc = zlib.crc32(mv[lo:min(lo + CHUNK, end)], crc)
    return crc


def _resolve_dtype(token: str) -> np.dtype:
    try:
        return np.dtype(token)
    except TypeError:
        import ml_dtypes  # noqa: F401  (registers bfloat16, float8, ...)
        return np.dtype(token)


def read_header(path: str) -> Tuple[Dict[str, Any], int, int]:
    """Parse + validate ONLY the frame header of ``path``.  Returns
    ``(header, payload_offset, file_size)``.  Raises FrameError on any
    structural damage."""
    try:
        size = os.path.getsize(path)
        with open(path, "rb") as f:
            fixed = f.read(_HDR_FIXED)
            if len(fixed) != _HDR_FIXED or fixed[:len(MAGIC)] != MAGIC:
                raise FrameError(f"{path}: bad frame magic")
            hdr_len, hdr_crc = struct.unpack_from("<II", fixed, len(MAGIC))
            if _HDR_FIXED + hdr_len + _FOOTER_LEN > size:
                raise FrameError(f"{path}: truncated header")
            hdr = f.read(hdr_len)
    except OSError as e:
        raise FrameError(f"{path}: {e}") from e
    if len(hdr) != hdr_len or zlib.crc32(hdr) != hdr_crc:
        raise FrameError(f"{path}: header CRC mismatch")
    try:
        header = json.loads(hdr)
        n = header["n"]
        if not (len(header["dtypes"]) == len(header["shapes"])
                == len(header["nbytes"]) == n):
            raise ValueError("inconsistent header arity")
    except (ValueError, KeyError, TypeError) as e:
        raise FrameError(f"{path}: unparseable header: {e}") from e
    return header, _HDR_FIXED + hdr_len, size


def read_frame(path: str, expected_crc: Optional[int] = None
               ) -> Tuple[List[np.ndarray], int, Dict[str, Any]]:
    """mmap-backed zero-copy read of one frame: validate structure +
    folded CRC (one pass over the page cache, no intermediate copies),
    then return ``np.frombuffer`` views into the mapping plus
    ``(payload_crc, header)``.  ``ACCESS_COPY`` makes the views private
    copy-on-write — callers may mutate them without touching the file.
    Raises FrameError on ANY mismatch, including ``expected_crc`` (the
    manifest/meta-recorded value) when given."""
    header, payload_off, size = read_header(path)
    payload = sum(header["nbytes"])
    if payload_off + payload + _FOOTER_LEN != size:
        raise FrameError(f"{path}: size mismatch (torn write?)")
    try:
        with open(path, "rb") as f:
            mm = mmap.mmap(f.fileno(), 0, access=mmap.ACCESS_COPY)
    except (OSError, ValueError) as e:
        raise FrameError(f"{path}: {e}") from e
    foot_off = payload_off + payload
    if mm[foot_off:foot_off + len(FOOTER)] != FOOTER:
        raise FrameError(f"{path}: bad footer magic")
    crc_stored, len_stored = struct.unpack_from(
        "<IQ", mm, foot_off + len(FOOTER))
    if len_stored != payload:
        raise FrameError(f"{path}: footer/header payload length mismatch")
    crc = 0
    with memoryview(mm) as view:
        for lo in range(payload_off, foot_off, CHUNK):
            crc = zlib.crc32(view[lo:min(lo + CHUNK, foot_off)], crc)
    if crc != crc_stored:
        raise FrameError(f"{path}: payload CRC mismatch")
    if expected_crc is not None and crc != expected_crc:
        raise FrameError(
            f"{path}: content does not match the recorded CRC "
            f"(overwritten by a later write?)")
    arrays: List[np.ndarray] = []
    off = payload_off
    try:
        for tok, shape, nb in zip(header["dtypes"], header["shapes"],
                                  header["nbytes"]):
            dt = _resolve_dtype(tok)
            count = nb // dt.itemsize if dt.itemsize else 0
            a = np.frombuffer(mm, dtype=dt, count=count,
                              offset=off).reshape(shape)
            arrays.append(a)
            off += nb
    except (TypeError, ValueError) as e:
        raise FrameError(f"{path}: undecodable leaf: {e}") from e
    return arrays, crc, header


def payload_span(path: str) -> Tuple[int, int]:
    """(offset, length) of the LARGEST leaf's data bytes inside the frame
    — the region the folded CRC provably covers.  The fault layer
    corrupts here so the read path must reject the file (mirrors the
    zip-member targeting of the legacy format)."""
    header, payload_off, _ = read_header(path)
    best_off, best_len, off = payload_off, 0, payload_off
    for nb in header["nbytes"]:
        if nb > best_len:
            best_off, best_len = off, nb
        off += nb
    return best_off, best_len
