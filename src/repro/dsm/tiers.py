"""Tier manager: HBM / host-staging / pool with CXL0 primitive semantics.

Per worker, per object:

* ``lstore(name, tree)``   — update the HBM tier (in-memory reference;
                             O(1), no copy — the training step already
                             produced the new arrays).  Marks dirty.
* ``rstore(name, peer)``   — stage a copy into a PEER worker's host buffer
                             (CXL0: store completing in the owner's cache).
                             Survives OUR crash; lost if the PEER crashes.
* ``rflush(name)``         — durable write of the current HBM value into the
                             pool.  Completes only when on storage (fsync).
* ``mstore(name, tree)``   — lstore + rflush fused (Prop. 1.8).

A background ``flush_async`` thread overlaps rflush I/O with compute; the
commit barrier (``DurableCommitter``) joins it before completeOp.

Sharded variants (``rflush_sharded`` / ``flush_async_sharded``) partition
the object's flattened leaves into byte-balanced shards and run one
LStore/RFlush pipeline per shard on a thread pool — the write path of the
sharded / sharded-async commit schedules.  When the pool's write path is
un-overridden the shard writes are SPLIT-PHASE (``DSMPool.start_write`` →
``PendingWrite.finish``): serialization/CRC of shard k+1 streams on the
flush pool while shard k's fsync runs on a dedicated one-thread fsync
lane — fsync releases the GIL, so the overlap is real even on one CPU.
``flush_wait`` joins either flavor; ``abort_flushes`` joins-and-discards
every outstanding write (used on crash recovery so a stale in-flight
write can never land AFTER a new incarnation started reusing version
numbers).
"""
from __future__ import annotations

import threading
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.dsm import meshio, stream
from repro.dsm.pool import (DSMPool, PoolObject, ShardedObject,
                            partition_leaves)


def _to_host(tree):
    """Device→host copy (the actual D2H of the staging tier).  A tree whose
    every leaf is already a host ``np.ndarray`` is returned as-is — the
    cluster spill path round-trips host arrays through here every step, and
    rebuilding an identical tree per call is pure overhead."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    if all(type(l) is np.ndarray for l in leaves):
        return tree
    return jax.tree_util.tree_unflatten(treedef,
                                        [np.asarray(l) for l in leaves])


class TierManager:
    def __init__(self, pool: DSMPool, worker_id: int):
        self.pool = pool
        self.worker_id = worker_id
        self.hbm: Dict[str, Any] = {}               # C_i — device tier
        self.staging: Dict[str, Tuple[int, Any]] = {}   # peer-staged copies:
        #   name -> (version, host tree) staged INTO this worker by peers
        self.versions: Dict[str, int] = {}
        self.flit_counter: Dict[str, int] = {}
        self._flush_threads: Dict[str, threading.Thread] = {}
        self._flush_results: Dict[str, PoolObject] = {}
        self._flush_errors: Dict[str, BaseException] = {}
        #   name -> (version, n_leaves, assignment, shard futures)
        self._sharded_futures: Dict[
            str, Tuple[int, int, List[List[int]], List[Future]]] = {}
        self._executor: Optional[ThreadPoolExecutor] = None
        self._fsync_lane: Optional[ThreadPoolExecutor] = None
        self._arena = stream.SpillArena()   # reusable spill pack buffers
        self._lock = threading.Lock()
        #: D2H accounting (bytes).  ``d2h_gather_bytes`` counts whole-tree
        #: host gathers on the legacy flush paths; ``d2h_shard_bytes``
        #: counts the per-device buffer copies of device-local shard
        #: pipelines (``meshio.assemble_leaf``).  A device-sharded commit
        #: must leave ``d2h_gather_bytes`` untouched — the "no host gather
        #: of the full tree" contract, asserted in tests/test_mesh_commit.
        self.d2h_gather_bytes = 0
        self.d2h_shard_bytes = 0

    def _count_d2h(self, kind: str, nbytes: int):
        with self._lock:
            if kind == "gather":
                self.d2h_gather_bytes += int(nbytes)
            else:
                self.d2h_shard_bytes += int(nbytes)

    def _to_host_counted(self, tree):
        """``_to_host`` with D2H accounting: every leaf that is NOT already
        a host ndarray is gathered whole (the legacy full-tree D2H) and
        its bytes charged to ``d2h_gather_bytes``."""
        leaves, treedef = jax.tree_util.tree_flatten(tree)
        if all(type(l) is np.ndarray for l in leaves):
            return tree
        out = []
        for l in leaves:
            if type(l) is np.ndarray:
                out.append(l)
            else:
                a = np.asarray(l)
                self._count_d2h("gather", a.nbytes)
                out.append(a)
        return jax.tree_util.tree_unflatten(treedef, out)

    def _get_executor(self, n_workers: int) -> ThreadPoolExecutor:
        """One lazily-created pool of flush pipelines, sized by the first
        sharded flush (the shard count is constant for a run)."""
        if self._executor is None:
            self._executor = ThreadPoolExecutor(
                max_workers=max(1, n_workers),
                thread_name_prefix=f"rflush-w{self.worker_id}")
        return self._executor

    def _get_fsync_lane(self) -> ThreadPoolExecutor:
        """One-thread executor that only runs ``PendingWrite.finish``
        (fsync + rename).  Serializing all fsyncs onto one lane lets the
        flush pool keep serializing/CRC-ing the NEXT shard while the
        current one flushes — fsync releases the GIL, so the pipeline
        genuinely overlaps even on a single CPU."""
        with self._lock:
            if self._fsync_lane is None:
                self._fsync_lane = ThreadPoolExecutor(
                    max_workers=1,
                    thread_name_prefix=f"fsync-w{self.worker_id}")
            return self._fsync_lane

    def _pool_write_is_stock(self) -> bool:
        """True iff the pool's write path is the stock ``DSMPool`` one —
        neither subclass-overridden nor instance-monkeypatched.  Fault
        harnesses and tests replace ``write_object`` wholesale (often
        with plain 3-positional-arg callables); the sharded pipeline must
        route through THAT override rather than the split-phase fast
        path, or the injection/assertion would be silently bypassed."""
        pool = self.pool
        return (type(pool).write_object is DSMPool.write_object
                and "write_object" not in pool.__dict__
                and type(pool).start_write is DSMPool.start_write
                and "start_write" not in pool.__dict__)

    # -- CXL0 primitive realizations ----------------------------------------
    def lstore(self, name: str, tree: Any):
        """Update the volatile HBM tier. Completes immediately.

        The first lstore of a name (fresh worker incarnation, or after a
        crash wiped the counters) seeds the version counter ABOVE the
        highest version already on disk: version numbers never repeat
        across incarnations, so a write can never overwrite a file a
        retained manifest still references."""
        self.hbm[name] = tree
        if name not in self.versions:
            self.versions[name] = self.pool.max_version(name)
        self.versions[name] += 1

    def rstore(self, name: str, peer: Any,
               tag: Optional[int] = None):
        """Stage our current value into a peer's host buffer.  On our crash
        the peer still holds it (newer than the pool) — CXL0's
        cache-to-cache propagation made useful (peer-cache recovery).
        ``tag`` (training step) makes staged copies comparable with pool
        manifests during recovery.  ``peer`` is anything exposing a
        ``.staging`` mapping: an in-process TierManager, or a
        cross-process ``StagingProxy`` (repro.dsm.cluster) that writes
        through to a sibling worker's spill-file buffer.

        The D2H copy is DEFERRED when the peer's buffer declares
        ``materializes_leaves`` (the spill-file buffer copies each leaf
        to host as it streams the frame): emulator-priced paths already
        charge the transfer from leaf ``nbytes`` at call time, so a
        placement policy can reject the spill without this method ever
        having paid the copy it would have skipped.  In-process dict
        peers still get an eager host snapshot — their staging entries
        are read back directly (recovery oracle, rload)."""
        tree = self.hbm[name]
        if not getattr(peer.staging, "materializes_leaves", False):
            tree = self._to_host_counted(tree)
        peer.staging[name] = (self.versions.get(name, 0) if tag is None
                              else tag, tree)

    def ldiscard(self, name: str):
        """Drop an object from the volatile HBM tier (slot freed — e.g. a
        finished serving session's KV cache).  The version counter is KEPT:
        if the name is ever lstored again the counter keeps rising, so a
        late write can never collide with a pool file an older manifest
        still references.  No-op if absent."""
        self.hbm.pop(name, None)

    def rload(self, name: str) -> Optional[Any]:
        """Read back a value staged INTO this worker's host buffer by a
        peer's rstore (the staging-tier restore path of the KV-cache
        manager).  Returns the host tree or None."""
        staged = self.staging.get(name)
        return None if staged is None else staged[1]

    def rflush(self, name: str) -> PoolObject:
        """Durable write; returns once the object is on storage."""
        self.flit_counter[name] = self.flit_counter.get(name, 0) + 1
        try:
            obj = self.pool.write_object(
                name, self.versions.get(name, 0),
                self._to_host_counted(self.hbm[name]))
        finally:
            self.flit_counter[name] -= 1
        return obj

    def mstore(self, name: str, tree: Any) -> PoolObject:
        self.lstore(name, tree)
        return self.rflush(name)

    # -- sharded flush (parallel per-shard RFlush pipelines) -----------------
    def _shard_submit(self, name: str, n_shards: int,
                      post_first_shard: Optional[Callable] = None,
                      device_local: bool = False
                      ) -> Tuple[int, int, List[List[int]], List[Future]]:
        """Snapshot the object NOW, partition its leaves into byte-balanced
        shards, and submit one write per shard to the flush pool.  If
        ``post_first_shard`` is given it runs after the FIRST shard is
        durable and before the rest are joined — the mid-flush
        fault-injection point of the scenario runner.

        ``device_local=True`` (mesh-native commit): the assignment comes
        from leaf METADATA (``meshio.leaf_nbytes`` — identical bytes to
        the gathered path, so the assignment and hence every shard file
        is bit-identical), and each shard is submitted as a THUNK that
        materializes only its own leaves from their per-device buffers
        inside that shard's pipeline (``meshio.assemble_leaf``).  The
        full tree is never gathered on host — ``d2h_gather_bytes`` stays
        untouched; per-buffer copies land in ``d2h_shard_bytes``.  jax
        arrays are immutable, so snapshotting by reference here and
        copying inside the pipeline observes the same value the caller
        committed."""
        version = self.versions.get(name, 0)
        if device_local:
            tree_leaves = jax.tree_util.tree_leaves(self.hbm[name])
            sizes = [meshio.leaf_nbytes(l) for l in tree_leaves]
            assignment = partition_leaves(sizes, n_shards)
            n_leaves = len(tree_leaves)

            def _shard_thunk(idxs):
                def thunk():
                    return [meshio.assemble_leaf(
                        tree_leaves[i],
                        lambda nb: self._count_d2h("shard", nb))
                        for i in idxs]
                return thunk

            shards = [_shard_thunk(tuple(idxs)) for idxs in assignment]
        else:
            leaves = [np.asarray(l) for l in jax.tree_util.tree_leaves(
                self._to_host_counted(self.hbm[name]))]
            assignment = partition_leaves(
                [a.nbytes for a in leaves], n_shards)
            n_leaves = len(leaves)
            shards = [[leaves[i] for i in idxs] for idxs in assignment]
        ex = self._get_executor(len(assignment))
        pipelined = self._pool_write_is_stock()
        futs = []
        try:
            for k, shard in enumerate(shards):
                if pipelined:
                    futs.append(self._submit_split_phase(
                        ex, f"{name}.s{k}", version, shard))
                else:
                    futs.append(ex.submit(self._write_shard,
                                          f"{name}.s{k}", version, shard))
                if k == 0 and post_first_shard is not None:
                    futs[0].result()
                    post_first_shard()
        except BaseException:
            # the mid-flush hook (fault injection) may raise between
            # submissions: already-submitted shard writes must fully land
            # (or fail) before the caller unwinds, else an untracked stale
            # write could race a later incarnation's version reuse
            for f in futs:
                try:
                    f.result()
                except Exception:
                    pass
            raise
        return version, n_leaves, assignment, futs

    def _write_shard(self, name: str, version: int, shard) -> PoolObject:
        """Write one shard via the pool's (possibly overridden)
        ``write_object``; a callable shard is a device-local materializer
        thunk and is resolved HERE, on the shard's own pipeline thread."""
        arrs = shard() if callable(shard) else shard
        return self.pool.write_object(name, version, arrs)

    def _submit_split_phase(self, ex: ThreadPoolExecutor, name: str,
                            version: int, leaves) -> Future:
        """Submit one shard write as a two-stage pipeline: the flush pool
        thread serializes + CRCs the frame (``start_write``, no fsync),
        then hands the pending write to the one-thread fsync lane for
        ``finish`` (fsync + atomic rename).  The returned future resolves
        only after the rename — same durability point as a monolithic
        ``write_object`` — but while shard k sits in its fsync, the flush
        pool is already streaming shard k+1's bytes.  ``leaves`` may be a
        device-local materializer thunk; it runs on the flush-pool thread
        so the D2H copies overlap across shard pipelines."""
        out: Future = Future()

        def serialize():
            try:
                arrs = leaves() if callable(leaves) else leaves
                pending = self.pool.start_write(name, version, arrs,
                                                arena=self._arena)
            except BaseException as e:
                out.set_exception(e)
                return
            def finish():
                try:
                    out.set_result(pending.finish())
                except BaseException as e:
                    try:
                        pending.abort()
                    except Exception:
                        pass
                    out.set_exception(e)
            try:
                self._get_fsync_lane().submit(finish)
            except BaseException as e:     # lane torn down mid-shutdown
                pending.abort()
                out.set_exception(e)

        ex.submit(serialize)
        return out

    def _shard_join(self, name: str, version: int, n_leaves: int,
                    assignment: List[List[int]],
                    futs: List[Future]) -> ShardedObject:
        """Join EVERY shard future (a failed shard must not leave later
        shards' writes in flight), then surface the first failure."""
        shards, first_err = [], None
        for f in futs:
            try:
                shards.append(f.result())
            except BaseException as e:
                if first_err is None:
                    first_err = e
        if first_err is not None:
            raise first_err
        return ShardedObject(name, version,
                             sum(s.nbytes for s in shards),
                             n_leaves, shards, assignment)

    def rflush_sharded(self, name: str, n_shards: int,
                       post_first_shard: Optional[Callable] = None,
                       device_local: bool = False) -> ShardedObject:
        """Blocking sharded durable write: all shards written in parallel,
        returns once every shard is on storage.  ``device_local=True``
        consumes per-device buffers inside each shard pipeline instead of
        gathering the tree first (see ``_shard_submit``)."""
        self.flit_counter[name] = self.flit_counter.get(name, 0) + 1
        try:
            return self._shard_join(
                name, *self._shard_submit(name, n_shards, post_first_shard,
                                          device_local=device_local))
        finally:
            self.flit_counter[name] -= 1

    def flush_async_sharded(self, name: str, n_shards: int,
                            post_first_shard: Optional[Callable] = None,
                            device_local: bool = False):
        """Start a sharded durable write in the background (double-buffered
        commit path); join via flush_wait.  The FliT counter stays raised
        until the join, so a concurrent joiner knows the pool copy may be
        stale."""
        self.flit_counter[name] = self.flit_counter.get(name, 0) + 1
        try:
            self._sharded_futures[name] = self._shard_submit(
                name, n_shards, post_first_shard,
                device_local=device_local)
        except BaseException:
            self.flit_counter[name] -= 1     # nothing tracked -> no join
            raise

    # -- async flush (compute/IO overlap) ------------------------------------
    def flush_async(self, name: str):
        """Start a durable write in the background; join via flush_wait.
        The FliT counter stays raised until the write completes, so any
        concurrent joiner knows the pool copy may be stale."""
        self.flit_counter[name] = self.flit_counter.get(name, 0) + 1
        version = self.versions.get(name, 0)
        host_copy = self._to_host_counted(self.hbm[name])  # snapshot NOW

        def work():
            # a failed write must surface at the join (flush_wait) AND the
            # FliT counter must come back down either way — a leaked raised
            # counter would make every later joiner think the pool copy is
            # permanently stale
            try:
                obj = self.pool.write_object(name, version, host_copy)
            except BaseException as e:
                with self._lock:
                    self._flush_errors[name] = e
            else:
                with self._lock:
                    self._flush_results[name] = obj
            finally:
                with self._lock:
                    self.flit_counter[name] -= 1

        t = threading.Thread(target=work, daemon=True)
        self._flush_threads[name] = t
        t.start()

    def flush_wait(self, name: str):
        """Join one outstanding async flush (threaded or sharded); returns
        the PoolObject / ShardedObject for the manifest.  A write that
        failed in the background re-raises its exception HERE — the commit
        simply is not durable (no manifest); the caller decides whether to
        retry or abort."""
        pending = self._sharded_futures.pop(name, None)
        if pending is not None:
            try:
                return self._shard_join(name, *pending)
            finally:
                self.flit_counter[name] -= 1
        t = self._flush_threads.pop(name, None)
        if t is not None:
            t.join()
        with self._lock:
            err = self._flush_errors.pop(name, None)
            if err is not None:
                raise err
            return self._flush_results.pop(name)

    def abort_flushes(self):
        """Join-and-discard every outstanding async write.  Called on crash
        recovery: a stale write must fully land (or fail) BEFORE the next
        incarnation reuses version numbers, else an old flush could
        overwrite a new one's file after its manifest committed."""
        for name, (_, _, _, futs) in list(self._sharded_futures.items()):
            for f in futs:
                try:
                    f.result()
                except Exception:
                    pass
            self.flit_counter[name] -= 1
        self._sharded_futures.clear()
        for name, t in list(self._flush_threads.items()):
            t.join()            # work()'s finally lowered the counter,
        #                         whether the write landed or failed
        self._flush_threads.clear()
        with self._lock:
            self._flush_results.clear()
            self._flush_errors.clear()

    def close(self):
        """Release the flush thread pool and fsync lane (idempotent;
        lazily recreated if another sharded flush happens)."""
        if self._executor is not None:
            self._executor.shutdown(wait=False)
            self._executor = None
        with self._lock:
            lane, self._fsync_lane = self._fsync_lane, None
        if lane is not None:
            lane.shutdown(wait=False)

    # -- crash ----------------------------------------------------------------
    def crash(self):
        """f_i: all volatile tiers of this worker vanish."""
        self.abort_flushes()
        self.close()
        self.hbm.clear()
        self.staging.clear()
        self.versions.clear()
        self.flit_counter.clear()
        self._flush_threads.clear()
        self._flush_results.clear()
        self._flush_errors.clear()
