"""Tier manager: HBM / host-staging / pool with CXL0 primitive semantics.

Per worker, per object:

* ``lstore(name, tree)``   — update the HBM tier (in-memory reference;
                             O(1), no copy — the training step already
                             produced the new arrays).  Marks dirty.
* ``rstore(name, peer)``   — stage a copy into a PEER worker's host buffer
                             (CXL0: store completing in the owner's cache).
                             Survives OUR crash; lost if the PEER crashes.
* ``rflush(name)``         — durable write of the current HBM value into the
                             pool.  Completes only when on storage (fsync).
* ``mstore(name, tree)``   — lstore + rflush fused (Prop. 1.8).

A background ``flush_async`` thread overlaps rflush I/O with compute; the
commit barrier (``DurableCommitter``) joins it before completeOp.
"""
from __future__ import annotations

import threading
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import numpy as np

from repro.dsm.pool import DSMPool, PoolObject


def _to_host(tree):
    """Device→host copy (the actual D2H of the staging tier)."""
    return jax.tree_util.tree_map(lambda a: np.asarray(a), tree)


class TierManager:
    def __init__(self, pool: DSMPool, worker_id: int):
        self.pool = pool
        self.worker_id = worker_id
        self.hbm: Dict[str, Any] = {}               # C_i — device tier
        self.staging: Dict[str, Tuple[int, Any]] = {}   # peer-staged copies:
        #   name -> (version, host tree) staged INTO this worker by peers
        self.versions: Dict[str, int] = {}
        self.flit_counter: Dict[str, int] = {}
        self._flush_threads: Dict[str, threading.Thread] = {}
        self._flush_results: Dict[str, PoolObject] = {}
        self._lock = threading.Lock()

    # -- CXL0 primitive realizations ----------------------------------------
    def lstore(self, name: str, tree: Any):
        """Update the volatile HBM tier. Completes immediately."""
        self.hbm[name] = tree
        self.versions[name] = self.versions.get(name, 0) + 1

    def rstore(self, name: str, peer: "TierManager",
               tag: Optional[int] = None):
        """Stage our current value into a peer's host buffer.  On our crash
        the peer still holds it (newer than the pool) — CXL0's
        cache-to-cache propagation made useful (peer-cache recovery).
        ``tag`` (training step) makes staged copies comparable with pool
        manifests during recovery."""
        peer.staging[name] = (self.versions.get(name, 0) if tag is None
                              else tag, _to_host(self.hbm[name]))

    def rflush(self, name: str) -> PoolObject:
        """Durable write; returns once the object is on storage."""
        self.flit_counter[name] = self.flit_counter.get(name, 0) + 1
        try:
            obj = self.pool.write_object(name, self.versions.get(name, 0),
                                         _to_host(self.hbm[name]))
        finally:
            self.flit_counter[name] -= 1
        return obj

    def mstore(self, name: str, tree: Any) -> PoolObject:
        self.lstore(name, tree)
        return self.rflush(name)

    # -- async flush (compute/IO overlap) ------------------------------------
    def flush_async(self, name: str):
        """Start a durable write in the background; join via flush_wait.
        The FliT counter stays raised until the write completes, so any
        concurrent joiner knows the pool copy may be stale."""
        self.flit_counter[name] = self.flit_counter.get(name, 0) + 1
        version = self.versions.get(name, 0)
        host_copy = _to_host(self.hbm[name])       # snapshot NOW

        def work():
            obj = self.pool.write_object(name, version, host_copy)
            with self._lock:
                self._flush_results[name] = obj
                self.flit_counter[name] -= 1

        t = threading.Thread(target=work, daemon=True)
        self._flush_threads[name] = t
        t.start()

    def flush_wait(self, name: str) -> PoolObject:
        t = self._flush_threads.pop(name, None)
        if t is not None:
            t.join()
        with self._lock:
            return self._flush_results.pop(name)

    # -- crash ----------------------------------------------------------------
    def crash(self):
        """f_i: all volatile tiers of this worker vanish."""
        self.hbm.clear()
        self.staging.clear()
        self.versions.clear()
        self.flit_counter.clear()
        self._flush_threads.clear()
        self._flush_results.clear()
