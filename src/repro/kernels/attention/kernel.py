"""Flash attention (forward) as a Pallas TPU kernel.

Flash-attention-2-style online softmax with GQA support:

* grid = (B, H, S/block_q, T/block_k); the kv-block axis is the innermost,
  ``arbitrary`` (sequential) dimension — running max / denominator / output
  accumulator live in VMEM scratch and persist across kv blocks;
* BlockSpecs tile q/o to (1, 1, block_q, hd) and k/v to (1, 1, block_k, hd)
  VMEM windows; the kv index_map folds the GQA head mapping (kv head =
  q head // group) so no repeated/broadcast KV is ever materialized;
* causal masking compares absolute positions; fully-masked kv blocks are
  skipped with ``pl.when`` (≈2× for causal — only the lower triangle runs);
* block sizes default to (128, 128): 128 lanes match the MXU/VREG tiling,
  and (128 q × 128 kv × hd≤256) keeps the working set ≤ ~1.5 MB of VMEM,
  far under the ~16 MB/core budget, leaving room for double buffering.

The MXU contractions (q·kᵀ and p·v) run in fp32 accumulation via
``preferred_element_type``; softmax statistics are fp32 throughout.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import compiler_params

NEG_INF = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
               scale: float, causal: bool, block_q: int, block_k: int,
               seq_q: int, seq_k: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * block_q
    k_start = ki * block_k

    # causal: skip kv blocks entirely above the diagonal
    run = True
    if causal:
        run = k_start <= q_start + block_q - 1

    @pl.when(run)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale          # (bq, hd)
        k = k_ref[0, 0].astype(jnp.float32)                  # (bk, hd)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_q, block_k), 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_q, block_k), 1)
        mask = k_pos < seq_k                                  # kv padding
        if causal:
            mask = mask & (q_pos >= k_pos)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]                                   # (bq,)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=1)
        m_scr[...] = m_new
        v = v_ref[0, 0].astype(jnp.float32)                   # (bk, hd)
        pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_scr[...] = acc_scr[...] * corr[:, None] + pv

    @pl.when(ki == nk - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-20)[:, None]
        o_ref[0, 0] = (acc_scr[...] / denom).astype(o_ref.dtype)


def flash_attention_kernel(
    q: jax.Array,                 # (B, H, Sq, hd)
    k: jax.Array,                 # (B, K, Sk, hd)  — K divides H (GQA)
    v: jax.Array,                 # (B, K, Sk, hd_v)
    *,
    causal: bool = True,
    scale: Optional[float] = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:                   # (B, H, Sq, hd_v)
    B, H, Sq, hd = q.shape
    _, K, Sk, hd_v = v.shape
    assert H % K == 0, (H, K)
    group = H // K
    scale = hd ** -0.5 if scale is None else scale

    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    # pad sequence dims to block multiples (masked out in-kernel)
    Sq_p = math.ceil(Sq / block_q) * block_q
    Sk_p = math.ceil(Sk / block_k) * block_k
    if Sq_p != Sq:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, Sq_p - Sq), (0, 0)))
    if Sk_p != Sk:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, Sk_p - Sk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, Sk_p - Sk), (0, 0)))

    grid = (B, H, Sq_p // block_q, Sk_p // block_k)

    kernel = functools.partial(
        _fa_kernel, scale=scale, causal=causal, block_q=block_q,
        block_k=block_k, seq_q=Sq, seq_k=Sk)

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, hd),
                         lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda b, h, qi, ki, g=group: (b, h // g, ki, 0)),
            pl.BlockSpec((1, 1, block_k, hd_v),
                         lambda b, h, qi, ki, g=group: (b, h // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, hd_v),
                               lambda b, h, qi, ki: (b, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq_p, hd_v), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),      # running max
            pltpu.VMEM((block_q,), jnp.float32),      # running denom
            pltpu.VMEM((block_q, hd_v), jnp.float32),  # output acc
        ],
        compiler_params=compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(q, k, v)
    return out[:, :, :Sq]
