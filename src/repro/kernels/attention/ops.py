"""jit'd public wrapper: model layout <-> kernel layout + CPU fallback.

Models use q (B, S, K, G, hd); the kernel wants (B, H, S, hd).  On TPU the
Pallas kernel runs natively; on CPU ``interpret=True`` executes the same
kernel body (used by the allclose sweeps); ``backend="ref"`` uses the
pure-jnp oracle (the default inside traced/sharded model code, where XLA's
fused attention is already near-roofline on CPU).
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.attention.kernel import flash_attention_kernel
from repro.kernels.attention.ref import attention_ref


def _pick_backend(backend: Optional[str]) -> str:
    if backend is not None:
        return backend
    try:
        plat = jax.devices()[0].platform
    except RuntimeError:          # pragma: no cover
        plat = "cpu"
    return "pallas" if plat == "tpu" else "ref"


@partial(jax.jit, static_argnames=("causal", "scale", "block_q", "block_k",
                                   "backend"))
def flash_attention(q, k, v, *, causal: bool = True,
                    scale: Optional[float] = None,
                    block_q: int = 128, block_k: int = 128,
                    backend: Optional[str] = None):
    """q: (B, S, K, G, hd); k/v: (B, T, K, hd[/v]) -> (B, S, K, G, hd_v)."""
    B, S, K, G, hd = q.shape
    T = k.shape[1]
    qh = jnp.transpose(q, (0, 2, 3, 1, 4)).reshape(B, K * G, S, hd)
    kh = jnp.transpose(k, (0, 2, 1, 3))                   # (B, K, T, hd)
    vh = jnp.transpose(v, (0, 2, 1, 3))
    be = _pick_backend(backend)
    if be == "ref":
        oh = attention_ref(qh, kh, vh, causal=causal, scale=scale)
    else:
        oh = flash_attention_kernel(
            qh, kh, vh, causal=causal, scale=scale, block_q=block_q,
            block_k=block_k, interpret=(be == "interpret"))
    hd_v = vh.shape[-1]
    return jnp.transpose(oh.reshape(B, K, G, S, hd_v), (0, 3, 1, 2, 4))
