"""Pure-jnp oracle for flash attention (dense softmax, fp32)."""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp


def attention_ref(q, k, v, *, causal: bool = True,
                  scale: Optional[float] = None):
    """q: (B, H, Sq, hd); k/v: (B, K, Sk, hd[/v]) with K | H. fp32 math."""
    B, H, Sq, hd = q.shape
    _, K, Sk, _ = k.shape
    group = H // K
    qf = q.astype(jnp.float32) * (hd ** -0.5 if scale is None else scale)
    kf = jnp.repeat(k.astype(jnp.float32), group, axis=1)
    vf = jnp.repeat(v.astype(jnp.float32), group, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", qf, kf)
    if causal:
        mask = jnp.arange(Sq)[:, None] >= jnp.arange(Sk)[None, :]
        s = jnp.where(mask[None, None], s, -jnp.inf)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = p / jnp.maximum(jnp.sum(p, axis=-1, keepdims=True), 1e-20)
    return jnp.einsum("bhqk,bhkd->bhqd", p, vf).astype(q.dtype)
