"""Pallas API / platform compatibility for the kernel package.

Two concerns, both version/host related rather than kernel logic:

* ``compiler_params(**kw)`` — Mosaic's compiler-params dataclass was
  renamed ``TPUCompilerParams`` -> ``CompilerParams`` across JAX releases;
  resolve whichever this JAX ships so the kernels import on both (the
  pre-rename class raised ``AttributeError`` on every kernel call and took
  32 tier-1 tests down with it on CPU hosts).
* ``on_accelerator()`` / ``default_interpret()`` — Pallas TPU kernels can
  only *compile* against a real TPU backend; on CPU they must run in
  ``interpret`` mode (the kernel body executed by the interpreter, same
  numerics).  Tests and benchmarks use ``default_interpret()`` so the same
  sweep runs compiled on TPU and interpreted on CPU instead of failing or
  skipping.
"""
from __future__ import annotations

import jax
from jax.experimental.pallas import tpu as pltpu

_COMPILER_PARAMS_CLS = getattr(pltpu, "CompilerParams", None) or getattr(
    pltpu, "TPUCompilerParams")


def compiler_params(**kwargs):
    """Build Mosaic compiler params under either JAX naming."""
    return _COMPILER_PARAMS_CLS(**kwargs)


def on_accelerator() -> bool:
    """True when a real TPU/GPU backend is the default."""
    return jax.default_backend() not in ("cpu",)


def default_interpret() -> bool:
    """interpret=... default for this host: compiled on TPU, interpreted
    elsewhere."""
    return not on_accelerator()
