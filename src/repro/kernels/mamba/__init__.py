from repro.kernels.mamba.ops import selective_scan  # noqa: F401
