"""Mamba (S6) selective scan as a Pallas TPU kernel.

Grid = (B, I/block_i, S/block_s); time is the innermost ``arbitrary``
dimension carrying h (block_i, N) in VMEM scratch.  Inside a time chunk
the affine recurrence h_t = dA_t h + dBu_t is evaluated by an in-kernel
``fori_loop`` over the chunk — each step is a fused (block_i, N) VPU
multiply-add plus a readout contraction against C_t, with zero HBM traffic
between steps (h never leaves VMEM).  This is the TPU adaptation of the
paper('s class of) GPU scan kernels: instead of warp-level prefix scans we
exploit the VPU's (8, 128) lanes across the state dimensions and keep the
sequential dependency in the grid's innermost loop.

Numerical notes: the log-cumsum closed form used by the pure-JAX path is
avoided here because exp(+cumsum) overflows for long chunks; the direct
recurrence is unconditionally stable (dA ∈ (0, 1)).

VMEM budget per program: dA/dBu chunks 2·block_s·block_i·N fp32
(= 4 MB at block_s=64, block_i=128, N=64), h (block_i, N), C (block_s, N).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import compiler_params


def _scan_kernel(dA_ref, dBu_ref, C_ref, h0_ref, y_ref, hout_ref, h_scr, *,
                 block_s: int, seq_s: int):
    si = pl.program_id(2)
    ns = pl.num_programs(2)

    @pl.when(si == 0)
    def _init():
        h_scr[...] = h0_ref[0].astype(jnp.float32)

    dA = dA_ref[0].astype(jnp.float32)      # (bs, bi, N)
    dBu = dBu_ref[0].astype(jnp.float32)    # (bs, bi, N)
    Cc = C_ref[0].astype(jnp.float32)       # (bs, N)
    bs = dA.shape[0]

    # padded positions: identity transition (dA=1, dBu=0) keeps h exact
    t_pos = si * block_s + jax.lax.broadcasted_iota(jnp.int32, (bs, 1, 1), 0)
    valid = t_pos < seq_s
    dA = jnp.where(valid, dA, 1.0)
    dBu = jnp.where(valid, dBu, 0.0)

    def step(t, carry):
        h, ys = carry
        h = dA[t] * h + dBu[t]                          # (bi, N)
        y_t = jnp.sum(h * Cc[t][None, :], axis=1)       # (bi,)
        ys = jax.lax.dynamic_update_index_in_dim(ys, y_t, t, 0)
        return h, ys

    ys0 = jnp.zeros((bs, dA.shape[1]), jnp.float32)
    h, ys = jax.lax.fori_loop(0, bs, step, (h_scr[...], ys0))
    h_scr[...] = h
    y_ref[0] = ys.astype(y_ref.dtype)

    @pl.when(si == ns - 1)
    def _fin():
        hout_ref[0] = h


def selective_scan_kernel(dA, dBu, C, h0, *, block_s: int = 64,
                          block_i: int = 128, interpret: bool = False):
    """dA/dBu: (B, S, I, N); C: (B, S, N); h0: (B, I, N).
    Returns y (B, S, I) fp32 and final h (B, I, N) fp32."""
    B, S, I, N = dA.shape
    block_s = min(block_s, S)
    block_i = min(block_i, I)
    S_p = math.ceil(S / block_s) * block_s
    if S_p != S:
        pad4 = ((0, 0), (0, S_p - S), (0, 0), (0, 0))
        dA = jnp.pad(dA, pad4)
        dBu = jnp.pad(dBu, pad4)
        C = jnp.pad(C, ((0, 0), (0, S_p - S), (0, 0)))
    assert I % block_i == 0, (I, block_i)

    grid = (B, I // block_i, S_p // block_s)
    kern = functools.partial(_scan_kernel, block_s=block_s, seq_s=S)
    y, h_out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_s, block_i, N),
                         lambda b, i, s: (b, s, i, 0)),
            pl.BlockSpec((1, block_s, block_i, N),
                         lambda b, i, s: (b, s, i, 0)),
            pl.BlockSpec((1, block_s, N), lambda b, i, s: (b, s, 0)),
            pl.BlockSpec((1, block_i, N), lambda b, i, s: (b, i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_s, block_i), lambda b, i, s: (b, s, i)),
            pl.BlockSpec((1, block_i, N), lambda b, i, s: (b, i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S_p, I), jnp.float32),
            jax.ShapeDtypeStruct((B, I, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((block_i, N), jnp.float32)],
        compiler_params=compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(dA, dBu, C, h0)
    return y[:, :S], h_out
