"""jit'd wrapper for the selective-scan kernel with CPU fallback."""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.mamba.kernel import selective_scan_kernel
from repro.kernels.mamba.ref import selective_scan_ref


def _pick_backend(backend: Optional[str]) -> str:
    if backend is not None:
        return backend
    try:
        plat = jax.devices()[0].platform
    except RuntimeError:          # pragma: no cover
        plat = "cpu"
    return "pallas" if plat == "tpu" else "ref"


@partial(jax.jit, static_argnames=("block_s", "block_i", "backend"))
def selective_scan(dA, dBu, C, h0=None, *, block_s: int = 64,
                   block_i: int = 128, backend: Optional[str] = None):
    B, S, I, N = dA.shape
    if h0 is None:
        h0 = jnp.zeros((B, I, N), jnp.float32)
    be = _pick_backend(backend)
    if be == "ref":
        return selective_scan_ref(dA, dBu, C, h0)
    return selective_scan_kernel(dA, dBu, C, h0, block_s=block_s,
                                 block_i=min(block_i, I),
                                 interpret=(be == "interpret"))
