"""Pure-jnp oracle for the Mamba (S6) selective scan (naive recurrence).

    h_t = dA_t ⊙ h_{t-1} + dBu_t          h ∈ R^{I×N}
    y_t = Σ_n h_t[:, n] · C_t[n]

Shapes: dA/dBu (B, S, I, N) fp32; C (B, S, N) fp32; h0 (B, I, N) fp32.
Returns y (B, S, I) fp32 and final h.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def selective_scan_ref(dA, dBu, C, h0=None):
    B, S, I, N = dA.shape
    h = (jnp.zeros((B, I, N), jnp.float32) if h0 is None
         else h0.astype(jnp.float32))

    def step(h, inputs):
        dA_t, dBu_t, C_t = inputs
        h = dA_t * h + dBu_t
        y_t = jnp.einsum("bin,bn->bi", h, C_t)
        return h, y_t

    xs = (jnp.moveaxis(dA.astype(jnp.float32), 1, 0),
          jnp.moveaxis(dBu.astype(jnp.float32), 1, 0),
          jnp.moveaxis(C.astype(jnp.float32), 1, 0))
    h, ys = jax.lax.scan(step, h, xs)
    return jnp.moveaxis(ys, 0, 1), h
