"""Grouped (per-expert) matmul as a Pallas TPU kernel.

The MoE hot loop after dispatch: every expert e multiplies its capacity
buffer (C, D) by its weights (D, F).  Grid = (E, C/bc, F/bf, D/bd) with the
contraction axis innermost (``arbitrary``) accumulating into fp32 VMEM
scratch — the classic MXU-tiled matmul, batched over experts by the grid's
leading (parallel) dimension.

Block defaults (bc, bf, bd) = (128, 128, 512): MXU-aligned (multiples of
128 on both matmul dims), working set bc·bd + bd·bf + bc·bf fp32 ≈ 640 KB —
small enough that Mosaic can double-buffer the weight stream.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import compiler_params


def _gmm_kernel(x_ref, w_ref, o_ref, acc_scr):
    di = pl.program_id(3)
    nd = pl.num_programs(3)

    @pl.when(di == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    x = x_ref[0]                    # (bc, bd)
    w = w_ref[0]                    # (bd, bf)
    acc_scr[...] += jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(di == nd - 1)
    def _fin():
        o_ref[0] = acc_scr[...].astype(o_ref.dtype)


def grouped_matmul_kernel(x, w, *, block_c: int = 128, block_f: int = 128,
                          block_d: int = 512, interpret: bool = False):
    """x: (E, C, D) @ w: (E, D, F) -> (E, C, F)."""
    E, C, D = x.shape
    _, _, F = w.shape
    block_c = min(block_c, C)
    block_f = min(block_f, F)
    block_d = min(block_d, D)

    C_p = math.ceil(C / block_c) * block_c
    F_p = math.ceil(F / block_f) * block_f
    D_p = math.ceil(D / block_d) * block_d
    if C_p != C or D_p != D:
        x = jnp.pad(x, ((0, 0), (0, C_p - C), (0, D_p - D)))
    if D_p != D or F_p != F:
        w = jnp.pad(w, ((0, 0), (0, D_p - D), (0, F_p - F)))

    grid = (E, C_p // block_c, F_p // block_f, D_p // block_d)
    out = pl.pallas_call(
        _gmm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_c, block_d),
                         lambda e, c, f, d: (e, c, d)),
            pl.BlockSpec((1, block_d, block_f),
                         lambda e, c, f, d: (e, d, f)),
        ],
        out_specs=pl.BlockSpec((1, block_c, block_f),
                               lambda e, c, f, d: (e, c, f)),
        out_shape=jax.ShapeDtypeStruct((E, C_p, F_p), x.dtype),
        scratch_shapes=[pltpu.VMEM((block_c, block_f), jnp.float32)],
        compiler_params=compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(x, w)
    return out[:, :C, :F]
