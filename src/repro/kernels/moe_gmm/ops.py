"""jit'd wrapper for the grouped matmul with CPU fallback."""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax

from repro.kernels.moe_gmm.kernel import grouped_matmul_kernel
from repro.kernels.moe_gmm.ref import grouped_matmul_ref


def _pick_backend(backend: Optional[str]) -> str:
    if backend is not None:
        return backend
    try:
        plat = jax.devices()[0].platform
    except RuntimeError:          # pragma: no cover
        plat = "cpu"
    return "pallas" if plat == "tpu" else "ref"


@partial(jax.jit, static_argnames=("block_c", "block_f", "block_d",
                                   "backend"))
def grouped_matmul(x, w, *, block_c: int = 128, block_f: int = 128,
                   block_d: int = 512, backend: Optional[str] = None):
    be = _pick_backend(backend)
    if be == "ref":
        return grouped_matmul_ref(x, w)
    return grouped_matmul_kernel(x, w, block_c=block_c, block_f=block_f,
                                 block_d=block_d,
                                 interpret=(be == "interpret"))
