"""RWKV-6 WKV recurrence as a Pallas TPU kernel (chunked closed form).

Grid = (B, H, T/block_t); the time axis is the innermost ``arbitrary``
(sequential) dimension, carrying the (n × n) per-head state in VMEM
scratch across chunks.  Within a chunk of Q tokens the recurrence is
evaluated in closed form (FLA-style):

    y_t = (r_t · decay_to_t) Sᵀ + Σ_{s<t} (r_t · k_s · exp(logP_{t-1} −
          logP_s)) v_s + (r_t · u · k_t) v_t
    S' = exp(logP_Q) ⊙ S + Σ_s (k_s · exp(logP_Q − logP_s)) vᵀ_s

All cross-token terms are matmuls/reductions over (Q, Q, n) tensors with
exponents ≤ 0 (numerically stable: we always exponentiate *differences*
clamped by causality, never exp(+cumsum)).  For block_t = 64 and head_dim
n = 64 the (Q, Q, n) intermediate is 1 MB fp32 — well inside VMEM; r/k/v/w
chunks are 4·Q·n fp32 = 64 KB.

VMEM working set ≈ 1.3 MB per (batch, head) program: fits with double
buffering.  The MXU sees the (Q,n)@(n,n) and (Q,Q)@(Q,n) contractions;
the (Q,Q,n) mask-exp is VPU work — this kernel is the fusion the pure-JAX
path cannot express without materializing (B,T,H,n,n) HBM traffic.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import compiler_params


def _wkv6_kernel(r_ref, k_ref, v_ref, lw_ref, u_ref, s0_ref,
                 y_ref, sout_ref, S_scr, *, block_t: int, seq_t: int):
    ti = pl.program_id(2)
    nt = pl.num_programs(2)

    @pl.when(ti == 0)
    def _init():
        S_scr[...] = s0_ref[0, 0].astype(jnp.float32)

    r = r_ref[0, 0].astype(jnp.float32)            # (Q, n)
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    lw = lw_ref[0, 0].astype(jnp.float32)          # log decay, <= 0
    u = u_ref[0].astype(jnp.float32)               # (n,)
    Q, n = r.shape

    # zero padded positions (identity decay, zero kv contribution)
    t_pos = ti * block_t + jax.lax.broadcasted_iota(jnp.int32, (Q, 1), 0)
    valid = t_pos < seq_t                           # (Q, 1)
    lw = jnp.where(valid, lw, 0.0)
    k = jnp.where(valid, k, 0.0)

    logP = jnp.cumsum(lw, axis=0)                   # inclusive  (Q, n)
    logPm1 = logP - lw                              # exclusive

    S = S_scr[...]                                  # (n, n) key x value
    # inter-chunk: r decayed against the carried state
    y_inter = jax.lax.dot_general(r * jnp.exp(logPm1), S,
                                  (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)
    # intra-chunk: A[t, s] = sum_i r[t,i] k[s,i] exp(logPm1[t,i] - logP[s,i])
    expo = logPm1[:, None, :] - logP[None, :, :]    # (Q, Q, n), <= 0 for s<t
    causal_lt = (jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
                 > jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1))
    expo = jnp.where(causal_lt[:, :, None], expo, -jnp.inf)
    A = jnp.sum(r[:, None, :] * k[None, :, :] * jnp.exp(expo), axis=2)
    diag = jnp.sum(r * (u[None, :] * k), axis=1)    # bonus term
    y = y_inter + jax.lax.dot_general(A, v, (((1,), (0,)), ((), ())),
                                      preferred_element_type=jnp.float32) \
        + diag[:, None] * v
    y_ref[0, 0] = y.astype(y_ref.dtype)

    # state to chunk end: S' = exp(logP_Q) * S + (k * exp(logP_Q - logP))^T v
    logP_last = logP[-1]                            # (n,)
    k_tilde = k * jnp.exp(logP_last[None, :] - logP)
    S_new = jnp.exp(logP_last)[:, None] * S + jax.lax.dot_general(
        k_tilde, v, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    S_scr[...] = S_new

    @pl.when(ti == nt - 1)
    def _fin():
        sout_ref[0, 0] = S_new


def wkv6_kernel(r, k, v, logw, u, S0, *, block_t: int = 64,
                interpret: bool = False):
    """r/k/v/logw: (B, T, H, n); u: (H, n); S0: (B, H, n, n).
    Returns y (B, T, H, n) in r.dtype and final state (B, H, n, n) fp32."""
    B, T, H, n = r.shape
    block_t = min(block_t, T)
    T_p = math.ceil(T / block_t) * block_t
    if T_p != T:
        pad = ((0, 0), (0, T_p - T), (0, 0), (0, 0))
        r, k, v, logw = (jnp.pad(a, pad) for a in (r, k, v, logw))

    # layout: (B, H, T, n) blocks
    rt, kt, vt, lwt = (jnp.transpose(a, (0, 2, 1, 3))
                       for a in (r, k, v, logw))

    grid = (B, H, T_p // block_t)
    kern = functools.partial(_wkv6_kernel, block_t=block_t, seq_t=T)
    y, s_out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_t, n), lambda b, h, t: (b, h, t, 0)),
            pl.BlockSpec((1, 1, block_t, n), lambda b, h, t: (b, h, t, 0)),
            pl.BlockSpec((1, 1, block_t, n), lambda b, h, t: (b, h, t, 0)),
            pl.BlockSpec((1, 1, block_t, n), lambda b, h, t: (b, h, t, 0)),
            pl.BlockSpec((1, n), lambda b, h, t: (h, 0)),
            pl.BlockSpec((1, 1, n, n), lambda b, h, t: (b, h, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, block_t, n), lambda b, h, t: (b, h, t, 0)),
            pl.BlockSpec((1, 1, n, n), lambda b, h, t: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, T_p, n), r.dtype),
            jax.ShapeDtypeStruct((B, H, n, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((n, n), jnp.float32)],
        compiler_params=compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(rt, kt, vt, lwt, u, S0)
    return jnp.transpose(y, (0, 2, 1, 3))[:, :T], s_out
