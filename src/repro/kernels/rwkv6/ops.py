"""jit'd wrapper for the WKV6 kernel with CPU fallback to the oracle."""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.rwkv6.kernel import wkv6_kernel
from repro.kernels.rwkv6.ref import wkv6_ref


def _pick_backend(backend: Optional[str]) -> str:
    if backend is not None:
        return backend
    try:
        plat = jax.devices()[0].platform
    except RuntimeError:          # pragma: no cover
        plat = "cpu"
    return "pallas" if plat == "tpu" else "ref"


@partial(jax.jit, static_argnames=("block_t", "backend"))
def wkv6(r, k, v, logw, u, S0=None, *, block_t: int = 64,
         backend: Optional[str] = None):
    """RWKV-6 WKV. r/k/v/logw: (B, T, H, n); u: (H, n).
    Returns (y (B,T,H,n) fp32-accurate in r.dtype, final state fp32)."""
    B, T, H, n = r.shape
    if S0 is None:
        S0 = jnp.zeros((B, H, n, n), jnp.float32)
    be = _pick_backend(backend)
    if be == "ref":
        y, S = wkv6_ref(r, k, v, logw, u, S0)
        return y.astype(r.dtype), S
    return wkv6_kernel(r, k, v, logw, u, S0, block_t=block_t,
                       interpret=(be == "interpret"))
