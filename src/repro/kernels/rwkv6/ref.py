"""Pure-jnp oracle for the RWKV-6 WKV recurrence (naive step-by-step).

Per head, head_dim n, state S ∈ R^{n×n} (key-major):

    y_t = (S_{t-1} + diag(u * k_t) v_t^T)^T r_t      (read out)
    S_t = diag(w_t) S_{t-1} + k_t v_t^T              (decay + rank-1 update)

All math fp32.  Shapes: r/k/v/logw (B, T, H, n); u (H, n); S0 (B, H, n, n).
Returns y (B, T, H, n) fp32 and the final state.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def wkv6_ref(r, k, v, logw, u, S0=None):
    B, T, H, n = r.shape
    rf = r.astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    wf = jnp.exp(logw.astype(jnp.float32))            # decay in (0, 1)
    uf = u.astype(jnp.float32)
    S = (jnp.zeros((B, H, n, n), jnp.float32) if S0 is None
         else S0.astype(jnp.float32))

    def step(S, inputs):
        r_t, k_t, v_t, w_t = inputs                   # (B, H, n) each
        # bonus: current token contributes diag(u*k) v^T without decay
        S_plus = S + (uf[None] * k_t)[..., :, None] * v_t[..., None, :]
        y_t = jnp.einsum("bhij,bhi->bhj", S_plus, r_t)
        S = w_t[..., :, None] * S + k_t[..., :, None] * v_t[..., None, :]
        return S, y_t

    xs = (jnp.moveaxis(rf, 1, 0), jnp.moveaxis(kf, 1, 0),
          jnp.moveaxis(vf, 1, 0), jnp.moveaxis(wf, 1, 0))
    S, ys = jax.lax.scan(step, S, xs)
    return jnp.moveaxis(ys, 0, 1), S
