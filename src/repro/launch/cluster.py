"""N-worker cluster launcher: elastic multi-process training over ONE
shared DSM pool (the multi-writer protocol of ``repro.dsm.cluster``).

Spawns N ``repro.scenarios.cluster_worker`` data-parallel rank processes
against one pool directory: each rank owns a partition of the model state
(``train.elastic.partition_plan``), stages it into its ring sibling's
host buffer every step (cross-process RStore), and commits through the
multi-writer manifest protocol — rank records, one elected cluster
manifest per step.  ``--shrink-at`` demonstrates elastic scale-down: the
victim rank leaves at that step after a planned GPF commit and the
survivors repartition and continue — the same protocol the crash
scenarios (``repro.scenarios.runner --suite cluster``) drive with a real
mid-commit process kill instead of a planned exit.

This launcher drives the deterministic toy cluster state (the emulation
harness — fast, CPU-only, bit-exact); per-host REAL-model training over
the same pool protocol rides ``repro.launch.train`` on each host.

    python -m repro.launch.cluster --workers 3 --steps 20 \
        --pool /tmp/cluster_pool [--commit-every 5] \
        [--shrink-at 10 --victim 1] [--no-replicate]
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys

from repro.dsm.cluster import ControlPlane
from repro.dsm.pool import DSMPool
from repro.scenarios.cluster import spawn_worker
from repro.train.elastic import shrink_plan


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--workers", type=int, default=3)
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--pool", default="/tmp/repro_cluster_pool")
    ap.add_argument("--commit-every", type=int, default=5)
    ap.add_argument("--dim", type=int, default=16)
    ap.add_argument("--tensors", type=int, default=6)
    ap.add_argument("--global-batch", type=int, default=6)
    ap.add_argument("--no-replicate", action="store_true",
                    help="disable RStore staging into the ring sibling "
                         "(recovery then only has the pool)")
    ap.add_argument("--retention", type=int, default=5,
                    help="cluster manifests kept by the elected "
                         "committer's post-commit gc (0 = unbounded)")
    ap.add_argument("--topology", default=None,
                    help="emulated CXL topology preset forwarded to every "
                         "rank (cost-driven staging + shard sizing — see "
                         "repro.dsm.emu.PRESETS)")
    ap.add_argument("--shrink-at", type=int, default=0,
                    help="planned elastic scale-down: --victim leaves at "
                         "this step (0 = no shrink)")
    ap.add_argument("--victim", type=int, default=1,
                    help="rank that departs at --shrink-at")
    ap.add_argument("--timeout", type=float, default=600.0)
    args = ap.parse_args(argv)
    assert args.workers >= 2, "a cluster needs at least 2 workers"

    if args.shrink_at:
        assert 0 < args.shrink_at < args.steps
        assert 0 <= args.victim < args.workers
        ControlPlane(os.path.join(args.pool, "control")).post(
            args.victim, planned=True, at_step=args.shrink_at)
        plan = shrink_plan(args.workers, args.workers - 1)
        print(f"planned shrink at step {args.shrink_at}: rank "
              f"{args.victim} departs; data-shard responsibilities "
              f"reassign {plan}")

    procs = {r: spawn_worker(args.pool, r, args.workers,
                             steps=args.steps,
                             commit_every=args.commit_every,
                             replicate=not args.no_replicate,
                             dim=args.dim, tensors=args.tensors,
                             global_batch=args.global_batch,
                             retention=args.retention,
                             topology=args.topology,
                             timeout=args.timeout)
             for r in range(args.workers)}
    print(f"launched {args.workers} workers over {args.pool}")

    failed = 0
    for r, p in procs.items():
        try:
            out, err = p.communicate(timeout=args.timeout)
        except subprocess.TimeoutExpired:
            p.kill()
            out, err = p.communicate()
            print(f"rank {r}: TIMEOUT\n{err[-1000:]}")
            failed += 1
            continue
        if p.returncode != 0:
            print(f"rank {r}: rc={p.returncode}\n{err[-1000:]}")
            failed += 1
            continue
        res = json.loads(out.strip().splitlines()[-1])
        if "planned_exit_at" in res:
            print(f"rank {r}: departed at step {res['planned_exit_at']} "
                  f"(planned shrink)")
        else:
            print(f"rank {r}: done; live={res['live']} gen={res['gen']} "
                  f"owned={sorted(res['digests'])}")
    m = DSMPool(args.pool).latest_manifest()
    if m is not None:
        print(f"pool: newest cluster commit step {m['step']} "
              f"(seq {m['seq']}, live {m['meta'].get('live')})")
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
