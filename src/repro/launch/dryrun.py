"""Multi-pod dry-run (assignment §MULTI-POD DRY-RUN).

NOTE: the first two executable lines set XLA_FLAGS *before any jax import*
— jax locks the device count on first backend init.  512 placeholder host
devices back both production meshes (16x16 single-pod, 2x16x16 multi-pod).

For every (architecture × input shape) cell and both production meshes:
``jax.jit(step).lower(...).compile()`` with the full sharding config, then
record ``memory_analysis()`` / ``cost_analysis()`` and the parsed
collective schedule.  Additionally two *unrolled cost probes* (1 and 2
layer-periods at full global shape, no while loops) provide the per-period
FLOPs/bytes/collective-bytes that §Roofline extrapolates to full depth.

Usage:
    python -m repro.launch.dryrun --arch olmo-1b --shape train_4k \
        --mesh single --out results/
    python -m repro.launch.dryrun --all --mesh both --out results/
"""
from __future__ import annotations

import os
if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=512")
# ^ MUST precede any jax-importing import (see module docstring)

import argparse
import dataclasses
import json
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCH_IDS, SHAPES_BY_NAME, get_config
from repro.configs.base import ModelConfig, ShapeConfig, shape_applicable
from repro.launch.mesh import make_production_mesh
from repro.models import lm
from repro.models.registry import build, input_specs
from repro.parallel.sharding import ctx_for_mesh, param_specs
from repro.roofline.analysis import model_flops_for, roofline_terms
from repro.roofline.hlo import collective_bytes_of_hlo
from repro.train.state import abstract_train_state
from repro.train.step import make_train_step


# ---------------------------------------------------------------------------
# sharding trees
# ---------------------------------------------------------------------------

def _shard(mesh, spec):
    return NamedSharding(mesh, spec)


def _tree_shardings(mesh, ctx, descs):
    specs = param_specs(ctx, descs)
    return jax.tree_util.tree_map(lambda s: _shard(mesh, s), specs)


def _batch_shardings(mesh, ctx, specs: Dict[str, Any]):
    dp = ctx.dp_axes if len(ctx.dp_axes) > 1 else ctx.dp_axes[0]
    out = {}
    for k, v in specs.items():
        gb = v.shape[0]
        p0 = dp if gb % ctx.dp_size == 0 else None
        out[k] = _shard(mesh, P(p0, *([None] * (len(v.shape) - 1))))
    return out


def _state_shardings(mesh, ctx, bundle, moment_dtype):
    p_sh = _tree_shardings(mesh, ctx, bundle.descs)
    rep = _shard(mesh, P())
    from repro.train.state import TrainState
    from repro.optim.adamw import AdamWState
    return TrainState(
        params=p_sh,
        opt=AdamWState(step=rep, mu=p_sh, nu=p_sh),
        rng=rep)


def _cache_shardings(mesh, ctx, bundle, batch, t_max):
    return _tree_shardings(mesh, ctx, bundle.cache_descs(batch, t_max))


# ---------------------------------------------------------------------------
# lower + compile one cell
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class CellResult:
    arch: str
    shape: str
    mesh: str
    kind: str
    ok: bool
    error: Optional[str] = None
    compile_s: float = 0.0
    # full (scanned) artifact
    bytes_per_device: Optional[int] = None
    argument_bytes: Optional[int] = None
    output_bytes: Optional[int] = None
    flops_cost: Optional[float] = None          # cost_analysis of full module
    # probes (per chip)
    probe1: Optional[Dict[str, float]] = None
    probe2: Optional[Dict[str, float]] = None
    n_periods: int = 1
    collective_kinds: Optional[Dict[str, int]] = None
    unresolved_trip: bool = False


def probe_cfg(cfg: ModelConfig, k_periods: int) -> ModelConfig:
    """cfg with prefix + k periods of layers (for the unrolled probes).

    enc-dec: one "period" = one decoder layer + proportionally many
    encoder layers (whisper: 1:1)."""
    if cfg.is_encdec:
        import dataclasses as _dc
        enc_per = cfg.encdec.n_enc_layers // cfg.n_layers
        return cfg.with_(
            n_layers=k_periods,
            encdec=_dc.replace(cfg.encdec,
                               n_enc_layers=max(enc_per * k_periods, 1)))
    groups = lm.layer_groups(cfg)
    prefix = sum(g.n_repeats * len(g.kinds) for g in groups[:-1])
    period = len(groups[-1].kinds)
    return cfg.with_(n_layers=prefix + k_periods * period)


def n_periods_of(cfg: ModelConfig) -> int:
    if cfg.is_encdec:
        return cfg.n_layers
    return lm.layer_groups(cfg)[-1].n_repeats


def _make_step(bundle, cfg, shape, ctx, *, unroll_layers=False,
               microbatch=1):
    """(fn, example args tree builder) for the cell's step kind."""
    if shape.kind == "train":
        def fn(state, batch):
            step = make_train_step(bundle, ctx, microbatch=microbatch)
            return step(state, batch)
        if unroll_layers:
            def fn(state, batch):  # noqa: F811
                def loss_of(params, b):
                    # probes unroll the KV/SSM chunk scans too -> while-free
                    return bundle.loss(params, b, ctx=ctx, with_remat=True,
                                       unroll_layers=True, unroll=True)
                (loss, metrics), grads = jax.value_and_grad(
                    loss_of, has_aux=True)(state.params, batch)
                from repro.optim.adamw import adamw_update
                params, opt, gn = adamw_update(state.params, grads,
                                               state.opt, 1e-4)
                from repro.train.state import TrainState
                return TrainState(params, opt, state.rng), {"loss": loss}
        return fn
    if shape.kind == "prefill":
        def fn(params, batch, caches):
            return bundle.prefill(params, batch, caches, ctx=ctx,
                                  unroll_layers=unroll_layers,
                                  unroll=unroll_layers)
        return fn
    # decode
    def fn(params, tokens, serve_state):
        return bundle.decode(params, tokens, serve_state, ctx=ctx,
                             unroll_layers=unroll_layers)
    return fn


def lower_cell(cfg: ModelConfig, shape: ShapeConfig, mesh, mesh_name: str,
               *, unroll_layers=False, want_hlo=False, strategy="tp",
               microbatch=1):
    """Lower+compile one cell; returns (compiled, lowered, meta)."""
    ctx = ctx_for_mesh(mesh, strategy=strategy)
    bundle = build(cfg, dec_pos_len=min(shape.seq_len, 2048))
    specs = input_specs(cfg, shape)
    b_sh = _batch_shardings(mesh, ctx, specs)
    fn = _make_step(bundle, cfg, shape, ctx, unroll_layers=unroll_layers,
                    microbatch=microbatch)

    if shape.kind == "train":
        state = abstract_train_state(bundle.abstract_params(),
                                     cfg.moment_dtype)
        st_sh = _state_shardings(mesh, ctx, bundle, cfg.moment_dtype)
        jitted = jax.jit(fn, in_shardings=(st_sh, b_sh),
                         donate_argnums=(0,))
        lowered = jitted.lower(state, specs)
    elif shape.kind == "prefill":
        params = bundle.abstract_params()
        p_sh = _tree_shardings(mesh, ctx, bundle.descs)
        caches = bundle.abstract_caches(shape.global_batch, shape.seq_len)
        c_sh = _cache_shardings(mesh, ctx, bundle, shape.global_batch,
                                shape.seq_len)
        jitted = jax.jit(fn, in_shardings=(p_sh, b_sh, c_sh),
                         donate_argnums=(2,))
        lowered = jitted.lower(params, specs, caches)
    else:
        params = bundle.abstract_params()
        p_sh = _tree_shardings(mesh, ctx, bundle.descs)
        caches = bundle.abstract_caches(shape.global_batch, shape.seq_len)
        c_sh = _cache_shardings(mesh, ctx, bundle, shape.global_batch,
                                shape.seq_len)
        serve_state = lm.ServeState(
            caches=caches, pos=jax.ShapeDtypeStruct((), jnp.int32))
        ss_sh = lm.ServeState(caches=c_sh, pos=_shard(mesh, P()))
        jitted = jax.jit(fn, in_shardings=(p_sh, b_sh["tokens"], ss_sh),
                         donate_argnums=(2,))
        lowered = jitted.lower(params, specs["tokens"], serve_state)

    compiled = lowered.compile()
    return compiled, lowered


def _cost_of(compiled) -> Dict[str, float]:
    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    return {"flops": float(ca.get("flops", 0.0)),
            "bytes": float(ca.get("bytes accessed", 0.0))}


def run_cell(arch: str, shape_name: str, mesh_name: str,
             with_probes: bool = True, strategy: str = "tp",
             cache_dtype: str = "", microbatch: int = 1) -> CellResult:
    cfg = get_config(arch)
    if cache_dtype:
        cfg = cfg.with_(cache_dtype=cache_dtype)
    shape = SHAPES_BY_NAME[shape_name]
    ok, reason = shape_applicable(cfg, shape)
    if not ok:
        return CellResult(arch, shape_name, mesh_name, shape.kind,
                          ok=True, error=f"SKIP: {reason}")
    mesh = make_production_mesh(multi_pod=(mesh_name == "multi"))
    res = CellResult(arch, shape_name, mesh_name, shape.kind, ok=False)
    t0 = time.time()
    try:
        compiled, lowered = lower_cell(cfg, shape, mesh, mesh_name,
                                       strategy=strategy,
                                       microbatch=microbatch)
        ma = compiled.memory_analysis()
        res.bytes_per_device = int(getattr(ma, "temp_size_in_bytes", 0)
                                   + getattr(ma, "output_size_in_bytes", 0))
        res.argument_bytes = int(getattr(ma, "argument_size_in_bytes", 0))
        res.output_bytes = int(getattr(ma, "output_size_in_bytes", 0))
        res.flops_cost = _cost_of(compiled)["flops"]

        if with_probes:
            n_per = n_periods_of(cfg)
            res.n_periods = n_per
            probes = {}
            for k in (1, 2):
                if n_per < 2 and k == 2:
                    probes[k] = dict(probes[1])
                    break
                pcfg = probe_cfg(cfg, k)
                c_k, l_k = lower_cell(pcfg, shape, mesh, mesh_name,
                                      unroll_layers=True, strategy=strategy,
                                      microbatch=microbatch)
                cost = _cost_of(c_k)
                coll = collective_bytes_of_hlo(c_k.as_text())
                probes[k] = {"flops": cost["flops"], "bytes": cost["bytes"],
                             "coll_bytes": float(coll.total_bytes)}
                if k == 1:
                    res.collective_kinds = dict(coll.by_kind)
                    res.unresolved_trip = coll.unresolved_trip
            res.probe1, res.probe2 = probes[1], probes[2]
        res.ok = True
    except Exception:
        res.error = traceback.format_exc(limit=25)
    res.compile_s = time.time() - t0
    return res


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--no-probes", action="store_true")
    ap.add_argument("--strategy", default="tp", choices=["tp", "dp_only"])
    ap.add_argument("--cache-dtype", default="")
    ap.add_argument("--microbatch", type=int, default=1)
    ap.add_argument("--out", default="results")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    meshes = (["single", "multi"] if args.mesh == "both" else [args.mesh])
    archs = ARCH_IDS if (args.all or not args.arch) else [args.arch]
    shapes = (list(SHAPES_BY_NAME) if (args.all or not args.shape)
              else [args.shape])

    failures = 0
    for arch in archs:
        for shape_name in shapes:
            for mesh_name in meshes:
                out_path = os.path.join(
                    args.out, f"{arch}__{shape_name}__{mesh_name}.json")
                if os.path.exists(out_path):
                    print(f"[skip cached] {out_path}")
                    continue
                r = run_cell(arch, shape_name, mesh_name,
                             with_probes=not args.no_probes,
                             strategy=args.strategy,
                             cache_dtype=args.cache_dtype,
                             microbatch=args.microbatch)
                with open(out_path, "w") as f:
                    json.dump(dataclasses.asdict(r), f, indent=1)
                status = "OK" if r.ok else "FAIL"
                if r.error and r.error.startswith("SKIP"):
                    status = "SKIP"
                print(f"[{status}] {arch} {shape_name} {mesh_name} "
                      f"({r.compile_s:.0f}s) mem/dev="
                      f"{(r.bytes_per_device or 0)/1e9:.2f}GB")
                if not r.ok:
                    failures += 1
                    print(r.error)
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
