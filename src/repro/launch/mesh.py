"""Production meshes.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so
importing this module never touches jax device state.  The single-pod mesh
is (data=16, model=16) = 256 chips (one TPU v5e pod); the multi-pod mesh
adds a leading pod axis: (pod=2, data=16, model=16) = 512 chips.  Data
parallelism (and FSDP weight sharding) runs over ('pod', 'data'); tensor/
expert/sequence parallelism over 'model'.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(n_devices: int = 1, model: int = 1):
    """Tiny mesh over however many local devices exist (tests/examples)."""
    data = max(n_devices // model, 1)
    return jax.make_mesh((data, model), ("data", "model"))
