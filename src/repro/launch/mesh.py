"""Production meshes.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so
importing this module never touches jax device state.  The single-pod mesh
is (data=16, model=16) = 256 chips (one TPU v5e pod); the multi-pod mesh
adds a leading pod axis: (pod=2, data=16, model=16) = 512 chips.  Data
parallelism (and FSDP weight sharding) runs over ('pod', 'data'); tensor/
expert/sequence parallelism over 'model'.
"""
from __future__ import annotations

import numpy as np

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(n_devices: int = 1, model: int = 1):
    """Tiny mesh over however many local devices exist (tests/examples)."""
    data = max(n_devices // model, 1)
    return jax.make_mesh((data, model), ("data", "model"))


def parse_mesh(spec: str):
    """``"2x4"`` → a live (data=2, model=4) Mesh; ``"2x2x2"`` adds the
    leading pod axis.  The CLI surface of the mesh lane (scenario runner
    ``--mesh``, worker ``--mesh``): one parser, so every front-end names
    the axes the same way."""
    dims = tuple(int(d) for d in spec.lower().split("x"))
    if len(dims) == 2:
        return jax.make_mesh(dims, ("data", "model"))
    if len(dims) == 3:
        return jax.make_mesh(dims, ("pod", "data", "model"))
    raise ValueError(f"mesh spec {spec!r}: want DxM or PxDxM")


def mesh_device_sets(live):
    """Per-rank mesh-slice weights for ``train.elastic.partition_plan``:
    how many devices each live rank's ``rank_submesh`` slice owns.  Pure
    function of (device count, live set) — every process derives the same
    map, so partition plans stay coordination-free."""
    order = sorted(live)
    per = max(1, len(jax.devices()) // max(1, len(order)))
    return {r: per for r in order}


def rank_submesh(rank: int, live, *, axes=("data", "model")):
    """The mesh SLICE a cluster rank owns: the process's devices are split
    into contiguous equal runs over the sorted live ranks and this rank's
    run becomes its own (n, 1) Mesh.  Every rank derives the same layout
    from the same ``live`` set (pure function of public state — no
    coordination), and after a shrink the survivors re-derive slices over
    the REMAINING ranks, so the dead rank's devices are re-adopted rather
    than idled.  With fewer devices than ranks, slices degrade to single
    (possibly shared) devices — the 1-device CI fallback."""
    devs = jax.devices()
    order = sorted(live)
    if rank not in order:
        raise ValueError(f"rank {rank} not in live set {order}")
    per = max(1, len(devs) // max(1, len(order)))
    pos = order.index(rank)
    mine = devs[pos * per:(pos + 1) * per] or [devs[pos % len(devs)]]
    arr = np.array(mine).reshape(len(mine), 1)
    return jax.sharding.Mesh(arr, axes)
