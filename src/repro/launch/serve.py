"""Serving launcher: continuous batching over the durable tier stack.

Thin front-end over ``repro.serve`` — the slot scheduler, tiered KV-cache
manager and durable session store live there; this file only parses
flags, builds the (data, model) mesh and reports throughput.

    # stateless continuous batching, mixed-length synthetic trace
    python -m repro.launch.serve --arch olmo-1b --smoke --requests 16

    # durable serving: sessions commit through the FliT path; re-running
    # the same command after a kill resumes every committed session
    python -m repro.launch.serve --smoke --pool /tmp/serve_pool \
        --commit-every 4

    # the static-batch baseline the benchmark compares against
    python -m repro.launch.serve --smoke --mode static

    # a 2-engine fleet over one pool: cost-routed admission, automatic
    # rebalancing migrations, cross-engine prefix reuse
    python -m repro.launch.serve --smoke --pool /tmp/fleet_pool \
        --engines 2 --topology cxl20-switched-pool
"""
from __future__ import annotations

import argparse
import time

import jax

from repro.dsm.api import CXL0Config
from repro.dsm.emu import PRESETS
from repro.dsm.flit_runtime import AUTO_MODE, COMMIT_MODES
from repro.parallel.sharding import ctx_for_mesh
from repro.serve.engine import build_serve_engine, servable_archs
from repro.serve.trace import synthetic_trace, trace_t_max


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b", choices=servable_archs())
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--mode", default="continuous",
                    choices=["continuous", "static"])
    ap.add_argument("--slots", type=int, default=4,
                    help="decode slots (= static batch size)")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", default="4,8,16,32,48",
                    help="cycled per-request decode budgets (the mixed-"
                         "length workload)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--mesh-model", type=int, default=1)
    ap.add_argument("--pool", default=None,
                    help="DSM pool dir: enables durable sessions + resume")
    ap.add_argument("--commit-every", type=int, default=4,
                    help="session-commit cadence in decode ticks")
    ap.add_argument("--commit-mode", default="sync",
                    choices=COMMIT_MODES + (AUTO_MODE,),
                    help="flush schedule; 'auto' defers to the placement "
                         "policy (requires --topology)")
    ap.add_argument("--topology", default=None, choices=sorted(PRESETS),
                    help="emulated CXL topology: cost-driven commit shard "
                         "count (and schedule, with --commit-mode auto)")
    ap.add_argument("--retire-done", action="store_true",
                    help="drop finished sessions from the committed table "
                         "(bounds commit cost for long-lived serving; "
                         "restarts then replay only unfinished sessions)")
    ap.add_argument("--restore-mode", default="cache",
                    choices=["cache", "replay"])
    ap.add_argument("--engines", type=int, default=1,
                    help=">= 2 serves the trace with a FLEET of engines "
                         "over one pool: cost-routed admission, "
                         "rebalancing live migrations, prefix reuse")
    ap.add_argument("--block-tokens", type=int, default=16,
                    help="paged KV layout: tokens per pool block")
    ap.add_argument("--no-prefix-reuse", action="store_true",
                    help="fleet: disable content-addressed cross-engine "
                         "prefix blocks")
    args = ap.parse_args()
    if args.commit_mode == AUTO_MODE and args.topology is None:
        ap.error("--commit-mode auto requires --topology")
    if args.topology is not None and args.pool is None:
        ap.error("--topology drives durable-commit placement: it needs "
                 "--pool (stateless serving has nothing to place)")
    if args.engines >= 2:
        if args.pool is None:
            ap.error("--engines >= 2 is fleet serving over a SHARED "
                     "pool: it needs --pool")
        if args.mode != "continuous":
            ap.error("fleet serving is continuous-batching only")
        return _fleet_main(args)

    n_dev = jax.device_count()
    mesh = jax.make_mesh((max(n_dev // args.mesh_model, 1),
                          args.mesh_model), ("data", "model"))
    ctx = ctx_for_mesh(mesh)

    new_tokens = tuple(int(t) for t in args.new_tokens.split(","))
    trace = synthetic_trace(args.requests, seed=args.seed,
                            prompt_lens=(args.prompt_len,),
                            new_tokens=new_tokens, vocab_size=1)
    # one wiring path: the pool/schedule/topology knobs land in the
    # unified config; stateless serving passes no config at all
    dsm = (CXL0Config(path=args.pool, schedule=args.commit_mode,
                      topology=args.topology, retention=2)
           if args.pool else None)
    engine, cfg = build_serve_engine(
        args.arch, smoke=args.smoke, n_slots=args.slots,
        t_max=trace_t_max(trace), ctx=ctx, dsm=dsm,
        commit_every=args.commit_every if args.pool else 0,
        restore_mode=args.restore_mode, retire_done=args.retire_done,
        seed=args.seed)
    # regenerate with the real vocab now the config is known
    trace = synthetic_trace(args.requests, seed=args.seed,
                            prompt_lens=(args.prompt_len,),
                            new_tokens=new_tokens,
                            vocab_size=cfg.vocab_size)

    resumed = engine.resume() if args.pool else None
    if resumed is not None:
        print(f"resumed from committed tick {resumed}")
    t0 = time.perf_counter()
    res = (engine.run(trace) if args.mode == "continuous"
           else engine.run_static(trace))
    dt = time.perf_counter() - t0
    engine.close()
    print(f"{res.mode}: {len(res.outputs)} requests, "
          f"{res.emitted_tokens} tokens in {dt:.2f}s "
          f"({res.emitted_tokens / dt:.0f} tok/s incl. compile), "
          f"{res.decode_ticks} decode ticks, {res.prefills} prefills"
          + (f", {res.commits} session commits" if res.commits else "")
          + (f", {res.resumed_sessions} sessions resumed"
             if res.resumed_sessions else ""))


def _fleet_main(args):
    from repro.configs import get_config, get_smoke_config
    from repro.serve.fleet import FleetController

    cfg = (get_smoke_config(args.arch) if args.smoke
           else get_config(args.arch))
    new_tokens = tuple(int(t) for t in args.new_tokens.split(","))
    trace = synthetic_trace(args.requests, seed=args.seed,
                            prompt_lens=(args.prompt_len,),
                            new_tokens=new_tokens,
                            vocab_size=cfg.vocab_size)
    fl = FleetController(
        args.arch, pool_path=args.pool, n_engines=args.engines,
        smoke=args.smoke, n_slots=args.slots, t_max=trace_t_max(trace),
        commit_every=args.commit_every, commit_mode=args.commit_mode,
        topology=args.topology, seed=args.seed,
        block_tokens=args.block_tokens,
        prefix_reuse=not args.no_prefix_reuse,
        restore_mode=args.restore_mode, retire_done=args.retire_done)
    steps = fl.resume()
    resumed = [f"e{i}@{s}" for i, s in steps.items() if s is not None]
    if resumed:
        print(f"resumed: {', '.join(resumed)}")
    t0 = time.perf_counter()
    res = fl.run(trace)
    dt = time.perf_counter() - t0
    fl.close()
    per = ", ".join(
        f"e{i}: {len(r.outputs)} req / {r.prefills} prefills / "
        f"{r.prefix_hits} prefix hits"
        for i, r in sorted(res.per_engine.items()))
    print(f"fleet[{args.engines}]: {len(res.outputs)} requests, "
          f"{res.emitted_tokens} tokens in {dt:.2f}s "
          f"({res.emitted_tokens / dt:.0f} tok/s incl. compile), "
          f"{res.migrations} migrations, {res.prefix_hits} prefix hits "
          f"({per})")


if __name__ == "__main__":
    main()
