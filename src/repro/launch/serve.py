"""Serving launcher: batched prefill + decode on a (data, model) mesh.

    python -m repro.launch.serve --arch deepseek-v2-236b --smoke \
        --batch 8 --prompt-len 128 --new-tokens 64
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.models.registry import build
from repro.parallel.sharding import ctx_for_mesh
from repro.train.elastic import shardings_for
from repro.train.step import make_serve_steps


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b", choices=ARCH_IDS)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--mesh-model", type=int, default=1)
    args = ap.parse_args()

    n_dev = jax.device_count()
    mesh = jax.make_mesh((max(n_dev // args.mesh_model, 1),
                          args.mesh_model), ("data", "model"))
    ctx = ctx_for_mesh(mesh)
    cfg = (get_smoke_config(args.arch) if args.smoke
           else get_config(args.arch))
    t_max = args.prompt_len + args.new_tokens
    bundle = build(cfg, dec_pos_len=t_max)
    key = jax.random.PRNGKey(0)
    params = jax.tree_util.tree_map(
        jax.device_put, bundle.init_params(key),
        shardings_for(ctx, bundle.descs))

    batch = {"tokens": jax.random.randint(
        key, (args.batch, args.prompt_len), 0, cfg.vocab_size)}
    if cfg.is_encdec:
        batch["enc_embeds"] = jax.random.normal(
            key, (args.batch, cfg.encdec.enc_seq, cfg.d_model), jnp.bfloat16)
    caches = bundle.init_caches(key, args.batch, t_max)

    prefill_fn, decode_fn = make_serve_steps(bundle, ctx)
    prefill = jax.jit(prefill_fn)
    decode = jax.jit(decode_fn)

    t0 = time.perf_counter()
    logits, state = prefill(params, batch, caches)
    jax.block_until_ready(logits)
    print(f"prefill {args.batch}x{args.prompt_len}: "
          f"{(time.perf_counter()-t0)*1e3:.0f} ms (incl. compile)")

    tokens = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    t0 = time.perf_counter()
    for _ in range(args.new_tokens - 1):
        logits, state = decode(params, tokens, state)
        tokens = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    jax.block_until_ready(tokens)
    dt = time.perf_counter() - t0
    print(f"decode: {(args.new_tokens-1)*args.batch/dt:.0f} tok/s")


if __name__ == "__main__":
    main()
