"""Production training launcher (one process per worker/host).

On a real cluster every host runs this with the usual JAX distributed
env (``jax.distributed.initialize`` picks up coordinator/rank from the
scheduler); on a dev box it runs single-process.  Wires together:

  mesh -> sharded state -> train_step -> durable FliT-commit loop
  (pool on shared storage; peer staging optional; elastic restart).

    python -m repro.launch.train --arch olmo-1b --steps 100 \
        --global-batch 8 --seq 512 --pool /tmp/pool [--mesh-data 4] \
        [--commit-every 10] [--mode sharded-async] [--shards 8] \
        [--retention 5] [--compress int8]

The default commit schedule is ``sharded-async``: per-device state shards
are flushed on parallel pipelines, double-buffered behind the next step's
compute, with manifest retention GC (see repro.dsm.flit_runtime).
"""
from __future__ import annotations

import argparse
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.data.pipeline import DataPipeline, SyntheticLMSource
from repro.dsm.api import CXL0Config
from repro.dsm.emu import PRESETS
from repro.models.registry import build
from repro.parallel.sharding import ctx_for_mesh
from repro.parallel.compression import make_int8_transform
from repro.train.elastic import shardings_for
from repro.train.loop import run_durable_loop
from repro.train.state import init_train_state
from repro.train.step import make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b", choices=ARCH_IDS)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU dev loop)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=512)
    ap.add_argument("--pool", default="/tmp/repro_pool")
    ap.add_argument("--commit-every", type=int, default=10)
    ap.add_argument("--mode", default="sharded-async",
                    choices=["sync", "async", "sharded", "sharded-async",
                             "auto"],
                    help="flush schedule; 'auto' defers to the placement "
                         "policy (requires --topology)")
    ap.add_argument("--topology", default=None, choices=sorted(PRESETS),
                    help="emulated CXL topology: cost-driven commit shard "
                         "count (and schedule, with --mode auto)")
    ap.add_argument("--shards", type=int, default=0,
                    help="shard pipelines per object (0 = auto: one per "
                         "local device, capped by state size)")
    ap.add_argument("--retention", type=int, default=5,
                    help="manifests kept by GC after each commit "
                         "(0 = unbounded)")
    ap.add_argument("--resume", action="store_true",
                    help="recover from the pool before training "
                         "(restart of a crashed/preempted worker)")
    ap.add_argument("--mesh-data", type=int, default=0,
                    help="data axis size (0 = all devices)")
    ap.add_argument("--mesh-model", type=int, default=1)
    ap.add_argument("--microbatch", type=int, default=1)
    ap.add_argument("--compress", default="none", choices=["none", "int8"])
    ap.add_argument("--distributed", action="store_true",
                    help="call jax.distributed.initialize() (multi-host)")
    args = ap.parse_args()

    if args.distributed:
        jax.distributed.initialize()

    n_dev = jax.device_count()
    data = args.mesh_data or max(n_dev // args.mesh_model, 1)
    mesh = jax.make_mesh((data, args.mesh_model), ("data", "model"))
    ctx = ctx_for_mesh(mesh)
    print(f"mesh: data={data} model={args.mesh_model} "
          f"({n_dev} devices, process {jax.process_index()})")

    cfg = (get_smoke_config(args.arch) if args.smoke
           else get_config(args.arch))
    bundle = build(cfg)
    key = jax.random.PRNGKey(0)
    params = bundle.init_params(key)
    params = jax.tree_util.tree_map(jax.device_put, params,
                                    shardings_for(ctx, bundle.descs))
    state = init_train_state(params, key, cfg.moment_dtype)

    grad_transform = None
    if args.compress == "int8":
        transform, _ = make_int8_transform(with_error_feedback=False)
        grad_transform = lambda g, ctx: transform(g, None)[0]

    step = jax.jit(make_train_step(bundle, ctx, microbatch=args.microbatch,
                                   total_steps=args.steps,
                                   grad_transform=grad_transform))
    pipe = DataPipeline(SyntheticLMSource(cfg.vocab_size),
                        args.global_batch, args.seq)
    if args.mode == "auto" and args.topology is None:
        ap.error("--mode auto requires --topology")
    # one wiring path: every DSM knob lands in the unified config.
    # --shards 0 -> None: the committer auto-sizes from the actual HBM
    # state volume at the first sharded flush (one heuristic, one place)
    ctx = CXL0Config(path=args.pool,
                     worker_id=jax.process_index(),
                     schedule=args.mode,
                     topology=args.topology,
                     n_shards=args.shards or None,
                     retention=args.retention or None).open()
    pool = ctx.pool
    r = run_durable_loop(step, state, pipe, ctx, n_steps=args.steps,
                         commit_every=args.commit_every,
                         resume=args.resume)
    if r.resumed_from is not None:
        print(f"resumed from step {r.resumed_from} "
              f"(source: {r.recoveries[0]})")
    if not r.losses:        # resume found every step already committed
        print(f"done: nothing to do; commits in pool up to step "
              f"{pool.latest_manifest()['step']}")
        return
    print(f"done: {len(r.losses)} steps, loss {r.losses[0]:.3f} -> "
          f"{r.losses[-1]:.3f}; commits in pool: "
          f"{pool.latest_manifest()['step'] + 1}")
    comp = np.mean([t.compute_s for t in r.timings if t.compute_s])
    print(f"mean step {comp*1e3:.1f} ms")


if __name__ == "__main__":
    main()
