"""Attention: grouped-query attention + MLA, chunked online-softmax.

Layout choices (see DESIGN.md §5):
* q is produced natively grouped as (B, S, K, G, hd) with K = kv heads
  (sharded on the model axis) and G = q-heads-per-kv-head (unsharded), so
  GQA needs no repeat/reshape of a sharded head axis.
* ``chunked_attention`` streams KV in chunks with an online softmax
  (the pure-JAX twin of the Pallas flash kernel in ``repro.kernels.attention``)
  so prefill_32k / decode_500k never materialize (S, T) score matrices.
* MLA decode reuses the same routine in "absorbed" form: a single shared
  latent KV head of width kv_lora(+rope) — K=1, G=H.
"""
from __future__ import annotations

from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.params import ParamDesc
from repro.models.common import rope, rms_head_norm

NEG_INF = -1e9


# ---------------------------------------------------------------------------
# Core: chunked online-softmax attention
# ---------------------------------------------------------------------------

def chunked_attention(
    q: jax.Array,                     # (B, S, K, G, hd_k) float
    kv,                               # pytree; each leaf (B, T, ...) on axis 1
    expand_fn: Callable,              # kv_chunk -> (k (B,Tc,K,hd_k), v (B,Tc,K,hd_v))
    q_positions: jax.Array,           # (B, S) int32
    kv_base: int,                     # kv chunk c covers positions [kv_base + c*chunk, ...)
    *,
    causal: bool,
    chunk: int,
    unroll: bool = False,
    softmax_scale: Optional[float] = None,
) -> jax.Array:                       # (B, S, K, G, hd_v)
    B, S, K, G, hd_k = q.shape
    T = jax.tree_util.tree_leaves(kv)[0].shape[1]
    chunk = min(chunk, T)
    T_valid = T
    if T % chunk:                      # pad KV to a chunk multiple; padded
        pad = chunk - T % chunk        # positions are masked out below
        kv = jax.tree_util.tree_map(
            lambda a: jnp.pad(a, [(0, 0), (0, pad)] + [(0, 0)] * (a.ndim - 2)),
            kv)
        T += pad
    n_chunks = T // chunk
    scale = softmax_scale if softmax_scale is not None else hd_k ** -0.5

    # probe hd_v
    k0, v0 = expand_fn(jax.tree_util.tree_map(lambda a: a[:, :chunk], kv))
    hd_v = v0.shape[-1]

    qf = q.astype(jnp.float32) * scale

    def body(carry, c):
        m, l, acc = carry
        kv_c = jax.tree_util.tree_map(
            lambda a: jax.lax.dynamic_slice_in_dim(a, c * chunk, chunk, 1), kv)
        k_c, v_c = expand_fn(kv_c)
        # scores: (B, K, G, S, Tc)
        s = jnp.einsum("bskgh,btkh->bkgst", qf, k_c.astype(jnp.float32))
        kv_pos = kv_base + c * chunk + jnp.arange(chunk, dtype=jnp.int32)
        if causal:
            mask = q_positions[:, None, :] >= kv_pos[None, :, None]  # (B,Tc,S)
            mask = jnp.transpose(mask, (0, 2, 1))[:, None, None]     # (B,1,1,S,Tc)
            s = jnp.where(mask, s, NEG_INF)
        if T_valid != T:               # mask the chunk-padding positions
            valid = (kv_pos < kv_base + T_valid)[None, None, None, None, :]
            s = jnp.where(valid, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bkgst,btkh->bskgh", p.astype(v_c.dtype), v_c)
        acc_new = acc * jnp.transpose(corr, (0, 3, 1, 2))[..., None] + \
            pv.astype(jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, K, G, S), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, K, G, S), jnp.float32)
    acc0 = jnp.zeros((B, S, K, G, hd_v), jnp.float32)
    # checkpoint the chunk body: without it the scan's BACKWARD stacks the
    # per-chunk (B,K,G,S,Tc) score tensors across all chunks (flash-attention
    # forward, dense-attention backward). With it the bwd recomputes each
    # chunk's scores — O(S·Tc) live, not O(S·T).
    body_ck = jax.checkpoint(body,
                             policy=jax.checkpoint_policies.nothing_saveable)
    (m, l, acc), _ = jax.lax.scan(
        body_ck, (m0, l0, acc0), jnp.arange(n_chunks, dtype=jnp.int32),
        unroll=n_chunks if unroll else 1)
    denom = jnp.maximum(jnp.transpose(l, (0, 3, 1, 2)), 1e-20)[..., None]
    return (acc / denom).astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA attention block
# ---------------------------------------------------------------------------

def gqa_descs(cfg: ModelConfig):
    d, K, hd = cfg.d_model, cfg.n_kv_heads, cfg.head_dim
    G = cfg.n_heads // cfg.n_kv_heads
    out = {
        "wq": ParamDesc((d, K, G, hd), ("embed", "kv_heads", "q_per_kv", "head_dim")),
        "wk": ParamDesc((d, K, hd), ("embed", "kv_heads", "head_dim")),
        "wv": ParamDesc((d, K, hd), ("embed", "kv_heads", "head_dim")),
        "wo": ParamDesc((K, G, hd, d), ("kv_heads", "q_per_kv", "head_dim", "embed")),
    }
    if cfg.qk_norm:
        out["q_norm"] = ParamDesc((hd,), ("head_dim",), init="ones")
        out["k_norm"] = ParamDesc((hd,), ("head_dim",), init="ones")
    return out


class KVCache(NamedTuple):
    """Decode-time cache for one attention layer (possibly layer-stacked)."""
    k: jax.Array          # (B, T_max, K, hd)  |  MLA: ckv (B, T_max, kv_lora)
    v: jax.Array          # (B, T_max, K, hd)  |  MLA: k_rope (B, T_max, qk_rope)


def gqa_cache_desc(cfg: ModelConfig, batch: int, t_max: int):
    shape = (batch, t_max, cfg.n_kv_heads, cfg.head_dim)
    dt = cfg.cache_dtype or cfg.compute_dtype
    return KVCache(
        k=ParamDesc(shape, ("batch", "seq_kv", "kv_heads", "head_dim"), dtype=dt, init="zeros"),
        v=ParamDesc(shape, ("batch", "seq_kv", "kv_heads", "head_dim"), dtype=dt, init="zeros"))


def _project_qkv(cfg: ModelConfig, p, x, positions):
    q = jnp.einsum("bsd,dkgh->bskgh", x, p["wq"])
    k = jnp.einsum("bsd,dkh->bskh", x, p["wk"])
    v = jnp.einsum("bsd,dkh->bskh", x, p["wv"])
    if cfg.qk_norm:
        q = rms_head_norm(q, p["q_norm"])
        k = rms_head_norm(k, p["k_norm"])
    if cfg.use_rope:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    return q, k, v


def gqa_forward(cfg: ModelConfig, p, x: jax.Array, positions: jax.Array,
                *, causal: bool = True, unroll: bool = False,
                kv_override=None) -> jax.Array:
    """Full-sequence attention (train / prefill). x: (B, S, D).

    ``cfg.use_pallas`` routes the inner attention through the Pallas flash
    kernel (TPU; validated on CPU via interpret mode in tests/benchmarks) —
    the jnp ``chunked_attention`` path is the oracle twin and the default
    under pjit (where XLA's fused attention is used)."""
    q, k, v = _project_qkv(cfg, p, x, positions)
    if kv_override is not None:       # cross-attention (whisper decoder)
        k, v = kv_override
    if cfg.use_pallas:
        from repro.kernels.attention.ops import flash_attention
        out = flash_attention(q, k, v, causal=causal)
    else:
        out = chunked_attention(
            q, (k, v), lambda kv: kv, positions, 0,
            causal=causal, chunk=cfg.attn_chunk, unroll=unroll)
    return jnp.einsum("bskgh,kghd->bsd", out, p["wo"])


def gqa_decode(cfg: ModelConfig, p, x: jax.Array, cache: KVCache,
               pos: jax.Array, *, unroll: bool = False):
    """One-token decode. x: (B, 1, D); pos: scalar int32 current position."""
    positions = jnp.broadcast_to(pos[None], (x.shape[0], 1)).astype(jnp.int32)
    q, k, v = _project_qkv(cfg, p, x, positions)
    new_cache = KVCache(
        k=jax.lax.dynamic_update_slice_in_dim(cache.k, k.astype(cache.k.dtype), pos, 1),
        v=jax.lax.dynamic_update_slice_in_dim(cache.v, v.astype(cache.v.dtype), pos, 1))
    out = chunked_attention(
        q, (new_cache.k, new_cache.v), lambda kv: kv, positions, 0,
        causal=True, chunk=cfg.attn_chunk, unroll=unroll)
    return jnp.einsum("bskgh,kghd->bsd", out, p["wo"]), new_cache


# ---------------------------------------------------------------------------
# MLA (deepseek-v2)
# ---------------------------------------------------------------------------

def mla_descs(cfg: ModelConfig):
    m = cfg.mla
    d, H = cfg.d_model, cfg.n_heads
    qk = m.qk_nope_head_dim + m.qk_rope_head_dim
    return {
        "w_dq": ParamDesc((d, m.q_lora_rank), ("embed", "lora")),
        "q_norm": ParamDesc((m.q_lora_rank,), ("lora",), init="ones"),
        "w_uq": ParamDesc((m.q_lora_rank, H, qk), ("lora", "heads", "head_dim")),
        "w_dkv": ParamDesc((d, m.kv_lora_rank + m.qk_rope_head_dim), ("embed", "lora")),
        "kv_norm": ParamDesc((m.kv_lora_rank,), ("lora",), init="ones"),
        "w_uk": ParamDesc((m.kv_lora_rank, H, m.qk_nope_head_dim),
                          ("lora", "heads", "head_dim")),
        "w_uv": ParamDesc((m.kv_lora_rank, H, m.v_head_dim),
                          ("lora", "heads", "head_dim")),
        "wo": ParamDesc((H, m.v_head_dim, d), ("heads", "head_dim", "embed")),
    }


def mla_cache_desc(cfg: ModelConfig, batch: int, t_max: int):
    # the latent (lora) dim is TP-sharded: scores contract over it (psum)
    # and it is the only >1 dim besides batch/seq — see sharding."mla_lora"
    m = cfg.mla
    dt = cfg.cache_dtype or cfg.compute_dtype
    return KVCache(
        k=ParamDesc((batch, t_max, m.kv_lora_rank),
                    ("batch", "seq_kv", "mla_lora"),
                    dtype=dt, init="zeros"),
        v=ParamDesc((batch, t_max, m.qk_rope_head_dim),
                    ("batch", "seq_kv", "mla_lora"),
                    dtype=dt, init="zeros"))


def _mla_q(cfg, p, x, positions):
    m = cfg.mla
    cq = jnp.einsum("bsd,dr->bsr", x, p["w_dq"])
    cq = rms_head_norm(cq, p["q_norm"])
    q = jnp.einsum("bsr,rhq->bshq", cq, p["w_uq"])
    q_nope = q[..., :m.qk_nope_head_dim]
    q_rope = rope(q[..., m.qk_nope_head_dim:], positions, cfg.rope_theta)
    return q_nope, q_rope


def _mla_ckv(cfg, p, x, positions):
    m = cfg.mla
    ckv_full = jnp.einsum("bsd,dr->bsr", x, p["w_dkv"])
    ckv = rms_head_norm(ckv_full[..., :m.kv_lora_rank], p["kv_norm"])
    k_rope = rope(ckv_full[..., None, m.kv_lora_rank:], positions, cfg.rope_theta)
    return ckv, k_rope[..., 0, :]


def mla_forward(cfg: ModelConfig, p, x: jax.Array, positions: jax.Array,
                *, causal: bool = True, unroll: bool = False) -> jax.Array:
    """Expanded MLA (train / prefill): KV up-projected chunk-by-chunk."""
    m = cfg.mla
    q_nope, q_rope = _mla_q(cfg, p, x, positions)
    q = jnp.concatenate([q_nope, q_rope], -1)[:, :, :, None, :]  # (B,S,K=H,G=1,qk)
    ckv, k_rope = _mla_ckv(cfg, p, x, positions)

    def expand(kv_c):
        ckv_c, kr_c = kv_c
        k_nope = jnp.einsum("btr,rhq->bthq", ckv_c, p["w_uk"])
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(kr_c[:, :, None, :], k_nope.shape[:3] + (m.qk_rope_head_dim,))], -1)
        v = jnp.einsum("btr,rhv->bthv", ckv_c, p["w_uv"])
        return k, v

    qk = m.qk_nope_head_dim + m.qk_rope_head_dim
    out = chunked_attention(
        q, (ckv, k_rope), expand, positions, 0,
        causal=causal, chunk=cfg.attn_chunk, unroll=unroll,
        softmax_scale=qk ** -0.5)                       # (B,S,H,1,v)
    return jnp.einsum("bshgv,hvd->bsd", out, p["wo"])


def mla_decode(cfg: ModelConfig, p, x: jax.Array, cache: KVCache,
               pos: jax.Array, *, unroll: bool = False):
    """Absorbed MLA decode: attention in latent space; K=1 shared head."""
    m = cfg.mla
    positions = jnp.broadcast_to(pos[None], (x.shape[0], 1)).astype(jnp.int32)
    q_nope, q_rope = _mla_q(cfg, p, x, positions)
    # absorb w_uk: q' = q_nope @ w_uk^T -> latent width
    q_lat = jnp.einsum("bshq,rhq->bshr", q_nope, p["w_uk"])
    q_cat = jnp.concatenate([q_lat, q_rope], -1)          # (B,1,H,r+rope)
    q_cat = q_cat[:, :, None, :, :]                        # (B,1,K=1,G=H,·)

    ckv, k_rope = _mla_ckv(cfg, p, x, positions)
    new_cache = KVCache(
        k=jax.lax.dynamic_update_slice_in_dim(cache.k, ckv.astype(cache.k.dtype), pos, 1),
        v=jax.lax.dynamic_update_slice_in_dim(cache.v, k_rope.astype(cache.v.dtype), pos, 1))

    def expand(kv_c):
        ckv_c, kr_c = kv_c
        k = jnp.concatenate([ckv_c, kr_c], -1)[:, :, None, :]  # (B,Tc,1,r+rope)
        v = ckv_c[:, :, None, :]                               # (B,Tc,1,r)
        return k, v

    qk = m.qk_nope_head_dim + m.qk_rope_head_dim
    out_lat = chunked_attention(
        q_cat, (new_cache.k, new_cache.v), expand, positions, 0,
        causal=True, chunk=cfg.attn_chunk, unroll=unroll,
        softmax_scale=qk ** -0.5)                          # (B,1,1,H,r)
    out = jnp.einsum("bskhr,rhv->bshv", out_lat, p["w_uv"])
    return jnp.einsum("bshv,hvd->bsd", out, p["wo"]), new_cache
