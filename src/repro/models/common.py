"""Shared layers: norms (incl. OLMo non-parametric LN), RoPE, MLP/SwiGLU."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.params import ParamDesc

EPS = 1e-5


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def norm_descs(cfg: ModelConfig, d: Optional[int] = None):
    d = d or cfg.d_model
    if cfg.norm == "rmsnorm":
        return {"scale": ParamDesc((d,), ("embed_nofsdp",), init="ones")}
    if cfg.norm == "layernorm":
        return {"scale": ParamDesc((d,), ("embed_nofsdp",), init="ones"),
                "bias": ParamDesc((d,), ("embed_nofsdp",), init="zeros")}
    if cfg.norm == "nonparametric_ln":
        return {}
    raise ValueError(cfg.norm)


def apply_norm(cfg: ModelConfig, p, x: jax.Array) -> jax.Array:
    xf = x.astype(jnp.float32)
    if cfg.norm == "rmsnorm":
        y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, -1, keepdims=True) + EPS)
        return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)
    # (non-)parametric layernorm
    mu = jnp.mean(xf, -1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mu), -1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + EPS)
    if cfg.norm == "layernorm":
        y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


def rms_head_norm(x: jax.Array, scale: jax.Array) -> jax.Array:
    """QK-norm over the trailing head_dim (chameleon / OLMoE)."""
    xf = x.astype(jnp.float32)
    y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, -1, keepdims=True) + EPS)
    return (y * scale.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding. x: (..., seq, ..., head_dim); positions: (..., seq).

    positions is broadcast against x's leading dims up to the seq axis; we
    require x shape (B, S, *rest, hd) and positions (B, S) or (S,).
    """
    hd = x.shape[-1]
    half = hd // 2
    freq = jnp.arange(0, half, dtype=jnp.float32)
    inv_freq = theta ** (-freq / half)
    if positions.ndim == 1:
        ang = positions.astype(jnp.float32)[:, None] * inv_freq  # (S, half)
        ang = ang.reshape((1,) + ang.shape)                      # (1,S,half)
    else:
        ang = positions.astype(jnp.float32)[..., None] * inv_freq  # (B,S,half)
    # insert singleton head dims so ang broadcasts against x (..., hd)
    extra = x.ndim - ang.ndim
    ang = ang.reshape(ang.shape[:-1] + (1,) * extra + (half,))
    sin, cos = jnp.sin(ang), jnp.cos(ang)
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP (SwiGLU or plain)
# ---------------------------------------------------------------------------

def mlp_descs(cfg: ModelConfig, d_ff: Optional[int] = None):
    d, ff = cfg.d_model, d_ff or cfg.d_ff
    out = {"w_up": ParamDesc((d, ff), ("embed", "mlp")),
           "w_down": ParamDesc((ff, d), ("mlp", "embed"))}
    if cfg.glu:
        out["w_gate"] = ParamDesc((d, ff), ("embed", "mlp"))
    return out


def apply_mlp(cfg: ModelConfig, p, x: jax.Array) -> jax.Array:
    h = jnp.einsum("...d,df->...f", x, p["w_up"])
    if cfg.glu:
        g = jnp.einsum("...d,df->...f", x, p["w_gate"])
        h = jax.nn.silu(g.astype(jnp.float32)).astype(h.dtype) * h
    else:
        h = jax.nn.gelu(h.astype(jnp.float32)).astype(h.dtype)
    return jnp.einsum("...f,fd->...d", h, p["w_down"])


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------

def embed_descs(cfg: ModelConfig):
    out = {"tok": ParamDesc((cfg.vocab_size, cfg.d_model), ("vocab", "embed"),
                            init_scale=0.02)}
    if not cfg.tied_embeddings:
        out["unembed"] = ParamDesc((cfg.vocab_size, cfg.d_model),
                                   ("vocab", "embed"), init_scale=0.02)
    return out


def embed_tokens(p, tokens: jax.Array, dtype, ctx=None) -> jax.Array:
    w = p["tok"]
    if ctx is not None and ctx.mesh is not None:
        from jax.sharding import NamedSharding, PartitionSpec as P
        v_ax = (ctx.tp_axis if w.shape[0] % ctx.tp_size == 0 else None)
        w = jax.lax.with_sharding_constraint(
            w, NamedSharding(ctx.mesh, P(v_ax, None)))
    return w.astype(dtype)[tokens]


def unembed(cfg: ModelConfig, p, x: jax.Array, ctx=None) -> jax.Array:
    """Project to vocab logits.

    The table is FSDP-sharded on d (the contraction dim) — naively that
    collides with the batch's use of the data axis and GSPMD can decide to
    replicate the *activations* (catastrophic: full (B,S,V) per device).
    We force the cheap resolution instead: gather the table over the data
    axis (vocab stays TP-sharded when divisible) right before the matmul.
    """
    w = p.get("unembed", p["tok"])
    if ctx is not None and ctx.mesh is not None:
        from jax.sharding import NamedSharding, PartitionSpec as P
        v_ax = (ctx.tp_axis if w.shape[0] % ctx.tp_size == 0 else None)
        w = jax.lax.with_sharding_constraint(
            w, NamedSharding(ctx.mesh, P(v_ax, None)))
    return jnp.einsum("...d,vd->...v", x, w.astype(x.dtype))
