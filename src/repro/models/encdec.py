"""Encoder-decoder (Whisper) assembly.

The audio frontend (log-mel + conv) is a STUB per the assignment:
``input_specs`` provides precomputed frame embeddings (B, enc_seq, D).
Encoder: bidirectional attention, learned positional embeddings.
Decoder: causal self-attention + cross-attention over encoder output + MLP.
Decode shapes run mechanically with a 32k self-attention cache (the model's
*trained* context is 448 tokens — noted in DESIGN.md §Arch-applicability);
the decoder positional table is sized to the requested horizon.
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.params import ParamDesc, tree_map_descs
from repro.models import common, attention
from repro.models.attention import KVCache
from repro.models.lm import _update_prefix, _resid_spec, _constrain, ServeState
from repro.models.params import tree_map_descs as _tmd


def _stack(descs, n: int):
    """Always adds the leading layers dim (the decoder scan/unroll slices
    per-layer params even when n == 1, unlike lm.py's singleton groups)."""
    return _tmd(
        lambda p: ParamDesc((n,) + p.shape, ("layers",) + p.logical,
                            dtype=p.dtype, init=p.init,
                            init_scale=p.init_scale), descs)


def _attn_block_descs(cfg: ModelConfig):
    return {"norm1": common.norm_descs(cfg), "attn": attention.gqa_descs(cfg)}


def _dec_block_descs(cfg: ModelConfig):
    return {
        "norm1": common.norm_descs(cfg),
        "self_attn": attention.gqa_descs(cfg),
        "norm_x": common.norm_descs(cfg),
        "cross_attn": attention.gqa_descs(cfg),
        "norm2": common.norm_descs(cfg),
        "mlp": common.mlp_descs(cfg),
    }


def model_descs(cfg: ModelConfig, dec_pos_len: int = 448) -> Dict[str, Any]:
    e = cfg.encdec
    d = cfg.d_model
    enc_block = dict(_attn_block_descs(cfg))
    enc_block.update({"norm2": common.norm_descs(cfg),
                      "mlp": common.mlp_descs(cfg)})
    return {
        "embed": common.embed_descs(cfg),
        "enc_pos": ParamDesc((e.enc_seq, d), (None, "embed"), init_scale=0.02),
        "dec_pos": ParamDesc((max(448, dec_pos_len), d), (None, "embed"),
                             init_scale=0.02),
        "enc_layers": _stack(enc_block, e.n_enc_layers),
        "enc_final_norm": common.norm_descs(cfg),
        "dec_layers": _stack(_dec_block_descs(cfg), cfg.n_layers),
        "dec_final_norm": common.norm_descs(cfg),
    }


class EncDecCaches(NamedTuple):
    self_kv: KVCache       # stacked (L, B, T_max, K, hd)
    cross_kv: KVCache      # stacked (L, B, enc_seq, K, hd)


def cache_descs(cfg: ModelConfig, batch: int, t_max: int):
    e = cfg.encdec
    return EncDecCaches(
        self_kv=_stack(attention.gqa_cache_desc(cfg, batch, t_max),
                       cfg.n_layers),
        cross_kv=_stack(attention.gqa_cache_desc(cfg, batch, e.enc_seq),
                        cfg.n_layers))


# ---------------------------------------------------------------------------
# Encoder
# ---------------------------------------------------------------------------

def encode(cfg: ModelConfig, params, enc_embeds, *, unroll: bool = False,
            with_remat: bool = False, unroll_layers: bool = False,
            ctx=None):
    """enc_embeds: (B, enc_seq, D) stubbed frontend output -> (B, enc_seq, D)."""
    B, S, D = enc_embeds.shape
    x = enc_embeds.astype(jnp.dtype(cfg.compute_dtype))
    x = x + params["enc_pos"][:S].astype(x.dtype)[None]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))

    def body(x, p):
        h = common.apply_norm(cfg, p["norm1"], x)
        y = attention.gqa_forward(cfg, p["attn"], h, positions, causal=False,
                                  unroll=unroll)
        x = x + y
        h2 = common.apply_norm(cfg, p["norm2"], x)
        x = x + common.apply_mlp(cfg, p["mlp"], h2)
        return x, None

    fn = body
    if with_remat and cfg.remat == "full":
        fn = jax.checkpoint(body,
                            policy=jax.checkpoint_policies.nothing_saveable)
    spec = _resid_spec(ctx, seq_shardable=False) if ctx is not None else None
    if unroll_layers:
        L = cfg.encdec.n_enc_layers
        for l in range(L):
            p_l = jax.tree_util.tree_map(lambda a: a[l],
                                         params["enc_layers"])
            x, _ = fn(x, p_l)
            x = _constrain(x, spec, ctx)
    else:
        def scan_body(c, p):
            y, _ = fn(c, p)
            return _constrain(y, spec, ctx), None
        x, _ = jax.lax.scan(scan_body, x, params["enc_layers"])
    return common.apply_norm(cfg, params["enc_final_norm"], x)


# ---------------------------------------------------------------------------
# Decoder
# ---------------------------------------------------------------------------

def _dec_block(cfg, p, x, positions, enc_out, *, self_cache=None, pos=None,
               decode=False, cross_cache=None, unroll=False):
    h = common.apply_norm(cfg, p["norm1"], x)
    if decode:
        y, new_self = attention.gqa_decode(cfg, p["self_attn"], h,
                                           self_cache, pos, unroll=unroll)
    else:
        y = attention.gqa_forward(cfg, p["self_attn"], h, positions,
                                  unroll=unroll)
        new_self = self_cache
        if self_cache is not None:
            _, k, v = attention._project_qkv(cfg, p["self_attn"], h,
                                             positions)
            new_self = KVCache(k=_update_prefix(self_cache.k, k),
                               v=_update_prefix(self_cache.v, v))
    x = x + y

    # cross attention (not causal; KV from encoder output or cache)
    hx = common.apply_norm(cfg, p["norm_x"], x)
    if cross_cache is not None:
        k, v = cross_cache.k, cross_cache.v
        new_cross = cross_cache
    else:
        enc_pos = jnp.broadcast_to(
            jnp.arange(enc_out.shape[1], dtype=jnp.int32)[None],
            enc_out.shape[:2])
        _, k, v = attention._project_qkv(
            cfg.with_(use_rope=False), p["cross_attn"], enc_out, enc_pos)
        new_cross = KVCache(k=k, v=v)
    q = jnp.einsum("bsd,dkgh->bskgh", hx, p["cross_attn"]["wq"])
    qpos = jnp.zeros(hx.shape[:2], jnp.int32)
    out = attention.chunked_attention(
        q, (k, v), lambda kv: kv, qpos, 0, causal=False,
        chunk=cfg.attn_chunk, unroll=unroll)
    x = x + jnp.einsum("bskgh,kghd->bsd", out, p["cross_attn"]["wo"])

    h2 = common.apply_norm(cfg, p["norm2"], x)
    x = x + common.apply_mlp(cfg, p["mlp"], h2)
    return x, new_self, new_cross


def decode_tokens(cfg: ModelConfig, params, tokens, enc_out, *,
                  caches: EncDecCaches = None, pos=None, decode=False,
                  unroll=False, with_remat=False, unroll_layers=False,
                  ctx=None):
    """Run the decoder stack. tokens: (B, S) int32."""
    B, S = tokens.shape
    dtype = jnp.dtype(cfg.compute_dtype)
    x = common.embed_tokens(params["embed"], tokens, dtype, ctx=ctx)
    if decode:
        positions = jnp.broadcast_to(pos[None], (B, 1)).astype(jnp.int32)
        x = x + jax.lax.dynamic_slice_in_dim(
            params["dec_pos"], jnp.minimum(pos, params["dec_pos"].shape[0] - 1),
            1, 0).astype(dtype)[None, 0:1]
    else:
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None],
                                     (B, S))
        ptab = params["dec_pos"]
        idx = jnp.minimum(jnp.arange(S), ptab.shape[0] - 1)
        x = x + ptab[idx].astype(dtype)[None]

    spec = (_resid_spec(ctx, seq_shardable=(S % max(ctx.tp_size, 1) == 0
                                            and S > 1))
            if ctx is not None and ctx.mesh is not None else None)
    x = _constrain(x, spec, ctx)

    def body(carry, xs):
        x = carry
        p, sc, cc = xs
        # at prefill the cross KV is COMPUTED from enc_out and written into
        # the cache; at decode it is read back
        x, new_self, new_cross = _dec_block(
            cfg, p, x, positions, enc_out, self_cache=sc, pos=pos,
            decode=decode, cross_cache=(cc if decode else None),
            unroll=unroll)
        x = _constrain(x, spec, ctx)
        if not decode:
            new_cross = KVCache(k=new_cross.k.astype(cc.k.dtype),
                                v=new_cross.v.astype(cc.v.dtype))
        return x, (new_self, new_cross)

    fn = body
    if with_remat and cfg.remat == "full" and not decode:
        fn = jax.checkpoint(body,
                            policy=jax.checkpoint_policies.nothing_saveable)

    if caches is not None:
        xs = (params["dec_layers"], caches.self_kv, caches.cross_kv)
        if unroll_layers:
            outs = []
            for l in range(cfg.n_layers):
                xs_l = jax.tree_util.tree_map(lambda a: a[l], xs)
                x, ys = fn(x, xs_l)
                outs.append(ys)
            new_self, new_cross = jax.tree_util.tree_map(
                lambda *a: jnp.stack(a, 0), *outs)
        else:
            x, (new_self, new_cross) = jax.lax.scan(fn, x, xs)
        new_caches = EncDecCaches(self_kv=new_self, cross_kv=new_cross)
    else:
        def body_nc(carry, p):
            x = carry
            x, _, _ = _dec_block(cfg, p, x, positions, enc_out,
                                 unroll=unroll)
            return _constrain(x, spec, ctx), None
        fn_nc = body_nc
        if with_remat and cfg.remat == "full":
            fn_nc = jax.checkpoint(
                body_nc, policy=jax.checkpoint_policies.nothing_saveable)
        if unroll_layers:
            for l in range(cfg.n_layers):
                p_l = jax.tree_util.tree_map(lambda a: a[l],
                                             params["dec_layers"])
                x, _ = fn_nc(x, p_l)
        else:
            x, _ = jax.lax.scan(fn_nc, x, params["dec_layers"])
        new_caches = None

    x = common.apply_norm(cfg, params["dec_final_norm"], x)
    return x, new_caches


# ---------------------------------------------------------------------------
# Public API (mirrors models.lm)
# ---------------------------------------------------------------------------

def loss_fn(cfg: ModelConfig, params, batch, *, ctx=None, with_remat=True,
            unroll=False, unroll_layers=False, **_):
    enc_out = encode(cfg, params, batch["enc_embeds"], unroll=unroll,
                     with_remat=with_remat, unroll_layers=unroll_layers,
                     ctx=ctx)
    tokens = batch["tokens"]
    x, _ = decode_tokens(cfg, params, tokens, enc_out, unroll=unroll,
                         with_remat=with_remat, unroll_layers=unroll_layers,
                         ctx=ctx)
    logits = common.unembed(cfg, params["embed"], x,
                            ctx=ctx).astype(jnp.float32)
    targets = jnp.concatenate(
        [tokens[:, 1:], jnp.zeros_like(tokens[:, :1])], axis=1)
    mask = jnp.concatenate(
        [jnp.ones_like(tokens[:, 1:], jnp.float32),
         jnp.zeros_like(tokens[:, :1], jnp.float32)], axis=1)
    logz = jax.nn.logsumexp(logits, axis=-1)
    tgt = jnp.take_along_axis(logits, targets[..., None].astype(jnp.int32),
                              axis=-1)[..., 0]
    loss = jnp.sum((logz - tgt) * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return loss, {"nll": loss, "aux": jnp.zeros((), jnp.float32)}


def prefill(cfg: ModelConfig, params, batch, caches: EncDecCaches, *,
            ctx=None, unroll=False, unroll_layers=False, **_):
    enc_out = encode(cfg, params, batch["enc_embeds"], unroll=unroll,
                     unroll_layers=unroll_layers, ctx=ctx)
    tokens = batch["tokens"]
    x, new_caches = decode_tokens(cfg, params, tokens, enc_out,
                                  caches=caches, unroll=unroll,
                                  unroll_layers=unroll_layers, ctx=ctx)
    logits = common.unembed(cfg, params["embed"], x[:, -1:], ctx=ctx)
    return (logits[:, 0].astype(jnp.dtype(cfg.logit_dtype)),
            ServeState(new_caches, jnp.asarray(tokens.shape[1], jnp.int32)))


def decode_step(cfg: ModelConfig, params, tokens, state: ServeState, *,
                ctx=None, unroll=False, unroll_layers=False, **_):
    """tokens: (B, 1). Cross-attention uses the cached encoder KV."""
    x, new_caches = decode_tokens(cfg, params, tokens, None,
                                  caches=state.caches, pos=state.pos,
                                  decode=True, unroll=unroll,
                                  unroll_layers=unroll_layers, ctx=ctx)
    logits = common.unembed(cfg, params["embed"], x, ctx=ctx)
    return (logits[:, 0].astype(jnp.dtype(cfg.logit_dtype)),
            ServeState(new_caches, state.pos + 1))
