"""Decoder-only LM assembly: params, forward, loss, prefill/decode.

Layer stacking
--------------
Architectures interleave heterogeneous blocks (jamba: 1 attention per 8
layers, MoE on odd layers; deepseek-v2: first layer dense).  We decompose
the layer sequence into a *prefix* of singleton groups plus one *periodic*
group: within a group of period P repeated R times, params of each of the P
positions are stacked with a leading (R,) dim and the group runs as a
``jax.lax.scan`` over R super-blocks (small HLO, fast 512-device compiles).
Remat (``cfg.remat``) wraps the super-block body.

Sharding (DESIGN.md §5)
-----------------------
Residual activations between blocks are constrained to
``P(dp, tp, None)`` — batch over the data axes, *sequence over the model
axis* (Megatron-style sequence parallelism) so that per-device saved
activations under full remat stay ~B·S·D/(dp·tp).  Inside a block GSPMD
re-shards to head-/ff-parallel layouts driven by the parameter shardings.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models.params import ParamDesc, tree_map_descs
from repro.models import common, attention, moe as moe_mod, mamba as mamba_mod
from repro.models import rwkv as rwkv_mod
from repro.models.attention import KVCache
from repro.models.mamba import MambaCache
from repro.models.rwkv import RWKVCache


# ---------------------------------------------------------------------------
# Layer grouping
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class LayerGroup:
    kinds: Tuple[Tuple[str, str], ...]    # per position: (mixer, mlp)
    n_repeats: int


def layer_kinds(cfg: ModelConfig) -> List[Tuple[str, str]]:
    return [(cfg.layer_kind(l), cfg.mlp_kind(l)) for l in range(cfg.n_layers)]


def layer_groups(cfg: ModelConfig) -> List[LayerGroup]:
    """(prefix of singletons) + one periodic group covering the rest."""
    kinds = layer_kinds(cfg)
    L = len(kinds)
    for prefix in range(0, L):
        rest = kinds[prefix:]
        n = len(rest)
        for p in range(1, min(16, n) + 1):
            if n % p:
                continue
            if all(rest[i] == rest[i % p] for i in range(n)):
                groups = [LayerGroup((kinds[i],), 1) for i in range(prefix)]
                groups.append(LayerGroup(tuple(rest[:p]), n // p))
                return groups
    return [LayerGroup((k,), 1) for k in kinds]


# ---------------------------------------------------------------------------
# Param descriptors
# ---------------------------------------------------------------------------

def _mixer_descs(cfg: ModelConfig, mixer: str):
    if mixer == "attn":
        att = attention.mla_descs(cfg) if cfg.mla else attention.gqa_descs(cfg)
        return {"norm1": common.norm_descs(cfg), "attn": att}
    if mixer == "mamba":
        return {"norm1": common.norm_descs(cfg),
                "mamba": mamba_mod.mamba_descs(cfg)}
    if mixer == "rwkv":
        # rwkv_descs includes both time-mix and channel-mix params
        return {"norm1": common.norm_descs(cfg),
                "norm2": common.norm_descs(cfg),
                "rwkv": rwkv_mod.rwkv_descs(cfg)}
    raise ValueError(mixer)


def _mlp_descs(cfg: ModelConfig, mlp: str):
    if mlp == "dense":
        return {"norm2": common.norm_descs(cfg),
                "mlp": common.mlp_descs(cfg)}
    if mlp == "moe":
        return {"norm2": common.norm_descs(cfg),
                "moe": moe_mod.moe_descs(cfg)}
    raise ValueError(mlp)


def block_descs(cfg: ModelConfig, kind: Tuple[str, str]):
    mixer, mlp = kind
    d = dict(_mixer_descs(cfg, mixer))
    if mixer != "rwkv":                   # rwkv has its own channel mix
        d.update(_mlp_descs(cfg, mlp))
    return d


def _stack(descs, n: int):
    if n == 1:
        return descs
    return tree_map_descs(
        lambda p: ParamDesc((n,) + p.shape, ("layers",) + p.logical,
                            dtype=p.dtype, init=p.init,
                            init_scale=p.init_scale), descs)


def model_descs(cfg: ModelConfig) -> Dict[str, Any]:
    out: Dict[str, Any] = {"embed": common.embed_descs(cfg)}
    out["groups"] = [
        {"blocks": [_stack(block_descs(cfg, kind), g.n_repeats)
                    for kind in g.kinds]}
        for g in layer_groups(cfg)]
    out["final_norm"] = common.norm_descs(cfg)
    return out


# ---------------------------------------------------------------------------
# Caches
# ---------------------------------------------------------------------------

def _block_cache_desc(cfg: ModelConfig, kind: Tuple[str, str], batch: int,
                      t_max: int):
    mixer, _ = kind
    if mixer == "attn":
        return (attention.mla_cache_desc(cfg, batch, t_max) if cfg.mla
                else attention.gqa_cache_desc(cfg, batch, t_max))
    if mixer == "mamba":
        return mamba_mod.mamba_cache_desc(cfg, batch)
    if mixer == "rwkv":
        return rwkv_mod.rwkv_cache_desc(cfg, batch)
    raise ValueError(mixer)


def cache_descs(cfg: ModelConfig, batch: int, t_max: int):
    return [
        {"blocks": [_stack(_block_cache_desc(cfg, kind, batch, t_max),
                           g.n_repeats)
                    for kind in g.kinds]}
        for g in layer_groups(cfg)]


# ---------------------------------------------------------------------------
# Block forward
# ---------------------------------------------------------------------------

def _resid_spec(ctx, seq_shardable: bool) -> Optional[P]:
    if ctx is None or ctx.mesh is None:
        return None
    dp = ctx.dp_axes if len(ctx.dp_axes) > 1 else ctx.dp_axes[0]
    if ctx.strategy == "dp_only":          # no TP -> no sequence sharding
        return P(dp, None, None)
    return P(dp, ctx.tp_axis if seq_shardable else None, None)


def _constrain(x, spec: Optional[P], ctx):
    if spec is None or ctx is None or ctx.mesh is None:
        return x
    from jax.sharding import NamedSharding
    return jax.lax.with_sharding_constraint(x, NamedSharding(ctx.mesh, spec))


def block_forward(cfg: ModelConfig, kind: Tuple[str, str], p, x, positions,
                  *, ctx=None, cache=None, pos=None, decode: bool = False,
                  moe_mode: str = "a2a", unroll: bool = False):
    """One transformer block. Returns (x, new_cache, aux_loss)."""
    mixer, mlp = kind
    aux = jnp.zeros((), jnp.float32)
    new_cache = cache

    if mixer == "rwkv":
        h = common.apply_norm(cfg, p["norm1"], x)
        tm_cache = cache if cache is not None else None
        y, (last_tm, S_last) = rwkv_mod.rwkv_time_mix(
            cfg, p["rwkv"], h, tm_cache, unroll=unroll)
        x = x + y
        h2 = common.apply_norm(cfg, p["norm2"], x)
        y2, last_cm = rwkv_mod.rwkv_channel_mix(
            cfg, p["rwkv"], h2, tm_cache)
        x = x + y2
        if cache is not None:
            new_cache = RWKVCache(last_tm=last_tm.astype(cache.last_tm.dtype),
                                  last_cm=last_cm.astype(cache.last_cm.dtype),
                                  S=S_last)
        return x, new_cache, aux

    # -- sequence mixer -----------------------------------------------------
    h = common.apply_norm(cfg, p["norm1"], x)
    # Megatron-SP: un-shard the SEQUENCE here, at residual width — before
    # the q/kv (MLA: 4.8x wider) or mamba in_proj (4x wider) projections.
    # Left to GSPMD, the gather lands on the post-projection tensors in
    # fp32 (~20x the bytes on deepseek-v2; see EXPERIMENTS §Perf H3).
    # CONDITION (H3-i1 refinement): only when attention is genuinely
    # head-sharded. For kv<tp archs the fallback shards head_dim, scores
    # need a psum over tp, and gathering the sequence first makes each
    # psum tp-times larger (internlm2: 7x worse collectives — measured).
    heads_shardable = (
        mixer == "mamba"
        or (cfg.mla is not None and cfg.n_heads % max(ctx.tp_size, 1) == 0
            if ctx is not None else False)
        or (cfg.mla is None and ctx is not None
            and cfg.n_kv_heads % max(ctx.tp_size, 1) == 0))
    if (ctx is not None and ctx.mesh is not None and heads_shardable
            and getattr(ctx, "strategy", "tp") == "tp" and not decode
            and x.shape[1] % max(ctx.tp_size, 1) == 0):
        dp = ctx.dp_axes if len(ctx.dp_axes) > 1 else ctx.dp_axes[0]
        h = _constrain(h, P(dp, None, None), ctx)
    if mixer == "attn":
        if decode:
            if cfg.mla:
                y, new_cache = attention.mla_decode(cfg, p["attn"], h, cache,
                                                    pos, unroll=unroll)
            else:
                y, new_cache = attention.gqa_decode(cfg, p["attn"], h, cache,
                                                    pos, unroll=unroll)
        else:
            if cfg.mla:
                y = attention.mla_forward(cfg, p["attn"], h, positions,
                                          unroll=unroll)
            else:
                y = attention.gqa_forward(cfg, p["attn"], h, positions,
                                          unroll=unroll)
            if cache is not None:       # prefill: write the cache
                q, k, v = (None, None, None)
                if cfg.mla:
                    ckv, k_rope = attention._mla_ckv(cfg, p["attn"], h,
                                                     positions)
                    new_cache = KVCache(
                        k=_update_prefix(cache.k, ckv),
                        v=_update_prefix(cache.v, k_rope))
                else:
                    _, k, v = attention._project_qkv(cfg, p["attn"], h,
                                                     positions)
                    new_cache = KVCache(k=_update_prefix(cache.k, k),
                                        v=_update_prefix(cache.v, v))
    elif mixer == "mamba":
        initial = cache if cache is not None else None
        if decode:
            y, new_cache = mamba_mod.mamba_decode(cfg, p["mamba"], h, cache)
        else:
            y, new_cache_full = mamba_mod.mamba_forward(
                cfg, p["mamba"], h, unroll=unroll, initial=initial)
            if cache is not None:
                new_cache = new_cache_full
    else:
        raise ValueError(mixer)
    x = x + y

    # -- channel mixer ------------------------------------------------------
    h2 = common.apply_norm(cfg, p["norm2"], x)
    if mlp == "dense":
        y2 = common.apply_mlp(cfg, p["mlp"], h2)
    else:
        y2, aux = moe_mod.moe_forward(cfg, p["moe"], h2, parallel=ctx,
                                      mode=moe_mode)
    x = x + y2
    return x, new_cache, aux


def _update_prefix(cache_arr, new_vals):
    """Write new_vals (B, S, ...) into cache_arr (B, T_max, ...) at t=0."""
    new_vals = new_vals.astype(cache_arr.dtype)
    idx = (0,) * cache_arr.ndim
    return jax.lax.dynamic_update_slice(cache_arr, new_vals, idx)


# ---------------------------------------------------------------------------
# Full model forward
# ---------------------------------------------------------------------------

def _remat_wrap(cfg: ModelConfig, fn):
    if cfg.remat == "full":
        return jax.checkpoint(fn,
                              policy=jax.checkpoint_policies.nothing_saveable)
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)
    return fn


def _run_groups(cfg: ModelConfig, params, x, positions, *, ctx, caches=None,
                pos=None, decode=False, moe_mode="a2a", with_remat=False,
                unroll=False, unroll_layers=False):
    """Apply all layer groups. caches: matching structure or None.

    ``unroll_layers=True`` replaces the layer scan with a Python loop
    (used by the dry-run cost probes: 1-2 periods, no while in the HLO)."""
    groups = layer_groups(cfg)
    spec = _resid_spec(ctx, seq_shardable=(x.shape[1] % max(
        ctx.tp_size, 1) == 0) if ctx and ctx.mesh else False)
    aux_total = jnp.zeros((), jnp.float32)
    new_caches = [] if caches is not None else None

    for gi, g in enumerate(groups):
        gp = params["groups"][gi]["blocks"]
        gc = caches[gi]["blocks"] if caches is not None else None

        def superblock(x, blk_params, blk_caches):
            aux_sb = jnp.zeros((), jnp.float32)
            out_caches = []
            for pi, kind in enumerate(g.kinds):
                c = blk_caches[pi] if blk_caches is not None else None
                x, nc, aux = block_forward(
                    cfg, kind, blk_params[pi], x, positions, ctx=ctx,
                    cache=c, pos=pos, decode=decode, moe_mode=moe_mode,
                    unroll=unroll)
                x = _constrain(x, spec, ctx)
                out_caches.append(nc)
                aux_sb = aux_sb + aux
            return x, out_caches, aux_sb

        if g.n_repeats == 1:
            x, ncs, aux = superblock(x, gp, gc)
            aux_total = aux_total + aux
            if new_caches is not None:
                new_caches.append({"blocks": ncs})
        elif unroll_layers:
            body_fn = superblock
            if with_remat:
                body_fn = _remat_wrap(cfg, superblock)
            ncs_list = []
            for r in range(g.n_repeats):
                blk_params = jax.tree_util.tree_map(lambda a: a[r], gp)
                blk_caches = (jax.tree_util.tree_map(lambda a: a[r], gc)
                              if gc is not None else None)
                x, ncs, aux = body_fn(x, blk_params, blk_caches)
                aux_total = aux_total + aux
                ncs_list.append(ncs)
            if new_caches is not None:
                stacked = jax.tree_util.tree_map(
                    lambda *a: jnp.stack(a, 0), *ncs_list)
                new_caches.append({"blocks": stacked})
        else:
            body_fn = superblock
            if with_remat:
                body_fn = _remat_wrap(cfg, superblock)

            def scan_body(carry, xs):
                x, aux_acc = carry
                blk_params, blk_caches = xs
                x, ncs, aux = body_fn(x, blk_params, blk_caches)
                return (x, aux_acc + aux), ncs

            xs = (gp, gc if gc is not None
                  else [None] * len(g.kinds))
            # scan needs a pytree with uniform leading dim; None caches are
            # replaced by a dummy zero array
            if gc is None:
                xs = (gp, jnp.zeros((g.n_repeats,), jnp.float32))

                def scan_body(carry, xs):      # noqa: F811
                    x, aux_acc = carry
                    blk_params, _ = xs
                    x, _, aux = body_fn(x, blk_params, None)
                    return (x, aux_acc + aux), None

            (x, aux_total), ncs = jax.lax.scan(scan_body,
                                               (x, aux_total), xs)
            if new_caches is not None:
                new_caches.append({"blocks": ncs})

    return x, new_caches, aux_total


def embed_inputs(cfg: ModelConfig, params, tokens_or_embeds, ctx=None):
    dtype = jnp.dtype(cfg.compute_dtype)
    if tokens_or_embeds.dtype in (jnp.int32, jnp.int64):
        return common.embed_tokens(params["embed"], tokens_or_embeds, dtype,
                                   ctx=ctx)
    return tokens_or_embeds.astype(dtype)     # stubbed modality frontend


def forward(cfg: ModelConfig, params, tokens, positions=None, *, ctx=None,
            moe_mode: str = "a2a", with_remat: bool = False,
            unroll: bool = False, unroll_layers: bool = False):
    """Full forward (train / prefill without cache). Returns (B, S, V) logits
    in ``cfg.logit_dtype`` and the MoE aux loss."""
    B, S = tokens.shape[:2]
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None],
                                     (B, S))
    x = embed_inputs(cfg, params, tokens, ctx)
    x, _, aux = _run_groups(cfg, params, x, positions, ctx=ctx,
                            moe_mode=moe_mode, with_remat=with_remat,
                            unroll=unroll, unroll_layers=unroll_layers)
    x = common.apply_norm(cfg, params["final_norm"], x)
    logits = common.unembed(cfg, params["embed"], x, ctx=ctx)
    return logits.astype(jnp.dtype(cfg.logit_dtype)), aux


def loss_fn(cfg: ModelConfig, params, batch, *, ctx=None,
            moe_mode: str = "a2a", aux_weight: float = 0.01,
            with_remat: bool = True, unroll: bool = False,
            unroll_layers: bool = False):
    """Next-token cross entropy + MoE aux. batch: {tokens, (targets)}."""
    tokens = batch["tokens"]
    targets = batch.get("targets")
    if targets is None:
        targets = jnp.concatenate(
            [tokens[:, 1:], jnp.zeros_like(tokens[:, :1])], axis=1)
        mask = jnp.concatenate(
            [jnp.ones_like(tokens[:, 1:], jnp.float32),
             jnp.zeros_like(tokens[:, :1], jnp.float32)], axis=1)
    else:
        mask = batch.get("mask", jnp.ones_like(targets, jnp.float32))
    logits, aux = forward(cfg, params, tokens, ctx=ctx, moe_mode=moe_mode,
                          with_remat=with_remat, unroll=unroll,
                          unroll_layers=unroll_layers)
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    tgt = jnp.take_along_axis(logits, targets[..., None].astype(jnp.int32),
                              axis=-1)[..., 0]
    nll = (logz - tgt) * mask
    loss = jnp.sum(nll) / jnp.maximum(jnp.sum(mask), 1.0)
    return loss + aux_weight * aux, {"nll": loss, "aux": aux}


# ---------------------------------------------------------------------------
# Serving: prefill + single-token decode
# ---------------------------------------------------------------------------

class ServeState(NamedTuple):
    caches: Any            # list of group cache dicts
    pos: jax.Array         # scalar int32: next position to write


def prefill(cfg: ModelConfig, params, tokens, caches, *, ctx=None,
            moe_mode: str = "a2a", unroll: bool = False,
            unroll_layers: bool = False):
    """Run the prompt through the model, filling caches.

    Returns (last-token logits (B, V), ServeState)."""
    B, S = tokens.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    x = embed_inputs(cfg, params, tokens, ctx)
    x, new_caches, _ = _run_groups(cfg, params, x, positions, ctx=ctx,
                                   caches=caches, moe_mode=moe_mode,
                                   unroll=unroll, unroll_layers=unroll_layers)
    x = common.apply_norm(cfg, params["final_norm"], x)
    logits = common.unembed(cfg, params["embed"], x[:, -1:], ctx=ctx)
    return (logits[:, 0].astype(jnp.dtype(cfg.logit_dtype)),
            ServeState(new_caches, jnp.asarray(S, jnp.int32)))


def decode_step(cfg: ModelConfig, params, tokens, state: ServeState, *,
                ctx=None, moe_mode: str = "psum", unroll: bool = False,
                unroll_layers: bool = False):
    """One decode step. tokens: (B, 1) int32. Returns (logits (B, V), state)."""
    B = tokens.shape[0]
    pos = state.pos
    positions = jnp.broadcast_to(pos[None], (B, 1)).astype(jnp.int32)
    x = embed_inputs(cfg, params, tokens, ctx)
    x, new_caches, _ = _run_groups(cfg, params, x, positions, ctx=ctx,
                                   caches=state.caches, pos=pos, decode=True,
                                   moe_mode=moe_mode, unroll=unroll,
                                   unroll_layers=unroll_layers)
    x = common.apply_norm(cfg, params["final_norm"], x)
    logits = common.unembed(cfg, params["embed"], x, ctx=ctx)
    return (logits[:, 0].astype(jnp.dtype(cfg.logit_dtype)),
            ServeState(new_caches, pos + 1))
