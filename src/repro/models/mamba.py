"""Mamba (S6) selective-scan block for the Jamba hybrid.

Training/prefill uses a *chunked associative scan*: the sequence is cut into
``cfg.ssm_chunk`` chunks iterated with ``lax.scan`` (bounded memory), and the
affine recurrence h_t = dA_t h_{t-1} + dBu_t inside a chunk is solved with
``jax.lax.associative_scan`` (log-depth, elementwise — TPU VPU friendly).
Decode is the O(1) single-step recurrence on a carried (conv, ssm) state.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.params import ParamDesc


class MambaCache(NamedTuple):
    conv: jax.Array   # (B, d_conv-1, inner) last inputs
    ssm: jax.Array    # (B, inner, d_state)


def _dims(cfg: ModelConfig):
    mc = cfg.mamba
    inner = mc.expand * cfg.d_model
    dt_rank = mc.dt_rank or cfg.d_model // 16
    return mc, inner, dt_rank


def mamba_descs(cfg: ModelConfig):
    mc, inner, dt_rank = _dims(cfg)
    d = cfg.d_model
    return {
        "in_proj": ParamDesc((d, 2, inner), ("embed", None, "mamba_inner")),
        "conv_w": ParamDesc((mc.d_conv, inner), (None, "mamba_inner"),
                            init="uniform_small"),
        "conv_b": ParamDesc((inner,), ("mamba_inner",), init="zeros"),
        "x_proj": ParamDesc((inner, dt_rank + 2 * mc.d_state),
                            ("mamba_inner", None)),
        "dt_proj": ParamDesc((dt_rank, inner), (None, "mamba_inner"),
                             init_scale=dt_rank ** -0.5),
        "dt_bias": ParamDesc((inner,), ("mamba_inner",), init="decay_bias"),
        "A_log": ParamDesc((inner, mc.d_state), ("mamba_inner", None),
                           init="decay_bias"),
        "D_skip": ParamDesc((inner,), ("mamba_inner",), init="ones"),
        "out_proj": ParamDesc((inner, d), ("mamba_inner", "embed")),
    }


def mamba_cache_desc(cfg: ModelConfig, batch: int):
    mc, inner, _ = _dims(cfg)
    return MambaCache(
        conv=ParamDesc((batch, mc.d_conv - 1, inner),
                       ("batch", None, "mamba_inner"),
                       dtype=cfg.compute_dtype, init="zeros"),
        ssm=ParamDesc((batch, inner, mc.d_state),
                      ("batch", "mamba_inner", None),
                      dtype="float32", init="zeros"))


def _causal_conv(cfg: ModelConfig, p, u: jax.Array, prepend: jax.Array):
    """Depthwise causal conv1d. u: (B,S,I); prepend: (B,d_conv-1,I)."""
    mc = cfg.mamba
    full = jnp.concatenate([prepend.astype(u.dtype), u], axis=1)
    out = p["conv_b"].astype(jnp.float32)
    acc = jnp.zeros(u.shape, jnp.float32) + out
    for j in range(mc.d_conv):
        acc = acc + (p["conv_w"][j].astype(jnp.float32)
                     * full[:, j:j + u.shape[1]].astype(jnp.float32))
    return jax.nn.silu(acc).astype(u.dtype)


def _ssm_inputs(cfg: ModelConfig, p, u: jax.Array):
    """u: (B,Q,I) conv'd+silu'd -> dA (B,Q,I,N) f32, dBu f32, C (B,Q,N).

    Called PER CHUNK inside the scan — materializing (B,S,I,N) for the whole
    sequence would be ~TBs for jamba-scale inner dims."""
    mc, _, dt_rank = _dims(cfg)
    proj = jnp.einsum("bsi,ir->bsr", u, p["x_proj"]).astype(jnp.float32)
    dt_raw = proj[..., :dt_rank]
    B_ = proj[..., dt_rank:dt_rank + mc.d_state]
    C_ = proj[..., dt_rank + mc.d_state:]
    dt = jax.nn.softplus(
        jnp.einsum("bsr,ri->bsi", dt_raw, p["dt_proj"].astype(jnp.float32))
        + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))                  # (I, N)
    dA = jnp.exp(dt[..., None] * A)                               # (B,S,I,N)
    dBu = dt[..., None] * B_[:, :, None, :] * u.astype(jnp.float32)[..., None]
    return dA, dBu, C_


def _chunk_scan(dA_c, dBu_c, h0):
    """Solve h_t = dA_t h_{t-1} + dBu_t within a chunk given h0 (B,I,N)."""
    def op(l, r):
        a1, b1 = l
        a2, b2 = r
        return a2 * a1, a2 * b1 + b2
    a, b = jax.lax.associative_scan(op, (dA_c, dBu_c), axis=1)
    h = a * h0[:, None] + b                                       # (B,Q,I,N)
    return h


def mamba_forward(cfg: ModelConfig, p, x: jax.Array, *, unroll: bool = False,
                  initial: MambaCache = None):
    """x: (B, S, D) -> (B, S, D). Full-sequence (train / prefill)."""
    mc, inner, _ = _dims(cfg)
    B, S, D = x.shape
    xz = jnp.einsum("bsd,dci->bcsi", x, p["in_proj"])
    u_raw, z = xz[:, 0], xz[:, 1]
    prepend = (initial.conv if initial is not None
               else jnp.zeros((B, mc.d_conv - 1, inner), x.dtype))
    u = _causal_conv(cfg, p, u_raw, prepend)

    Q = min(cfg.ssm_chunk, S)
    S_pad = S
    u_s = u
    if S % Q:                      # pad the input; padded positions are
        pad = Q - S % Q            # masked to IDENTITY transitions below
        u_s = jnp.pad(u, ((0, 0), (0, pad), (0, 0)))
        S_pad = S + pad
    n_chunks = S_pad // Q
    pad_valid = jnp.arange(S_pad) < S
    h0 = (initial.ssm.astype(jnp.float32) if initial is not None
          else jnp.zeros((B, inner, mc.d_state), jnp.float32))

    def body(h, c):
        # dA/dBu are computed PER CHUNK: materializing (B,S,I,N) for the
        # whole sequence would be TBs at jamba scale
        u_c = jax.lax.dynamic_slice_in_dim(u_s, c * Q, Q, 1)
        dA, dBu, C_ = _ssm_inputs(cfg, p, u_c)
        if S_pad != S:
            v = jax.lax.dynamic_slice_in_dim(pad_valid, c * Q, Q, 0)
            dA = jnp.where(v[None, :, None, None], dA, 1.0)
            dBu = jnp.where(v[None, :, None, None], dBu, 0.0)
        h_chunk = _chunk_scan(dA, dBu, h)
        y_c = jnp.einsum("bqin,bqn->bqi", h_chunk, C_)
        return h_chunk[:, -1], y_c

    # checkpoint: the scan bwd otherwise stacks per-chunk (B,Q,I,N) tensors
    body_ck = jax.checkpoint(body,
                             policy=jax.checkpoint_policies.nothing_saveable)
    h_last, ys = jax.lax.scan(body_ck, h0, jnp.arange(n_chunks),
                              unroll=n_chunks if unroll else 1)
    y = jnp.moveaxis(ys, 0, 1).reshape(B, S_pad, inner)[:, :S]
    y = y + p["D_skip"].astype(jnp.float32) * u.astype(jnp.float32)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    out = jnp.einsum("bsi,id->bsd", y.astype(x.dtype), p["out_proj"])
    new_cache = MambaCache(conv=jnp.concatenate(
        [prepend, u_raw], 1)[:, -(mc.d_conv - 1):].astype(jnp.float32).astype(x.dtype),
        ssm=h_last)
    return out, new_cache


def mamba_decode(cfg: ModelConfig, p, x: jax.Array, cache: MambaCache):
    """One-token decode. x: (B, 1, D)."""
    mc, inner, _ = _dims(cfg)
    B = x.shape[0]
    xz = jnp.einsum("bsd,dci->bcsi", x, p["in_proj"])
    u_raw, z = xz[:, 0], xz[:, 1]                                 # (B,1,I)
    u = _causal_conv(cfg, p, u_raw, cache.conv)
    dA, dBu, C_ = _ssm_inputs(cfg, p, u)
    h = dA[:, 0] * cache.ssm.astype(jnp.float32) + dBu[:, 0]      # (B,I,N)
    y = jnp.einsum("bin,bn->bi", h, C_[:, 0])[:, None]
    y = y + p["D_skip"].astype(jnp.float32) * u.astype(jnp.float32)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    out = jnp.einsum("bsi,id->bsd", y.astype(x.dtype), p["out_proj"])
    new_conv = jnp.concatenate([cache.conv, u_raw.astype(cache.conv.dtype)],
                               1)[:, 1:]
    return out, MambaCache(conv=new_conv, ssm=h)
