"""Mixture-of-Experts with shard_map expert parallelism.

Token-choice top-k routing with capacity-factor dropping (GShard-style),
implemented scatter-based (no (T, E, C) one-hot tensors):

* ``mode="a2a"`` (train / prefill): tokens are split over the model axis
  inside ``shard_map``; each device routes its token slice locally, packs a
  per-expert capacity buffer (E, C, D) via local scatter, exchanges it with
  ``all_to_all`` over the model axis (real EP dispatch), runs its local
  experts as one batched matmul, and reverses the exchange.
* ``mode="psum"`` (decode): routing is computed redundantly on every model
  shard (seq_len is tiny), each shard computes only its local experts'
  contribution and the combine is a single ``psum`` — no all_to_all on the
  latency-critical decode path.
* ``mode="dense"``: pure-jnp fallback (no mesh needed) — the oracle used by
  tests and the smoke configs.

Shared experts (deepseek-v2) are folded into one wider dense MLP, which is
mathematically identical (hidden-dim concatenation commutes with the
per-channel activation).
"""
from __future__ import annotations

import math
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

try:  # jax >= 0.6 exposes shard_map at top level
    from jax import shard_map as _shard_map
    _SHMAP_NO_CHECK = {"check_vma": False}
except ImportError:  # pragma: no cover
    from jax.experimental.shard_map import shard_map as _shard_map
    # pre-rename API: the replication check is check_rep, not check_vma
    _SHMAP_NO_CHECK = {"check_rep": False}

from jax.sharding import PartitionSpec as P


def _axis_size(name):
    """jax.lax.axis_size is a newer addition; psum of 1 over the axis is
    the classic spelling (constant-folded, no collective)."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(name)
    return jax.lax.psum(1, name)

from repro.configs.base import ModelConfig
from repro.models.params import ParamDesc
from repro.models.common import mlp_descs, apply_mlp


def moe_descs(cfg: ModelConfig):
    m = cfg.moe
    d, E, ff = cfg.d_model, m.n_experts, m.d_ff_expert
    out = {
        "router": ParamDesc((d, E), ("embed_nofsdp", None), dtype="float32",
                            init_scale=0.02),
        "w_up": ParamDesc((E, d, ff), ("expert", "embed", "mlp_e")),
        "w_down": ParamDesc((E, ff, d), ("expert", "mlp_e", "embed")),
    }
    if cfg.glu:
        out["w_gate"] = ParamDesc((E, d, ff), ("expert", "embed", "mlp_e"))
    if m.n_shared:
        out["shared"] = mlp_descs(cfg, d_ff=m.n_shared * ff)
    return out


def _capacity(n_tokens: int, cfg: ModelConfig) -> int:
    m = cfg.moe
    c = math.ceil(n_tokens * m.top_k * m.capacity_factor / m.n_experts)
    return max(8, ((c + 7) // 8) * 8)


def _route(cfg: ModelConfig, router_w, x_flat):
    """x_flat: (T, D) -> (weights (T,k), idx (T,k) int32, aux_loss scalar)."""
    m = cfg.moe
    logits = jnp.einsum("td,de->te", x_flat.astype(jnp.float32),
                        router_w.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    w, idx = jax.lax.top_k(probs, m.top_k)
    w = w / jnp.maximum(jnp.sum(w, -1, keepdims=True), 1e-9)
    # switch-style load balance loss
    frac_tokens = jnp.mean(
        jax.nn.one_hot(idx, m.n_experts, dtype=jnp.float32), axis=(0, 1))
    frac_probs = jnp.mean(probs, axis=0)
    aux = m.n_experts * jnp.sum(frac_tokens * frac_probs) * m.top_k
    return w.astype(x_flat.dtype), idx.astype(jnp.int32), aux


def _pack(cfg: ModelConfig, x_flat, idx, capacity):
    """Scatter tokens into (E, C, D) capacity buffers. Returns (buf, dest)."""
    m = cfg.moe
    T, D = x_flat.shape
    flat_e = idx.reshape(-1)                                     # (T*k,)
    onehot = jax.nn.one_hot(flat_e, m.n_experts, dtype=jnp.int32)
    pos = jnp.cumsum(onehot, axis=0) - 1                          # pos within expert
    pos = jnp.take_along_axis(pos, flat_e[:, None], 1)[:, 0]      # (T*k,)
    keep = pos < capacity
    dest = jnp.where(keep, flat_e * capacity + pos, m.n_experts * capacity)
    src = jnp.repeat(jnp.arange(T, dtype=jnp.int32), m.top_k)
    buf = jnp.zeros((m.n_experts * capacity, D), x_flat.dtype)
    buf = buf.at[dest].add(x_flat[src], mode="drop")
    return buf.reshape(m.n_experts, capacity, D), dest.reshape(T, m.top_k)


def _expert_mlp(cfg: ModelConfig, p_up, p_gate, p_down, buf):
    """buf: (E?, C, D) batched expert matmuls."""
    h = jnp.einsum("ecd,edf->ecf", buf, p_up)
    if p_gate is not None:
        g = jnp.einsum("ecd,edf->ecf", buf, p_gate)
        h = jax.nn.silu(g.astype(jnp.float32)).astype(h.dtype) * h
    return jnp.einsum("ecf,efd->ecd", h, p_down)


def _combine(out_buf_flat, dest, weights):
    """Gather per-token expert outputs. out_buf_flat: (E*C(+1), D)."""
    picked = out_buf_flat[dest]                                  # (T, k, D)
    return jnp.einsum("tkd,tk->td", picked, weights.astype(picked.dtype))


# ---------------------------------------------------------------------------
# dense (oracle) path
# ---------------------------------------------------------------------------

def _moe_dense(cfg: ModelConfig, p, x_flat):
    cap = _capacity(x_flat.shape[0], cfg)
    w, idx, aux = _route(cfg, p["router"], x_flat)
    buf, dest = _pack(cfg, x_flat, idx, cap)
    out_buf = _expert_mlp(cfg, p["w_up"], p.get("w_gate"), p["w_down"], buf)
    out_flat = jnp.concatenate(
        [out_buf.reshape(-1, x_flat.shape[1]),
         jnp.zeros((1, x_flat.shape[1]), out_buf.dtype)], 0)
    return _combine(out_flat, dest, w), aux


# ---------------------------------------------------------------------------
# shard_map EP paths
# ---------------------------------------------------------------------------

def _gather_fsdp(ws, fsdp_axes, D):
    """All-gather FSDP-sharded expert weights over the data axes."""
    if ws["w_up"].shape[1] != D:
        ws = dict(ws)
        ws["w_up"] = jax.lax.all_gather(ws["w_up"], fsdp_axes, axis=1, tiled=True)
        if "w_gate" in ws:
            ws["w_gate"] = jax.lax.all_gather(ws["w_gate"], fsdp_axes, axis=1,
                                              tiled=True)
        ws["w_down"] = jax.lax.all_gather(ws["w_down"], fsdp_axes, axis=2,
                                          tiled=True)
    return ws


def _moe_local_a2a(cfg, tp_axis, dp_axes, fsdp_axes, x_loc, router_w, ws):
    """Local body under shard_map: x_loc (B_l, S_l, D) token slice."""
    B_l, S_l, D = x_loc.shape
    x_flat = x_loc.reshape(-1, D)
    cap = _capacity(x_flat.shape[0], cfg)
    w, idx, aux = _route(cfg, router_w, x_flat)
    buf, dest = _pack(cfg, x_flat, idx, cap)                     # (E, C, D)
    # dispatch: every device sends expert-group j to device j
    buf = jax.lax.all_to_all(buf, tp_axis, split_axis=0, concat_axis=1,
                             tiled=True)                          # (E_l, tp*C, D)
    ws = _gather_fsdp(ws, fsdp_axes, D)
    out = _expert_mlp(cfg, ws["w_up"], ws.get("w_gate"), ws["w_down"], buf)
    out = jax.lax.all_to_all(out, tp_axis, split_axis=1, concat_axis=0,
                             tiled=True)                          # (E, C, D)
    out_flat = jnp.concatenate([out.reshape(-1, D),
                                jnp.zeros((1, D), out.dtype)], 0)
    y = _combine(out_flat, dest, w).reshape(B_l, S_l, D)
    aux = jax.lax.pmean(aux, (*dp_axes, tp_axis))
    return y, aux


def _moe_local_psum(cfg, tp_axis, dp_axes, fsdp_axes, x_loc, router_w, ws):
    """Decode path: replicated routing, local experts only, psum combine."""
    m = cfg.moe
    B_l, S_l, D = x_loc.shape
    tp = _axis_size(tp_axis)
    e_loc = m.n_experts // tp
    my = jax.lax.axis_index(tp_axis)
    x_flat = x_loc.reshape(-1, D)
    cap = _capacity(x_flat.shape[0], cfg)
    w, idx, aux = _route(cfg, router_w, x_flat)
    buf, dest = _pack(cfg, x_flat, idx, cap)                      # (E, C, D)
    buf_loc = jax.lax.dynamic_slice_in_dim(buf, my * e_loc, e_loc, 0)
    ws = _gather_fsdp(ws, fsdp_axes, D)
    out_loc = _expert_mlp(cfg, ws["w_up"], ws.get("w_gate"), ws["w_down"],
                          buf_loc)                                # (E_l, C, D)
    # place local outputs into the global (E*C+1, D) flat buffer, rest zero
    out_flat = jnp.zeros((m.n_experts * cap + 1, D), out_loc.dtype)
    out_flat = jax.lax.dynamic_update_slice_in_dim(
        out_flat, out_loc.reshape(-1, D), my * e_loc * cap, 0)
    y = _combine(out_flat, dest, w)
    y = jax.lax.psum(y, tp_axis)
    aux = jax.lax.pmean(aux, (*dp_axes, tp_axis))
    return y.reshape(B_l, S_l, D), aux


def moe_forward(cfg: ModelConfig, p, x: jax.Array, *, parallel=None,
                mode: str = "a2a"):
    """x: (B, S, D) -> (out (B, S, D), aux_loss scalar).

    ``parallel``: a ``repro.parallel.sharding.ParallelCtx`` or None (dense).
    """
    m = cfg.moe
    if m.n_shared:
        shared = apply_mlp(cfg, p["shared"], x)
    else:
        shared = 0.0

    use_ep = (parallel is not None and parallel.ep
              and m.n_experts % parallel.tp_size == 0
              and x.shape[0] % parallel.dp_size == 0
              and (mode == "psum" or x.shape[1] % parallel.tp_size == 0))
    if not use_ep:
        B, S, D = x.shape
        y, aux = _moe_dense(cfg, p, x.reshape(-1, D))
        return y.reshape(B, S, D) + shared, aux

    dp, tp, fsdp = parallel.dp_axes, parallel.tp_axis, parallel.fsdp_axes
    ws = {k: p[k] for k in ("w_up", "w_gate", "w_down") if k in p}
    D = x.shape[-1]
    fs = fsdp if (fsdp and D % parallel.fsdp_size == 0) else ()
    f = (fs if len(fs) > 1 else fs[0]) if fs else None
    w_spec = {k: (P(tp, f, None) if k != "w_down" else P(tp, None, f))
              for k in ws}
    body = _moe_local_a2a if mode == "a2a" else _moe_local_psum
    x_spec = P(dp, tp, None) if mode == "a2a" else P(dp, None, None)
    fn = _shard_map(
        partial(body, cfg, tp, dp, fsdp),
        mesh=parallel.mesh,
        in_specs=(x_spec, P(None, None), w_spec),
        out_specs=(x_spec, P()),
        **_SHMAP_NO_CHECK)
    y, aux = fn(x, p["router"], ws)
    return y + shared, aux
