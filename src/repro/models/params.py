"""Parameter descriptors: one source of truth for shape / init / sharding.

Every model module builds a pytree of ``ParamDesc`` leaves. From that tree we
derive (a) randomly-initialized params (smoke tests / examples), (b) abstract
``ShapeDtypeStruct`` trees (dry-run lowering — no allocation), and (c)
``PartitionSpec`` trees via the logical-axis rules in ``repro.parallel.sharding``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class ParamDesc:
    shape: Tuple[int, ...]
    logical: Tuple[Optional[str], ...]   # logical axis name per dim
    dtype: Optional[str] = None          # None -> model param_dtype
    init: str = "normal"                 # normal | zeros | ones | uniform_small
    init_scale: float = 0.02

    def __post_init__(self):
        assert len(self.shape) == len(self.logical), (self.shape, self.logical)


def is_desc(x: Any) -> bool:
    return isinstance(x, ParamDesc)


def tree_map_descs(fn, tree):
    return jax.tree_util.tree_map(fn, tree, is_leaf=is_desc)


def abstract_params(descs, default_dtype: str):
    def f(d: ParamDesc):
        return jax.ShapeDtypeStruct(d.shape, jnp.dtype(d.dtype or default_dtype))
    return tree_map_descs(f, descs)


def init_params(descs, key: jax.Array, default_dtype: str):
    """Materialize params (for small/smoke configs; NOT used by the dry-run)."""
    leaves, treedef = jax.tree_util.tree_flatten(descs, is_leaf=is_desc)
    keys = jax.random.split(key, max(len(leaves), 1))
    out = []
    for d, k in zip(leaves, keys):
        dt = jnp.dtype(d.dtype or default_dtype)
        if d.init == "zeros":
            v = jnp.zeros(d.shape, dt)
        elif d.init == "ones":
            v = jnp.ones(d.shape, dt)
        elif d.init == "uniform_small":
            v = jax.random.uniform(k, d.shape, jnp.float32, -0.5, 0.5).astype(dt)
        elif d.init == "decay_bias":
            # rwkv/mamba style: biases spread over a range for stable decay
            v = jnp.linspace(-6.0, -0.5, int(np.prod(d.shape)),
                             dtype=jnp.float32).reshape(d.shape).astype(dt)
        else:
            fan_in = d.shape[0] if len(d.shape) > 1 else max(d.shape[-1], 1)
            scale = d.init_scale if d.init_scale else 1.0 / np.sqrt(fan_in)
            v = (jax.random.normal(k, d.shape, jnp.float32) * scale).astype(dt)
        out.append(v)
    return jax.tree_util.tree_unflatten(treedef, out)


def count_params(descs) -> int:
    leaves = jax.tree_util.tree_leaves(descs, is_leaf=is_desc)
    return int(sum(int(np.prod(d.shape)) for d in leaves))


# Activation logical-axis helper: annotate intermediate values so the
# sharding layer can constrain them (used sparingly; XLA propagates the rest).
def logical_axes(**kw) -> Dict[str, Any]:
    return kw
