"""Model registry: config -> params / steps / input specs.

One uniform surface consumed by smoke tests, the dry-run, the trainer and
the examples:

    bundle = build(cfg)
    params = bundle.init_params(key)            # smoke configs only
    loss, metrics = bundle.loss(params, batch, ctx=ctx)
    logits, state = bundle.prefill(params, batch, caches, ctx=ctx)
    logits, state = bundle.decode(params, tokens, state, ctx=ctx)

``input_specs(cfg, shape)`` builds ShapeDtypeStruct stand-ins for the
dry-run (weak-type-correct, shardable, no allocation): token ids for LM
archs, precomputed frame embeddings for ``[audio]`` (stubbed frontend),
token ids (VQ image tokens live in the vocab) for ``[vlm]``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import encdec, lm
from repro.models.params import (
    abstract_params, count_params, init_params, tree_map_descs,
)


@dataclasses.dataclass
class ModelBundle:
    cfg: ModelConfig
    descs: Any
    loss: Callable
    forward: Optional[Callable]
    prefill: Callable
    decode: Callable
    cache_descs: Callable      # (batch, t_max) -> cache desc tree

    def abstract_params(self):
        return abstract_params(self.descs, self.cfg.param_dtype)

    def init_params(self, key):
        return init_params(self.descs, key, self.cfg.param_dtype)

    def abstract_caches(self, batch: int, t_max: int):
        return abstract_params(self.cache_descs(batch, t_max),
                               self.cfg.compute_dtype)

    def init_caches(self, key, batch: int, t_max: int):
        return init_params(self.cache_descs(batch, t_max), key,
                           self.cfg.compute_dtype)

    def n_params(self) -> int:
        return count_params(self.descs)


def build(cfg: ModelConfig, dec_pos_len: int = 448) -> ModelBundle:
    if cfg.is_encdec:
        descs = encdec.model_descs(cfg, dec_pos_len=dec_pos_len)
        return ModelBundle(
            cfg=cfg, descs=descs,
            loss=lambda p, b, **kw: encdec.loss_fn(cfg, p, b, **kw),
            forward=None,
            prefill=lambda p, b, caches, **kw: encdec.prefill(
                cfg, p, b, caches, **kw),
            decode=lambda p, t, s, **kw: encdec.decode_step(
                cfg, p, t, s, **kw),
            cache_descs=lambda batch, t_max: encdec.cache_descs(
                cfg, batch, t_max))
    descs = lm.model_descs(cfg)
    return ModelBundle(
        cfg=cfg, descs=descs,
        loss=lambda p, b, **kw: lm.loss_fn(cfg, p, b, **kw),
        forward=lambda p, t, **kw: lm.forward(cfg, p, t, **kw),
        prefill=lambda p, b, caches, **kw: lm.prefill(
            cfg, p, b["tokens"], caches, **kw),
        decode=lambda p, t, s, **kw: lm.decode_step(cfg, p, t, s, **kw),
        cache_descs=lambda batch, t_max: lm.cache_descs(cfg, batch, t_max))


# ---------------------------------------------------------------------------
# Input specs (dry-run stand-ins; also shapes for the data pipeline)
# ---------------------------------------------------------------------------

def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """ShapeDtypeStructs for one (arch × input-shape) cell.

    train/prefill: the full (global_batch, seq_len) token batch.
    decode: one new token per sequence (the KV cache of length seq_len is a
    separate argument produced by ``ModelBundle.abstract_caches``).
    """
    B, S = shape.global_batch, shape.seq_len
    tok = jnp.int32
    if shape.kind == "train":
        specs = {"tokens": jax.ShapeDtypeStruct((B, S), tok)}
        if cfg.is_encdec:
            specs["enc_embeds"] = jax.ShapeDtypeStruct(
                (B, cfg.encdec.enc_seq, cfg.d_model),
                jnp.dtype(cfg.compute_dtype))
        return specs
    if shape.kind == "prefill":
        specs = {"tokens": jax.ShapeDtypeStruct((B, S), tok)}
        if cfg.is_encdec:
            specs["enc_embeds"] = jax.ShapeDtypeStruct(
                (B, cfg.encdec.enc_seq, cfg.d_model),
                jnp.dtype(cfg.compute_dtype))
        return specs
    if shape.kind == "decode":
        return {"tokens": jax.ShapeDtypeStruct((B, 1), tok)}
    raise ValueError(shape.kind)
