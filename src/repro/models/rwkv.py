"""RWKV-6 "Finch" block: data-dependent decay time-mix + channel-mix.

Per head h with head_dim n, state S in R^{n x n}:

    y_t = r_t^T (S_{t-1} + diag(u * k_t) v_t^T)
    S_t = diag(w_t) S_{t-1} + k_t v_t^T          (w_t in (0,1), per channel)

Training/prefill uses the *chunked* closed form (FLA-style): within a chunk
of Q tokens all cross-token terms are matmuls weighted by cumulative decay
ratios exp(logP_{t-1} - logP_s) (s <= t-1, exponent <= 0 so it is stable),
and the state is carried across chunks with a ``lax.scan``. Decode is the
O(1) recurrence. Token shift uses RWKV-6's data-dependent lerp (ddlerp).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.params import ParamDesc

MIX_KEYS = ("w", "r", "k", "v", "g")  # decay, receptance, key, value, gate


class RWKVCache(NamedTuple):
    last_tm: jax.Array   # (B, 1, D) last input of time-mix (token shift)
    last_cm: jax.Array   # (B, 1, D) last input of channel-mix
    S: jax.Array         # (B, H, n, n) wkv state, float32


def _dims(cfg: ModelConfig):
    rc = cfg.rwkv
    H = cfg.d_model // rc.head_dim
    return rc, H, rc.head_dim


def rwkv_descs(cfg: ModelConfig):
    rc, H, n = _dims(cfg)
    d, ff = cfg.d_model, cfg.d_ff
    return {
        # --- time mix ---
        "mu_x": ParamDesc((d,), ("embed_nofsdp",), init="uniform_small"),
        "mu": ParamDesc((5, d), (None, "embed_nofsdp"), init="uniform_small"),
        "tm_w1": ParamDesc((d, 5, rc.mix_lora), ("embed_nofsdp", None, "lora")),
        "tm_w2": ParamDesc((5, rc.mix_lora, d), (None, "lora", "embed_nofsdp")),
        "w_r": ParamDesc((d, H, n), ("embed", "heads", "head_dim")),
        "w_k": ParamDesc((d, H, n), ("embed", "heads", "head_dim")),
        "w_v": ParamDesc((d, H, n), ("embed", "heads", "head_dim")),
        "w_g": ParamDesc((d, H, n), ("embed", "heads", "head_dim")),
        "w_o": ParamDesc((H, n, d), ("heads", "head_dim", "embed")),
        "dec_w1": ParamDesc((d, rc.decay_lora), ("embed_nofsdp", "lora")),
        "dec_w2": ParamDesc((rc.decay_lora, H, n), ("lora", "heads", "head_dim")),
        "dec_bias": ParamDesc((H, n), ("heads", "head_dim"), init="decay_bias"),
        "bonus_u": ParamDesc((H, n), ("heads", "head_dim"),
                             init="uniform_small"),
        "gn_scale": ParamDesc((H, n), ("heads", "head_dim"), init="ones"),
        "gn_bias": ParamDesc((H, n), ("heads", "head_dim"), init="zeros"),
        # --- channel mix ---
        "mu_ck": ParamDesc((d,), ("embed_nofsdp",), init="uniform_small"),
        "mu_cr": ParamDesc((d,), ("embed_nofsdp",), init="uniform_small"),
        "w_ck": ParamDesc((d, ff), ("embed", "mlp")),
        "w_cv": ParamDesc((ff, d), ("mlp", "embed")),
        "w_cr": ParamDesc((d, d), ("embed", "embed_nofsdp")),
    }


def rwkv_cache_desc(cfg: ModelConfig, batch: int):
    rc, H, n = _dims(cfg)
    d = cfg.d_model
    return RWKVCache(
        last_tm=ParamDesc((batch, 1, d), ("batch", None, None),
                          dtype=cfg.compute_dtype, init="zeros"),
        last_cm=ParamDesc((batch, 1, d), ("batch", None, None),
                          dtype=cfg.compute_dtype, init="zeros"),
        S=ParamDesc((batch, H, n, n), ("batch", "heads", None, None),
                    dtype="float32", init="zeros"))


def _shift(x: jax.Array, last: jax.Array) -> jax.Array:
    """x_{t-1} stream. x: (B,S,D); last: (B,1,D) value before the window."""
    return jnp.concatenate([last.astype(x.dtype), x[:, :-1]], axis=1)


def _ddlerp(p, x, xx):
    """RWKV-6 data-dependent token-shift mix -> dict of 5 mixed inputs."""
    delta = xx - x
    x_base = x + delta * p["mu_x"].astype(x.dtype)
    z = jnp.tanh(jnp.einsum("bsd,dfm->bsfm", x_base, p["tm_w1"]))
    adj = jnp.einsum("bsfm,fmd->bsfd", z, p["tm_w2"]) + p["mu"].astype(x.dtype)
    return {k: x + delta * adj[:, :, i] for i, k in enumerate(MIX_KEYS)}


def _tm_project(cfg, p, mixed):
    """-> r,k,v,g (B,S,H,n) and per-channel decay w (B,S,H,n) in (0,1), f32."""
    r = jnp.einsum("bsd,dhn->bshn", mixed["r"], p["w_r"])
    k = jnp.einsum("bsd,dhn->bshn", mixed["k"], p["w_k"])
    v = jnp.einsum("bsd,dhn->bshn", mixed["v"], p["w_v"])
    g = jax.nn.silu(jnp.einsum("bsd,dhn->bshn", mixed["g"], p["w_g"])
                    .astype(jnp.float32))
    w_raw = (jnp.einsum("bsd,dl,lhn->bshn",
                        mixed["w"].astype(jnp.float32),
                        p["dec_w1"].astype(jnp.float32),
                        p["dec_w2"].astype(jnp.float32))
             + p["dec_bias"].astype(jnp.float32))
    logw = -jnp.exp(w_raw)                       # log of decay, < 0
    return r, k, v, g, logw


def _group_norm(p, y):
    """Per-head layer norm of the wkv output. y: (B,S,H,n) float32."""
    mu = jnp.mean(y, -1, keepdims=True)
    var = jnp.mean(jnp.square(y - mu), -1, keepdims=True)
    yn = (y - mu) * jax.lax.rsqrt(var + 64e-5)
    return yn * p["gn_scale"].astype(jnp.float32) + p["gn_bias"].astype(jnp.float32)


def _wkv_chunked(r, k, v, logw, u, S0, chunk, unroll):
    """Chunked WKV-6. r,k,v (B,S,H,n); logw (B,S,H,n) f32; u (H,n).

    Returns y (B,S,H,n) f32 and final state (B,H,n,n) f32.
    """
    B, S, H, n = r.shape
    Q = min(chunk, S)
    S_pad = S
    if S % Q:                      # pad with identity decay (logw=0) and
        pad = Q - S % Q            # zero k/v so the carried state is exact
        zpad = ((0, 0), (0, pad), (0, 0), (0, 0))
        r, k, v = (jnp.pad(a, zpad) for a in (r, k, v))
        logw = jnp.pad(logw, zpad)
        S_pad = S + pad
    n_chunks = S_pad // Q
    rf = r.astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)

    causal_lt = jnp.tril(jnp.ones((Q, Q), bool), -1)              # s < t

    def body(S_c, c):
        sl = lambda a: jax.lax.dynamic_slice_in_dim(a, c * Q, Q, 1)
        r_c, k_c, v_c, lw_c = sl(rf), sl(kf), sl(vf), sl(logw)
        logP = jnp.cumsum(lw_c, axis=1)                           # inclusive
        logPm1 = logP - lw_c                                      # exclusive
        # inter-chunk: r_t decayed against carried state
        rdec = r_c * jnp.exp(logPm1)
        y_inter = jnp.einsum("bthi,bhij->bthj", rdec, S_c)
        # intra-chunk: A[t,s] = sum_i r_t k_s exp(logPm1_t - logP_s), s < t
        expo = logPm1[:, :, None] - logP[:, None, :]              # (B,t,s,H,n)
        expo = jnp.where(causal_lt[None, :, :, None, None], expo, -jnp.inf)
        A = jnp.einsum("bthi,bshi,btshi->bths", r_c, k_c,
                       jnp.exp(expo))
        diag = jnp.einsum("bthi,bthi->bth", r_c, u.astype(jnp.float32) * k_c)
        y_intra = jnp.einsum("bths,bshj->bthj", A, v_c) \
            + diag[..., None] * v_c
        # state update to chunk end
        k_tilde = k_c * jnp.exp(logP[:, -1:] - logP)
        S_new = jnp.exp(logP[:, -1])[..., None] * S_c \
            + jnp.einsum("bshi,bshj->bhij", k_tilde, v_c)
        return S_new, y_inter + y_intra

    # checkpoint: the scan bwd otherwise stacks per-chunk (B,Q,Q,H,n)
    # decay tensors across all chunks (TBs at rwkv6-7b scale)
    body_ck = jax.checkpoint(body,
                             policy=jax.checkpoint_policies.nothing_saveable)
    S_last, ys = jax.lax.scan(body_ck, S0, jnp.arange(n_chunks),
                              unroll=n_chunks if unroll else 1)
    y = jnp.moveaxis(ys, 0, 1).reshape(B, S_pad, H, n)[:, :S]
    return y, S_last


def rwkv_time_mix(cfg: ModelConfig, p, x: jax.Array, cache: RWKVCache = None,
                  *, unroll: bool = False):
    rc, H, n = _dims(cfg)
    B, S, D = x.shape
    last = cache.last_tm if cache is not None else jnp.zeros((B, 1, D), x.dtype)
    S0 = (cache.S if cache is not None
          else jnp.zeros((B, H, n, n), jnp.float32))
    mixed = _ddlerp(p, x, _shift(x, last))
    r, k, v, g, logw = _tm_project(cfg, p, mixed)
    if S == 1:  # decode: direct recurrence
        y = jnp.einsum("bthi,bhij->bthj", r.astype(jnp.float32),
                       S0 + (p["bonus_u"].astype(jnp.float32) * k.astype(jnp.float32))[:, 0, :, :, None]
                       * v.astype(jnp.float32)[:, 0, :, None, :])
        S_last = jnp.exp(logw[:, 0])[..., None] * S0 \
            + k.astype(jnp.float32)[:, 0, :, :, None] * v.astype(jnp.float32)[:, 0, :, None, :]
    else:
        y, S_last = _wkv_chunked(r, k, v, logw, p["bonus_u"], S0,
                                 cfg.ssm_chunk, unroll)
    y = _group_norm(p, y) * g
    out = jnp.einsum("bshn,hnd->bsd", y.astype(x.dtype), p["w_o"])
    return out, (x[:, -1:], S_last)


def rwkv_channel_mix(cfg: ModelConfig, p, x: jax.Array,
                     cache: RWKVCache = None):
    B, S, D = x.shape
    last = cache.last_cm if cache is not None else jnp.zeros((B, 1, D), x.dtype)
    xx = _shift(x, last)
    xk = x + (xx - x) * p["mu_ck"].astype(x.dtype)
    xr = x + (xx - x) * p["mu_cr"].astype(x.dtype)
    kk = jnp.einsum("bsd,df->bsf", xk, p["w_ck"])
    kk = jnp.square(jax.nn.relu(kk.astype(jnp.float32))).astype(x.dtype)
    vv = jnp.einsum("bsf,fd->bsd", kk, p["w_cv"])
    rr = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", xr, p["w_cr"])
                        .astype(jnp.float32)).astype(x.dtype)
    return rr * vv, x[:, -1:]
