"""Sharded AdamW with dtype-configurable moments.

Moments inherit each parameter's sharding (same tree structure, same logical
axes), so optimizer state is fully FSDP/TP-sharded for free.  ≥100 B-param
configs keep moments in bf16 to fit 16 GB/chip (``cfg.moment_dtype`` —
DESIGN.md §5); the update math runs in fp32 regardless.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array            # () int32
    mu: Any                    # first moment, tree like params
    nu: Any                    # second moment, tree like params


def adamw_init(params, moment_dtype: str = "float32") -> AdamWState:
    dt = jnp.dtype(moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      mu=jax.tree_util.tree_map(zeros, params),
                      nu=jax.tree_util.tree_map(zeros, params))


def adamw_abstract(params_abstract, moment_dtype: str = "float32"):
    """ShapeDtypeStruct twin of adamw_init (dry-run; no allocation)."""
    dt = jnp.dtype(moment_dtype)
    z = lambda p: jax.ShapeDtypeStruct(p.shape, dt)
    return AdamWState(step=jax.ShapeDtypeStruct((), jnp.int32),
                      mu=jax.tree_util.tree_map(z, params_abstract),
                      nu=jax.tree_util.tree_map(z, params_abstract))


def adamw_update(params, grads, state: AdamWState, lr,
                 *, b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
                 weight_decay: float = 0.1,
                 grad_clip: Optional[float] = 1.0):
    """One AdamW step. ``lr`` may be a scalar array (from a schedule)."""
    step = state.step + 1
    if grad_clip is not None:
        gnorm = jnp.sqrt(sum(
            jnp.sum(jnp.square(g.astype(jnp.float32)))
            for g in jax.tree_util.tree_leaves(grads)))
        scale = jnp.minimum(1.0, grad_clip / jnp.maximum(gnorm, 1e-9))
    else:
        gnorm = jnp.zeros((), jnp.float32)
        scale = jnp.ones((), jnp.float32)

    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32) * scale
        m_new = b1 * m.astype(jnp.float32) + (1 - b1) * gf
        v_new = b2 * v.astype(jnp.float32) + (1 - b2) * gf * gf
        mhat = m_new / c1
        vhat = v_new / c2
        delta = mhat / (jnp.sqrt(vhat) + eps)
        if p.ndim >= 2:                      # no decay on norms/biases
            delta = delta + weight_decay * p.astype(jnp.float32)
        p_new = p.astype(jnp.float32) - lr * delta
        return (p_new.astype(p.dtype), m_new.astype(m.dtype),
                v_new.astype(v.dtype))

    out = jax.tree_util.tree_map(upd, params, grads, state.mu, state.nu)
    p_new = jax.tree_util.tree_map(lambda t: t[0], out,
                                   is_leaf=lambda t: isinstance(t, tuple))
    m_new = jax.tree_util.tree_map(lambda t: t[1], out,
                                   is_leaf=lambda t: isinstance(t, tuple))
    v_new = jax.tree_util.tree_map(lambda t: t[2], out,
                                   is_leaf=lambda t: isinstance(t, tuple))
    return p_new, AdamWState(step=step, mu=m_new, nu=v_new), gnorm
