"""Gradient compression with error feedback (distributed-optimization trick).

Two schemes, both pluggable into ``make_train_step(grad_transform=...)``:

* ``int8_compress`` — stochastic-free symmetric int8 quantization with a
  per-tensor fp32 scale; error feedback carries the quantization residual
  into the next step so the optimizer sees an unbiased long-run gradient.
* ``topk_compress`` — magnitude top-k sparsification (k as a fraction),
  error feedback accumulates the dropped mass.

At 1000-node scale these shrink the DP all-reduce payload 4x (int8) /
~1/k x (top-k).  In this framework the transform runs *inside* the jitted
train step, so XLA fuses quantize -> all-reduce -> dequantize; the dry-run
HLO shows the all-reduce operands at the compressed width (verified in
tests/test_compression.py).
"""
from __future__ import annotations

from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# int8 with error feedback
# ---------------------------------------------------------------------------

def int8_roundtrip(g: jax.Array) -> jax.Array:
    """Quantize to int8 + dequantize (what the wire carries)."""
    gf = g.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    return q.astype(jnp.float32) * scale


def make_int8_transform(with_error_feedback: bool = True):
    """grad_transform(grads, ctx[, err]) with error-feedback state threaded
    by the caller (see train.step.make_train_step's grad_transform hook).

    Returns (transform, init_err) — init_err(params) builds the residual
    tree (zeros, fp32)."""
    def init_err(params):
        return jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)

    def transform(grads, err=None):
        def one(g, e):
            gf = g.astype(jnp.float32) + (e if e is not None else 0.0)
            deq = int8_roundtrip(gf)
            new_e = gf - deq
            return deq.astype(g.dtype), new_e
        if err is None or not with_error_feedback:
            out = jax.tree_util.tree_map(lambda g: one(g, None)[0], grads)
            return out, err
        pairs = jax.tree_util.tree_map(one, grads, err)
        deq = jax.tree_util.tree_map(lambda t: t[0], pairs,
                                     is_leaf=lambda t: isinstance(t, tuple))
        new_err = jax.tree_util.tree_map(lambda t: t[1], pairs,
                                         is_leaf=lambda t: isinstance(t, tuple))
        return deq, new_err

    return transform, init_err


# ---------------------------------------------------------------------------
# top-k with error feedback
# ---------------------------------------------------------------------------

def topk_roundtrip(g: jax.Array, frac: float) -> jax.Array:
    """Keep the top-``frac`` entries by magnitude; zero the rest."""
    gf = g.astype(jnp.float32)
    flat = gf.reshape(-1)
    k = max(1, int(flat.shape[0] * frac))
    thresh = jax.lax.top_k(jnp.abs(flat), k)[0][-1]
    kept = jnp.where(jnp.abs(flat) >= thresh, flat, 0.0)
    return kept.reshape(g.shape)


def make_topk_transform(frac: float = 0.1):
    def init_err(params):
        return jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)

    def transform(grads, err=None):
        def one(g, e):
            gf = g.astype(jnp.float32) + (e if e is not None else 0.0)
            kept = topk_roundtrip(gf, frac)
            return kept.astype(g.dtype), gf - kept
        if err is None:
            out = jax.tree_util.tree_map(lambda g: one(g, None)[0], grads)
            return out, None
        pairs = jax.tree_util.tree_map(one, grads, err)
        kept = jax.tree_util.tree_map(lambda t: t[0], pairs,
                                      is_leaf=lambda t: isinstance(t, tuple))
        new_err = jax.tree_util.tree_map(lambda t: t[1], pairs,
                                         is_leaf=lambda t: isinstance(t, tuple))
        return kept, new_err

    return transform, init_err
