"""GPipe-style pipeline parallelism via shard_map + collective_permute.

An optional stage axis for depth-dominated models (jamba 72L, deepseek 60L)
when TP×FSDP alone leaves the mesh under-utilized.  The schedule is the
classic GPipe fill-drain: M microbatches stream through P stages; stage p
computes microbatch m at tick t = p + m, activations hop stages via
``jax.lax.ppermute``.  Bubble fraction = (P-1)/(M+P-1).

This is a self-contained reference implementation operating on a
per-stage ``apply_fn(stage_params, x) -> x`` — the launcher lowers it on a
('stage', 'data') mesh.  Tested at small scale (tests/test_pipeline.py);
it is NOT part of the 40-cell baseline (DESIGN.md §5).
"""
from __future__ import annotations

from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp

try:  # jax >= 0.6 exposes shard_map at top level
    from jax import shard_map as _shard_map
    _SHMAP_NO_CHECK = {"check_vma": False}
except ImportError:  # pragma: no cover
    from jax.experimental.shard_map import shard_map as _shard_map
    # pre-rename API: the replication check is check_rep, not check_vma
    _SHMAP_NO_CHECK = {"check_rep": False}

from jax.sharding import PartitionSpec as P


def gpipe_forward(apply_fn: Callable, mesh, stage_axis: str = "stage",
                  n_microbatches: int = None):
    """Build a pipelined forward: (stage_params, x) -> y.

    ``stage_params``: pytree with leading stage dim sharded over the stage
    axis; ``x``: (M, mb, ...) microbatched input, replicated over stages.
    """
    P_stages = mesh.shape[stage_axis]

    def local_fn(stage_params, x_mb):
        # stage_params leaves: (1, ...) local slice -> squeeze
        params = jax.tree_util.tree_map(lambda a: a[0], stage_params)
        stage = jax.lax.axis_index(stage_axis)
        M = x_mb.shape[0]
        n_ticks = M + P_stages - 1

        def tick(carry, t):
            buf, out = carry          # buf: activation entering this stage
            m = t - stage             # microbatch this stage works on
            active = (m >= 0) & (m < M)
            x_in = jnp.where(active, buf, jnp.zeros_like(buf))
            y = apply_fn(params, x_in)
            y = jnp.where(active, y, jnp.zeros_like(y))
            # last stage collects finished microbatches
            out = jax.lax.cond(
                active & (stage == P_stages - 1),
                lambda o: jax.lax.dynamic_update_index_in_dim(
                    o, y, jnp.maximum(m, 0), 0),
                lambda o: o, out)
            # hop activations to the next stage
            y_next = jax.lax.ppermute(
                y, stage_axis,
                [(i, (i + 1) % P_stages) for i in range(P_stages)])
            # stage 0 ingests the next microbatch from x_mb
            nxt = t + 1 - 0
            feed = jax.lax.dynamic_index_in_dim(
                x_mb, jnp.clip(t + 1, 0, M - 1), 0, keepdims=False)
            buf_new = jnp.where(stage == 0, feed, y_next)
            return (buf_new, out), None

        buf0 = jnp.where(stage == 0,
                         x_mb[0], jnp.zeros_like(x_mb[0]))
        out0 = jnp.zeros_like(x_mb)
        (buf, out), _ = jax.lax.scan(tick, (buf0, out0),
                                     jnp.arange(n_ticks))
        # only the last stage holds real outputs (every other stage's
        # ``out`` is still zeros), so a psum over the stage axis IS the
        # broadcast — ppermute can't fan one source out to all
        return jax.lax.psum(out, stage_axis)

    return _shard_map(
        local_fn, mesh=mesh,
        in_specs=(P(stage_axis), P()),
        out_specs=P(),
        **_SHMAP_NO_CHECK)


def pipeline_bubble_fraction(n_stages: int, n_microbatches: int) -> float:
    return (n_stages - 1) / (n_microbatches + n_stages - 1)
