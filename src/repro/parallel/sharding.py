"""Logical-axis sharding rules: FSDP('data') x TP('model') x DP(+'pod').

Every parameter dimension carries a *logical* axis name (see
``repro.models.params.ParamDesc``); this module maps logical axes to mesh
axes and produces ``PartitionSpec`` trees for params, optimizer moments,
activations and caches. GSPMD's padded uneven sharding is relied on for
head counts not divisible by the model axis (phi3 40H/10kv, yi 56H,
whisper 12H) — see DESIGN.md §5.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.params import ParamDesc, is_desc, tree_map_descs


@dataclasses.dataclass(frozen=True)
class ParallelCtx:
    mesh: Optional[Mesh]
    dp_axes: Tuple[str, ...] = ("data",)
    tp_axis: str = "model"
    fsdp_axes: Tuple[str, ...] = ("data",)
    ep: bool = True
    #: "tp" (default): TP over the model axis, Megatron-SP residuals.
    #: "dp_only": batch over ALL axes, weights FSDP over data, no TP — the
    #: right mapping for small dense models whose per-layer compute cannot
    #: amortize TP/SP collectives (see EXPERIMENTS §Perf H1).
    strategy: str = "tp"

    @property
    def tp_size(self) -> int:
        if self.mesh is None or self.strategy == "dp_only":
            return 1
        return self.mesh.shape[self.tp_axis]

    @property
    def dp_size(self) -> int:
        if not self.mesh:
            return 1
        return math.prod(self.mesh.shape[a] for a in self.dp_axes)

    @property
    def fsdp_size(self) -> int:
        if not self.mesh:
            return 1
        return math.prod(self.mesh.shape[a] for a in self.fsdp_axes)


def ctx_for_mesh(mesh: Optional[Mesh], *, ep: bool = True,
                 fsdp: bool = True, strategy: str = "tp") -> ParallelCtx:
    if mesh is None:
        return ParallelCtx(None, ep=False)
    if strategy == "dp_only":
        dp = tuple(a for a in ("pod", "data", "model")
                   if a in mesh.axis_names)
        return ParallelCtx(mesh, dp_axes=dp,
                           fsdp_axes=("data",) if fsdp else (),
                           ep=False, strategy="dp_only")
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    return ParallelCtx(mesh, dp_axes=dp or ("data",),
                       fsdp_axes=("data",) if fsdp else (),
                       ep=ep, strategy=strategy)


# logical axis -> mesh axis resolver --------------------------------------
#
# Two passes (top-level jit in_shardings require exact divisibility, so no
# GSPMD padding is available here):
#   1. primary: embed->FSDP(data), {vocab,heads,mlp,expert,mamba_inner,
#      kv_heads,mla_lora}->TP(model), batch->DP — each only if divisible;
#   2. TP fallback: if no dim took the model axis (e.g. kv_heads=8 < 16),
#      the first divisible fallback dim (q_per_kv, then head_dim) takes it —
#      contractions over a TP-sharded head_dim turn into psums, which is the
#      baseline cost of uneven head counts (hillclimb lever, see §Perf).

_TP_PRIMARY = ("vocab", "heads", "mlp", "expert", "mamba_inner", "kv_heads",
               "mla_lora")
_TP_FALLBACK = ("q_per_kv", "head_dim")


def spec_for(ctx: ParallelCtx, desc: ParamDesc) -> P:
    if ctx.mesh is None:
        return P(*([None] * len(desc.shape)))
    spec = [None] * len(desc.shape)
    tp_used = ctx.strategy == "dp_only"     # disables TP assignment
    fsdp = (ctx.fsdp_axes if len(ctx.fsdp_axes) > 1
            else (ctx.fsdp_axes[0] if ctx.fsdp_axes else None))
    dp = ctx.dp_axes if len(ctx.dp_axes) > 1 else ctx.dp_axes[0]
    for i, (ax, n) in enumerate(zip(desc.logical, desc.shape)):
        if ax == "embed" and fsdp is not None and n % ctx.fsdp_size == 0:
            spec[i] = fsdp
        elif ax in _TP_PRIMARY and not tp_used and n % ctx.tp_size == 0:
            spec[i] = ctx.tp_axis
            tp_used = True
        elif ax == "batch" and n % ctx.dp_size == 0:
            spec[i] = dp
    if not tp_used:
        for i, (ax, n) in enumerate(zip(desc.logical, desc.shape)):
            if (spec[i] is None and ax in _TP_FALLBACK
                    and n % ctx.tp_size == 0):
                spec[i] = ctx.tp_axis
                tp_used = True
                break
    return P(*spec)


def param_specs(ctx: ParallelCtx, descs):
    return tree_map_descs(lambda d: spec_for(ctx, d), descs)


def param_shardings(ctx: ParallelCtx, descs):
    assert ctx.mesh is not None
    return tree_map_descs(lambda d: NamedSharding(ctx.mesh, spec_for(ctx, d)),
                          descs)


# activations ---------------------------------------------------------------

def batch_spec(ctx: ParallelCtx, ndim_rest: int = 1) -> P:
    dp = ctx.dp_axes if len(ctx.dp_axes) > 1 else ctx.dp_axes[0]
    return P(dp, *([None] * ndim_rest))


def constrain(ctx: ParallelCtx, x, spec: P):
    if ctx.mesh is None:
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(ctx.mesh, spec))
