from repro.roofline.constants import TPU_V5E  # noqa: F401
from repro.roofline.hlo import collective_bytes_of_hlo  # noqa: F401
from repro.roofline.analysis import roofline_terms  # noqa: F401
