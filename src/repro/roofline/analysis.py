"""Three-term roofline from compiled dry-run artifacts (assignment §ROOFLINE).

    compute    = HLO_FLOPs / (chips × peak_FLOP/s)
    memory     = HLO_bytes / (chips × HBM_bw)
    collective = collective_bytes / (chips × links × link_bw)

FLOPs/bytes come from ``cost_analysis()`` of the *unrolled probes*
(1 and 2 layer-periods at full global shape):  per_period = probe2 −
probe1; total = probe1 + (n_periods − 1) × per_period.  Collective bytes
come from the probes' HLO via ``roofline.hlo``.

``cost_analysis()`` on a partitioned module reports per-partition numbers,
so terms are already per-chip; utilization = compute / max(all three).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

from repro.roofline.constants import Chip, TPU_V5E


@dataclasses.dataclass
class RooflineTerms:
    arch: str
    shape: str
    mesh: str
    flops: float                  # per chip, per step
    hbm_bytes: float              # per chip, per step
    coll_bytes: float             # per chip, per step
    model_flops: float            # 6·N(_active)·D_tokens — whole model
    n_chips: int
    chip: Chip = TPU_V5E

    @property
    def t_compute(self) -> float:
        return self.flops / self.chip.peak_bf16_flops

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / self.chip.hbm_bw

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / (self.chip.ici_links * self.chip.ici_link_bw)

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def t_bound(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / (HLO_FLOPs × chips): remat/dispatch waste check."""
        total_hlo = self.flops * self.n_chips
        return self.model_flops / total_hlo if total_hlo else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the compute roofline actually achieved if the step
        runs at t_bound: (useful model FLOPs / chips / peak) / t_bound."""
        if self.t_bound == 0:
            return 0.0
        t_useful = (self.model_flops / self.n_chips
                    / self.chip.peak_bf16_flops)
        return t_useful / self.t_bound

    def row(self) -> Dict[str, object]:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "flops_per_chip": self.flops, "hbm_bytes_per_chip": self.hbm_bytes,
            "coll_bytes_per_chip": self.coll_bytes,
            "t_compute_s": self.t_compute, "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "model_flops": self.model_flops,
            "useful_flops_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
        }


def roofline_terms(arch: str, shape: str, mesh: str, *,
                   probe1: Dict[str, float], probe2: Dict[str, float],
                   n_periods: int, model_flops: float, n_chips: int,
                   chip: Chip = TPU_V5E) -> RooflineTerms:
    """Extrapolate probe costs to the full depth.

    probes: {"flops": ..., "bytes": ..., "coll_bytes": ...} per chip.
    """
    def extrapolate(key):
        # clamp: XLA occasionally dedups more in the deeper probe, which
        # would extrapolate negative; per-period cost is never below zero
        per_period = max(probe2[key] - probe1[key], 0.0)
        return probe1[key] + (n_periods - 1) * per_period

    return RooflineTerms(
        arch=arch, shape=shape, mesh=mesh,
        flops=extrapolate("flops"),
        hbm_bytes=extrapolate("bytes"),
        coll_bytes=extrapolate("coll_bytes"),
        model_flops=model_flops, n_chips=n_chips, chip=chip)


def analytic_traffic_bytes(cfg, shape, n_chips: int,
                           moment_bytes: int = None) -> float:
    """TPU-realistic per-chip HBM traffic model (fused execution).

    The HLO "bytes accessed" from the XLA:CPU pipeline counts unfused
    operand/result bytes — a large upper bound.  This model counts what a
    fused TPU step actually moves:

    train: weights read fwd+bwd+remat (3x) + written once; f32 grads
    written+read; moments read+written; remat-saved boundaries written+read
    + per-layer activation stream (~4 resid-sized tensors per layer).
    serve: weights once + caches read(+write) + activation stream.
    """
    from repro.models.registry import build
    n_params = build(cfg, dec_pos_len=min(shape.seq_len, 2048)).n_params()
    pb = 2 if cfg.param_dtype == "bfloat16" else 4
    mb = 2 if cfg.moment_dtype == "bfloat16" else 4
    p_chip = n_params * pb / n_chips
    # dp/tp of the single-pod mesh; multi-pod adds a pure-DP pod axis
    dp, tp = 16, 16
    B_loc = max(shape.global_batch // dp, 1)
    if shape.kind == "train":
        S_loc = (shape.seq_len // tp if shape.seq_len % tp == 0
                 else shape.seq_len)
        resid = B_loc * shape.seq_len * cfg.d_model * 2 / tp
        act_stream = cfg.n_layers * resid * 8      # qkv/ff/bwd intermediates
        boundaries = cfg.n_layers * resid * 3      # write + read + recompute
        grads = n_params * 4 / n_chips * 2
        moments = 2 * n_params * mb / n_chips * 2
        return 4 * p_chip + grads + moments + act_stream + boundaries
    if shape.kind == "prefill":
        resid = B_loc * shape.seq_len * cfg.d_model * 2 / tp
        return p_chip + cfg.n_layers * resid * 6
    # decode: weights + full cache read per token
    from repro.models.params import is_desc
    import numpy as np, jax
    bundle = build(cfg, dec_pos_len=min(shape.seq_len, 2048))
    cache = 0
    for d in jax.tree_util.tree_leaves(
            bundle.cache_descs(shape.global_batch, shape.seq_len),
            is_leaf=is_desc):
        cache += int(np.prod(d.shape)) * 2
    return p_chip + cache / n_chips * 2


def model_flops_for(cfg, shape) -> float:
    """6·N_active·D_tokens (train) / 2·N_active·D (prefill & decode fwd)."""
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence; attention reads the cache (not in 2ND)
    return 2.0 * n_active * shape.global_batch
