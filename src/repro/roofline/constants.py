"""Target hardware constants (TPU v5e — the assignment's production part)."""
import dataclasses


@dataclasses.dataclass(frozen=True)
class Chip:
    name: str
    peak_bf16_flops: float        # FLOP/s per chip
    hbm_bw: float                 # bytes/s per chip
    hbm_bytes: float              # capacity per chip
    ici_link_bw: float            # bytes/s per link per direction
    ici_links: int                # links per chip used by a 2D torus


TPU_V5E = Chip(
    name="tpu_v5e",
    peak_bf16_flops=197e12,
    hbm_bw=819e9,
    hbm_bytes=16e9,
    ici_link_bw=50e9,             # ~50 GB/s/link (assignment constant)
    ici_links=4,                  # 2D torus: 4 links/chip
)
