"""Collective-traffic extraction from post-SPMD optimized HLO text.

``cost_analysis()`` does not report collective bytes, so we parse
``compiled.as_text()``: every ``all-gather`` / ``all-reduce`` /
``reduce-scatter`` / ``all-to-all`` / ``collective-permute`` instruction
contributes its operand bytes (per-device shapes — the module is already
partitioned).

While-loop handling: HLO puts loop bodies in separate computations; a
collective inside a body runs ``trip_count`` times.  We resolve the
computation call graph (while ``body=``/``condition=`` attributes), extract
the trip count from the condition's comparison constant (best effort;
falls back to 1 with a flag), and multiply.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?\S+\s*=\s*(\([^=]*\)|\S+)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(", re.M)


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class CollectiveStats:
    #: bytes per kind, per device, trip-count-weighted
    by_kind: Dict[str, int]
    #: number of collective instructions (static count)
    n_instructions: int
    #: True if some while trip count could not be resolved (counted as 1)
    unresolved_trip: bool

    @property
    def total_bytes(self) -> int:
        return sum(self.by_kind.values())


def _split_computations(hlo: str) -> Dict[str, str]:
    """computation name -> body text."""
    comps: Dict[str, str] = {}
    cur_name, cur_lines = None, []
    for line in hlo.splitlines():
        m = re.match(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*(?:\([^)]*\))?\s*->.*{",
                     line) or re.match(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s+{",
                                       line)
        if m and not line.startswith(" "):
            cur_name = m.group(1)
            cur_lines = [line]
            comps[cur_name] = ""
        elif cur_name is not None:
            cur_lines.append(line)
            if line.startswith("}"):
                comps[cur_name] = "\n".join(cur_lines)
                cur_name = None
    return comps


def _while_calls(comp_text: str) -> List[Tuple[str, str]]:
    """(body, condition) computation names of while instructions."""
    out = []
    for m in re.finditer(
            r"while\(.*?\).*?condition=%?([\w\.\-]+).*?body=%?([\w\.\-]+)",
            comp_text):
        out.append((m.group(2), m.group(1)))
    for m in re.finditer(
            r"while\(.*?\).*?body=%?([\w\.\-]+).*?condition=%?([\w\.\-]+)",
            comp_text):
        out.append((m.group(1), m.group(2)))
    return out


def _trip_count(cond_text: str) -> Optional[int]:
    """Best-effort: the comparison constant in the loop condition."""
    consts = [int(c) for c in
              re.findall(r"constant\((-?\d+)\)", cond_text)]
    consts = [c for c in consts if c > 0]
    return max(consts) if consts else None


def _direct_collective_bytes(comp_text: str) -> Dict[str, int]:
    out: Dict[str, int] = {}
    for m in _INSTR_RE.finditer(comp_text):
        shape_str, kind = m.group(1), m.group(2)
        out[kind] = out.get(kind, 0) + _shape_bytes(shape_str)
    return out


def collective_bytes_of_hlo(hlo: str) -> CollectiveStats:
    comps = _split_computations(hlo)
    unresolved = False

    # weight of each computation = product of enclosing while trip counts;
    # build naive one-level nesting resolution by fixpoint
    weights: Dict[str, int] = {name: 1 for name in comps}
    entry_like = [n for n, t in comps.items() if "ENTRY" in t.split("\n")[0]
                  or n.startswith("main")]
    # collect while edges
    edges: List[Tuple[str, str, int]] = []     # (parent, body, trips)
    for name, text in comps.items():
        for body, cond in _while_calls(text):
            trips = _trip_count(comps.get(cond, ""))
            if trips is None:
                trips = 1
                unresolved = True
            edges.append((name, body, trips))

    # propagate weights down the while nesting (few levels; iterate)
    for _ in range(8):
        changed = False
        for parent, body, trips in edges:
            w = weights.get(parent, 1) * trips
            if body in weights and weights[body] < w:
                weights[body] = w
                changed = True
        if not changed:
            break

    by_kind: Dict[str, int] = {}
    n_instr = 0
    for name, text in comps.items():
        direct = _direct_collective_bytes(text)
        n_instr += sum(1 for _ in _INSTR_RE.finditer(text))
        for kind, b in direct.items():
            by_kind[kind] = by_kind.get(kind, 0) + b * weights.get(name, 1)
    return CollectiveStats(by_kind, n_instr, unresolved)
