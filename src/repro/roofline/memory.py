"""Analytic per-device HBM residency model (TPU-realistic lower bound).

``memory_analysis()`` from the XLA:CPU pipeline is an UPPER bound for the
TPU target: the CPU backend lacks the reduce-scatter fusion pass (full-size
f32 gradient all-reduces stay materialized) and its arena packing is
conservative around remat barriers.  This module computes the structural
residency a TPU execution needs:

train:   params + moments(2) + grads + remat-saved layer-boundary
         activations + logits transient + one layer's working set
serve:   params + KV/SSM caches + one layer's working set

Both numbers are reported side by side in §Dry-run; the fit/no-fit verdict
against 16 GB uses the analytic number, the XLA number tracks relative
change across perf iterations.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models.registry import build
from repro.parallel.sharding import ParallelCtx


def _dtype_bytes(name: str) -> int:
    return {"bfloat16": 2, "float16": 2, "float32": 4, "float64": 8,
            "int32": 4, "int8": 1}[name]


@dataclasses.dataclass
class MemoryEstimate:
    params: float
    moments: float
    grads: float
    activations: float
    caches: float
    transients: float

    @property
    def total(self) -> float:
        return (self.params + self.moments + self.grads + self.activations
                + self.caches + self.transients)

    def row(self) -> Dict[str, float]:
        return dataclasses.asdict(self) | {"total": self.total}


def analytic_memory(cfg: ModelConfig, shape: ShapeConfig, *,
                    dp: int = 16, tp: int = 16,
                    microbatch: int = 1) -> MemoryEstimate:
    n_chips = dp * tp
    bundle = build(cfg, dec_pos_len=min(shape.seq_len, 2048))
    n_params = bundle.n_params()
    pb = _dtype_bytes(cfg.param_dtype)
    mb = _dtype_bytes(cfg.moment_dtype)

    # params/moments/grads fully sharded over the whole mesh (FSDP x TP)
    params = n_params * pb / n_chips
    if shape.kind == "train":
        moments = 2 * n_params * mb / n_chips
        grads = n_params * 4 / n_chips          # f32 at reduce-scatter width
        # remat-full saves the residual per layer boundary, seq-sharded
        B_loc = max(shape.global_batch // dp, 1)
        S_loc = shape.seq_len // tp if shape.seq_len % tp == 0 else shape.seq_len
        act = (cfg.n_layers * B_loc * S_loc * cfg.d_model * 2) / microbatch
        if cfg.is_encdec:
            act += (cfg.encdec.n_enc_layers * B_loc
                    * cfg.encdec.enc_seq * cfg.d_model * 2)
        # logits transient: (B_loc, S, V) split over tp via vocab (if it
        # divides) or via the sequence; f32 + bf16 copies
        tp_split = tp if (cfg.vocab_size % tp == 0
                          or shape.seq_len % tp == 0) else 1
        logits = B_loc * shape.seq_len * cfg.vocab_size / tp_split
        transients = logits * 6 / microbatch
        return MemoryEstimate(params, moments, grads, act, 0.0, transients)

    # serving
    caches_tree = bundle.cache_descs(shape.global_batch, shape.seq_len)
    import numpy as np
    import jax
    from repro.models.params import is_desc
    total_cache = 0
    for d in jax.tree_util.tree_leaves(caches_tree, is_leaf=is_desc):
        n = int(np.prod(d.shape))
        bytes_ = n * _dtype_bytes(d.dtype or cfg.compute_dtype)
        # sharded over whichever axes divide (batch->dp, kv/lora dims->tp)
        shard = 1
        if d.shape[0] % dp == 0 and "batch" in (d.logical[0] or ""):
            shard *= dp
        for ax, sz in zip(d.logical, d.shape):
            if ax in ("kv_heads", "mla_lora", "heads", "mamba_inner",
                      "head_dim") and sz % tp == 0:
                shard *= tp
                break
        total_cache += bytes_ / shard
    B_loc = max(shape.global_batch // dp, 1)
    S = shape.seq_len if shape.kind == "prefill" else 1
    act = 2 * B_loc * min(S, 4096) * cfg.d_model * 2
    return MemoryEstimate(params, 0.0, 0.0, act, total_cache,
                          transients=act)
