"""Aggregate dry-run cell JSONs into the §Dry-run / §Roofline tables.

Reads ``results/<arch>__<shape>__<mesh>.json`` written by launch/dryrun.py,
computes the three-term roofline per cell (probe extrapolation), and emits
markdown tables + a machine-readable CSV.

Usage:  python -m repro.roofline.report --results results/ [--csv out.csv]
"""
from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict, List, Optional

from repro.configs import SHAPES_BY_NAME, get_config
from repro.roofline.analysis import (
    analytic_traffic_bytes, model_flops_for, roofline_terms,
)
from repro.roofline.constants import TPU_V5E


def load_cells(results_dir: str) -> List[dict]:
    cells = []
    for path in sorted(glob.glob(os.path.join(results_dir, "*.json"))):
        with open(path) as f:
            cells.append(json.load(f))
    return cells


def cell_terms(cell: dict):
    if not cell.get("ok") or not cell.get("probe1"):
        return None
    arch, shape_name, mesh = cell["arch"], cell["shape"], cell["mesh"]
    cfg = get_config(arch)
    shape = SHAPES_BY_NAME[shape_name]
    n_chips = 512 if mesh == "multi" else 256
    return roofline_terms(
        arch, shape_name, mesh,
        probe1=cell["probe1"], probe2=cell["probe2"],
        n_periods=cell["n_periods"],
        model_flops=model_flops_for(cfg, shape),
        n_chips=n_chips)


def fmt_bytes(b: Optional[float]) -> str:
    if b is None:
        return "-"
    return f"{b/1e9:.2f}"


def dryrun_table(cells: List[dict]) -> str:
    lines = ["| arch | shape | mesh | status | mem/dev GB | compile s | "
             "collectives (probe, GB: AG/AR/RS/A2A/CP) |",
             "|---|---|---|---|---|---|---|"]
    for c in cells:
        status = "OK" if c["ok"] else "FAIL"
        if (c.get("error") or "").startswith("SKIP"):
            status = "SKIP (long-context on full attention)"
        kinds = c.get("collective_kinds") or {}
        coll = "/".join(
            f"{kinds.get(k, 0)/1e9:.2f}"
            for k in ("all-gather", "all-reduce", "reduce-scatter",
                      "all-to-all", "collective-permute"))
        lines.append(
            f"| {c['arch']} | {c['shape']} | {c['mesh']} | {status} "
            f"| {fmt_bytes(c.get('bytes_per_device'))} "
            f"| {c.get('compile_s', 0):.0f} | {coll} |")
    return "\n".join(lines)


def roofline_table(cells: List[dict]) -> str:
    """t_mem(HLO) is the assignment formula (unfused upper bound from the
    CPU pipeline); t_mem(model) is the fused-TPU traffic model — the
    bottleneck verdict and roofline fraction use the three assignment
    terms with memory replaced by min(HLO, model) to avoid the CPU
    pipeline's systematic overstatement."""
    lines = ["| arch | shape | mesh | t_comp ms | t_mem(HLO) ms | "
             "t_mem(model) ms | t_coll ms | bottleneck | useful-FLOPs | "
             "roofline-frac |",
             "|---|---|---|---|---|---|---|---|---|---|"]
    for c in cells:
        t = cell_terms(c)
        if t is None:
            continue
        cfg = get_config(c["arch"])
        shape = SHAPES_BY_NAME[c["shape"]]
        t_model = (analytic_traffic_bytes(cfg, shape, t.n_chips)
                   / t.chip.hbm_bw)
        t_mem_eff = min(t.t_memory, t_model)
        terms = {"compute": t.t_compute, "memory": t_mem_eff,
                 "collective": t.t_collective}
        bottleneck = max(terms, key=terms.get)
        t_bound = max(terms.values())
        t_useful = t.model_flops / t.n_chips / t.chip.peak_bf16_flops
        frac = t_useful / t_bound if t_bound else 0.0
        lines.append(
            f"| {t.arch} | {t.shape} | {t.mesh} | {t.t_compute*1e3:.1f} "
            f"| {t.t_memory*1e3:.1f} | {t_model*1e3:.1f} "
            f"| {t.t_collective*1e3:.1f} "
            f"| {bottleneck} | {t.useful_flops_ratio:.2f} "
            f"| {frac:.3f} |")
    return "\n".join(lines)


def csv_rows(cells: List[dict]) -> List[Dict[str, object]]:
    out = []
    for c in cells:
        t = cell_terms(c)
        if t is None:
            continue
        row = t.row()
        row["bytes_per_device"] = c.get("bytes_per_device")
        out.append(row)
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--results", default="results")
    ap.add_argument("--csv", default=None)
    args = ap.parse_args()
    cells = load_cells(args.results)
    print("## §Dry-run\n")
    print(dryrun_table(cells))
    print("\n## §Roofline (single-pod baselines)\n")
    print(roofline_table([c for c in cells if c["mesh"] == "single"]))
    print("\n## §Roofline (multi-pod)\n")
    print(roofline_table([c for c in cells if c["mesh"] == "multi"]))
    if args.csv:
        import csv as _csv
        rows = csv_rows(cells)
        if rows:
            with open(args.csv, "w", newline="") as f:
                w = _csv.DictWriter(f, fieldnames=list(rows[0]))
                w.writeheader()
                w.writerows(rows)


if __name__ == "__main__":
    main()
