"""Elastic scaling: grow-by-repartition, synthetic traffic, autoscaling.

The cluster could only *shrink* (on failure or by plan) and nothing ever
*decided* to scale.  This package closes the loop in three parts:

* ``scale.traffic`` — deterministic diurnal x bursty session arrival
  processes layered on ``serve/trace.py``'s pure-function contract, so
  killed-and-restarted workers regenerate the same offered load;
* ``scale.autoscaler`` — a controller that watches queue depth /
  admission latency / occupancy and prices "add/remove an engine" with
  the same ``dsm/emu.py`` cost model that prices spills, emitting logged
  ``Decision``s through ``dsm/placement.py``;
* ``scale.grow`` — helpers for the grow-by-repartition join protocol
  (scenarios/cluster_worker.py): which tensors move to a joiner, and
  the join kill-point constants.
"""
from repro.scale.autoscaler import (Autoscaler, AutoscaleConfig,
                                    ScaleEvent, SimResult,
                                    simulate_autoscale, simulate_fixed)
from repro.scale.grow import JOIN_POINTS, join_moves, join_templates
from repro.scale.traffic import TrafficConfig, arrival_counts, traffic_trace

__all__ = [
    "Autoscaler", "AutoscaleConfig", "ScaleEvent", "SimResult",
    "simulate_autoscale", "simulate_fixed",
    "JOIN_POINTS", "join_moves", "join_templates",
    "TrafficConfig", "arrival_counts", "traffic_trace",
]
