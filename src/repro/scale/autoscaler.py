"""Cost-priced autoscaling: capacity follows demand, per topology.

The controller watches the fleet signals a ``FleetController`` exposes —
queue depth, per-engine occupancy — and every ``window_ticks`` prices
three alternatives with the SAME emulator cost model that prices spills
(``dsm.placement.PlacementPolicy.choose_scale``):

* **hold**   — keep paying the projected queue wait at current capacity;
* **grow**   — pay the join capital (staged state transfer + gen+1
  re-flush, ``emu.join_transfer_ns``) up front to widen the lane set;
* **shrink** — pay draining a closing engine's sessions to peers, to
  stop paying one engine's capacity rent.

Every decision is a logged ``Decision`` (kind ``"scale"``) carrying all
priced alternatives, so the decision log shows WHY capacity moved —
and flips per ``--topology`` preset, emucxl-style, instead of hand-tuned
thresholds.

``simulate_autoscale`` / ``simulate_fixed`` run a deterministic queueing
simulation of a fleet under an arrival-timed trace (``scale.traffic``):
a pure function of (trace, config), used by the bench to show the
autoscaled fleet beats every fixed size on priced cost, and by the scale
scenario suite to drive a real ``FleetController`` through the same
decisions.
"""
from __future__ import annotations

import dataclasses
import json
import math
from typing import Dict, List, Optional, Sequence

from repro.dsm.emu import get_topology, join_transfer_ns
from repro.dsm.placement import Decision, PlacementPolicy
from repro.serve.scheduler import Request


@dataclasses.dataclass(frozen=True)
class AutoscaleConfig:
    """Controller + cost-model knobs.  ``state_nbytes`` is what a grow
    moves (the joining engine's share of pool-resident state);
    ``session_nbytes`` what a shrink drains per slot.  ``engine_tick_ns``
    is one engine's capacity rent per tick — the price of standing
    still; the emulator prices everything else."""
    topology: str = "cxl20-switched-pool"
    slots_per_engine: int = 4
    min_engines: int = 1
    max_engines: int = 12                # auto may BURST past any fixed
    state_nbytes: int = 1 << 20          # 1 MiB moved per join
    session_nbytes: int = 1 << 16        # 64 KiB drained per slot
    session_ticks: float = 16.0          # a lane is HELD this long
    window_ticks: int = 1                # decision cadence
    cooldown_ticks: int = 16             # min ticks between SHRINKS
    engine_tick_ns: float = 1e6

    def __post_init__(self):
        assert 1 <= self.min_engines <= self.max_engines
        assert self.slots_per_engine >= 1 and self.window_ticks >= 1
        assert self.session_ticks > 0


@dataclasses.dataclass(frozen=True)
class ScaleEvent:
    """One applied scale action (decisions that chose ``hold`` are in the
    policy's decision log but are not events)."""
    tick: int
    action: str                          # "grow" | "shrink"
    engines_before: int
    engines_after: int
    costs: Dict[str, float]              # the priced alternatives


class Autoscaler:
    """The decision loop: price hold/grow/shrink through the placement
    policy, apply a cooldown so one burst cannot thrash capacity, and
    keep the applied-event history.  Stateless about the FLEET — the
    caller (simulator or a live FleetController driver) owns engines and
    applies the returned action."""

    def __init__(self, cfg: AutoscaleConfig,
                 policy: Optional[PlacementPolicy] = None):
        self.cfg = cfg
        self.policy = policy or PlacementPolicy(cfg.topology)
        self.events: List[ScaleEvent] = []
        self._last_event_tick = -10**9

    def join_delay_ticks(self) -> int:
        """How many ticks a grow takes to come online: the modelled join
        transfer at the policy's decode-tick granularity.  New capacity
        is NOT instant — the simulator and the live driver both wait
        this out, so the controller cannot pretend joins are free."""
        ns = join_transfer_ns(get_topology(self.cfg.topology),
                              self.cfg.state_nbytes)
        return max(1, math.ceil(ns / self.policy.decode_tick_ns))

    def decide(self, tick: int, queue_depth: int, n_engines: int,
               busy_lanes: int = 0) -> int:
        """Price the three alternatives and return the signed ENGINE
        DELTA to apply (0 = hold).  Grow is greedy-proportional: the
        controller keeps adding engines while the marginal engine still
        pays for itself under the cost model, so one burst is answered
        by one decision, not a window-paced trickle.  Every iteration
        logs a ``scale`` Decision; cooldown forces hold (also logged —
        an auditable suppressed decision, not silence)."""
        c = self.cfg
        kw = dict(busy_lanes=busy_lanes, session_ticks=c.session_ticks,
                  session_nbytes=c.session_nbytes,
                  window_ticks=c.window_ticks,
                  engine_tick_ns=c.engine_tick_ns,
                  min_engines=c.min_engines, max_engines=c.max_engines)
        choice = self.policy.choose_scale(
            f"fleet@t{tick}", queue_depth, n_engines, c.slots_per_engine,
            c.state_nbytes, **kw)
        # asymmetric cooldown: scale-OUT is never suppressed (queue wait
        # compounds every tick a burst goes unanswered); scale-IN waits
        # out the cooldown so one lull between bursts cannot thrash
        # capacity into a fresh join right after a drain
        if (choice == "shrink"
                and tick - self._last_event_tick < c.cooldown_ticks):
            return 0
        if choice == "hold":
            return 0
        delta = 1 if choice == "grow" else -1
        while (choice == "grow"
               and n_engines + delta < c.max_engines
               and self.policy.choose_scale(
                   f"fleet@t{tick}+{delta}", queue_depth,
                   n_engines + delta, c.slots_per_engine,
                   c.state_nbytes, **kw) == "grow"):
            delta += 1
        self._last_event_tick = tick
        self.events.append(ScaleEvent(
            tick, choice, n_engines, n_engines + delta,
            self.policy.decisions[-1].costs))
        return delta

    # -- decision-log export -------------------------------------------------
    def dump_decisions(self, path: str):
        """One JSONL line per scale Decision (all priced alternatives) —
        the artifact the CI scale-smoke job uploads."""
        with open(path, "w") as f:
            for d in self.policy.decisions_for("scale"):
                f.write(json.dumps(dataclasses.asdict(d)) + "\n")


@dataclasses.dataclass(frozen=True)
class SimResult:
    """Deterministic outcome of one simulated fleet under one trace."""
    n_requests: int
    served: int
    lost_sessions: int
    emitted_tokens: int
    total_ticks: int
    p99_admission_ticks: float
    mean_admission_ticks: float
    priced_cost_ns: float                # rent + wait + scale capital
    engines_min: int
    engines_max: int
    decisions: int                       # scale decisions logged
    grows: int
    shrinks: int

    @property
    def tokens_per_tick(self) -> float:
        return self.emitted_tokens / max(1, self.total_ticks)


class _Lane:
    __slots__ = ("remaining",)

    def __init__(self, remaining: int):
        self.remaining = remaining


class _SimEngine:
    __slots__ = ("eid", "lanes", "draining")

    def __init__(self, eid: int, n_slots: int):
        self.eid = eid
        self.lanes: List[Optional[_Lane]] = [None] * n_slots
        self.draining = False

    @property
    def busy(self) -> int:
        return sum(1 for l in self.lanes if l is not None)


def _simulate(trace: Sequence[Request], cfg: AutoscaleConfig, *,
              scaler: Optional[Autoscaler], n_engines: int,
              max_ticks: Optional[int] = None) -> SimResult:
    """The shared engine: time-stepped, one decoded token per busy lane
    per tick.  With ``scaler`` the fleet resizes (grow comes online after
    the modelled join delay; shrink drains the highest-id engine); the
    run extends past the last arrival until the queue drains or
    ``max_ticks`` hits (undrained sessions count as LOST)."""
    assert all(trace[i].arrival <= trace[i + 1].arrival
               for i in range(len(trace) - 1)), "trace must be arrival-sorted"
    horizon = (trace[-1].arrival + 1) if trace else 1
    max_ticks = max_ticks or 16 * horizon
    policy = scaler.policy if scaler else None
    topo = get_topology(cfg.topology)
    decode_tick_ns = (policy.decode_tick_ns if policy
                      else PlacementPolicy(cfg.topology).decode_tick_ns)

    engines: List[_SimEngine] = [_SimEngine(i + 1, cfg.slots_per_engine)
                                 for i in range(n_engines)]
    next_eid = n_engines + 1
    pending_grow: List[int] = []         # ticks each pending join lands
    queue: List[Request] = []
    latencies: List[int] = []
    emitted = 0
    cost = 0.0
    grows = shrinks = 0
    emin = emax = len(engines)
    i = 0                                # next trace index
    t = 0
    while t < max_ticks:
        while i < len(trace) and trace[i].arrival <= t:
            queue.append(trace[i])
            i += 1
        # decode: every busy lane emits one token
        for e in engines:
            for s, lane in enumerate(e.lanes):
                if lane is None:
                    continue
                lane.remaining -= 1
                emitted += 1
                if lane.remaining == 0:
                    e.lanes[s] = None
        # a draining engine with no busy lane closes NOW
        closing = [e for e in engines if e.draining and e.busy == 0]
        for e in closing:
            engines.remove(e)
        # pending joins land
        for d in list(pending_grow):
            if d <= t:
                pending_grow.remove(d)
                engines.append(_SimEngine(next_eid, cfg.slots_per_engine))
                next_eid += 1
        # admit FIFO into free lanes of non-draining engines
        for e in engines:
            if e.draining:
                continue
            for s, lane in enumerate(e.lanes):
                if lane is None and queue:
                    r = queue.pop(0)
                    latencies.append(t - r.arrival)
                    e.lanes[s] = _Lane(r.max_new_tokens)
        # the controller
        if scaler is not None and t % cfg.window_ticks == 0:
            effective = len(engines) + len(pending_grow)
            busy = sum(e.busy for e in engines)
            delta = scaler.decide(t, len(queue), effective,
                                  busy_lanes=busy)
            if delta > 0:
                for _ in range(delta):
                    pending_grow.append(t + scaler.join_delay_ticks())
                    cost += join_transfer_ns(topo, cfg.state_nbytes)
                grows += 1
            elif delta < 0:
                # drain the highest-id non-draining engine
                cands = [e for e in engines if not e.draining]
                if len(cands) > cfg.min_engines:
                    victim = max(cands, key=lambda e: e.eid)
                    victim.draining = True
                    cost += cfg.session_nbytes * victim.busy * 2.0
                    shrinks += 1
        # per-tick rent + queue wait
        cost += ((len(engines) + len(pending_grow)) * cfg.engine_tick_ns
                 + len(queue) * decode_tick_ns)
        emin = min(emin, len(engines) + len(pending_grow))
        emax = max(emax, len(engines) + len(pending_grow))
        t += 1
        if i >= len(trace) and not queue \
                and all(e.busy == 0 for e in engines):
            break
    lost = len(queue) + (len(trace) - i)
    lat = sorted(latencies)
    p99 = float(lat[min(len(lat) - 1, math.ceil(0.99 * len(lat)) - 1)]) \
        if lat else 0.0
    mean = sum(lat) / len(lat) if lat else 0.0
    n_dec = len(policy.decisions_for("scale")) if policy else 0
    return SimResult(
        n_requests=len(trace), served=len(latencies),
        lost_sessions=lost, emitted_tokens=emitted, total_ticks=t,
        p99_admission_ticks=p99, mean_admission_ticks=mean,
        priced_cost_ns=cost, engines_min=emin, engines_max=emax,
        decisions=n_dec, grows=grows, shrinks=shrinks)


def simulate_fixed(trace: Sequence[Request], n_engines: int,
                   cfg: AutoscaleConfig) -> SimResult:
    """A fixed-size fleet under the trace — the baseline family the
    autoscaled run must beat on priced cost."""
    return _simulate(trace, cfg, scaler=None, n_engines=n_engines)


def simulate_autoscale(trace: Sequence[Request], cfg: AutoscaleConfig, *,
                       start_engines: Optional[int] = None,
                       scaler: Optional[Autoscaler] = None) -> SimResult:
    """The autoscaled fleet: same simulator, controller in the loop.
    Pass ``scaler`` to keep its decision log for export."""
    scaler = scaler or Autoscaler(cfg)
    return _simulate(trace, cfg, scaler=scaler,
                     n_engines=start_engines or cfg.min_engines)
