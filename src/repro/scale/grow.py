"""Grow-by-repartition helpers: what moves to a joiner, and where.

The join protocol (``scenarios/cluster_worker.py``) is three phases —

1. **staged** — every old rank RStores the entries the new partition
   assigns to the joiner into the JOINER's staging buffer (under the
   ``join/<name>`` namespace, tagged with the pre-join step ``q``);
2. **committed** — the old ranks flush their state at ``q`` under the
   OLD partition and elect ONE gen+1 cluster manifest whose meta names
   the joiner (``join={"member": j, "at_step": s}``) and carries both
   partitions;
3. **adopted** — everyone (joiner included) switches to the new
   membership: the joiner installs its partition staging-first
   (pool-fallback through the manifest's old-partition meta), survivors
   re-lay their mesh slices (``launch.mesh.rank_submesh``).

A kill at any phase boundary (``dsm.faults.JOIN_POINTS``) must recover
to either the old or the new membership bit-identically: before the
manifest the grow simply never happened; after it, the joiner's state
is derivable from the manifest alone (its staging buffer is a volatile
copy, by the CXL0 cache-loss contract).

These helpers are pure functions of the two partition plans, so every
process — old rank, joiner, a replay — derives the identical move set
with no coordinator.
"""
from __future__ import annotations

from typing import Any, Dict

import numpy as np

from repro.dsm.faults import JOIN_POINTS           # noqa: F401  (re-export)
from repro.train.elastic import plan_delta

#: staging namespace of entries in flight to a joiner — disjoint from the
#: ``w<i>/`` rank namespaces, so a join in progress can never shadow a
#: rank's own ring-staged copies
JOIN_NS = "join"


def join_name(tensor: str) -> str:
    return f"{JOIN_NS}/{tensor}"


def join_moves(old_partition: Dict[str, int], new_partition: Dict[str, int],
               joiner: int) -> Dict[str, int]:
    """``{tensor: old_owner}`` for every entry the new partition assigns
    to ``joiner`` — the transfer set each old rank filters by ownership
    to know what IT must stage."""
    return {n: src for n, (src, dst) in
            plan_delta(old_partition, new_partition).items()
            if dst == joiner}


def join_templates(moves: Dict[str, int], dim: int) -> Dict[str, Any]:
    """Pytree prototypes of the staged join entries, in the cluster toy
    state format ({p, mu, nu} per tensor, see
    ``scenarios.cluster_worker.init_tensor``)."""
    z = lambda: np.zeros((dim, dim), np.float32)
    return {join_name(t): {"p": z(), "mu": z(), "nu": z()}
            for t in sorted(moves)}
