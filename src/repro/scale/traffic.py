"""Deterministic synthetic traffic: diurnal sinusoid x bursty arrivals.

A 24h day is compressed onto ``horizon_ticks`` scheduler ticks.  The
per-tick arrival intensity is

    lam(t) = base_rate * (1 + diurnal_amplitude * sin(2*pi*(t/H) + phase))
             + sum over burst starts b <= t of
                   burst_size * burst_decay ** (t - b)

— a diurnal carrier with seeded hawkes-like burst trains riding on top
(each burst start injects an exponentially decaying excitation, the
self-exciting shape of real flash crowds without the unbounded
branching).  Counts are Poisson draws from ``lam``; burst starts are
Bernoulli(burst_rate) per tick.  Everything is a pure function of
``(seed, config)`` drawn from one ``np.random.default_rng(seed)`` in a
fixed order, so a killed-and-restarted worker regenerates the exact
offered load — the same cross-process contract ``serve/trace.py`` keeps
for prompts.
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence, Tuple

import numpy as np

from repro.serve.scheduler import Request
from repro.serve.trace import synthetic_trace


@dataclasses.dataclass(frozen=True)
class TrafficConfig:
    """One offered-load shape.  ``horizon_ticks`` is the compressed day;
    defaults give ~5-minute buckets (288 = 24h / 5min) with a pronounced
    day/night swing and a few bursts."""
    seed: int = 0
    horizon_ticks: int = 288
    base_rate: float = 1.0            # mean sessions/tick at the carrier
    diurnal_amplitude: float = 0.8    # 0..1: day/night swing
    diurnal_phase: float = -0.5 * np.pi   # troughs at t=0 ("midnight")
    burst_rate: float = 0.02          # P(burst starts) per tick
    burst_size: float = 6.0           # initial excitation of a burst
    burst_decay: float = 0.7          # per-tick decay of the excitation
    prompt_lens: Tuple[int, ...] = (16, 32)
    new_tokens: Tuple[int, ...] = (4, 8, 16, 32)
    vocab_size: int = 256

    def __post_init__(self):
        assert self.horizon_ticks >= 1, self.horizon_ticks
        assert 0.0 <= self.diurnal_amplitude <= 1.0, self.diurnal_amplitude
        assert 0.0 <= self.burst_decay < 1.0, self.burst_decay


def arrival_counts(cfg: TrafficConfig) -> np.ndarray:
    """Sessions arriving per tick, shape ``(horizon_ticks,)`` int64.
    Deterministic in (seed, config): burst starts are drawn for every
    tick first, then one Poisson vector over the full intensity, so the
    draw order never depends on the values drawn."""
    rng = np.random.default_rng(cfg.seed)
    h = cfg.horizon_ticks
    t = np.arange(h, dtype=np.float64)
    diurnal = cfg.base_rate * (
        1.0 + cfg.diurnal_amplitude
        * np.sin(2.0 * np.pi * t / h + cfg.diurnal_phase))
    starts = rng.random(h) < cfg.burst_rate
    excitation = np.zeros(h)
    carry = 0.0
    for i in range(h):
        carry *= cfg.burst_decay
        if starts[i]:
            carry += cfg.burst_size
        excitation[i] = carry
    lam = np.maximum(diurnal + excitation, 0.0)
    return rng.poisson(lam).astype(np.int64)


def traffic_trace(cfg: TrafficConfig) -> List[Request]:
    """The full request trace for one compressed day: ``arrival_counts``
    expanded into per-request arrival ticks (requests of one tick are
    consecutive rids, FIFO within the tick), prompts and token budgets
    from ``synthetic_trace`` under the same seed.  Pure in (seed,
    config); identical across processes."""
    counts = arrival_counts(cfg)
    arrivals = np.repeat(np.arange(len(counts)), counts)
    return synthetic_trace(
        int(counts.sum()), seed=cfg.seed, vocab_size=cfg.vocab_size,
        prompt_lens=cfg.prompt_lens, new_tokens=cfg.new_tokens,
        arrivals=arrivals.tolist())


def offered_tokens(requests: Sequence[Request]) -> int:
    """Total decode tokens the trace asks for (the work the fleet must
    emit to serve the day with zero lost sessions)."""
    return sum(r.max_new_tokens for r in requests)
