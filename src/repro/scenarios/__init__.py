"""Crash-injection scenario subsystem.

Verifies the durable-linearizability claim at SYSTEM scale: a real worker
process is killed (``os._exit``) at a configurable point inside the commit
window — pre-flush, mid-flush (some shards durable, manifest missing), or
post-completeOp — then restarted; the restarted process must recover to
SOME completed commit (in fact the newest one) and finish the run with a
final state bit-identical to an uninterrupted reference run.

* ``repro.scenarios.worker`` — the killable TRAINING worker process (CLI);
* ``repro.scenarios.serve_worker`` — the killable SERVING worker: a
  continuous-batching engine whose session commits ride the same FliT
  path; kill + restart must replay every committed session with
  bit-identical output tokens;
* ``repro.scenarios.cluster_worker`` — rank i of N data-parallel CLUSTER
  processes sharing one pool through the multi-writer manifest protocol
  (``repro.dsm.cluster``); killing one rank mid-commit makes the
  survivors shrink-remesh, recover the victim's partition (cross-process
  peer staging or pool) and finish bit-identically to a planned shrink;
* ``repro.scenarios.cluster`` — the cluster suite orchestration
  (``run_cluster_scenario`` / ``run_cluster_suite``: kill points x
  {peer-newer, pool-newer} recovery sources);
* ``repro.scenarios.runner`` — orchestrates kill -> inspect -> restart ->
  compare, one scenario per kill point for all suites (CLI:
  ``--suite train|serve|cluster|all``; library: ``run_scenario`` /
  ``run_suite`` / ``run_serve_scenario`` / ``run_serve_suite`` /
  ``run_cluster_suite``).

Import the run functions from ``repro.scenarios.runner`` (submodules are
not re-exported here so ``python -m`` entry points stay clean).
"""
from repro.dsm.flit_runtime import KILL_POINTS

__all__ = ["KILL_POINTS"]
