"""Cluster scenario suite: kill 1 of N REAL worker processes sharing one
pool, inside the commit window, and require the survivors to shrink,
recover and finish bit-identically to a planned (uninterrupted) shrink.

One scenario (``run_cluster_scenario``):

1. **kill phase** — launch N ``repro.scenarios.cluster_worker`` processes
   over one pool; the victim ``os._exit``s at the configured commit-window
   point (pre_flush / mid_flush / post_completeOp).  The orchestrator
   then plays the environment's part in the partial-crash model: it wipes
   the victim's (volatile) staging buffer and posts the membership change
   on the control plane.  The survivors — blocked on the victim's
   all-reduce contribution — run the shrink protocol and finish the run
   with one fewer rank;
2. **inspect** — the cluster manifests durable at the moment of death
   (read before the survivors are released, so the set is exact);
3. **verdict** — the survivors must report the EXPECTED recovery source
   (peer-staging when the sibling's staged copy is newer than the pool's
   newest cluster manifest, pool otherwise — e.g. when replication is off
   or the kill came after completeOp), must resume from the expected
   step, and their merged final per-tensor digests must equal a planned
   reference shrink at the same step (``run_cluster_planned``).

``run_cluster_suite`` runs the full matrix: every kill point x
{replicated (peer-newer), unreplicated (pool-newer)} — reference runs are
shared across scenarios that recover at the same step.
"""
from __future__ import annotations

import dataclasses
import json
import os
import subprocess
import sys
import tempfile
from typing import Dict, List, Optional, Sequence, Tuple

from repro.dsm.cluster import ControlPlane, FileStagingArea
from repro.dsm.flit_runtime import KILL_POINTS
from repro.dsm.pool import DSMPool
from repro.scenarios.runner import _worker_env
from repro.scenarios.worker import KILL_EXIT


def spawn_worker(pool: str, rank: int, world: int, *, steps: int,
                 commit_every: int, replicate: bool,
                 kill_point: str = "none", kill_step: int = 0,
                 dim: int = 16, tensors: int = 6, global_batch: int = 6,
                 retention: int = 0, topology: str = None,
                 joiner: bool = False, join_at: int = 0,
                 timeout: float = 120.0) -> subprocess.Popen:
    """THE cluster_worker command builder — shared by the scenario suite,
    the N-worker launcher, the scale suite and the cluster benchmark so a
    new worker flag is threaded through in one place.  ``joiner=True``
    spawns a rank OUTSIDE ``world`` that grows the cluster at
    ``join_at`` (the launcher must also post the planned grow change)."""
    cmd = [sys.executable, "-m", "repro.scenarios.cluster_worker",
           "--pool", pool, "--rank", str(rank), "--world", str(world),
           "--steps", str(steps), "--commit-every", str(commit_every),
           "--dim", str(dim), "--tensors", str(tensors),
           "--global-batch", str(global_batch),
           "--replicate", str(int(replicate)),
           "--retention", str(retention),
           "--timeout", str(timeout),
           "--kill-point", kill_point, "--kill-step", str(kill_step)]
    if topology:
        cmd += ["--topology", topology]
    if joiner:
        cmd += ["--joiner", "--join-at", str(join_at)]
    return subprocess.Popen(cmd, env=_worker_env(),
                            stdout=subprocess.PIPE,
                            stderr=subprocess.PIPE, text=True)


def _last_json(out: str) -> dict:
    return json.loads(out.strip().splitlines()[-1])


def _terminate(procs: Dict[int, subprocess.Popen]):
    for p in procs.values():
        if p.poll() is None:
            p.kill()
    for p in procs.values():
        try:
            p.communicate(timeout=10)
        except subprocess.TimeoutExpired:
            pass


def merge_digests(results: Sequence[dict]) -> Dict[str, int]:
    """Union of the per-rank final-partition digests; a tensor reported by
    two ranks with different values means the partition was inconsistent
    — surfaced as a failure, never silently picked."""
    merged: Dict[str, int] = {}
    for res in results:
        for t, crc in (res.get("digests") or {}).items():
            if t in merged and merged[t] != crc:
                raise ValueError(f"conflicting digests for {t}")
            merged[t] = crc
    return merged


def run_cluster_planned(pool: str, *, world: int, victim: int,
                        shrink_at: int, steps: int, commit_every: int,
                        replicate: bool = True, dim: int = 16,
                        tensors: int = 6,
                        timeout: float = 300.0) -> Dict[str, int]:
    """The reference: an uninterrupted run whose rank set shrinks at the
    SAME step as the kill scenario's recovery — posted as a planned
    elastic scale-down before launch.  Returns merged final digests."""
    ControlPlane(os.path.join(pool, "control")).post(
        victim, planned=True, at_step=shrink_at)
    procs = {r: spawn_worker(pool, r, world, steps=steps,
                             commit_every=commit_every,
                             replicate=replicate, dim=dim,
                             tensors=tensors, timeout=timeout)
             for r in range(world)}
    results = []
    try:
        for r, p in procs.items():
            out, err = p.communicate(timeout=timeout)
            if p.returncode != 0:
                raise RuntimeError(
                    f"planned-shrink rank {r} rc={p.returncode}: "
                    f"{err[-2000:]}")
            results.append(_last_json(out))
    finally:
        _terminate(procs)
    return merge_digests(results)


@dataclasses.dataclass
class ClusterScenarioResult:
    kill_point: str
    replicate: bool
    killed: bool
    completed_steps_at_kill: List[int]   # cluster-manifest steps at death
    resumed_from: Optional[int]
    recovery_source: Optional[str]
    expected_resume: int
    expected_source: str
    digests: Dict[str, int]
    reference_digests: Dict[str, int]
    n_tensors: int
    detail: str = ""

    @property
    def recovered_completed_commit(self) -> bool:
        """Pool recovery must land on the NEWEST completed cluster commit;
        peer-staging legitimately resumes AHEAD of every manifest."""
        if self.resumed_from is None:
            return False
        if self.recovery_source == "peer-staging":
            return self.resumed_from >= max(self.completed_steps_at_kill)
        return self.resumed_from == max(self.completed_steps_at_kill)

    @property
    def ok(self) -> bool:
        return (self.killed
                and self.recovery_source == self.expected_source
                and self.resumed_from == self.expected_resume
                and self.recovered_completed_commit
                and len(self.digests) == self.n_tensors
                and self.digests == self.reference_digests)


def expected_recovery(kill_point: str, replicate: bool, kill_step: int,
                      commit_every: int) -> Tuple[int, str]:
    """Where recovery MUST land for each matrix cell.  A post-completeOp
    kill leaves the manifest of the dying commit durable, so the pool
    already matches the sibling's staged copy and wins the tie; before
    completeOp the staged copy (updated every step) is newer than the
    last manifest iff replication is on."""
    if kill_point == "post_completeOp":
        return kill_step, "pool"
    if replicate:
        return kill_step, "peer-staging"
    return kill_step - commit_every, "pool"


def run_cluster_scenario(kill_point: str, workdir: str, *,
                         replicate: bool = True, world: int = 3,
                         victim: int = 1, steps: int = 10,
                         commit_every: int = 2,
                         kill_step: Optional[int] = None,
                         dim: int = 16, tensors: int = 6,
                         ref_cache: Optional[Dict[int, Dict[str, int]]]
                         = None,
                         timeout: float = 300.0) -> ClusterScenarioResult:
    assert kill_point in KILL_POINTS, kill_point
    assert world >= 3, "need N >= 3 so the shrunk cluster still has peers"
    if kill_step is None:
        # the second commit: at least one completed cluster commit (plus
        # the initial floor) precedes the kill
        kill_step = 2 * commit_every - 1
    exp_resume, exp_source = expected_recovery(kill_point, replicate,
                                               kill_step, commit_every)
    pool = os.path.join(
        workdir, f"cluster_{kill_point}_{'peer' if replicate else 'pool'}")

    # 1. kill phase
    procs = {r: spawn_worker(
        pool, r, world, steps=steps, commit_every=commit_every,
        replicate=replicate, dim=dim, tensors=tensors, timeout=timeout,
        kill_point=kill_point if r == victim else "none",
        kill_step=kill_step) for r in range(world)}
    try:
        procs[victim].communicate(timeout=timeout)
    except subprocess.TimeoutExpired:
        _terminate(procs)
        return ClusterScenarioResult(
            kill_point, replicate, False, [], None, None, exp_resume,
            exp_source, {}, {}, tensors, detail="victim never died")
    if procs[victim].returncode != KILL_EXIT:
        _terminate(procs)
        return ClusterScenarioResult(
            kill_point, replicate, False, [], None, None, exp_resume,
            exp_source, {}, {}, tensors,
            detail=f"victim rc={procs[victim].returncode}")

    # 2. cluster commits durable at the moment of death (survivors are
    #    still blocked on the victim's all-reduce slot, so this set is
    #    exact), then the environment side of the crash: the victim's
    #    volatile staging buffer vanishes and the membership change goes
    #    out on the control plane
    completed = sorted({m["step"]
                        for m in DSMPool(pool).manifests_desc()})
    FileStagingArea(os.path.join(pool, "staging")).wipe(victim)
    ControlPlane(os.path.join(pool, "control")).post(victim)

    # 3. survivors shrink + finish
    results = []
    try:
        for r, p in procs.items():
            if r == victim:
                continue
            out, err = p.communicate(timeout=timeout)
            if p.returncode != 0:
                _terminate(procs)
                return ClusterScenarioResult(
                    kill_point, replicate, True, completed, None, None,
                    exp_resume, exp_source, {}, {}, tensors,
                    detail=f"survivor {r} rc={p.returncode}: "
                           f"{err[-1500:]}")
            results.append(_last_json(out))
    finally:
        _terminate(procs)

    resumed = {res["resumed_from"] for res in results}
    sources = {res["source"] for res in results}
    if len(resumed) != 1 or len(sources) != 1:
        return ClusterScenarioResult(
            kill_point, replicate, True, completed, None, None,
            exp_resume, exp_source, {}, {}, tensors,
            detail=f"survivors disagree: resumed={resumed} "
                   f"sources={sources}")
    resumed_from, source = resumed.pop(), sources.pop()
    try:
        digests = merge_digests(results)
    except ValueError as e:
        return ClusterScenarioResult(
            kill_point, replicate, True, completed, resumed_from, source,
            exp_resume, exp_source, {}, {}, tensors, detail=str(e))

    # 4. reference: a planned shrink at the recovered step + 1 (cached —
    #    every scenario recovering at the same step shares one reference)
    ref_cache = ref_cache if ref_cache is not None else {}
    if resumed_from not in ref_cache:
        ref_pool = os.path.join(workdir, f"cluster_ref_{resumed_from}")
        ref_cache[resumed_from] = run_cluster_planned(
            ref_pool, world=world, victim=victim,
            shrink_at=resumed_from + 1, steps=steps,
            commit_every=commit_every, dim=dim, tensors=tensors,
            timeout=timeout)
    return ClusterScenarioResult(
        kill_point, replicate, True, completed, resumed_from, source,
        exp_resume, exp_source, digests, ref_cache[resumed_from], tensors)


def run_cluster_suite(workdir: Optional[str] = None,
                      points: Sequence[str] = KILL_POINTS,
                      sources: Sequence[str] = ("peer", "pool"),
                      **kwargs) -> List[ClusterScenarioResult]:
    """The full matrix: every kill point x {peer-newer, pool-newer}
    recovery source (``sources`` trims the matrix for smoke jobs)."""
    workdir = workdir or tempfile.mkdtemp(prefix="scenarios_cluster_")
    ref_cache: Dict[int, Dict[str, int]] = {}
    out = []
    for point in points:
        for src in sources:
            out.append(run_cluster_scenario(
                point, workdir, replicate=(src == "peer"),
                ref_cache=ref_cache, **kwargs))
    return out
