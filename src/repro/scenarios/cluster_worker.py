"""The killable CLUSTER worker: rank i of N data-parallel processes over
ONE shared DSM pool (the tentpole of the multi-writer protocol,
``repro.dsm.cluster``).

Each rank OWNS a disjoint partition of a deterministic toy model state
(``repro.train.elastic.partition_plan``) and the ranks advance in
lockstep: per step every rank computes the gradient contribution of its
data shard (``data.pipeline.shard_plan`` slice of the global batch), the
contributions are summed bit-exactly on the file all-reduce board, and
each rank applies the identical scalar update to its owned tensors — so
the CLUSTER state at step k is a pure function of (seed, membership
history), and a crash + shrink + replay must be bit-identical to a
planned shrink at the same step.

Every step each rank LStores its partition and RStore-stages it into its
ring sibling's spill-file buffer; every ``--commit-every`` steps it
RFlushes (sharded pipelines) and completes through the multi-writer
cluster protocol: rank record, then ONE elected cluster manifest
referencing every rank's objects at that step.

``--kill-point`` arms the commit-window fault hook exactly like the
single-worker scenario process: the rank ``os._exit``s at
pre_flush / mid_flush / post_completeOp of the first commit at or after
``--kill-step``.  Survivors detect the death while blocked on the
victim's all-reduce contribution (the orchestrator posts the membership
change), then run the elastic shrink protocol:

1. the victim's ring sibling recovers the victim's partition —
   **peer-staging** (its own spill buffer) if the staged step tag beats
   the newest cluster manifest, else **pool** — and publishes the
   recovered step ``q`` + source;
2. if ``q`` is older than the survivors' live step they ROLL BACK to the
   cluster manifest at ``q`` (never mix steps);
3. all survivors (sibling also covering the victim's objects) GPF-flush
   state at ``q`` and commit a gen+1 recovery manifest;
4. everyone re-reads the full state from that manifest, repartitions over
   the survivor set (``partition_plan``), re-places adopted tensors via
   ``train.elastic.remesh``, re-plans data shards, and resumes at
   ``q + 1``.

A planned shrink (``--shrink-at``, posted as a planned control entry by
the launcher) runs steps 3-4 with the departing rank still alive — the
reference run every kill scenario must match bit-for-bit.

A planned GROW (posted as a ``kind="grow"`` control change; the joiner
process runs with ``--joiner --join-at s``) is the inverse: old ranks
stage the joiner's new partition into its buffer, elect ONE gen+1 join
manifest at ``s - 1`` under the old partition, and repartition over the
grown live set; the joiner observes each phase and adopts staging-first
with pool fallback.  ``--kill-point join_staged|join_committed|
join_adopted`` arms the three join-phase boundaries
(``dsm.faults.JOIN_POINTS``); killing the joiner there must take the
survivors back to the old membership bit-identically (the crash shrink
recovers the joiner's entries through the join manifest's partition
meta, since the joiner never committed under its own namespace).

    PYTHONPATH=src python -m repro.scenarios.cluster_worker --pool /tmp/p \
        --rank 1 --world 3 --kill-point mid_flush --kill-step 3
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
import zlib
from typing import Any, Dict, List, Optional

import numpy as np

import jax

from repro.data.pipeline import SyntheticLMSource, shard_plan
from repro.dsm.api import open_cxl0
from repro.dsm.cluster import (POLL_S, ClusterProtocol, ControlPlane,
                               FileStagingArea, MembershipChange,
                               ScalarReduceBoard, rank_ns, ring_sibling)
from repro.dsm.faults import JOIN_POINTS
from repro.dsm.flit_runtime import KILL_POINTS
from repro.dsm.pool import DSMPool, manifest_entry
from repro.dsm.recovery import ColdStartError
from repro.launch.mesh import mesh_device_sets, rank_submesh
from repro.models.params import ParamDesc
from repro.scale.grow import join_moves, join_name, join_templates
from repro.scenarios.worker import KILL_EXIT
from repro.train.elastic import partition_plan, remesh


def tensor_names(n: int) -> List[str]:
    return [f"t{i:02d}" for i in range(n)]


def init_tensor(name: str, dim: int, seed: int) -> Dict[str, np.ndarray]:
    """Deterministic per-tensor init — any rank (or a replay) derives the
    identical values, which is what makes ownership a pure bookkeeping
    choice."""
    rng = np.random.default_rng((seed, int(name[1:]), 0xC1))
    return {
        "p": rng.standard_normal((dim, dim)).astype(np.float32),
        "mu": np.zeros((dim, dim), np.float32),
        "nu": np.zeros((dim, dim), np.float32),
    }


def partition_templates(rank: int, partition: Dict[str, int],
                        dim: int) -> Dict[str, Any]:
    """Pytree prototypes of one rank's two objects (for recovery reads)."""
    owned = sorted(t for t, r in partition.items() if r == rank)
    z = lambda: np.zeros((dim, dim), np.float32)
    return {
        rank_ns(rank, "params"): {t: z() for t in owned},
        rank_ns(rank, "opt"): {t: {"mu": z(), "nu": z()} for t in owned},
    }


class ClusterWorker:
    def __init__(self, args, fault_hook=None):
        self.args = args
        self.rank = args.rank
        self.fault_hook = fault_hook
        # --world is always the ORIGINAL world; a joiner's rank is outside
        # it and enters the live set only through the join protocol
        self.live = list(range(args.world))
        self.gen = 0
        #: control-log indices this process already acted on — a planned
        #: change is applied AT MOST ONCE, so a crash shrink that undoes
        #: a grow cannot make the next step re-apply the same grow (a
        #: livelock: re-adopt the dead joiner, re-detect its death, ...)
        self._applied_changes: set = set()
        self.pool = DSMPool(args.pool)
        self.control = ControlPlane(os.path.join(args.pool, "control"))
        self.board = ScalarReduceBoard(os.path.join(args.pool, "reduce"))
        self.staging = FileStagingArea(os.path.join(args.pool, "staging"))
        self.names = tensor_names(args.tensors)
        # each rank owns a mesh SLICE (contiguous run of the process's
        # devices, launch.mesh.rank_submesh); the partition plan weights
        # ranks by their slice's device count so state lands where the
        # devices are
        self.partition = partition_plan(self.names, self.live,
                                        mesh_device_sets(self.live))
        self.tensors = {t: init_tensor(t, args.dim, args.seed)
                        for t in self.names if self.partition[t] == self.rank}
        self.source = SyntheticLMSource(1024)
        self.proto = ClusterProtocol(self.pool, self.rank, self.live,
                                     confirm=fault_hook is not None,
                                     retention=args.retention or None,
                                     timeout=args.timeout)
        # cost-driven placement (--topology): the policy decides whether
        # ring RStore-staging this rank's partition is worth its per-step
        # cost under the emulated topology, and sizes the shard pipelines
        # from the partition bytes instead of the fixed --shards
        placement = None
        self._stage_to_sibling = bool(args.replicate)
        n_shards = args.shards
        if getattr(args, "topology", None):
            from repro.dsm.emu import tree_nbytes
            from repro.dsm.placement import (PlacementPolicy,
                                             plan_rank_staging)
            placement = PlacementPolicy(args.topology)
            part_bytes = tree_nbytes(self.state_objects())
            self._stage_to_sibling = (args.replicate and plan_rank_staging(
                placement, part_bytes))
            n_shards = None             # resolved by the policy per bytes
        # one wiring path: the context owns tiers + committer; the cluster
        # protocol plugs in as the delegated completeOp (rank record + ONE
        # elected cluster manifest) and the ring sibling as the RStore peer
        self.ctx = open_cxl0(
            self.pool, self.rank, schedule="sharded", n_shards=n_shards,
            placement=placement, fault_hook=fault_hook,
            complete_fn=self.proto.cluster_complete,
            replicate_to=self._proxy())
        self.tiers = self.ctx.tiers
        self.placement = self.ctx.placement
        self.committer = self.ctx.committer
        self.step_done = -1          # last step whose update is applied
        self.resumed_from: Optional[int] = None
        self.source_used: Optional[str] = None

    def _proxy(self):
        if not self._stage_to_sibling or self.rank not in self.live:
            return None       # a joiner has no ring sibling until adopted
        return self.staging.proxy(ring_sibling(self.rank, self.live))

    def _point(self, point: str, step: int):
        """Fire a protocol-phase fault point OUTSIDE the committer's
        commit window (the join phases) — same hook, same semantics."""
        if self.fault_hook is not None:
            self.fault_hook(point, step)

    # -- state objects -------------------------------------------------------
    @property
    def owned(self) -> List[str]:
        return sorted(t for t, r in self.partition.items()
                      if r == self.rank)

    def state_objects(self) -> Dict[str, Any]:
        return {
            rank_ns(self.rank, "params"):
                {t: self.tensors[t]["p"] for t in self.owned},
            rank_ns(self.rank, "opt"):
                {t: {"mu": self.tensors[t]["mu"],
                     "nu": self.tensors[t]["nu"]} for t in self.owned},
        }

    def _meta(self, extra: Optional[dict] = None) -> dict:
        return self.proto.meta_for(partition=self.partition,
                                   **(extra or {}))

    # -- the deterministic data-parallel step --------------------------------
    def _partial(self, step: int) -> float:
        plan = shard_plan(self.args.global_batch, len(self.live))
        s, c = plan[sorted(self.live).index(self.rank)]
        tok = self.source.sequence_batch(
            self.args.seed, step * self.args.global_batch + s, c,
            self.args.seq + 1)
        # sum (not mean) of per-sequence means: the cross-rank combine is
        # then independent of how the batch is sharded
        return float(tok[:, :-1].astype(np.float64).mean(axis=1).sum())

    def _apply(self, x: np.float32):
        for t in self.owned:
            d = self.tensors[t]
            g = np.float32(0.01) * d["p"] + x
            d["p"] = d["p"] - np.float32(0.1) * g
            d["mu"] = np.float32(0.9) * d["mu"] + np.float32(0.1) * g
            d["nu"] = (np.float32(0.95) * d["nu"]
                       + np.float32(0.05) * g * g)

    # -- shrink protocol -----------------------------------------------------
    def _flush_and_record(self, q: int,
                          extra: Optional[Dict[str, Any]] = None,
                          meta: Optional[dict] = None) -> dict:
        """GPF leg of a shrink: durably flush my objects (+ any adopted
        victim objects) at step ``q``, record, elect, and WAIT for the
        cluster manifest — the barrier every shrink participant crosses."""
        entries = {}
        objs = dict(self.state_objects())
        objs.update(extra or {})
        for name, tree in objs.items():
            self.tiers.lstore(name, tree)
            entries[name] = manifest_entry(self.tiers.rflush(name))
        self.proto.write_record(q, entries)
        self.proto.try_commit(q, meta or self._meta())
        return self.proto.wait_manifest(q, control=self.control)

    def _repartition(self, m: dict, old_partition: Dict[str, int],
                     old_live: List[int]):
        """Re-read the FULL state from the shrink manifest, take my slice
        of the new partition over the survivor set, and re-place adopted
        tensors on the local mesh (``train.elastic.remesh`` — on a real
        cluster this is the resharding transfer)."""
        full: Dict[str, Dict[str, np.ndarray]] = {}
        for r in sorted(old_live):
            tpl = partition_templates(r, old_partition, self.args.dim)
            pname, oname = rank_ns(r, "params"), rank_ns(r, "opt")
            params = self.pool.read_entry(pname, m["objects"][pname],
                                          tpl[pname])
            opt = self.pool.read_entry(oname, m["objects"][oname],
                                       tpl[oname])
            for t, p in params.items():
                full[t] = {"p": p, "mu": opt[t]["mu"], "nu": opt[t]["nu"]}
        self.partition = partition_plan(self.names, self.live,
                                        mesh_device_sets(self.live))
        mine = {t: full[t] for t in self.names
                if self.partition[t] == self.rank}
        # adopted tensors are re-placed onto THIS rank's mesh slice — the
        # survivors' sub-grids re-derived over the shrunken live set, so
        # the victim's devices are re-adopted rather than idled
        mesh = rank_submesh(self.rank, self.live)
        descs = {t: {k: ParamDesc(v.shape, (None,) * v.ndim)
                     for k, v in d.items()} for t, d in mine.items()}
        placed, _ = remesh(mine, descs, mesh)
        self.tensors = {
            t: {k: np.asarray(v) for k, v in d.items()}
            for t, d in placed.items()}
        if self.placement is not None:
            # partition sizes changed: re-price the staging decision and
            # let the next commit re-resolve the shard count from the
            # post-shrink partition bytes
            self.committer.n_shards = None
            if self.args.replicate:
                from repro.dsm.emu import tree_nbytes
                from repro.dsm.placement import plan_rank_staging
                self._stage_to_sibling = plan_rank_staging(
                    self.placement, tree_nbytes(self.state_objects()))
        self.committer.replicate_to = self._proxy()

    def _crash_shrink(self, victim: int):
        """A peer died mid-run: recover its partition (peer-staging beats
        the pool if newer), roll back if the pool copy is older than our
        live step, commit the gen+1 recovery manifest, repartition."""
        old_live, old_partition = list(self.live), dict(self.partition)
        gen_new = self.gen + 1
        live_new = [r for r in old_live if r != victim]
        adopter = ring_sibling(victim, old_live)
        victim_tpl = partition_templates(victim, old_partition,
                                         self.args.dim)
        if self.rank == adopter:
            view = self.staging.view(self.rank, victim_tpl)
            try:
                vobjs, q, source = self.ctx.recover(
                    victim_tpl, peers=(view,), exact=False)
            except ColdStartError:
                # the victim never durably committed under its OWN
                # namespace (a joiner killed mid-join): its entries are
                # still derivable from the newest manifest through that
                # manifest's partition meta — the old owners' aggregates
                vobjs, q, source = self._recover_via_manifest(victim)
            self.control.post_shrink_result(
                gen_new, {"q": q, "source": source, "victim": victim,
                          "live": live_new})
        else:
            doc = self.control.wait_shrink_result(
                gen_new, timeout=self.args.timeout)
            q, source, vobjs = doc["q"], doc["source"], None
        self.gen = gen_new
        self.live = live_new
        self.proto.set_membership(gen_new, live_new)
        if q < self.step_done:
            # the victim's newest copy predates our live state: the whole
            # cluster rolls back to the manifest at q — never mix steps
            mq = self.proto.find_manifest(q)
            my_tpl = partition_templates(self.rank, old_partition,
                                         self.args.dim)
            pname, oname = rank_ns(self.rank, "params"), \
                rank_ns(self.rank, "opt")
            params = self.pool.read_entry(pname, mq["objects"][pname],
                                          my_tpl[pname])
            opt = self.pool.read_entry(oname, mq["objects"][oname],
                                       my_tpl[oname])
            self.tensors = {t: {"p": params[t], "mu": opt[t]["mu"],
                                "nu": opt[t]["nu"]} for t in params}
            self.step_done = q
        meta = self.proto.meta_for(
            partition=old_partition,
            next_partition=partition_plan(self.names, live_new,
                                          mesh_device_sets(live_new)),
            recovered={"victim": victim, "source": source})
        m = self._flush_and_record(q, extra=vobjs, meta=meta)
        self._repartition(m, old_partition, old_live)
        self.step_done = q
        self.resumed_from = q
        self.source_used = source

    def _planned_shrink(self, victim: int, at_step: int) -> bool:
        """Elastic scale-down at a step boundary (the paper's sanctioned
        GPF use): every rank — the departing one included — flushes state
        at ``at_step - 1`` into a gen+1 manifest; survivors repartition
        and continue.  Returns True if THIS rank is the one departing."""
        old_live, old_partition = list(self.live), dict(self.partition)
        q = at_step - 1
        gen_new = self.gen + 1
        self.gen = gen_new
        self.proto.set_membership(gen_new, old_live)   # all ranks record
        live_new = [r for r in old_live if r != victim]
        meta = self.proto.meta_for(
            partition=old_partition,
            next_partition=partition_plan(self.names, live_new,
                                          mesh_device_sets(live_new)),
            planned_shrink={"victim": victim, "at_step": at_step})
        m = self._flush_and_record(q, meta=meta)
        if self.rank == victim:
            return True
        self.live = [r for r in old_live if r != victim]
        self.proto.set_membership(gen_new, self.live)
        self._repartition(m, old_partition, old_live)
        return False

    # -- grow protocol -------------------------------------------------------
    def _planned_grow(self, joiner: int, at_step: int):
        """Elastic scale-UP at a step boundary, old-rank side.  Three
        phases, each ending in a ``JOIN_POINTS`` fault point:

        1. **staged** — RStore every entry the new partition assigns to
           the joiner into ITS staging buffer (``join/<t>``, tag q);
        2. **committed** — flush my state at ``q = at_step - 1`` under
           the OLD partition and elect ONE gen+1 manifest whose meta
           names the joiner (the single completeOp the whole grow hangs
           on: before it the grow never happened, after it the joiner's
           state is derivable from the manifest alone);
        3. **adopted** — switch to the grown live set and repartition
           (``_repartition`` is direction-agnostic).

        A joiner killed at any of these leaves the survivors blocked on
        its all-reduce contribution at ``at_step``; the posted crash
        shrink then takes them back to the old membership — the staged
        copies are volatile and the manifest meta maps the joiner's
        entries back to their old owners (``_recover_via_manifest``)."""
        old_live, old_partition = list(self.live), dict(self.partition)
        q = at_step - 1
        gen_new = self.gen + 1
        live_new = sorted(old_live + [joiner])
        new_partition = partition_plan(self.names, live_new,
                                       mesh_device_sets(live_new))
        moves = join_moves(old_partition, new_partition, joiner)
        buf = self.staging.proxy(joiner).staging
        for t in sorted(moves):
            if moves[t] == self.rank:
                d = self.tensors[t]
                buf[join_name(t)] = (q, {"p": d["p"], "mu": d["mu"],
                                         "nu": d["nu"]})
        self._point("join_staged", q)
        self.gen = gen_new
        self.proto.set_membership(gen_new, old_live)   # old ranks record
        meta = self.proto.meta_for(
            partition=old_partition, next_partition=new_partition,
            join={"member": joiner, "at_step": at_step})
        m = self._flush_and_record(q, meta=meta)
        self._point("join_committed", q)
        self.live = live_new
        self.proto.set_membership(gen_new, live_new)
        self._repartition(m, old_partition, old_live)
        self._point("join_adopted", q)

    def _join(self, at_step: int):
        """Joiner side: observe the three phases and adopt.  The new
        partition is a pure function of the grown live set, so the
        joiner derives its own slice with no coordinator; its state
        comes staging-first (the copies the old ranks RStored into THIS
        rank's buffer, tag ``q``) with pool fallback through the join
        manifest's old-partition meta."""
        q = at_step - 1
        old_live, old_partition = list(self.live), dict(self.partition)
        live_new = sorted(old_live + [self.rank])
        new_partition = partition_plan(self.names, live_new,
                                       mesh_device_sets(live_new))
        moves = join_moves(old_partition, new_partition, self.rank)
        tpl = join_templates(moves, self.args.dim)
        # phase 1 (observed): my staged partition is complete in my own
        # buffer — or the join manifest already exists (stale staging is
        # then irrelevant: the pool path below serves)
        deadline = time.monotonic() + self.args.timeout
        staged: Dict[str, Any] = {}
        while True:
            view = self.staging.view(self.rank, tpl)
            staged = {n: t for n, (tag, t) in view.staging.items()
                      if tag == q}
            if set(staged) == set(tpl):
                break
            m = self.proto.find_manifest(q)
            if m is not None and \
                    m["meta"].get("join", {}).get("member") == self.rank:
                staged = {}
                break
            if time.monotonic() > deadline:
                raise TimeoutError(f"join staging for rank {self.rank} "
                                   f"never completed")
            time.sleep(POLL_S)
        self._point("join_staged", q)
        # phase 2 (observed): the ONE elected gen+1 manifest naming me
        while True:
            m = self.proto.find_manifest(q)
            if m is not None and \
                    m["meta"].get("join", {}).get("member") == self.rank:
                break
            if time.monotonic() > deadline:
                raise TimeoutError(f"join manifest for rank {self.rank} "
                                   f"never appeared")
            time.sleep(POLL_S)
        self._point("join_committed", q)
        # phase 3: adopt the new membership and install my partition
        self.gen = int(m["meta"]["gen"])
        self.live = live_new
        self.proto.set_membership(self.gen, live_new)
        self.partition = new_partition
        if set(staged) == set(tpl) and tpl:
            mine = {t: {k: np.asarray(v)
                        for k, v in staged[join_name(t)].items()}
                    for t in moves}
            source = "peer-staging"
        else:
            mine, source = self._read_via_partition_meta(
                m, sorted(moves)), "pool"
        mesh = rank_submesh(self.rank, self.live)
        descs = {t: {k: ParamDesc(v.shape, (None,) * v.ndim)
                     for k, v in d.items()} for t, d in mine.items()}
        placed, _ = remesh(mine, descs, mesh)
        self.tensors = {t: {k: np.asarray(v) for k, v in d.items()}
                        for t, d in placed.items()}
        if self.placement is not None:
            self.committer.n_shards = None
            if self.args.replicate:
                from repro.dsm.emu import tree_nbytes
                from repro.dsm.placement import plan_rank_staging
                self._stage_to_sibling = plan_rank_staging(
                    self.placement, tree_nbytes(self.state_objects()))
        self.committer.replicate_to = self._proxy()
        self.step_done = q
        self.resumed_from = q
        self.source_used = source
        self._point("join_adopted", q)

    def _recover_via_manifest(self, victim: int):
        """Recover a victim that owns entries under the CURRENT partition
        but never committed them under its own ``w<victim>/`` namespace —
        a joiner killed at any join phase.  The newest manifest's
        partition meta maps those entries back to the ranks that flushed
        them, so recovery lands on the manifest step exactly as the pool
        path would."""
        ms = self.proto._manifests_desc()
        assert ms, "no manifest to recover a joiner victim from"
        m = ms[0]
        need = sorted(t for t, r in self.partition.items() if r == victim)
        full = self._read_via_partition_meta(m, need)
        vobjs = {
            rank_ns(victim, "params"): {t: full[t]["p"] for t in need},
            rank_ns(victim, "opt"): {t: {"mu": full[t]["mu"],
                                         "nu": full[t]["nu"]}
                                     for t in need},
        }
        return vobjs, int(m["step"]), "pool"

    def _read_via_partition_meta(self, m: dict, tensors: List[str]
                                 ) -> Dict[str, Dict[str, np.ndarray]]:
        """Read ``tensors`` out of manifest ``m`` through ITS partition
        meta — the owners' ``w<r>/params`` / ``w<r>/opt`` aggregates as
        of that manifest, whatever the partition is NOW."""
        mpart = {t: int(r) for t, r in m["meta"]["partition"].items()}
        out: Dict[str, Dict[str, np.ndarray]] = {}
        owners = sorted({mpart[t] for t in tensors})
        for r in owners:
            tpl = partition_templates(r, mpart, self.args.dim)
            pname, oname = rank_ns(r, "params"), rank_ns(r, "opt")
            params = self.pool.read_entry(pname, m["objects"][pname],
                                          tpl[pname])
            opt = self.pool.read_entry(oname, m["objects"][oname],
                                       tpl[oname])
            for t in tensors:
                if mpart[t] == r:
                    out[t] = {"p": params[t], "mu": opt[t]["mu"],
                              "nu": opt[t]["nu"]}
        return out

    # -- main loop -----------------------------------------------------------
    def run(self) -> dict:
        if getattr(self.args, "joiner", False):
            # a joiner enters through the join protocol, not the floor
            # barrier: it adopts at join_at - 1 and steps from join_at
            self._join(self.args.join_at)
            k = self.args.join_at
        else:
            # initial durable floor (step -1): even a kill inside the
            # FIRST commit window leaves a recoverable cluster manifest.
            # Doubles as the start barrier — every rank waits for it.
            self.ctx.put(self.state_objects(), step=-1)
            with self.ctx.commit(-1, meta=self._meta()):
                pass
            self.proto.wait_manifest(-1, control=self.control)
            k = 0

        while k < self.args.steps:
            for ch in self.control.changes():
                if (ch["idx"] in self._applied_changes
                        or not ch.get("planned")
                        or ch.get("at_step") != k):
                    continue
                self._applied_changes.add(ch["idx"])
                if ch["kind"] == "shrink" and ch["member"] in self.live:
                    if self._planned_shrink(ch["member"], k):
                        return {"rank": self.rank, "planned_exit_at": k}
                elif (ch["kind"] == "grow"
                        and ch["member"] not in self.live
                        and ch["member"] != self.rank):
                    self._planned_grow(ch["member"], k)
            self.board.contribute(self.gen, k, self.rank, self._partial(k))
            try:
                total = self.board.combine(self.gen, k, self.live,
                                           control=self.control,
                                           timeout=self.args.timeout)
            except MembershipChange as e:
                self._crash_shrink(e.victim)
                k = self.step_done + 1
                continue
            self._apply(np.float32(total / self.args.global_batch / 1000.0))
            self.step_done = k
            self.ctx.put(self.state_objects(), step=k)
            if (k + 1) % self.args.commit_every == 0:
                with self.ctx.commit(k, meta=self._meta()):
                    pass
            k += 1

        # final GPF commit: make the last step durable whatever the cadence
        last = self.args.steps - 1
        if self.proto.find_manifest(last, gen=self.gen) is None:
            self._flush_and_record(last, meta=self._meta())
        digests = {
            t: zlib.crc32(np.ascontiguousarray(
                self.tensors[t]["p"]).tobytes())
            for t in self.owned}
        return {"rank": self.rank, "live": sorted(self.live),
                "gen": self.gen, "resumed_from": self.resumed_from,
                "source": self.source_used, "digests": digests,
                "final_step": last}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--pool", required=True)
    ap.add_argument("--rank", type=int, required=True)
    ap.add_argument("--world", type=int, required=True)
    ap.add_argument("--steps", type=int, default=10)
    ap.add_argument("--commit-every", type=int, default=2)
    ap.add_argument("--dim", type=int, default=16)
    ap.add_argument("--tensors", type=int, default=6)
    ap.add_argument("--global-batch", type=int, default=6)
    ap.add_argument("--seq", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--shards", type=int, default=2)
    ap.add_argument("--replicate", type=int, default=1,
                    help="RStore-stage into the ring sibling (1) or not "
                         "(0 — recovery must come from the pool)")
    ap.add_argument("--retention", type=int, default=0,
                    help="cluster manifests kept by the elected "
                         "committer's post-commit gc (0 = unbounded; the "
                         "crash scenarios run unbounded so every commit "
                         "stays inspectable)")
    ap.add_argument("--timeout", type=float, default=120.0,
                    help="rendezvous timeout (s)")
    ap.add_argument("--topology", default=None,
                    help="emulated CXL topology preset (dsm.emu.PRESETS); "
                         "when set, the placement policy decides ring "
                         "staging and shard count from the partition "
                         "bytes (--replicate 0 still forces pool-only)")
    ap.add_argument("--joiner", action="store_true",
                    help="this rank GROWS the cluster: it is outside "
                         "--world, runs the join protocol at --join-at "
                         "and steps from there (rank must not be in "
                         "range(world))")
    ap.add_argument("--join-at", type=int, default=0,
                    help="step the planned grow is posted for (the "
                         "joiner adopts state at join_at - 1)")
    ap.add_argument("--kill-point", default="none",
                    choices=("none",) + KILL_POINTS + JOIN_POINTS)
    ap.add_argument("--kill-step", type=int, default=3)
    args = ap.parse_args(argv)

    hook = None
    if args.kill_point != "none":
        def hook(point, step):
            if point == args.kill_point and step >= args.kill_step:
                sys.stderr.write(f"KILL rank={args.rank} {point} "
                                 f"step={step}\n")
                sys.stderr.flush()
                os._exit(KILL_EXIT)

    result = ClusterWorker(args, fault_hook=hook).run()
    print(json.dumps(result))
    return 0


if __name__ == "__main__":
    sys.exit(main())
