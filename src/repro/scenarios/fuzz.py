"""Adversarial crash fuzzer: seeded episodes of kills + torn writes +
stragglers against train / serve / cluster / scale workloads, checked
against ONE invariant — *recovery always lands on a completed commit,
bit-identical to a clean run replayed to that step*.

The ``scale`` workload is the cluster workload plus one planned
grow-by-repartition (``repro.scale.grow``): a joiner rank enters the
live generation mid-run, and kills can land at any of the three join
windows (``JOIN_POINTS``) on any rank — joiner included.

Where the kill-point suites enumerate ~6 hand-picked cells at 3 fixed
commit-window points, an episode here draws a whole ``FaultSchedule``
(repro.dsm.faults) from a seed: worker deaths at arbitrary primitive
boundaries (any lstore/rstore/rflush/mstore/completeOp call index),
torn durable writes (visible rename, wrong bytes) and seeded straggler
delays — then drives the real DSM stack (``open_cxl0`` + the fault-hook
plumbing) through crash / recover / resume until the workload finishes.

The checker is an independent oracle, NOT the recovery code itself:

* the expected recovery point is recomputed from the pool's manifest
  files and the ``FaultyPool`` corruption ledger (and, for the cluster,
  from the raw peer ``.staging`` contents) — manifests whose required
  entries were torn must be skipped, peer staging wins only when it
  covers the victim at one consistent strictly-newer tag;
* the expected recovered *bytes* come from a pure-numpy clean replay of
  the workload (no DSM involved), so "bit-identical to a clean run" is
  checked against something the system under test never touched.

Every episode is a pure function of (config, schedule): no wall clock,
no unseeded randomness.  On a violation the suite greedily shrinks the
schedule (drop straggler → drop torn → drop each kill, keep whatever
still violates) and dumps a minimal-reproducer JSON that
``replay_reproducer`` re-runs to the same violation.

``REPRO_FUZZ_BREAK_RECOVERY=1`` deliberately breaks the recovery seam
(the recovered objects are swapped for a stale commit's while keeping
the claimed step) — the checker must then fail; tests and the CI canary
use this to prove the invariant has teeth.
"""
from __future__ import annotations

import dataclasses
import json
import os
import shutil
import tempfile
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.dsm.api import open_cxl0
from repro.dsm.cluster import rank_ns, ring_sibling
from repro.dsm.emu import PRESETS, TopologyEmulator, attach_emulator
from repro.dsm.faults import (FaultInjector, FaultSchedule, FaultyPool,
                              InjectedCrash, JOIN_POINTS, KillSpec,
                              StragglerSpec, TornSpec, attach_faults,
                              PRIMITIVES)
from repro.dsm.flit_runtime import COMMIT_MODES, KILL_POINTS
from repro.dsm.recovery import ColdStartError, RecoveryManager
from repro.scale.grow import join_moves
from repro.train.elastic import partition_plan

import zlib

WORKLOADS = ("train", "serve", "cluster", "scale")
TOPOLOGIES = tuple(PRESETS)

#: setting this env var swaps recovered objects for a STALE commit's
#: (keeping the claimed step) at the recovery seam — the injected bug the
#: invariant checker must catch
BREAK_ENV = "REPRO_FUZZ_BREAK_RECOVERY"

#: incarnations per episode before declaring a livelock (kills are finite
#: and torn decisions are per-version, so convergence is guaranteed —
#: this guard only turns a checker bug into a violation, not a hang)
MAX_INCARNATIONS = 60


@dataclasses.dataclass
class EpisodeConfig:
    """One episode's workload shape.  Everything that affects behaviour is
    here or in the FaultSchedule — together they ARE the reproducer."""
    workload: str
    topology: str = "cxl11-direct"
    mode: str = "sync"              # commit schedule (cluster: always sync)
    steps: int = 12                 # train/cluster step count
    commit_every: int = 3
    n_tensors: int = 3              # train tensor count / cluster objects
    dim: int = 8
    n_shards: int = 2
    world: int = 3                  # cluster ranks
    replicate: bool = True          # cluster ring RStore replication
    requests: int = 5               # serve sessions
    arrival_every: int = 2          # serve ticks between arrivals
    decode_len: int = 4             # serve decode ticks per session
    grow_at: int = 0                # scale: step at which rank `world` joins
    emu_seed: int = 0

    @property
    def serve_ticks(self) -> int:
        return (self.requests - 1) * self.arrival_every + self.decode_len + 2

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "EpisodeConfig":
        return cls(**d)


@dataclasses.dataclass
class EpisodeResult:
    workload: str
    topology: str
    ok: bool
    violations: List[str]
    kills_fired: List[dict]
    recoveries: List[dict]
    cold_restarts: int
    torn_writes: int
    config: dict
    schedule: dict

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


class _Events:
    """Per-episode accumulator the workload engines write into."""

    def __init__(self):
        self.violations: List[str] = []
        self.kills: List[dict] = []
        self.recoveries: List[dict] = []
        self.cold = 0
        self.torn = 0


# ---------------------------------------------------------------------------
# digests + the independent oracle
# ---------------------------------------------------------------------------

def _arr_crc(arr, d: int = 0) -> int:
    a = np.asarray(arr)
    d = zlib.crc32(str((str(a.dtype), a.shape)).encode(), d)
    return zlib.crc32(np.ascontiguousarray(a).tobytes(), d)


def _named_crc(named: Dict[str, Any], names: Sequence[str]) -> int:
    d = 0
    for n in sorted(names):
        d = zlib.crc32(n.encode(), d)
        d = _arr_crc(named[n], d)
    return d


def _entry_corrupt(entry: dict, corrupt: set) -> bool:
    """Does a manifest entry (plain or sharded) reference any payload the
    FaultyPool ledger says was torn?"""
    if entry.get("sharded"):
        return any((sh["name"], sh["version"]) in corrupt
                   for sh in entry["shards"])
    return (entry["name"], entry["version"]) in corrupt


def _oracle_pool_step(pool: FaultyPool, required: set, *,
                      exact: bool) -> Optional[int]:
    """The expected recovery step, recomputed from manifest FILES plus the
    corruption ledger — independent of RecoveryManager's read path."""
    corrupt = {(n, v) for n, v, _ in pool.injected}
    for m in pool.manifests_desc():
        entries = m["objects"]
        if exact and set(entries) != required:
            continue
        if not required <= set(entries):
            continue
        if any(_entry_corrupt(entries[n], corrupt) for n in required):
            continue
        return m["step"]
    return None


def _oracle_latest_step(pool: FaultyPool) -> Optional[int]:
    """Expected step for dynamic-set (recover_latest) recovery: newest
    manifest NONE of whose entries reference a torn payload."""
    corrupt = {(n, v) for n, v, _ in pool.injected}
    for m in pool.manifests_desc():
        if not any(_entry_corrupt(e, corrupt)
                   for e in m["objects"].values()):
            return m["step"]
    return None


# ---------------------------------------------------------------------------
# the breakable recovery seam
# ---------------------------------------------------------------------------

def _stale_pool_objs(pool, templates: Dict[str, Any], newer_than: int, *,
                     exact: bool) -> Optional[Dict[str, Any]]:
    """Objects of some VALID manifest strictly older than ``newer_than``
    (used only by the deliberate break: stale bytes under a fresh step)."""
    for m in pool.manifests_desc():
        if m["step"] >= newer_than:
            continue
        entries = m["objects"]
        if exact and set(entries) != set(templates):
            continue
        if not set(templates) <= set(entries):
            continue
        try:
            return {n: pool.read_entry(n, entries[n], templates[n])
                    for n in templates}
        except Exception:
            continue
    return None


def _recover_seam(recovery, pool, templates: Dict[str, Any], *,
                  peers: Sequence[Any] = (), exact: bool = True
                  ) -> Optional[Tuple[Dict[str, Any], int, str]]:
    """THE recovery invocation every workload goes through.  With
    ``REPRO_FUZZ_BREAK_RECOVERY`` set, the recovered objects are swapped
    for a stale commit's while the claimed step stays — the bug the
    invariant must catch."""
    try:
        objs, step, source = recovery.recover(templates, tuple(peers),
                                              exact=exact)
    except ColdStartError:
        return None
    if os.environ.get(BREAK_ENV):
        stale = _stale_pool_objs(pool, templates, step, exact=exact)
        if stale is not None:
            objs = stale
    return objs, step, source


def _recover_latest_seam(recovery, pool, template_for
                         ) -> Optional[Tuple[Dict[str, Any], dict]]:
    got = recovery.recover_latest(template_for)
    if got is None or not os.environ.get(BREAK_ENV):
        return got
    _, m = got
    for m2 in pool.manifests_desc():
        if m2["step"] >= m["step"]:
            continue
        try:
            objs2 = {n: pool.read_entry(n, e, template_for(n, e))
                     for n, e in m2["objects"].items()}
        except Exception:
            continue
        stale = dict(m2)
        stale["step"] = m["step"]      # stale state under the fresh step
        return objs2, stale
    return got


# ---------------------------------------------------------------------------
# clean-replay models (pure numpy — the DSM stack never touches these)
# ---------------------------------------------------------------------------

def _train_names(cfg: EpisodeConfig) -> List[str]:
    return [f"t{j}" for j in range(cfg.n_tensors)]


def _train_init(cfg: EpisodeConfig) -> Dict[str, np.ndarray]:
    return {f"t{j}": np.full((cfg.dim, cfg.dim), 0.05 * (j + 1), np.float32)
            for j in range(cfg.n_tensors)}


def _train_advance(state: Dict[str, np.ndarray], i: int
                   ) -> Dict[str, np.ndarray]:
    out = {}
    for n, v in state.items():
        out[n] = (v * np.float32(0.99)
                  + np.float32(np.mean(v)) * np.float32(0.01)
                  + np.float32(0.001) * np.float32(i + 1)).astype(np.float32)
    return out


def _train_clean_digests(cfg: EpisodeConfig) -> Dict[int, int]:
    names = _train_names(cfg)
    state = _train_init(cfg)
    digests = {-1: _named_crc(state, names)}
    for i in range(cfg.steps):
        state = _train_advance(state, i)
        digests[i] = _named_crc(state, names)
    return digests


def _cluster_names(cfg: EpisodeConfig) -> List[str]:
    return [f"t{k}" for k in range(cfg.n_tensors)]


def _cluster_step_val(v: np.ndarray, s: int) -> np.ndarray:
    return (v * np.float32(0.97) + np.float32(np.mean(v)) * np.float32(0.03)
            + np.float32(0.001) * np.float32(s + 1)).astype(np.float32)


def _cluster_values_at(cfg: EpisodeConfig, step: int
                       ) -> Dict[str, np.ndarray]:
    """Cluster tensor values after completing ``step`` (-1 = initial).
    Membership-independent by design: a shrink changes who OWNS a tensor,
    never its value — so the clean trajectory is one pure function."""
    vals = {f"t{k}": np.full((cfg.dim,), 0.1 * (k + 1), np.float32)
            for k in range(cfg.n_tensors)}
    for s in range(step + 1):
        vals = {n: _cluster_step_val(v, s) for n, v in vals.items()}
    return vals


def _serve_clean(cfg: EpisodeConfig
                 ) -> Tuple[Dict[int, int], Dict[str, List[int]]]:
    """Pure replay of the serve workload: per-tick digests of
    (session table, active KV caches) + the final per-session outputs."""
    table: Dict[str, dict] = {}
    kvs: Dict[str, np.ndarray] = {}
    digests: Dict[int, int] = {}
    for t in range(cfg.serve_ticks):
        _serve_sim_step(cfg, table, kvs, t)
        digests[t] = _serve_digest(table, kvs)
    return digests, {r: rec["outputs"] for r, rec in table.items()}


def _serve_sim_step(cfg: EpisodeConfig, table: Dict[str, dict],
                    kvs: Dict[str, np.ndarray], t: int) -> List[str]:
    """Advance the serve state ONE tick in place; returns the session ids
    that finished this tick.  str keys throughout — the table travels via
    manifest meta (JSON), and int keys would not round-trip."""
    for r in range(cfg.requests):
        if r * cfg.arrival_every == t:
            rid = str(r)
            table[rid] = {"outputs": [], "done": False, "arrived": t}
            kvs[rid] = np.full((cfg.dim,), 0.01 * (r + 1), np.float32)
    finished: List[str] = []
    for rid in sorted(kvs, key=int):
        kv = (kvs[rid] * np.float32(0.98)
              + np.float32(0.002) * np.float32(t + 1)).astype(np.float32)
        kvs[rid] = kv
        tok = int(float(np.abs(kv).sum(dtype=np.float32)) * 1000.0) % 9973
        table[rid]["outputs"].append(tok)
        if len(table[rid]["outputs"]) >= cfg.decode_len:
            table[rid]["done"] = True
            del kvs[rid]
            finished.append(rid)
    return finished


def _serve_digest(table: Dict[str, dict], kvs: Dict[str, np.ndarray]) -> int:
    d = zlib.crc32(json.dumps(table, sort_keys=True).encode())
    for rid in sorted(kvs, key=int):
        d = zlib.crc32(rid.encode(), d)
        d = _arr_crc(kvs[rid], d)
    return d


# ---------------------------------------------------------------------------
# workload engines
# ---------------------------------------------------------------------------

def _reincarnate(ctx, open_ctx: Callable):
    """Kill this incarnation (volatile tiers vanish, in-flight flushes are
    joined-and-discarded) and start the next one."""
    ctx.crash()
    ctx.close()
    return open_ctx()


def _train_objects(cfg, state: Dict[str, np.ndarray], i: int
                   ) -> Dict[str, Any]:
    return {**state, "meta": {"step": np.int64(i)}}


def _train_templates(cfg) -> Dict[str, Any]:
    return {**{n: np.zeros((cfg.dim, cfg.dim), np.float32)
               for n in _train_names(cfg)},
            "meta": {"step": np.zeros((), np.int64)}}


def _check_train_recovery(cfg, pool, ctx, ev, digests, *, final=False):
    """One recovery + the full invariant: lands exactly on the oracle's
    newest un-torn completed commit, bit-identical to the clean replay.
    Returns (state, resume_step) or None (expected cold start)."""
    tag = "final recovery" if final else "recovery"
    templates = _train_templates(cfg)
    expected = _oracle_pool_step(pool, set(templates), exact=True)
    got = _recover_seam(ctx.recovery, pool, templates, exact=True)
    if expected is None:
        if got is not None:
            ev.violations.append(
                f"{tag}: recovered step {got[1]} but every completed commit "
                "references torn payloads")
        return None
    if got is None:
        ev.violations.append(
            f"{tag}: cold start despite a completed commit at step "
            f"{expected}")
        return None
    objs, step, source = got
    ev.recoveries.append({"step": step, "source": source,
                          "expected": expected, "final": final})
    if step != expected:
        ev.violations.append(
            f"{tag}: landed on step {step}; newest completed un-torn commit "
            f"is step {expected}")
        return None
    if _named_crc(objs, _train_names(cfg)) != digests[expected]:
        ev.violations.append(
            f"{tag}: state at step {step} is not bit-identical to the clean "
            "run replayed to that step")
        return None
    if int(np.asarray(objs["meta"]["step"])) != expected:
        ev.violations.append(
            f"{tag}: committed meta.step != manifest step {expected}")
        return None
    state = {n: np.asarray(objs[n]) for n in _train_names(cfg)}
    return state, step + 1


def _run_train(cfg: EpisodeConfig, sched: FaultSchedule,
               pool_dir: str) -> _Events:
    ev = _Events()
    digests = _train_clean_digests(cfg)
    pool = FaultyPool(pool_dir, torn=sched.torn)
    inj = FaultInjector(sched, worker=0)

    def open_ctx():
        ctx = open_cxl0(pool, worker_id=0, schedule=cfg.mode,
                        n_shards=cfg.n_shards, fault_hook=inj.window)
        attach_emulator(ctx.tiers, TopologyEmulator(
            cfg.topology, seed=cfg.emu_seed, fault_model=sched.straggler))
        return attach_faults(ctx, inj)

    ctx = open_ctx()
    state = _train_init(cfg)
    i, initialized = 0, False
    for _ in range(MAX_INCARNATIONS):
        try:
            if not initialized:
                ctx.put(_train_objects(cfg, state, -1), step=-1)
                with ctx.commit(-1):
                    pass
                ctx.drain()
                initialized = True
            while i < cfg.steps:
                state = _train_advance(state, i)
                ctx.put(_train_objects(cfg, state, i), step=i)
                if (i + 1) % cfg.commit_every == 0:
                    with ctx.commit(i):
                        pass
                i += 1
            ctx.drain()
            break
        except InjectedCrash as e:
            ev.kills.append({"worker": e.worker, "op": e.op,
                             "index": e.index, "phase": e.phase})
            ctx = _reincarnate(ctx, open_ctx)
            rec = _check_train_recovery(cfg, pool, ctx, ev, digests)
            if rec is None:
                state, i, initialized = _train_init(cfg), 0, False
                ev.cold += 1
            else:
                state, i = rec
                initialized = True
    else:
        ev.violations.append("episode did not converge (livelock guard)")
    # the forced last word: crash the finished worker and require recovery
    # to land on the newest completed commit one more time
    ctx = _reincarnate(ctx, open_ctx)
    _check_train_recovery(cfg, pool, ctx, ev, digests, final=True)
    if _named_crc(state, _train_names(cfg)) != digests[cfg.steps - 1]:
        ev.violations.append(
            "final in-memory state diverged from the clean run")
    ctx.close()
    ev.torn = len(pool.injected)
    return ev


def _check_serve_recovery(cfg, pool, ctx, ev, digests, *, final=False):
    tag = "final recovery" if final else "recovery"
    expected = _oracle_latest_step(pool)
    kv_tpl = np.zeros((cfg.dim,), np.float32)
    got = _recover_latest_seam(ctx.recovery, pool, lambda name, entry: kv_tpl)
    if expected is None:
        if got is not None:
            ev.violations.append(
                f"{tag}: recovered tick {got[1]['step']} but every "
                "completed commit references torn payloads")
        return None
    if got is None:
        ev.violations.append(
            f"{tag}: cold start despite a completed commit at tick "
            f"{expected}")
        return None
    objs, m = got
    step = m["step"]
    ev.recoveries.append({"step": step, "source": "pool",
                          "expected": expected, "final": final})
    if step != expected:
        ev.violations.append(
            f"{tag}: landed on tick {step}; newest completed un-torn commit "
            f"is tick {expected}")
        return None
    if int(m["meta"].get("tick", -2)) != expected:
        ev.violations.append(
            f"{tag}: committed meta.tick != manifest tick {expected}")
        return None
    table = m["meta"]["table"]
    kvs = {name.split("/", 1)[1]: np.asarray(v) for name, v in objs.items()}
    if _serve_digest(table, kvs) != digests[expected]:
        ev.violations.append(
            f"{tag}: state at tick {step} is not bit-identical to the clean "
            "run replayed to that tick")
        return None
    return table, kvs, step + 1


def _run_serve(cfg: EpisodeConfig, sched: FaultSchedule,
               pool_dir: str) -> _Events:
    ev = _Events()
    digests, clean_outputs = _serve_clean(cfg)
    pool = FaultyPool(pool_dir, torn=sched.torn)
    inj = FaultInjector(sched, worker=0)

    def open_ctx():
        ctx = open_cxl0(pool, worker_id=0, schedule=cfg.mode,
                        n_shards=cfg.n_shards, fault_hook=inj.window)
        attach_emulator(ctx.tiers, TopologyEmulator(
            cfg.topology, seed=cfg.emu_seed, fault_model=sched.straggler))
        return attach_faults(ctx, inj)

    ctx = open_ctx()
    table: Dict[str, dict] = {}
    kvs: Dict[str, np.ndarray] = {}
    t = 0
    for _ in range(MAX_INCARNATIONS):
        try:
            while t < cfg.serve_ticks:
                finished = _serve_sim_step(cfg, table, kvs, t)
                ctx.put({f"kv/{rid}": kvs[rid]
                         for rid in sorted(kvs, key=int)}, step=t)
                for rid in finished:
                    ctx.tiers.ldiscard(f"kv/{rid}")
                if (t + 1) % cfg.commit_every == 0 or t == cfg.serve_ticks - 1:
                    with ctx.commit(t, meta={"tick": t, "table":
                                             json.loads(json.dumps(table))}):
                        pass
                t += 1
            ctx.drain()
            break
        except InjectedCrash as e:
            ev.kills.append({"worker": e.worker, "op": e.op,
                             "index": e.index, "phase": e.phase})
            ctx = _reincarnate(ctx, open_ctx)
            rec = _check_serve_recovery(cfg, pool, ctx, ev, digests)
            if rec is None:
                table, kvs, t = {}, {}, 0
                ev.cold += 1
            else:
                table, kvs, t = rec
                table = json.loads(json.dumps(table))
    else:
        ev.violations.append("episode did not converge (livelock guard)")
    ctx = _reincarnate(ctx, open_ctx)
    _check_serve_recovery(cfg, pool, ctx, ev, digests, final=True)
    outputs = {r: rec["outputs"] for r, rec in table.items()}
    if outputs != clean_outputs:
        ev.violations.append(
            "final served outputs diverged from the clean run")
    ctx.close()
    ev.torn = len(pool.injected)
    return ev


def _cluster_commit(cfg, pool, ctxs, injs, live, plan, vals, step):
    """The cluster's commit protocol for one step: every rank flushes its
    owned partitions (pre/mid-flush windows fire per rank), the leader —
    lowest live rank — performs the single elected completeOp, then every
    rank passes its post-completeOp window."""
    written: Dict[str, Any] = {}
    leader = min(live)
    for r in sorted(live):
        injs[r].window("pre_flush", step)
        first = True
        for n in sorted(k for k in vals if plan[k] == r):
            nsname = rank_ns(r, n)
            ctxs[r].tiers.lstore(nsname, vals[n])
            written[nsname] = ctxs[r].tiers.rflush(nsname)
            if first:
                injs[r].window("mid_flush", step)
                first = False
    injs[leader].call("completeOp", f"manifest@{step}",
                      pool.commit_manifest, step, written,
                      {"live": sorted(live)})
    for r in sorted(live):
        injs[r].window("post_completeOp", step)


def _cluster_recover(cfg, pool, ctxs, ev, live, old_plan, victim):
    """Recover the victim's partition through the real seam and check it
    against the oracle: expected source/step recomputed from raw peer
    staging + manifest files + the corruption ledger; expected bytes from
    the pure clean replay.  Returns the roll-back step, or None for an
    (expected) cold start."""
    vnames = sorted(n for n in old_plan if old_plan[n] == victim)
    templates = {rank_ns(victim, n): np.zeros((cfg.dim,), np.float32)
                 for n in vnames}
    pool_step = _oracle_pool_step(pool, set(templates), exact=False)
    peer_tag = None
    for p in sorted(live):
        tags = {(ctxs[p].tiers.staging.get(rank_ns(victim, n)) or
                 (None,))[0] for n in vnames}
        if None not in tags and len(tags) == 1:
            t = tags.pop()
            peer_tag = t if peer_tag is None else max(peer_tag, t)
    if peer_tag is not None and (pool_step is None or peer_tag > pool_step):
        expected, exp_src = peer_tag, "peer-staging"
    elif pool_step is not None:
        expected, exp_src = pool_step, "pool"
    else:
        expected, exp_src = None, None
    got = _recover_seam(RecoveryManager(pool), pool, templates,
                        peers=[ctxs[p].tiers for p in sorted(live)],
                        exact=False)
    if expected is None:
        if got is not None:
            ev.violations.append(
                f"cluster recovery: recovered step {got[1]} for w{victim} "
                "but nothing recoverable exists")
        return None
    if got is None:
        ev.violations.append(
            f"cluster recovery: cold start for w{victim} despite "
            f"recoverable state at step {expected} ({exp_src})")
        return None
    objs, step, source = got
    ev.recoveries.append({"victim": victim, "step": step, "source": source,
                          "expected": expected, "expected_source": exp_src})
    if (step, source) != (expected, exp_src):
        ev.violations.append(
            f"cluster recovery landed on ({step}, {source}); oracle says "
            f"({expected}, {exp_src})")
        return None
    want = _cluster_values_at(cfg, expected)
    for n in vnames:
        if _arr_crc(objs[rank_ns(victim, n)]) != _arr_crc(want[n]):
            ev.violations.append(
                f"cluster recovery: {n}@{expected} is not bit-identical to "
                "the clean run replayed to that step")
            return None
    return expected


def _run_cluster(cfg: EpisodeConfig, sched: FaultSchedule,
                 pool_dir: str) -> _Events:
    ev = _Events()
    names = _cluster_names(cfg)
    pool = FaultyPool(pool_dir, torn=sched.torn)
    injs = {r: FaultInjector(sched, worker=r) for r in range(cfg.world)}
    live = sorted(injs)
    ctxs: Dict[int, Any] = {}

    def open_rank(r):
        ctx = open_cxl0(pool, worker_id=r, schedule="sync",
                        fault_hook=injs[r].window)
        attach_emulator(ctx.tiers, TopologyEmulator(
            cfg.topology, seed=cfg.emu_seed + r,
            fault_model=sched.straggler))
        return attach_faults(ctx, injs[r], wrap_pool=False)

    for r in live:
        ctxs[r] = open_rank(r)
    plan = partition_plan(names, live)
    s = 0
    pending_commit: Optional[int] = -1      # the initial / re-mesh commit
    for _ in range(MAX_INCARNATIONS):
        try:
            if pending_commit is not None:
                _cluster_commit(cfg, pool, ctxs, injs, live, plan,
                                _cluster_values_at(cfg, pending_commit),
                                pending_commit)
                pending_commit = None
            while s < cfg.steps:
                vals = _cluster_values_at(cfg, s)
                for r in sorted(live):
                    sib = (ring_sibling(r, live)
                           if cfg.replicate and len(live) > 1 else None)
                    for n in sorted(k for k in names if plan[k] == r):
                        nsname = rank_ns(r, n)
                        ctxs[r].tiers.lstore(nsname, vals[n])
                        if sib is not None:
                            ctxs[r].tiers.rstore(nsname, ctxs[sib].tiers,
                                                 tag=s)
                if (s + 1) % cfg.commit_every == 0 or s == cfg.steps - 1:
                    _cluster_commit(cfg, pool, ctxs, injs, live, plan,
                                    vals, s)
                s += 1
            break
        except InjectedCrash as e:
            ev.kills.append({"worker": e.worker, "op": e.op,
                             "index": e.index, "phase": e.phase})
            victim = e.worker
            live.remove(victim)
            ctxs[victim].crash()
            ctxs[victim].close()
            ctxs.pop(victim)
            if not live:
                ev.violations.append("every worker dead — episode undefined")
                break
            old_plan = plan
            roll = _cluster_recover(cfg, pool, ctxs, ev, live, old_plan,
                                    victim)
            plan = partition_plan(names, live)
            if roll is None:
                # nothing recoverable for the victim's partition: the whole
                # (shrunk) cluster cold-restarts — every survivor's stale
                # staging is wiped with it
                for r in live:
                    ctxs[r].crash()
                    ctxs[r].close()
                    ctxs[r] = open_rank(r)
                s, pending_commit = 0, -1
                ev.cold += 1
            else:
                # survivors re-mesh at the recovered step: the adopted
                # partition re-enters under its NEW owner's namespace via a
                # GPF commit at the roll-back step
                s, pending_commit = roll + 1, roll
    else:
        ev.violations.append("episode did not converge (livelock guard)")
    # the forced last word: wipe EVERY survivor (staging included) — the
    # full cluster state must come back from the pool alone
    for r in sorted(live):
        ctxs[r].crash()
        ctxs[r].close()
        ctxs[r] = open_rank(r)
    templates = {rank_ns(plan[n], n): np.zeros((cfg.dim,), np.float32)
                 for n in names}
    expected = _oracle_pool_step(pool, set(templates), exact=False)
    got = _recover_seam(RecoveryManager(pool), pool, templates, exact=False)
    if expected is None:
        if got is not None:
            ev.violations.append(
                f"final recovery: recovered step {got[1]} but every "
                "completed commit references torn payloads")
    elif got is None:
        ev.violations.append(
            f"final recovery: cold start despite a completed commit at "
            f"step {expected}")
    else:
        objs, step, _source = got
        ev.recoveries.append({"step": step, "source": _source,
                              "expected": expected, "final": True})
        if step != expected:
            ev.violations.append(
                f"final recovery landed on step {step}; newest completed "
                f"un-torn commit is step {expected}")
        else:
            want = _cluster_values_at(cfg, expected)
            for n in names:
                if _arr_crc(objs[rank_ns(plan[n], n)]) != _arr_crc(want[n]):
                    ev.violations.append(
                        f"final recovery: {n}@{expected} is not "
                        "bit-identical to the clean run")
                    break
    for r in sorted(live):
        ctxs[r].close()
    ev.torn = len(pool.injected)
    return ev


def _scale_join(cfg, pool, ctxs, injs, live, open_rank, s):
    """The three-phase grow-by-repartition (see ``repro.scale.grow``) in
    fuzz form — stage, commit, adopt — with a JOIN_POINTS window at every
    phase boundary.  Mutates ``live``/``ctxs`` in place; the caller's
    crash handling covers every interleaving: before the adoption commit
    the joiner owns nothing (a death anywhere just abandons the grow),
    after it the joiner is ordinary membership."""
    names = _cluster_names(cfg)
    joiner = cfg.world
    q = s - 1
    old_plan = partition_plan(names, sorted(live))
    new_plan = partition_plan(names, sorted(live) + [joiner])
    moves = join_moves(old_plan, new_plan, joiner)
    vals_q = _cluster_values_at(cfg, q)
    ctxs[joiner] = open_rank(joiner)
    # staged: each old rank RStores the entries the new partition re-homes
    # to the joiner into the joiner's volatile staging buffer at tag q
    for r in sorted(live):
        for n in sorted(k for k, src in moves.items() if src == r):
            ctxs[r].tiers.rstore(rank_ns(r, n), ctxs[joiner].tiers, tag=q)
        injs[r].window("join_staged", q)
    injs[joiner].window("join_staged", q)
    # committed: the OLD membership elects one more manifest at q — until
    # this lands, the grow simply never happened
    _cluster_commit(cfg, pool, ctxs, injs, live, old_plan, vals_q, q)
    for r in sorted(live):
        injs[r].window("join_committed", q)
    injs[joiner].window("join_committed", q)
    # adopted: the joiner installs its partition and the NEW membership
    # elects its re-meshed base manifest at q
    live.append(joiner)
    live.sort()
    for n in sorted(moves):
        ctxs[joiner].tiers.lstore(rank_ns(joiner, n), vals_q[n])
    _cluster_commit(cfg, pool, ctxs, injs, live, new_plan, vals_q, q)
    for r in sorted(live):
        injs[r].window("join_adopted", q)


def _run_scale(cfg: EpisodeConfig, sched: FaultSchedule,
               pool_dir: str) -> _Events:
    """The cluster workload plus ONE planned grow at ``cfg.grow_at``: rank
    ``world`` joins the live generation mid-run through the three-phase
    protocol.  The invariant is unchanged — the clean trajectory is
    membership-independent (``_cluster_values_at``), so recovery from a
    kill at ANY join window must land on a completed commit bit-identical
    to the clean replay, under whichever membership that commit carries
    (pre-manifest: the grow never happened; post-manifest: the joiner's
    partition is derivable from the pool alone)."""
    ev = _Events()
    names = _cluster_names(cfg)
    pool = FaultyPool(pool_dir, torn=sched.torn)
    joiner = cfg.world
    injs = {r: FaultInjector(sched, worker=r)
            for r in range(cfg.world + 1)}
    live = sorted(range(cfg.world))
    ctxs: Dict[int, Any] = {}

    def open_rank(r):
        ctx = open_cxl0(pool, worker_id=r, schedule="sync",
                        fault_hook=injs[r].window)
        attach_emulator(ctx.tiers, TopologyEmulator(
            cfg.topology, seed=cfg.emu_seed + r,
            fault_model=sched.straggler))
        return attach_faults(ctx, injs[r], wrap_pool=False)

    for r in live:
        ctxs[r] = open_rank(r)
    s = 0
    grown = False
    pending_commit: Optional[int] = -1      # the initial / re-mesh commit
    for _ in range(MAX_INCARNATIONS):
        try:
            if pending_commit is not None:
                _cluster_commit(cfg, pool, ctxs, injs, live,
                                partition_plan(names, live),
                                _cluster_values_at(cfg, pending_commit),
                                pending_commit)
                pending_commit = None
            while s < cfg.steps:
                if not grown and s == cfg.grow_at:
                    grown = True        # at-most-once, like the live protocol
                    _scale_join(cfg, pool, ctxs, injs, live, open_rank, s)
                plan = partition_plan(names, live)
                vals = _cluster_values_at(cfg, s)
                for r in sorted(live):
                    sib = (ring_sibling(r, live)
                           if cfg.replicate and len(live) > 1 else None)
                    for n in sorted(k for k in names if plan[k] == r):
                        nsname = rank_ns(r, n)
                        ctxs[r].tiers.lstore(nsname, vals[n])
                        if sib is not None:
                            ctxs[r].tiers.rstore(nsname, ctxs[sib].tiers,
                                                 tag=s)
                if (s + 1) % cfg.commit_every == 0 or s == cfg.steps - 1:
                    _cluster_commit(cfg, pool, ctxs, injs, live, plan,
                                    vals, s)
                s += 1
            break
        except InjectedCrash as e:
            ev.kills.append({"worker": e.worker, "op": e.op,
                             "index": e.index, "phase": e.phase})
            victim = e.worker
            # a joiner that never adopted owns nothing: drop it and
            # abandon the half-done grow, whoever the victim was
            if joiner in ctxs and joiner not in live:
                ctxs[joiner].crash()
                ctxs[joiner].close()
                ctxs.pop(joiner)
                if victim == joiner:
                    continue
            old_plan = partition_plan(names, live)
            live.remove(victim)
            ctxs[victim].crash()
            ctxs[victim].close()
            ctxs.pop(victim)
            if not live:
                ev.violations.append("every worker dead — episode undefined")
                break
            roll = _cluster_recover(cfg, pool, ctxs, ev, live, old_plan,
                                    victim)
            if roll is None:
                for r in live:
                    ctxs[r].crash()
                    ctxs[r].close()
                    ctxs[r] = open_rank(r)
                s, pending_commit = 0, -1
                ev.cold += 1
            else:
                s, pending_commit = roll + 1, roll
    else:
        ev.violations.append("episode did not converge (livelock guard)")
    # the forced last word: wipe EVERY survivor (staging included) — the
    # final membership's full state must come back from the pool alone
    for r in sorted(live):
        ctxs[r].crash()
        ctxs[r].close()
        ctxs[r] = open_rank(r)
    if live:
        plan = partition_plan(names, live)
        templates = {rank_ns(plan[n], n): np.zeros((cfg.dim,), np.float32)
                     for n in names}
        expected = _oracle_pool_step(pool, set(templates), exact=False)
        got = _recover_seam(RecoveryManager(pool), pool, templates,
                            exact=False)
        if expected is None:
            if got is not None:
                ev.violations.append(
                    f"final recovery: recovered step {got[1]} but every "
                    "completed commit references torn payloads")
        elif got is None:
            ev.violations.append(
                f"final recovery: cold start despite a completed commit at "
                f"step {expected}")
        else:
            objs, step, _source = got
            ev.recoveries.append({"step": step, "source": _source,
                                  "expected": expected, "final": True})
            if step != expected:
                ev.violations.append(
                    f"final recovery landed on step {step}; newest completed "
                    f"un-torn commit is step {expected}")
            else:
                want = _cluster_values_at(cfg, expected)
                for n in names:
                    if _arr_crc(objs[rank_ns(plan[n], n)]) != \
                            _arr_crc(want[n]):
                        ev.violations.append(
                            f"final recovery: {n}@{expected} is not "
                            "bit-identical to the clean run")
                        break
    for r in sorted(live):
        ctxs[r].close()
    ev.torn = len(pool.injected)
    return ev


_ENGINES = {"train": _run_train, "serve": _run_serve,
            "cluster": _run_cluster, "scale": _run_scale}


def run_episode(cfg: EpisodeConfig, sched: FaultSchedule,
                workdir: str) -> EpisodeResult:
    """One episode in a fresh pool under ``workdir``.  Engine exceptions
    are violations too — a fault schedule must never be able to crash the
    HARNESS, only the workers inside it."""
    os.makedirs(workdir, exist_ok=True)
    pool_dir = os.path.join(workdir, "pool")
    if os.path.exists(pool_dir):
        shutil.rmtree(pool_dir)
    try:
        ev = _ENGINES[cfg.workload](cfg, sched, pool_dir)
    except Exception as e:                      # noqa: BLE001
        ev = _Events()
        ev.violations.append(
            f"episode raised {type(e).__name__}: {e}")
    return EpisodeResult(
        workload=cfg.workload, topology=cfg.topology,
        ok=not ev.violations, violations=ev.violations,
        kills_fired=ev.kills, recoveries=ev.recoveries,
        cold_restarts=ev.cold, torn_writes=ev.torn,
        config=cfg.to_dict(), schedule=sched.to_dict())


# ---------------------------------------------------------------------------
# episode generation (pure function of the seed path)
# ---------------------------------------------------------------------------

def _op_estimate(cfg: EpisodeConfig) -> Dict[str, int]:
    """Rough per-worker op-count ceilings used to draw kill indices; an
    overshoot is a vacuous kill (a clean episode), which is fine — the
    distribution just thins toward the tail."""
    if cfg.workload == "train":
        n_obj = cfg.n_tensors + 1
        commits = cfg.steps // cfg.commit_every + 2
        est = {"lstore": (cfg.steps + 1) * n_obj, "rstore": 2, "mstore": 2,
               "rflush": commits * n_obj, "completeOp": commits}
    elif cfg.workload == "serve":
        active = cfg.decode_len // cfg.arrival_every + 1
        commits = cfg.serve_ticks // cfg.commit_every + 2
        est = {"lstore": cfg.serve_ticks * active, "rstore": 2, "mstore": 2,
               "rflush": commits * active, "completeOp": commits}
    else:                                   # cluster / scale
        per_rank = max(1, cfg.n_tensors // cfg.world)
        commits = cfg.steps // cfg.commit_every + 2
        if cfg.workload == "scale":
            commits += 2                    # the join's two extra elections
        est = {"lstore": (cfg.steps + commits) * per_rank,
               "rstore": cfg.steps * per_rank if cfg.replicate else 2,
               "mstore": 2, "rflush": commits * per_rank,
               "completeOp": commits}
    est["any"] = sum(est.values())
    return est


def make_episode(seed_path: Sequence[int], workload: str, topology: str
                 ) -> Tuple[EpisodeConfig, FaultSchedule]:
    """Draw one episode — config knobs + fault schedule — as a pure
    function of the seed path (``np.random.default_rng`` sequence seed)."""
    rng = np.random.default_rng(list(seed_path))
    cfg = EpisodeConfig(workload=workload, topology=topology)
    if workload in ("cluster", "scale"):
        cfg.mode = "sync"
        cfg.steps, cfg.commit_every, cfg.n_tensors = 8, 2, 4
        cfg.replicate = bool(rng.integers(0, 2))
        if workload == "scale":
            cfg.grow_at = int(rng.integers(1, cfg.steps - 1))
    else:
        cfg.mode = str(rng.choice(COMMIT_MODES))
    cfg.emu_seed = int(rng.integers(0, 2 ** 31 - 1))
    est = _op_estimate(cfg)
    n_kills = int(rng.choice([0, 1, 1, 1, 1, 2]
                             if workload in ("train", "serve")
                             else [0, 1, 1, 1, 1]))
    kills = []
    for _ in range(n_kills):
        if workload == "cluster":
            worker = int(rng.integers(0, cfg.world))
        elif workload == "scale":       # the joiner (rank `world`) included
            worker = int(rng.integers(0, cfg.world + 1))
        else:
            worker = 0
        if rng.random() < 0.25:
            points = (KILL_POINTS + JOIN_POINTS if workload == "scale"
                      else KILL_POINTS)
            point = str(rng.choice(points))
            # join windows only ever fire at the pre-join step q — pin the
            # kill there so a drawn join point is never vacuous
            at = (cfg.grow_at - 1 if point in JOIN_POINTS
                  else int(rng.integers(0, cfg.steps)))
            kills.append(KillSpec(worker=worker, point=point, at_step=at))
        else:
            op = str(rng.choice(("any",) + PRIMITIVES))
            kills.append(KillSpec(
                worker=worker, op=op,
                index=int(rng.integers(0, max(1, est[op]))),
                phase=str(rng.choice(("before", "after")))))
    torn = None
    if rng.random() < 0.5:
        torn = TornSpec(rate=float(rng.uniform(0.03, 0.3)),
                        salt=int(rng.integers(0, 2 ** 31 - 1)))
    straggler = None
    if rng.random() < 0.5:
        straggler = StragglerSpec(rate=float(rng.uniform(0.05, 0.3)),
                                  max_mult=float(rng.uniform(2.0, 8.0)),
                                  salt=int(rng.integers(0, 2 ** 31 - 1)))
    return cfg, FaultSchedule(kills=tuple(kills), torn=torn,
                              straggler=straggler)


# ---------------------------------------------------------------------------
# shrinking + reproducers
# ---------------------------------------------------------------------------

def _reductions(sched: FaultSchedule) -> List[FaultSchedule]:
    out = []
    if sched.straggler is not None:
        out.append(dataclasses.replace(sched, straggler=None))
    if sched.torn is not None:
        out.append(dataclasses.replace(sched, torn=None))
    for i in range(len(sched.kills)):
        out.append(dataclasses.replace(
            sched, kills=sched.kills[:i] + sched.kills[i + 1:]))
    return out


def _still_violates(cfg: EpisodeConfig, sched: FaultSchedule) -> bool:
    with tempfile.TemporaryDirectory(prefix="fuzz-shrink-") as d:
        return bool(run_episode(cfg, sched, d).violations)


def shrink_schedule(cfg: EpisodeConfig,
                    sched: FaultSchedule) -> FaultSchedule:
    """Greedy component removal to a fixpoint: drop the straggler model,
    the torn model, then each kill — keep any reduction that still
    violates.  Small schedules (<= 2 kills + 2 models) converge in a
    handful of re-runs."""
    changed = True
    while changed:
        changed = False
        for cand in _reductions(sched):
            if _still_violates(cfg, cand):
                sched = cand
                changed = True
                break
    return sched


def dump_reproducer(workdir: str, seed_path: Sequence[int],
                    cfg: EpisodeConfig, sched: FaultSchedule,
                    res: EpisodeResult, *, shrink: bool = True) -> str:
    """Write the minimal-reproducer JSON for a violated episode."""
    if shrink:
        try:
            sched = shrink_schedule(cfg, sched)
        except Exception:                       # noqa: BLE001
            pass          # an unshrunk reproducer still reproduces
    doc = {"kind": "cxl0-fuzz-reproducer", "version": 1,
           "seed_path": list(seed_path), "workload": cfg.workload,
           "topology": cfg.topology, "config": cfg.to_dict(),
           "schedule": sched.to_dict(), "violations": res.violations}
    path = os.path.join(
        workdir, "repro_{}_{}.json".format(
            cfg.workload, "-".join(str(p) for p in seed_path)))
    with open(path, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
    return path


def replay_reproducer(doc_or_path, workdir: Optional[str] = None
                      ) -> EpisodeResult:
    """Re-run a reproducer document (or its file path) and return the
    episode result — same seed, same schedule, same outcome."""
    if isinstance(doc_or_path, str):
        with open(doc_or_path) as f:
            doc = json.load(f)
    else:
        doc = doc_or_path
    cfg = EpisodeConfig.from_dict(doc["config"])
    sched = FaultSchedule.from_dict(doc["schedule"])
    if workdir is not None:
        return run_episode(cfg, sched, workdir)
    with tempfile.TemporaryDirectory(prefix="fuzz-replay-") as d:
        return run_episode(cfg, sched, d)


# ---------------------------------------------------------------------------
# the suite
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class SuiteSummary:
    episodes: int = 0
    violations: int = 0
    kills_fired: int = 0
    torn_writes: int = 0
    recoveries: int = 0
    cold_starts: int = 0
    cells: List[dict] = dataclasses.field(default_factory=list)
    reproducers: List[str] = dataclasses.field(default_factory=list)
    log_path: str = ""


def run_fuzz_suite(workdir: str, *, episodes: int = 10, seed: int = 0,
                   topologies: Optional[Sequence[str]] = None,
                   workloads: Sequence[str] = WORKLOADS,
                   shrink: bool = True) -> SuiteSummary:
    """episodes x workloads x topologies, one fresh pool each.  Appends
    every episode result to ``fuzz_episodes.jsonl``; violated episodes are
    shrunk and dumped as reproducer JSONs next to it."""
    topologies = list(topologies or TOPOLOGIES)
    os.makedirs(workdir, exist_ok=True)
    summary = SuiteSummary(log_path=os.path.join(workdir,
                                                 "fuzz_episodes.jsonl"))
    with open(summary.log_path, "w") as log:
        for wi, workload in enumerate(WORKLOADS):
            if workload not in workloads:
                continue
            for ti, topo in enumerate(TOPOLOGIES):
                if topo not in topologies:
                    continue
                cell = {"workload": workload, "topology": topo,
                        "episodes": 0, "violations": 0, "kills": 0,
                        "torn": 0, "recoveries": 0, "cold_starts": 0}
                for ep in range(episodes):
                    seed_path = [seed, ep, wi, ti]
                    cfg, sched = make_episode(seed_path, workload, topo)
                    epdir = os.path.join(
                        workdir, f"ep_{workload}_{ti}_{ep}")
                    res = run_episode(cfg, sched, epdir)
                    log.write(json.dumps(
                        {"seed_path": seed_path, **res.to_json()}) + "\n")
                    cell["episodes"] += 1
                    cell["violations"] += len(res.violations)
                    cell["kills"] += len(res.kills_fired)
                    cell["torn"] += res.torn_writes
                    cell["recoveries"] += len(res.recoveries)
                    cell["cold_starts"] += res.cold_restarts
                    if res.violations:
                        summary.reproducers.append(dump_reproducer(
                            workdir, seed_path, cfg, sched, res,
                            shrink=shrink))
                    shutil.rmtree(epdir, ignore_errors=True)
                summary.cells.append(cell)
                summary.episodes += cell["episodes"]
                summary.violations += cell["violations"]
                summary.kills_fired += cell["kills"]
                summary.torn_writes += cell["torn"]
                summary.recoveries += cell["recoveries"]
                summary.cold_starts += cell["cold_starts"]
    return summary


def corpus_cluster_cell(point: str, replicate: bool, workdir: str, *,
                        steps: int = 6, commit_every: int = 2,
                        kill_step: Optional[int] = None) -> EpisodeResult:
    """One cell of the legacy 6-cell cluster kill matrix as a PINNED fuzz
    schedule: kill rank 1 at ``point`` of the commit window for
    ``kill_step`` (default: the second commit).  tests/test_cluster.py
    parametrizes over the full matrix — the old hand-enumerated suite is
    now a named corpus of the fuzzer."""
    if kill_step is None:
        kill_step = 2 * commit_every - 1
    cfg = EpisodeConfig(workload="cluster", topology="cxl11-direct",
                        mode="sync", steps=steps,
                        commit_every=commit_every, n_tensors=3, dim=8,
                        world=3, replicate=replicate)
    sched = FaultSchedule(kills=(
        KillSpec(worker=1, point=point, at_step=kill_step),))
    return run_episode(cfg, sched, workdir)
