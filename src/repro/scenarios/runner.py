"""Scenario runner: kill a worker inside the commit window, restart it,
and check the durable-linearizability contract end to end.

One TRAIN scenario (``run_scenario``):

1. **kill phase** — launch ``repro.scenarios.worker`` with a kill point;
   the process ``os._exit``s mid-commit (exit code KILL_EXIT);
2. **inspect** — read the pool's manifests: these are the commits that
   COMPLETED before the death (a manifest exists iff its atomic rename
   finished);
3. **restart phase** — relaunch the same worker without the kill; it must
   recover and report the step it resumed from;
4. **verdict** — the resumed step must be the NEWEST completed commit (so
   recovery restored a completed commit, never torn state), and the final
   params digest must equal an uninterrupted reference run (crash +
   recover + replay is bit-identical — prefix consistency).

One SERVE scenario (``run_serve_scenario``) applies the same protocol to
the continuous-batching serving worker (``repro.scenarios.serve_worker``):
kill inside a SESSION commit, restart, and require that the restarted
worker (a) resumed from the newest completed session commit and (b)
finished the trace with every session's output tokens BIT-IDENTICAL to an
uninterrupted reference run — committed sessions replay exactly, whether
restored from their committed KV cache or re-decoded from the prompt.

One CLUSTER scenario (``repro.scenarios.cluster.run_cluster_scenario``)
kills one of N>=3 REAL worker processes sharing one pool inside the
commit window; the survivors must shrink-remesh, recover the victim's
state partition from the expected source (a sibling's cross-process
RStore-staged copy when newer than the pool, else the newest cluster
manifest) and finish bit-identically to a planned shrink at the same
step.

One SCALE suite (``repro.scenarios.scale``) grows a live 3-rank cluster
by a joining rank (killing the joiner at each join-phase boundary in the
kill cells — recovery must fall back to the old membership
bit-identically), drains a fleet engine under load, and checks the
cost-priced autoscaler beats every fixed fleet size under the bursty
trace (decision log written to the workdir).

``run_suite`` / ``run_serve_suite`` / ``run_cluster_suite`` run all the
kill points; the CLI prints one line per scenario:

    PYTHONPATH=src python -m repro.scenarios.runner [--suite all]
        [--workdir DIR] [--steps 8] [--commit-every 2]
        [--mode sharded-async] [--shards 4]
        [--kill-points pre_flush,mid_flush,post_completeOp]
        [--cluster-sources peer,pool]
"""
from __future__ import annotations

import dataclasses
import json
import os
import subprocess
import sys
import tempfile
from typing import Dict, List, Optional

from repro.dsm.flit_runtime import KILL_POINTS
from repro.dsm.pool import DSMPool
from repro.scenarios.worker import KILL_EXIT


@dataclasses.dataclass
class ScenarioResult:
    kill_point: str
    killed: bool                         # kill phase exited with KILL_EXIT
    completed_steps_at_kill: List[int]   # manifest steps durable at death
    resumed_from: Optional[int]          # step the restart recovered at
    recovery_source: Optional[str]       # "pool" / "peer-staging"
    final_digest: Optional[int]
    reference_digest: Optional[int]
    detail: str = ""

    @property
    def recovered_completed_commit(self) -> bool:
        return (self.resumed_from is not None
                and self.resumed_from in self.completed_steps_at_kill)

    @property
    def ok(self) -> bool:
        return (self.killed
                and self.recovered_completed_commit
                and self.resumed_from == max(self.completed_steps_at_kill)
                and self.final_digest is not None
                and self.final_digest == self.reference_digest)


def _mesh_devices(mesh: str) -> int:
    out = 1
    for d in mesh.lower().split("x"):
        out *= int(d)
    return out


def _worker_env(n_devices: int = 0) -> Dict[str, str]:
    src = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", ".."))
    env = dict(os.environ)
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    if n_devices:
        # the worker builds a real Mesh on CPU: force the host platform to
        # expose one device per mesh cell BEFORE its jax backend comes up
        # (an inherited force wins — CI's mesh lane sets it job-wide)
        flags = env.get("XLA_FLAGS", "")
        if "xla_force_host_platform_device_count" not in flags:
            env["XLA_FLAGS"] = ((flags + " ") if flags else "") + \
                f"--xla_force_host_platform_device_count={n_devices}"
    return env


def _run_worker(pool: str, *, steps: int, commit_every: int, mode: str,
                shards: int, retention: int, kill_point: str, kill_step: int,
                model: str, timeout: int, mesh: str = "",
                topology: str = "",
                decision_log: str = "") -> subprocess.CompletedProcess:
    cmd = [sys.executable, "-m", "repro.scenarios.worker",
           "--pool", pool, "--steps", str(steps),
           "--commit-every", str(commit_every), "--mode", mode,
           "--shards", str(shards), "--retention", str(retention),
           "--kill-point", kill_point, "--kill-step", str(kill_step),
           "--model", model]
    if mesh:
        cmd += ["--mesh", mesh]
    if topology:
        cmd += ["--topology", topology]
    if decision_log:
        cmd += ["--decision-log", decision_log]
    return subprocess.run(cmd,
                          env=_worker_env(_mesh_devices(mesh) if mesh else 0),
                          capture_output=True, text=True, timeout=timeout)


def _result_json(proc: subprocess.CompletedProcess) -> dict:
    return json.loads(proc.stdout.strip().splitlines()[-1])


def reference_digest(workdir: str, *, steps: int = 8, commit_every: int = 2,
                     mode: str = "sharded-async", shards: int = 4,
                     retention: int = 0, model: str = "toy",
                     mesh: str = "", topology: str = "",
                     timeout: int = 600) -> int:
    """Digest of an uninterrupted run with the same configuration."""
    pool = os.path.join(workdir, "pool_reference")
    proc = _run_worker(pool, steps=steps,
                       commit_every=commit_every, mode=mode, shards=shards,
                       retention=retention, kill_point="none", kill_step=0,
                       model=model, mesh=mesh, topology=topology,
                       decision_log=(pool + "_decisions.jsonl"
                                     if topology else ""),
                       timeout=timeout)
    if proc.returncode != 0:
        raise RuntimeError(f"reference run failed: {proc.stderr[-2000:]}")
    return _result_json(proc)["digest"]


def run_scenario(kill_point: str, workdir: str, *, steps: int = 8,
                 commit_every: int = 2, mode: str = "sharded-async",
                 shards: int = 4, retention: int = 0,
                 kill_step: Optional[int] = None, model: str = "toy",
                 ref_digest: Optional[int] = None,
                 mesh: str = "", topology: str = "",
                 timeout: int = 600) -> ScenarioResult:
    # a real raise, not an assert: under ``python -O`` an assert silently
    # accepts a bogus kill point and the scenario "passes" vacuously
    if kill_point not in KILL_POINTS:
        raise ValueError(f"unknown kill point {kill_point!r}; "
                         f"expected one of {KILL_POINTS}")
    if kill_step is None:
        # the second commit point: at least one real commit precedes the kill
        kill_step = 2 * commit_every - 1
    pool = os.path.join(workdir, f"pool_{kill_point}")

    # 1. kill phase
    p1 = _run_worker(pool, steps=steps, commit_every=commit_every, mode=mode,
                     shards=shards, retention=retention,
                     kill_point=kill_point, kill_step=kill_step, model=model,
                     mesh=mesh, topology=topology,
                     decision_log=(pool + "_decisions_kill.jsonl"
                                   if topology else ""),
                     timeout=timeout)
    killed = p1.returncode == KILL_EXIT
    if not killed:
        return ScenarioResult(kill_point, False, [], None, None, None,
                              ref_digest,
                              detail=f"kill phase rc={p1.returncode}: "
                                     f"{p1.stderr[-1000:]}")

    # 2. what was durably committed at the moment of death?
    completed = sorted(m["step"] for m in DSMPool(pool).manifests_desc())

    # 3. restart phase: same worker, no kill, resume from the pool
    p2 = _run_worker(pool, steps=steps, commit_every=commit_every, mode=mode,
                     shards=shards, retention=retention, kill_point="none",
                     kill_step=0, model=model,
                     mesh=mesh, topology=topology,
                     decision_log=(pool + "_decisions_restart.jsonl"
                                   if topology else ""),
                     timeout=timeout)
    if p2.returncode != 0:
        return ScenarioResult(kill_point, True, completed, None, None, None,
                              ref_digest,
                              detail=f"restart rc={p2.returncode}: "
                                     f"{p2.stderr[-1000:]}")
    res = _result_json(p2)

    # 4. verdict inputs
    if ref_digest is None:
        ref_digest = reference_digest(
            workdir, steps=steps, commit_every=commit_every, mode=mode,
            shards=shards, retention=retention, model=model,
            mesh=mesh, topology=topology, timeout=timeout)
    return ScenarioResult(
        kill_point, True, completed, res["resumed_from"],
        (res["recoveries"] or [None])[0], res["digest"], ref_digest)


def run_suite(workdir: Optional[str] = None, **kwargs) -> List[ScenarioResult]:
    """All three kill points, sharing one reference run."""
    workdir = workdir or tempfile.mkdtemp(prefix="scenarios_")
    ref = reference_digest(workdir, **{k: v for k, v in kwargs.items()
                                       if k != "kill_step"})
    return [run_scenario(p, workdir, ref_digest=ref, **kwargs)
            for p in KILL_POINTS]


# ---------------------------------------------------------------------------
# Serve-worker scenarios
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ServeScenarioResult:
    kill_point: str
    killed: bool
    completed_ticks_at_kill: List[int]   # session-commit ticks durable at death
    resumed_from: Optional[int]
    resumed_sessions: int
    recovered_done: int                  # sessions already finished at death
    outputs_match: bool                  # restart outputs == reference, exact
    detail: str = ""

    @property
    def recovered_completed_commit(self) -> bool:
        return (self.resumed_from is not None
                and self.resumed_from in self.completed_ticks_at_kill)

    @property
    def ok(self) -> bool:
        return (self.killed
                and self.recovered_completed_commit
                and self.resumed_from == max(self.completed_ticks_at_kill)
                and self.outputs_match)


def _run_serve_worker(pool: str, *, requests: int, slots: int,
                      commit_every: int, restore_mode: str,
                      kill_point: str, kill_step: int,
                      timeout: int) -> subprocess.CompletedProcess:
    cmd = [sys.executable, "-m", "repro.scenarios.serve_worker",
           "--pool", pool, "--requests", str(requests),
           "--slots", str(slots), "--commit-every", str(commit_every),
           "--restore-mode", restore_mode,
           "--kill-point", kill_point, "--kill-step", str(kill_step)]
    return subprocess.run(cmd, env=_worker_env(), capture_output=True,
                          text=True, timeout=timeout)


def serve_reference(workdir: str, *, requests: int = 10, slots: int = 4,
                    commit_every: int = 3, restore_mode: str = "cache",
                    timeout: int = 600) -> dict:
    """Uninterrupted serve run: per-session outputs every kill scenario
    must reproduce exactly."""
    proc = _run_serve_worker(os.path.join(workdir, "serve_reference"),
                             requests=requests, slots=slots,
                             commit_every=commit_every,
                             restore_mode=restore_mode,
                             kill_point="none", kill_step=0,
                             timeout=timeout)
    if proc.returncode != 0:
        raise RuntimeError(f"serve reference failed: {proc.stderr[-2000:]}")
    return _result_json(proc)["outputs"]


def run_serve_scenario(kill_point: str, workdir: str, *, requests: int = 10,
                       slots: int = 4, commit_every: int = 3,
                       restore_mode: str = "cache",
                       kill_step: int = 6,
                       ref_outputs: Optional[dict] = None,
                       timeout: int = 600) -> ServeScenarioResult:
    if kill_point not in KILL_POINTS:
        raise ValueError(f"unknown kill point {kill_point!r}; "
                         f"expected one of {KILL_POINTS}")
    pool = os.path.join(workdir, f"serve_{kill_point}_{restore_mode}")

    # 1. kill phase: die inside the session-commit window
    p1 = _run_serve_worker(pool, requests=requests, slots=slots,
                           commit_every=commit_every,
                           restore_mode=restore_mode,
                           kill_point=kill_point, kill_step=kill_step,
                           timeout=timeout)
    killed = p1.returncode == KILL_EXIT
    if not killed:
        return ServeScenarioResult(kill_point, False, [], None, 0, 0, False,
                                   detail=f"kill phase rc={p1.returncode}: "
                                          f"{p1.stderr[-1000:]}")

    # 2. session commits durable at the moment of death
    completed = sorted(m["step"] for m in DSMPool(pool).manifests_desc())

    # 3. restart: recover + finish the trace
    p2 = _run_serve_worker(pool, requests=requests, slots=slots,
                           commit_every=commit_every,
                           restore_mode=restore_mode,
                           kill_point="none", kill_step=0, timeout=timeout)
    if p2.returncode != 0:
        return ServeScenarioResult(kill_point, True, completed, None, 0, 0,
                                   False,
                                   detail=f"restart rc={p2.returncode}: "
                                          f"{p2.stderr[-1000:]}")
    res = _result_json(p2)

    # 4. verdict: every session's tokens bit-identical to the reference
    if ref_outputs is None:
        ref_outputs = serve_reference(workdir, requests=requests,
                                      slots=slots,
                                      commit_every=commit_every,
                                      restore_mode=restore_mode,
                                      timeout=timeout)
    return ServeScenarioResult(
        kill_point, True, completed, res["resumed_from"],
        res["resumed_sessions"], res["recovered_done"],
        res["outputs"] == ref_outputs)


def run_serve_suite(workdir: Optional[str] = None, **kwargs
                    ) -> List[ServeScenarioResult]:
    """All three kill points against one shared serve reference run."""
    workdir = workdir or tempfile.mkdtemp(prefix="scenarios_")
    ref = serve_reference(workdir, **{k: v for k, v in kwargs.items()
                                      if k != "kill_step"})
    return [run_serve_scenario(p, workdir, ref_outputs=ref, **kwargs)
            for p in KILL_POINTS]


# ---------------------------------------------------------------------------
# Fleet migration scenarios (2 engines, one pool, kill mid-migration)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class FleetScenarioResult:
    """One kill-during-migration cell: the source engine dies right
    after a migration phase; the restarted fleet must re-establish the
    exactly-one-owner invariant and finish with outputs BIT-IDENTICAL to
    a single-engine reference run of the same trace.  ``staging`` says
    whether the target's host buffer survived ("kept") or was wiped
    ("wiped" — adoption must take the pool arm of staging-or-pool)."""
    kill_point: str
    staging: str
    killed: bool
    outputs_match: bool
    resumed_sessions: int
    migrations_after_restart: int
    detail: str = ""

    @property
    def ok(self) -> bool:
        return self.killed and self.outputs_match


def _run_fleet_worker(pool: str, *, requests: int, slots: int,
                      commit_every: int, engines: int, migrate_at: int,
                      mig_kill_point: str, wipe_staging: int,
                      timeout: int) -> subprocess.CompletedProcess:
    cmd = [sys.executable, "-m", "repro.scenarios.serve_worker",
           "--pool", pool, "--requests", str(requests),
           "--slots", str(slots), "--commit-every", str(commit_every),
           "--engines", str(engines), "--migrate-at", str(migrate_at),
           "--mig-kill-point", mig_kill_point,
           "--wipe-staging", str(wipe_staging)]
    return subprocess.run(cmd, env=_worker_env(), capture_output=True,
                          text=True, timeout=timeout)


def run_fleet_scenario(mig_kill_point: str, workdir: str, *,
                       requests: int = 6, slots: int = 2,
                       commit_every: int = 2, engines: int = 2,
                       migrate_at: int = 4, wipe_staging: bool = False,
                       ref_outputs: Optional[dict] = None,
                       timeout: int = 600) -> FleetScenarioResult:
    from repro.serve.fleet import MIGRATION_POINTS
    if mig_kill_point not in MIGRATION_POINTS:
        raise ValueError(f"unknown migration point {mig_kill_point!r}; "
                         f"expected one of {MIGRATION_POINTS}")
    staging = "wiped" if wipe_staging else "kept"
    pool = os.path.join(workdir, f"fleet_{mig_kill_point}_{staging}")

    # 1. kill phase: the fleet process dies right after the phase
    p1 = _run_fleet_worker(pool, requests=requests, slots=slots,
                           commit_every=commit_every, engines=engines,
                           migrate_at=migrate_at,
                           mig_kill_point=mig_kill_point,
                           wipe_staging=-1, timeout=timeout)
    if p1.returncode != KILL_EXIT:
        return FleetScenarioResult(mig_kill_point, staging, False, False,
                                   0, 0,
                                   detail=f"kill phase rc={p1.returncode}"
                                          f": {p1.stderr[-1000:]}")

    # 2. restart: recover all engines, complete the handoff, finish.
    #    The wiped variant loses the target's host buffer with the crash
    #    (the CXL0 cache-loss model): adoption must read the pool.
    p2 = _run_fleet_worker(pool, requests=requests, slots=slots,
                           commit_every=commit_every, engines=engines,
                           migrate_at=0, mig_kill_point="none",
                           wipe_staging=2 if wipe_staging else -1,
                           timeout=timeout)
    if p2.returncode != 0:
        return FleetScenarioResult(mig_kill_point, staging, True, False,
                                   0, 0,
                                   detail=f"restart rc={p2.returncode}: "
                                          f"{p2.stderr[-1000:]}")
    res = _result_json(p2)

    # 3. verdict: bit-identical to a single-engine run of the same trace
    if ref_outputs is None:
        ref_outputs = fleet_reference(workdir, requests=requests,
                                      slots=slots,
                                      commit_every=commit_every,
                                      timeout=timeout)
    return FleetScenarioResult(
        mig_kill_point, staging, True, res["outputs"] == ref_outputs,
        res["resumed_sessions"], res.get("migrations", 0))


def fleet_reference(workdir: str, *, requests: int = 6, slots: int = 2,
                    commit_every: int = 2, timeout: int = 600) -> dict:
    """Single-engine uninterrupted run of the fleet trace — migration
    and fleet routing must not change a single output token."""
    proc = _run_serve_worker(os.path.join(workdir, "fleet_reference"),
                             requests=requests, slots=slots,
                             commit_every=commit_every,
                             restore_mode="cache",
                             kill_point="none", kill_step=0,
                             timeout=timeout)
    if proc.returncode != 0:
        raise RuntimeError(f"fleet reference failed: "
                           f"{proc.stderr[-2000:]}")
    return _result_json(proc)["outputs"]


def run_fleet_suite(workdir: Optional[str] = None, *,
                    points: Optional[List[str]] = None,
                    **kwargs) -> List[FleetScenarioResult]:
    """Kill at every migration phase x (staging kept, staging wiped),
    against one shared single-engine reference."""
    from repro.serve.fleet import MIGRATION_POINTS
    workdir = workdir or tempfile.mkdtemp(prefix="scenarios_")
    ref = fleet_reference(workdir,
                          **{k: v for k, v in kwargs.items()
                             if k in ("requests", "slots", "commit_every",
                                      "timeout")})
    out = []
    for p in (points or MIGRATION_POINTS):
        for wipe in (False, True):
            out.append(run_fleet_scenario(p, workdir, wipe_staging=wipe,
                                          ref_outputs=ref, **kwargs))
    return out


def main(argv=None) -> int:
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--suite", default="train",
                    choices=["train", "serve", "cluster", "scale", "fuzz",
                             "all"])
    ap.add_argument("--workdir", default=None)
    ap.add_argument("--steps", type=int, default=8)
    ap.add_argument("--commit-every", type=int, default=2)
    ap.add_argument("--mode", default="sharded-async")
    ap.add_argument("--shards", type=int, default=4)
    ap.add_argument("--mesh", default="",
                    help="train suite: run every worker on a real Mesh "
                         "(e.g. 2x4) with device-local sharded commits; "
                         "the runner forces the matching XLA host device "
                         "count into the worker env, prices shard counts "
                         "under --topology (default cxl20-switched-pool) "
                         "and writes the priced-decision JSONL logs next "
                         "to each pool in --workdir")
    ap.add_argument("--model", default="toy", choices=["toy", "smoke"])
    ap.add_argument("--requests", type=int, default=10,
                    help="serve suite: trace length")
    ap.add_argument("--slots", type=int, default=4,
                    help="serve suite: decode slots")
    ap.add_argument("--restore-mode", default="cache",
                    choices=["cache", "replay"])
    ap.add_argument("--engines", type=int, default=1,
                    help="serve suite: >= 2 switches to the fleet "
                         "migration kill cells (kill the source engine "
                         "after each migration phase; the restarted "
                         "fleet must finish bit-identically, with the "
                         "target adopting from staging-or-pool)")
    def _world(v):
        if int(v) < 3:
            raise argparse.ArgumentTypeError(
                "--world must be >= 3 (the shrunk cluster still needs a "
                "staging sibling for every rank)")
        return int(v)
    ap.add_argument("--world", type=_world, default=3,
                    help="cluster suite: worker processes (N >= 3)")
    ap.add_argument("--kill-points", default=",".join(KILL_POINTS),
                    help="cluster suite: comma-separated subset of the "
                         "kill points (reduced matrix for smoke jobs)")
    ap.add_argument("--cluster-sources", default="peer,pool",
                    help="cluster suite: recovery sources to exercise "
                         "(peer = sibling staging newer than the pool, "
                         "pool = replication off)")
    ap.add_argument("--scale-points", default="none,join_staged,"
                    "join_committed,join_adopted",
                    help="scale suite: grow cells to run ('none' = the "
                         "no-kill grow; join_* kill the joiner at that "
                         "phase boundary)")
    ap.add_argument("--episodes", type=int, default=10,
                    help="fuzz suite: episodes per (workload, topology)")
    ap.add_argument("--seed", type=int, default=0,
                    help="fuzz suite: base seed of every episode draw")
    ap.add_argument("--topology", default="all",
                    help="fuzz suite: one topology preset, or 'all'")
    ap.add_argument("--fuzz-workloads", default="train,serve,cluster",
                    help="fuzz suite: comma-separated workload subset")
    args = ap.parse_args(argv)
    workdir = args.workdir or tempfile.mkdtemp(prefix="scenarios_")
    failed = 0

    def _suite_guard(name, fn):
        """A crashed suite is a FAILED suite, and the remaining suites
        still run — no assert-and-continue, no masked exit code."""
        nonlocal failed
        try:
            fn()
        except Exception as e:                  # noqa: BLE001
            failed += 1
            print(f"runner_error,{name},{type(e).__name__}: {e}")

    def _train_suite():
        nonlocal failed
        # mesh lane: shard count 0 = auto, so the placement policy prices
        # it from the real per-device bytes (and logs the decision);
        # --topology doubles as the pricing preset when it names one
        topology = ""
        shards = args.shards
        if args.mesh:
            topology = (args.topology if args.topology not in ("", "all")
                        else "cxl20-switched-pool")
            shards = 0
        for r in run_suite(workdir, steps=args.steps,
                           commit_every=args.commit_every, mode=args.mode,
                           shards=shards, model=args.model,
                           mesh=args.mesh, topology=topology):
            status = "OK" if r.ok else "FAIL"
            failed += not r.ok
            print(f"scenario,{r.kill_point},{status},"
                  f"completed={r.completed_steps_at_kill},"
                  f"resumed={r.resumed_from},source={r.recovery_source},"
                  f"digest_match={r.final_digest == r.reference_digest}"
                  + (f",detail={r.detail}" if r.detail else ""))

    def _serve_suite():
        nonlocal failed
        if args.engines >= 2:
            for r in run_fleet_suite(workdir, engines=args.engines):
                status = "OK" if r.ok else "FAIL"
                failed += not r.ok
                print(f"fleet_scenario,{r.kill_point},{r.staging},"
                      f"{status},"
                      f"resumed_sessions={r.resumed_sessions},"
                      f"outputs_bit_identical={r.outputs_match}"
                      + (f",detail={r.detail}" if r.detail else ""))
            return
        for r in run_serve_suite(workdir, requests=args.requests,
                                 slots=args.slots,
                                 restore_mode=args.restore_mode):
            status = "OK" if r.ok else "FAIL"
            failed += not r.ok
            print(f"serve_scenario,{r.kill_point},{status},"
                  f"completed={r.completed_ticks_at_kill},"
                  f"resumed={r.resumed_from},"
                  f"resumed_sessions={r.resumed_sessions},"
                  f"recovered_done={r.recovered_done},"
                  f"outputs_bit_identical={r.outputs_match}"
                  + (f",detail={r.detail}" if r.detail else ""))

    def _cluster_suite():
        nonlocal failed
        from repro.scenarios.cluster import run_cluster_suite
        points = [p for p in args.kill_points.split(",") if p]
        srcs = [s for s in args.cluster_sources.split(",") if s]
        for r in run_cluster_suite(workdir, points=points, sources=srcs,
                                   world=args.world,
                                   # survivors must reach at least one
                                   # all-reduce AFTER the kill at commit
                                   # step 2C-1 to detect the death
                                   steps=max(args.steps,
                                             2 * args.commit_every + 1),
                                   commit_every=args.commit_every):
            status = "OK" if r.ok else "FAIL"
            failed += not r.ok
            print(f"cluster_scenario,{r.kill_point},"
                  f"{'peer' if r.replicate else 'pool'},{status},"
                  f"completed={r.completed_steps_at_kill},"
                  f"resumed={r.resumed_from},source={r.recovery_source},"
                  f"expected=({r.expected_resume},{r.expected_source}),"
                  f"digest_match={r.digests == r.reference_digests}"
                  + (f",detail={r.detail}" if r.detail else ""))

    def _scale_suite():
        nonlocal failed
        from repro.scenarios.scale import (run_autoscale_cell,
                                           run_fleet_scale_cell,
                                           run_grow_suite)
        points = [p for p in args.scale_points.split(",") if p]
        for r in run_grow_suite(workdir, points=points):
            status = "OK" if r.ok else "FAIL"
            failed += not r.ok
            print(f"grow_scenario,{r.kill_point},{status},"
                  f"lives={sorted(set(r.lives))},"
                  f"sources={sorted(set(map(str, r.sources)))},"
                  f"digest_match={r.digests == r.reference_digests}"
                  + (f",detail={r.detail}" if r.detail else ""))
        fr = run_fleet_scale_cell(workdir)
        failed += not fr.ok
        print(f"fleet_scale,{'OK' if fr.ok else 'FAIL'},"
              f"grew={fr.grew},drained={fr.drained},"
              f"migrations={fr.migrations},"
              f"outputs_bit_identical={fr.outputs_match}"
              + (f",detail={fr.detail}" if fr.detail else ""))
        ar = run_autoscale_cell(workdir)
        failed += not ar.ok
        print(f"autoscale,{'OK' if ar.ok else 'FAIL'},"
              f"auto_cost={ar.auto_cost_ns:.3g},"
              f"best_fixed(n={ar.best_fixed_n})={ar.best_fixed_cost_ns:.3g},"
              f"p99={ar.auto_p99}vs{ar.best_fixed_p99},"
              f"lost={ar.lost_sessions},decisions={ar.decisions},"
              f"grows={ar.grows},shrinks={ar.shrinks},"
              f"log={ar.decision_log}")

    def _fuzz_suite():
        nonlocal failed
        from repro.dsm.emu import PRESETS
        from repro.scenarios.fuzz import run_fuzz_suite
        topos = (sorted(PRESETS) if args.topology == "all"
                 else [args.topology])
        workloads = [w for w in args.fuzz_workloads.split(",") if w]
        s = run_fuzz_suite(os.path.join(workdir, "fuzz"),
                           episodes=args.episodes, seed=args.seed,
                           topologies=topos, workloads=workloads)
        for cell in s.cells:
            status = "OK" if not cell["violations"] else "FAIL"
            print(f"fuzz,{cell['workload']},{cell['topology']},{status},"
                  f"episodes={cell['episodes']},kills={cell['kills']},"
                  f"torn={cell['torn']},recoveries={cell['recoveries']},"
                  f"cold_starts={cell['cold_starts']},"
                  f"violations={cell['violations']}")
        failed += s.violations
        for p in s.reproducers:
            print(f"fuzz_reproducer,{p}")
        print(f"fuzz_summary,episodes={s.episodes},"
              f"violations={s.violations},kills={s.kills_fired},"
              f"torn={s.torn_writes},recoveries={s.recoveries},"
              f"log={s.log_path}")

    if args.suite in ("train", "all"):
        _suite_guard("train", _train_suite)
    if args.suite in ("serve", "all"):
        _suite_guard("serve", _serve_suite)
    if args.suite in ("cluster", "all"):
        _suite_guard("cluster", _cluster_suite)
    if args.suite in ("scale", "all"):
        _suite_guard("scale", _scale_suite)
    if args.suite in ("fuzz", "all"):
        _suite_guard("fuzz", _fuzz_suite)
    print(f"runner,{'FAIL' if failed else 'OK'},failed={failed}")
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
