"""Scale scenario suite: elastic autoscaling, end to end.

Three cell families, each an independent verdict:

* **grow cells** (``run_grow_scenario``) — N=3 REAL worker processes
  plus a JOINER that enters the live generation at ``join_at`` through
  the three-phase join protocol (``scale.grow``).  The no-kill cell
  requires the grown cluster (live = 4 ranks, gen+1) to finish with
  per-tensor digests BIT-IDENTICAL to a straight 3-rank run — growth
  must not perturb a single value.  The kill cells ``os._exit`` the
  joiner at each ``JOIN_POINTS`` boundary; the orchestrator wipes the
  joiner's volatile staging buffer and posts the unplanned shrink, and
  the survivors must fall back to the OLD membership and still finish
  bit-identically to the straight reference — a torn join never
  happened, whatever phase it died in (the joiner's entries are
  derivable from the gen+1 manifest's partition meta alone);

* **fleet drain cell** (``run_fleet_scale_cell``) — an in-process
  FleetController grows by one engine mid-trace, then drains an engine
  with RUNNING sessions (live-migrating them to peers, re-routing its
  queue); every output token must equal a fixed-size fleet of the same
  trace — elasticity is invisible in the token streams;

* **autoscaler cell** (``run_autoscale_cell``) — the cost-priced
  controller under the deterministic bursty trace (``scale.traffic``)
  must beat EVERY fixed fleet size on priced cost with zero lost
  sessions, and its decision log (each decision carrying all priced
  alternatives) is written to ``autoscale_decisions.jsonl`` in the
  workdir — the artifact the CI scale-smoke job uploads.
"""
from __future__ import annotations

import dataclasses
import os
import tempfile
from typing import Dict, List, Optional, Sequence

from repro.dsm.cluster import ControlPlane, FileStagingArea
from repro.dsm.faults import JOIN_POINTS
from repro.scenarios.cluster import _last_json, merge_digests, spawn_worker
from repro.scenarios.worker import KILL_EXIT


@dataclasses.dataclass
class GrowScenarioResult:
    kill_point: str                       # "none" or a JOIN_POINTS entry
    killed: bool                          # joiner exited with KILL_EXIT
    lives: List[tuple]                    # final live sets reported
    gens: List[int]
    sources: List[Optional[str]]
    digests: Dict[str, int]
    reference_digests: Dict[str, int]
    n_tensors: int
    detail: str = ""

    @property
    def expected_live(self) -> tuple:
        # a killed joiner must be shrunk back OUT; an unkilled one stays
        return (0, 1, 2) if self.kill_point != "none" else (0, 1, 2, 3)

    @property
    def ok(self) -> bool:
        return ((self.kill_point == "none" or self.killed)
                and set(self.lives) == {self.expected_live}
                and len(self.digests) == self.n_tensors
                and self.digests == self.reference_digests)


def straight_reference(workdir: str, *, world: int = 3, steps: int = 8,
                       commit_every: int = 2, tensors: int = 8,
                       timeout: float = 300.0) -> Dict[str, int]:
    """An uninterrupted ``world``-rank run with NO membership change —
    the reference every grow cell must match bit-identically (state
    updates are membership-independent, so a grown, a failed-grow and a
    never-grown cluster all converge to the same values)."""
    pool = os.path.join(workdir, "scale_reference")
    procs = {r: spawn_worker(pool, r, world, steps=steps,
                             commit_every=commit_every, replicate=True,
                             tensors=tensors, timeout=timeout)
             for r in range(world)}
    results = []
    for r, p in procs.items():
        out, err = p.communicate(timeout=timeout)
        if p.returncode != 0:
            raise RuntimeError(f"reference rank {r} rc={p.returncode}: "
                               f"{err[-2000:]}")
        results.append(_last_json(out))
    return merge_digests(results)


def run_grow_scenario(kill_point: str, workdir: str, *, world: int = 3,
                      join_at: int = 4, steps: int = 8,
                      commit_every: int = 2, tensors: int = 8,
                      ref_digests: Optional[Dict[str, int]] = None,
                      timeout: float = 300.0) -> GrowScenarioResult:
    """One grow cell: post the planned grow, launch ``world`` old ranks
    + the joiner (killed at ``kill_point`` unless "none"), orchestrate
    the environment's side of a joiner death (wipe its volatile staging
    buffer, post the crash shrink), and compare final digests against
    the straight reference."""
    if kill_point != "none" and kill_point not in JOIN_POINTS:
        raise ValueError(f"unknown join point {kill_point!r}; "
                         f"expected 'none' or one of {JOIN_POINTS}")
    joiner = world                        # first rank id outside the world
    pool = os.path.join(workdir, f"scale_grow_{kill_point}")
    control = ControlPlane(os.path.join(pool, "control"))
    control.post_change("grow", joiner, planned=True, at_step=join_at)

    procs = {r: spawn_worker(pool, r, world, steps=steps,
                             commit_every=commit_every, replicate=True,
                             tensors=tensors, timeout=timeout)
             for r in range(world)}
    procs[joiner] = spawn_worker(
        pool, joiner, world, steps=steps, commit_every=commit_every,
        replicate=True, tensors=tensors, joiner=True, join_at=join_at,
        kill_point=kill_point if kill_point != "none" else "none",
        kill_step=0, timeout=timeout)

    killed = False
    survivors = list(range(world))
    if kill_point != "none":
        # the joiner must die at the phase boundary; then the
        # environment plays its part: volatile staging vanishes, the
        # membership change goes out on the control plane
        try:
            procs[joiner].communicate(timeout=timeout)
        except Exception:
            _terminate(procs)
            return GrowScenarioResult(kill_point, False, [], [], [], {},
                                      ref_digests or {}, tensors,
                                      detail="joiner never died")
        if procs[joiner].returncode != KILL_EXIT:
            _terminate(procs)
            return GrowScenarioResult(
                kill_point, False, [], [], [], {}, ref_digests or {},
                tensors, detail=f"joiner rc={procs[joiner].returncode}")
        killed = True
        FileStagingArea(os.path.join(pool, "staging")).wipe(joiner)
        control.post_change("shrink", joiner)
    else:
        survivors = survivors + [joiner]

    results = []
    try:
        for r in survivors:
            out, err = procs[r].communicate(timeout=timeout)
            if procs[r].returncode != 0:
                _terminate(procs)
                return GrowScenarioResult(
                    kill_point, killed, [], [], [], {},
                    ref_digests or {}, tensors,
                    detail=f"rank {r} rc={procs[r].returncode}: "
                           f"{err[-1500:]}")
            results.append(_last_json(out))
    finally:
        _terminate(procs)

    if ref_digests is None:
        ref_digests = straight_reference(
            workdir, world=world, steps=steps, commit_every=commit_every,
            tensors=tensors, timeout=timeout)
    try:
        digests = merge_digests(results)
    except ValueError as e:
        return GrowScenarioResult(kill_point, killed, [], [], [], {},
                                  ref_digests, tensors, detail=str(e))
    return GrowScenarioResult(
        kill_point, killed,
        [tuple(r["live"]) for r in results],
        [r["gen"] for r in results],
        [r["source"] for r in results],
        digests, ref_digests, tensors)


def _terminate(procs):
    for p in procs.values():
        if p.poll() is None:
            p.kill()
    for p in procs.values():
        try:
            p.communicate(timeout=10)
        except Exception:
            pass


def run_grow_suite(workdir: Optional[str] = None,
                   points: Sequence[str] = ("none",) + JOIN_POINTS,
                   **kwargs) -> List[GrowScenarioResult]:
    """The grow matrix: the no-kill cell + a kill at every join phase,
    all against ONE straight reference run."""
    workdir = workdir or tempfile.mkdtemp(prefix="scenarios_scale_")
    ref = straight_reference(workdir, **kwargs)
    return [run_grow_scenario(p, workdir, ref_digests=ref, **kwargs)
            for p in points]


# ---------------------------------------------------------------------------
# In-process cells: fleet drain-under-load + autoscaler decision log
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class FleetScaleResult:
    grew: bool
    drained: bool
    migrations: int
    outputs_match: bool                   # == fixed-size fleet, exact
    n_outputs: int
    detail: str = ""

    @property
    def ok(self) -> bool:
        return (self.grew and self.drained and self.migrations >= 1
                and self.outputs_match)


def run_fleet_scale_cell(workdir: str, *, requests: int = 8,
                         n_slots: int = 2, t_max: int = 32
                         ) -> FleetScaleResult:
    """Grow a live fleet by one engine mid-trace, then drain an engine
    that still has RUNNING sessions.  Every session's tokens must equal
    a fixed 2-engine fleet of the same trace — add/remove engines moves
    sessions, never tokens."""
    from repro.serve.fleet import FleetController
    from repro.serve.trace import synthetic_trace

    reqs = synthetic_trace(requests, seed=0, prompt_lens=(4, 8),
                           new_tokens=(2, 6), vocab_size=64)
    fc = FleetController(pool_path=os.path.join(workdir, "fleet_pool"),
                         n_engines=2, n_slots=n_slots, t_max=t_max)
    try:
        fc.submit(reqs[: requests // 2])
        for _ in range(3):
            fc.tick(rebalance=False)
        new_eid = fc.add_engine()
        fc.submit(reqs[requests // 2:])
        for _ in range(2):
            fc.tick(rebalance=False)
        # drain an engine with running sessions if any has one (the new
        # engine took fresh admissions, so it usually does)
        busy = [i for i, e in sorted(fc.engines.items())
                if e.sched.running]
        victim = busy[-1] if busy else new_eid
        had_running = bool(fc.engines[victim].sched.running)
        fc.remove_engine(victim)
        res = fc.run()
    finally:
        fc.close()

    ref = FleetController(pool_path=os.path.join(workdir, "fleet_ref"),
                          n_engines=2, n_slots=n_slots, t_max=t_max)
    try:
        ref_res = ref.run(reqs, rebalance=False)
    finally:
        ref.close()
    return FleetScaleResult(
        grew=new_eid == 3, drained=had_running,
        migrations=res.migrations,
        outputs_match=(res.outputs == ref_res.outputs
                       and len(res.outputs) == requests),
        n_outputs=len(res.outputs))


@dataclasses.dataclass
class AutoscaleCellResult:
    auto_cost_ns: float
    best_fixed_cost_ns: float
    best_fixed_n: int
    auto_p99: float
    best_fixed_p99: float
    lost_sessions: int
    decisions: int
    grows: int
    shrinks: int
    decision_log: str

    @property
    def ok(self) -> bool:
        return (self.auto_cost_ns < self.best_fixed_cost_ns
                and self.lost_sessions == 0
                and self.decisions > 0 and self.grows > 0
                and os.path.exists(self.decision_log))


def run_autoscale_cell(workdir: str, *, seed: int = 3,
                       topology: str = "cxl20-switched-pool"
                       ) -> AutoscaleCellResult:
    """The controller under the bursty diurnal trace vs every fixed
    fleet size, on one topology preset.  Writes the full scale-decision
    log (JSONL, one priced decision per line) into the workdir."""
    from repro.scale.autoscaler import (Autoscaler, AutoscaleConfig,
                                        simulate_autoscale, simulate_fixed)
    from repro.scale.traffic import TrafficConfig, traffic_trace

    trace = traffic_trace(TrafficConfig(seed=seed))
    cfg = AutoscaleConfig(topology=topology)
    scaler = Autoscaler(cfg)
    auto = simulate_autoscale(trace, cfg, scaler=scaler)
    fixed = {n: simulate_fixed(trace, n, cfg)
             for n in range(1, cfg.max_engines + 1)}
    best_n = min(fixed, key=lambda n: fixed[n].priced_cost_ns)
    log = os.path.join(workdir, "autoscale_decisions.jsonl")
    scaler.dump_decisions(log)
    return AutoscaleCellResult(
        auto_cost_ns=auto.priced_cost_ns,
        best_fixed_cost_ns=fixed[best_n].priced_cost_ns,
        best_fixed_n=best_n,
        auto_p99=auto.p99_admission_ticks,
        best_fixed_p99=fixed[best_n].p99_admission_ticks,
        lost_sessions=auto.lost_sessions,
        decisions=auto.decisions, grows=auto.grows,
        shrinks=auto.shrinks, decision_log=log)
