"""The killable SERVE worker: one continuous-batching serving process
over a DSM pool.

Serving twin of ``repro.scenarios.worker``: runs the durable serving
engine (``repro.serve``) on a deterministic synthetic request trace and,
when ``--kill-point`` is set, dies with ``os._exit(KILL_EXIT)`` the first
time the session committer's fault hook fires at that point on or after
``--kill-step`` — a real process death inside the session-commit window,
cutting cache flushes off wherever they happen to be.

On restart (same command, ``--kill-point none``) the engine recovers the
newest completed session commit from the pool: finished sessions come
back as results, running sessions resume from their committed KV cache
(or replay from the prompt with ``--restore-mode replay``).  The JSON
result on stdout reports every session's output tokens plus a CRC digest
so the runner can compare kill+restart against an uninterrupted
reference run — the durable-serving contract is that they are
bit-identical.

    PYTHONPATH=src python -m repro.scenarios.serve_worker \
        --pool /tmp/sp --kill-point mid_flush --kill-step 6
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import zlib

from repro.dsm.flit_runtime import COMMIT_MODES, KILL_POINTS
from repro.scenarios.worker import KILL_EXIT


def outputs_digest(outputs: dict) -> int:
    """CRC32 over the canonicalized per-session outputs — the
    cross-process equality check."""
    doc = json.dumps({k: outputs[k] for k in sorted(outputs)},
                     separators=(",", ":"))
    return zlib.crc32(doc.encode())


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--pool", required=True)
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--requests", type=int, default=10)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", default="4,8,16,24")
    ap.add_argument("--commit-every", type=int, default=3)
    ap.add_argument("--commit-mode", default="sync", choices=COMMIT_MODES)
    ap.add_argument("--restore-mode", default="cache",
                    choices=["cache", "replay"])
    ap.add_argument("--kill-point", default="none",
                    choices=("none",) + KILL_POINTS)
    ap.add_argument("--kill-step", type=int, default=6,
                    help="fire at the first --kill-point hook whose commit "
                         "tick is >= this")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--result", default="")
    args = ap.parse_args(argv)

    hook = None
    if args.kill_point != "none":
        def hook(point, step):
            if point == args.kill_point and step >= args.kill_step:
                sys.stderr.write(f"KILL {point} tick={step}\n")
                sys.stderr.flush()
                os._exit(KILL_EXIT)

    # imports after arg parsing: a bad flag should not pay jax startup
    from repro.dsm.api import CXL0Config
    from repro.serve.engine import build_serve_engine
    from repro.serve.trace import synthetic_trace, trace_t_max

    new_tokens = tuple(int(t) for t in args.new_tokens.split(","))
    # the trace is a pure function of the CLI args: the restarted process
    # regenerates the exact request stream the killed one was serving
    trace = synthetic_trace(args.requests, seed=args.seed,
                            prompt_lens=(args.prompt_len,),
                            new_tokens=new_tokens, vocab_size=1)
    engine, cfg = build_serve_engine(
        args.arch, smoke=True, n_slots=args.slots,
        t_max=trace_t_max(trace),
        dsm=CXL0Config(path=args.pool, schedule=args.commit_mode,
                       retention=2, fault_hook=hook),
        commit_every=args.commit_every,
        restore_mode=args.restore_mode, seed=args.seed)
    trace = synthetic_trace(args.requests, seed=args.seed,
                            prompt_lens=(args.prompt_len,),
                            new_tokens=new_tokens,
                            vocab_size=cfg.vocab_size)

    resumed_from = engine.resume()
    recovered_done = len(engine.results)      # finished before the kill
    res = engine.run(trace)
    engine.close()

    result = {
        "ok": True,
        "outputs": res.outputs,
        "digest": outputs_digest(res.outputs),
        "resumed_from": resumed_from,
        "resumed_sessions": res.resumed_sessions,
        "recovered_done": recovered_done,
        "commits": res.commits,
        "decode_ticks": res.decode_ticks,
        "prefills": res.prefills,
    }
    line = json.dumps(result)
    if args.result:
        with open(args.result, "w") as f:
            f.write(line)
    print(line)
    return 0


if __name__ == "__main__":
    sys.exit(main())
