"""The killable SERVE worker: one continuous-batching serving process
over a DSM pool.

Serving twin of ``repro.scenarios.worker``: runs the durable serving
engine (``repro.serve``) on a deterministic synthetic request trace and,
when ``--kill-point`` is set, dies with ``os._exit(KILL_EXIT)`` the first
time the session committer's fault hook fires at that point on or after
``--kill-step`` — a real process death inside the session-commit window,
cutting cache flushes off wherever they happen to be.

On restart (same command, ``--kill-point none``) the engine recovers the
newest completed session commit from the pool: finished sessions come
back as results, running sessions resume from their committed KV cache
(or replay from the prompt with ``--restore-mode replay``).  The JSON
result on stdout reports every session's output tokens plus a CRC digest
so the runner can compare kill+restart against an uninterrupted
reference run — the durable-serving contract is that they are
bit-identical.

Fleet mode (``--engines N``, N >= 2) runs the FleetController over the
same pool: cost-routed admission, optional forced live migration
(``--migrate-at TICK`` moves the oldest running session from engine 1 to
engine 2) and migration-phase kill points (``--mig-kill-point`` dies at
one of serve.fleet.MIGRATION_POINTS).  ``--wipe-staging R`` simulates
the loss of engine R's host staging buffer before recovery, forcing the
pool arm of the staging-or-pool adoption.  ``--engine-id`` +
``--trace-slice`` instead run ONE namespaced engine of a fleet pool over
a slice of the trace — the benchmark's parallel-speedup cell.

    PYTHONPATH=src python -m repro.scenarios.serve_worker \
        --pool /tmp/sp --kill-point mid_flush --kill-step 6
    PYTHONPATH=src python -m repro.scenarios.serve_worker \
        --pool /tmp/fp --engines 2 --migrate-at 4 --mig-kill-point mig_commit
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
import zlib

from repro.dsm.flit_runtime import COMMIT_MODES, KILL_POINTS
from repro.scenarios.worker import KILL_EXIT
from repro.serve.fleet import MIGRATION_POINTS


def outputs_digest(outputs: dict) -> int:
    """CRC32 over the canonicalized per-session outputs — the
    cross-process equality check."""
    doc = json.dumps({k: outputs[k] for k in sorted(outputs)},
                     separators=(",", ":"))
    return zlib.crc32(doc.encode())


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--pool", required=True)
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--requests", type=int, default=10)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", default="4,8,16,24")
    ap.add_argument("--commit-every", type=int, default=3)
    ap.add_argument("--commit-mode", default="sync", choices=COMMIT_MODES)
    ap.add_argument("--restore-mode", default="cache",
                    choices=["cache", "replay"])
    ap.add_argument("--kill-point", default="none",
                    choices=("none",) + KILL_POINTS)
    ap.add_argument("--kill-step", type=int, default=6,
                    help="fire at the first --kill-point hook whose commit "
                         "tick is >= this")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--result", default="")
    # fleet mode -------------------------------------------------------------
    ap.add_argument("--engines", type=int, default=1,
                    help=">= 2 runs the FleetController over the pool")
    ap.add_argument("--migrate-at", type=int, default=0,
                    help="fleet: once engine 1 reaches this tick, live-"
                         "migrate its oldest running session to engine 2 "
                         "(0 = no forced migration)")
    ap.add_argument("--mig-kill-point", default="none",
                    choices=("none",) + MIGRATION_POINTS,
                    help="fleet: os._exit after this migration phase")
    ap.add_argument("--wipe-staging", type=int, default=-1,
                    help="wipe engine R's staging buffer before recovery "
                         "(simulated host-buffer loss; -1 = keep)")
    ap.add_argument("--prefix-reuse", action="store_true",
                    help="content-addressed cross-engine prefix blocks")
    ap.add_argument("--rebalance", action="store_true",
                    help="fleet: cost-approved automatic rebalancing")
    # single-engine-of-a-fleet mode (the parallel bench cell) ----------------
    ap.add_argument("--engine-id", type=int, default=0,
                    help="run ONE namespaced engine of a fleet pool")
    ap.add_argument("--trace-slice", default="",
                    help="serve only trace[a:b] (python slice 'a:b')")
    ap.add_argument("--n-prompts", type=int, default=0,
                    help="shared-prefix workload: draw only this many "
                         "distinct prompts and cycle them (0 = every "
                         "request gets a fresh prompt)")
    ap.add_argument("--warmup", action="store_true",
                    help="compile prefill/decode before the timed run; the "
                         "result's serve_seconds then excludes compilation")
    args = ap.parse_args(argv)

    hook = None
    if args.kill_point != "none":
        def hook(point, step):
            if point == args.kill_point and step >= args.kill_step:
                sys.stderr.write(f"KILL {point} tick={step}\n")
                sys.stderr.flush()
                os._exit(KILL_EXIT)

    mig_hook = None
    if args.mig_kill_point != "none":
        def mig_hook(point, rid=None, src=None, dst=None):
            if point == args.mig_kill_point:
                sys.stderr.write(f"KILL {point} rid={rid} "
                                 f"{src}->{dst}\n")
                sys.stderr.flush()
                os._exit(KILL_EXIT)

    # imports after arg parsing: a bad flag should not pay jax startup
    from repro.configs import get_smoke_config
    from repro.dsm.api import CXL0Config
    from repro.serve.engine import build_serve_engine
    from repro.serve.fleet import FleetController
    from repro.serve.trace import synthetic_trace, trace_t_max

    new_tokens = tuple(int(t) for t in args.new_tokens.split(","))
    # the trace is a pure function of the CLI args: the restarted process
    # regenerates the exact request stream the killed one was serving —
    # and every member of a fleet bench cell generates the same stream
    trace = synthetic_trace(args.requests, seed=args.seed,
                            prompt_lens=(args.prompt_len,),
                            new_tokens=new_tokens,
                            vocab_size=get_smoke_config(
                                args.arch).vocab_size,
                            n_prompts=args.n_prompts)
    t_max = trace_t_max(trace)
    if args.trace_slice:
        a, b = args.trace_slice.split(":")
        trace = trace[int(a or 0):int(b) if b else None]

    if args.engines >= 2:
        return _fleet_main(args, trace, t_max, hook, mig_hook)

    engine, cfg = build_serve_engine(
        args.arch, smoke=True, n_slots=args.slots, t_max=t_max,
        dsm=CXL0Config(path=args.pool, schedule=args.commit_mode,
                       retention=2, fault_hook=hook),
        commit_every=args.commit_every,
        restore_mode=args.restore_mode, seed=args.seed,
        engine_id=args.engine_id, prefix_reuse=args.prefix_reuse)

    resumed_from = engine.resume()
    recovered_done = len(engine.results)      # finished before the kill
    if args.warmup:
        engine.warmup([len(r.prompt) for r in trace])
    t0 = time.perf_counter()
    res = engine.run(trace)
    serve_seconds = time.perf_counter() - t0
    engine.close()

    result = {
        "ok": True,
        "outputs": res.outputs,
        "digest": outputs_digest(res.outputs),
        "resumed_from": resumed_from,
        "resumed_sessions": res.resumed_sessions,
        "recovered_done": recovered_done,
        "commits": res.commits,
        "decode_ticks": res.decode_ticks,
        "prefills": res.prefills,
        "prefix_hits": res.prefix_hits,
        "emitted_tokens": res.emitted_tokens,
        "serve_seconds": serve_seconds,
    }
    return _emit(result, args)


def _emit(result: dict, args) -> int:
    line = json.dumps(result)
    if args.result:
        with open(args.result, "w") as f:
            f.write(line)
    print(line)
    return 0


def _fleet_main(args, trace, t_max, fault_hook, mig_hook) -> int:
    """N engines over one pool in this process: forced-migration kill
    cells and the zero-token-loss check.  The restart command (kill
    points off) recovers every engine, completes any half-done handoff
    and finishes the identical trace."""
    from repro.serve.fleet import FleetController
    fl = FleetController(
        args.arch, pool_path=args.pool, n_engines=args.engines,
        n_slots=args.slots, t_max=t_max,
        commit_every=args.commit_every, commit_mode=args.commit_mode,
        prefix_reuse=args.prefix_reuse, seed=args.seed,
        restore_mode=args.restore_mode, fault_hook=fault_hook,
        mig_hook=mig_hook)
    if args.wipe_staging >= 0:
        # the target's host buffer vanished with its previous
        # incarnation: adoption must take the pool arm
        fl.staging.wipe(args.wipe_staging)
    steps = fl.resume()
    resumed_from = max((s for s in steps.values() if s is not None),
                      default=None)
    resumed_sessions = sum(e._n_resumed for e in fl.engines.values())
    recovered_done = sum(len(e.results) for e in fl.engines.values())
    fl.submit(trace)
    if args.warmup:
        for e in fl.engines.values():
            e.warmup([len(r.prompt) for r in trace])
    migrated = False
    ticks0 = {i: e._tick for i, e in fl.engines.items()}
    t0 = time.perf_counter()
    while not fl.done:
        fl.tick(rebalance=args.rebalance)
        if (args.migrate_at and not migrated
                and fl.engines[1]._tick >= args.migrate_at):
            src = fl.engines[1]
            rid = next((r for r in src.sched.admission_order
                        if r in src.sched.running), None)
            if rid is not None:
                migrated = True
                fl.migrate(rid, 1, 2)
    res = fl.finish(ticks0)
    serve_seconds = time.perf_counter() - t0
    fl.close()

    result = {
        "ok": True,
        "outputs": res.outputs,
        "digest": outputs_digest(res.outputs),
        "resumed_from": resumed_from,
        "resumed_sessions": resumed_sessions,
        "recovered_done": recovered_done,
        "commits": sum(r.commits for r in res.per_engine.values()),
        "decode_ticks": max(r.decode_ticks
                            for r in res.per_engine.values()),
        "prefills": sum(r.prefills for r in res.per_engine.values()),
        "prefix_hits": res.prefix_hits,
        "migrations": res.migrations,
        "emitted_tokens": res.emitted_tokens,
        "serve_seconds": serve_seconds,
        "per_engine_outputs": {i: len(r.outputs)
                               for i, r in res.per_engine.items()},
    }
    return _emit(result, args)


if __name__ == "__main__":
    sys.exit(main())
