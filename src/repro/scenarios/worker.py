"""The killable scenario worker: one training process over a DSM pool.

Runs the durable training loop and, when ``--kill-point`` is set, dies with
``os._exit(KILL_EXIT)`` the first time the committer's fault hook fires at
that point on or after ``--kill-step`` — a REAL process death in the middle
of the commit window, not a simulated exception: background shard writes
are cut off wherever they happen to be, exactly the partial-crash model.

On restart (same command, ``--kill-point none``) the loop runs with
``resume=True``: it recovers from the pool and continues; the JSON result
on stdout reports the recovered step + source and a CRC digest of the final
params so the runner can compare against an uninterrupted reference run.

By default the worker trains a small deterministic toy state (fast enough
for CI); ``--model smoke`` trains a real smoke-config transformer through
the identical code path for heavier manual runs:

    PYTHONPATH=src python -m repro.scenarios.worker --pool /tmp/p \
        --kill-point mid_flush --kill-step 3
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import zlib

import numpy as np
import jax
import jax.numpy as jnp

from repro.data.pipeline import DataPipeline, SyntheticLMSource
from repro.dsm.api import CXL0Config
from repro.dsm.flit_runtime import COMMIT_MODES, KILL_POINTS
from repro.train.loop import run_durable_loop
from repro.train.state import TrainState, init_train_state

#: exit code of an injected kill (distinguishes it from real failures)
KILL_EXIT = 17


def make_toy_state(dim: int = 64, n_tensors: int = 6,
                   seed: int = 0) -> TrainState:
    """A small multi-tensor state pytree — enough leaves that the sharded
    write path genuinely partitions work across pipelines."""
    key = jax.random.PRNGKey(seed)
    params = {}
    for t in range(n_tensors):
        key, k = jax.random.split(key)
        params[f"w{t}"] = jax.random.normal(k, (dim, dim), jnp.float32)
    return init_train_state(params, key)


def make_toy_step():
    """Deterministic pseudo-training step (no model build, fast on CPU):
    a pure function of (state, batch), so crash + recover + replay must be
    bit-identical to an uninterrupted run."""

    def step(state: TrainState, batch):
        x = jnp.mean(batch["tokens"].astype(jnp.float32)) / 1000.0
        grads = jax.tree_util.tree_map(lambda p: 0.01 * p + x, state.params)
        params = jax.tree_util.tree_map(lambda p, g: p - 0.1 * g,
                                        state.params, grads)
        opt = state.opt._replace(
            step=state.opt.step + 1,
            mu=jax.tree_util.tree_map(lambda m, g: 0.9 * m + 0.1 * g,
                                      state.opt.mu, grads),
            nu=jax.tree_util.tree_map(lambda v, g: 0.95 * v + 0.05 * g * g,
                                      state.opt.nu, grads))
        loss = sum(jnp.mean(jnp.square(l))
                   for l in jax.tree_util.tree_leaves(params))
        return TrainState(params, opt, state.rng), {"loss": loss}

    return jax.jit(step)


def make_smoke_model():
    """The real-model variant (heavier; manual runs): smoke-config olmo."""
    from repro.configs import get_smoke_config
    from repro.models.registry import build
    from repro.train.step import make_train_step
    cfg = get_smoke_config("olmo-1b")
    bundle = build(cfg)
    key = jax.random.PRNGKey(0)
    state = init_train_state(bundle.init_params(key), key)
    return jax.jit(make_train_step(bundle)), state, cfg.vocab_size


def state_digest(state: TrainState) -> int:
    """CRC32 over the final params — the cross-process equality check."""
    crc = 0
    for l in jax.tree_util.tree_leaves(state.params):
        a = np.ascontiguousarray(np.asarray(l, np.float32))
        crc = zlib.crc32(a.tobytes(), crc)
    return crc


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--pool", required=True)
    ap.add_argument("--steps", type=int, default=8)
    ap.add_argument("--commit-every", type=int, default=2)
    ap.add_argument("--mode", default="sharded-async", choices=COMMIT_MODES)
    ap.add_argument("--shards", type=int, default=4)
    ap.add_argument("--retention", type=int, default=0,
                    help="manifests kept by GC (0 = unbounded)")
    ap.add_argument("--kill-point", default="none",
                    choices=("none",) + KILL_POINTS)
    ap.add_argument("--kill-step", type=int, default=3,
                    help="fire at the first hook of --kill-point whose "
                         "commit step is >= this")
    ap.add_argument("--model", default="toy", choices=["toy", "smoke"])
    ap.add_argument("--dim", type=int, default=64)
    ap.add_argument("--mesh", default="",
                    help="mesh spec like 2x4: device-shard the toy state "
                         "on a real (data, model) Mesh and commit "
                         "device-local (requires the XLA host-device "
                         "force, e.g. XLA_FLAGS=--xla_force_host_"
                         "platform_device_count=8)")
    ap.add_argument("--topology", default="",
                    help="emulated CXL topology preset: builds a "
                         "PlacementPolicy so shard counts are priced "
                         "(with --mesh, from real per-device bytes)")
    ap.add_argument("--decision-log", default="",
                    help="write the placement policy's priced decisions "
                         "as JSONL to this path")
    ap.add_argument("--result", default="", help="also write the result "
                                                 "JSON to this path")
    args = ap.parse_args(argv)

    hook = None
    if args.kill_point != "none":
        def hook(point, step):
            if point == args.kill_point and step >= args.kill_step:
                sys.stderr.write(f"KILL {point} step={step}\n")
                sys.stderr.flush()
                os._exit(KILL_EXIT)

    if args.model == "smoke":
        step_fn, state, vocab = make_smoke_model()
    else:
        step_fn, state, vocab = make_toy_step(), make_toy_state(args.dim), 1024

    mesh = None
    if args.mesh:
        from jax.sharding import NamedSharding, PartitionSpec
        from repro.launch.mesh import parse_mesh
        mesh = parse_mesh(args.mesh)
        # device-shard the (dim, dim) tensors over the full grid; the
        # recovered state is put back onto the same layout by the loop's
        # _restore_placement, so every post-crash commit stays device-local
        sh = NamedSharding(mesh, PartitionSpec("data", "model"))
        rep = NamedSharding(mesh, PartitionSpec())
        put = lambda p: jax.device_put(p, sh)
        # scalars (opt step, rng, batch) ride replicated — jit rejects a
        # mixed single-device/mesh argument set
        state = state._replace(
            params=jax.tree_util.tree_map(put, state.params),
            opt=state.opt._replace(
                mu=jax.tree_util.tree_map(put, state.opt.mu),
                nu=jax.tree_util.tree_map(put, state.opt.nu),
                step=jax.device_put(state.opt.step, rep)),
            rng=jax.device_put(state.rng, rep))

    pipe = DataPipeline(SyntheticLMSource(vocab), 4, 32)
    # one wiring path: every CLI knob lands in the unified config and the
    # loop runs over the context it opens
    ctx = CXL0Config(path=args.pool, schedule=args.mode,
                     n_shards=args.shards or None,
                     retention=args.retention or None,
                     topology=args.topology or None,
                     mesh=mesh,
                     fault_hook=hook).open()

    to_device = jnp.asarray
    if mesh is not None:
        from jax.sharding import NamedSharding, PartitionSpec
        rep = NamedSharding(mesh, PartitionSpec())
        to_device = lambda v: jax.device_put(jnp.asarray(v), rep)
    r = run_durable_loop(step_fn, state, pipe, ctx, n_steps=args.steps,
                         commit_every=args.commit_every, resume=True,
                         to_device=to_device)

    if args.decision_log and ctx.placement is not None:
        import dataclasses
        with open(args.decision_log, "w") as f:
            for d in ctx.placement.decisions:
                f.write(json.dumps(dataclasses.asdict(d)) + "\n")

    result = {
        "ok": True,
        "completed_losses": len(r.losses),
        "resumed_from": r.resumed_from,
        "recoveries": r.recoveries,
        "digest": state_digest(r.state),
        "final_manifest_step": ctx.pool.latest_manifest()["step"],
        "pipeline_step": r.pipeline_state.step,
        "mesh": args.mesh or None,
        "n_devices": jax.device_count(),
        "d2h_gather_bytes": ctx.tiers.d2h_gather_bytes,
        "d2h_shard_bytes": ctx.tiers.d2h_shard_bytes,
    }
    line = json.dumps(result)
    if args.result:
        with open(args.result, "w") as f:
            f.write(line)
    print(line)
    return 0


if __name__ == "__main__":
    sys.exit(main())
