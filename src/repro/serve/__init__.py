"""Durable continuous-batching serving subsystem over the CXL0 tier stack.

Three layers, one per module:

* ``serve.scheduler`` — slot-based continuous batching: requests are
  admitted FIFO into fixed decode slots, prefill of new requests
  interleaves with batched decode of running ones, finished sequences
  free their slot immediately (no static-batch stragglers);
* ``serve.kvcache``   — tiered KV-cache manager: per-slot cache blocks in
  HBM, cold session caches spilled/restored through ``TierManager``'s
  host-staging (RStore) and pool (RFlush) tiers with byte-balanced block
  layout (``partition_leaves``);
* ``serve.sessions``  — durable session store: session state (prompt,
  emitted tokens, KV-cache version) commits through the FliT commit path
  (``dsm.flit_runtime.DurableCommitter``), so a killed serving worker
  restarts via ``dsm.recovery`` and resumes every committed session with
  bit-identical continuations.

``serve.engine.ServeEngine`` wires them to the model bundle's prefill +
slot-masked decode steps (``train.step.make_slot_decode_step``);
``serve.trace`` generates the deterministic synthetic request traces the
benchmarks and crash scenarios share.  ``launch/serve.py`` and
``examples/serve.py`` are thin front-ends over this package.
"""
from repro.serve.engine import ServeEngine, ServeResult
from repro.serve.scheduler import Request, SlotScheduler
from repro.serve.trace import synthetic_trace

__all__ = ["ServeEngine", "ServeResult", "Request", "SlotScheduler",
           "synthetic_trace"]
