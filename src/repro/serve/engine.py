"""ServeEngine: continuous batching + tiered KV caches + durable sessions.

The serving loop per decode tick:

1. **admit** — free slots refill FIFO from the scheduler; each admission
   prefills ONE sequence (B=1, compiled once per distinct prompt length),
   writes its cache into the slot lane and emits its first token;
2. **decode** — one slot-masked batched decode step advances every
   running slot at its own position (``train.step.make_slot_decode_step``
   — a per-slot vmap, so slot contents never influence each other);
3. **retire** — sequences that hit their token budget free their slot in
   the same tick (the scheduler contract), and their cache leaves the
   host tier;
4. **commit** (every ``commit_every`` ticks, durable pools only) — every
   running slot's cache is staged into the host tier and the FliT
   committer flushes them + the full session table in one atomic
   completeOp (serve.sessions).

Crash recovery: a restarted worker calls ``resume()`` — finished
sessions come back as results; running sessions re-enter the admission
queue AHEAD of fresh requests with their committed cache restored into a
lane (``restore_mode="cache"``) or replayed from the prompt
(``restore_mode="replay"``).  Both are bit-identical to the
uninterrupted run: the restored bytes ARE the committed HBM bytes, and a
replay re-executes the identical deterministic computation.

``run_static`` is the old static-batch loop kept as the benchmark
baseline: batched prefill, then decode until the LONGEST sequence of the
batch finishes — the behaviour whose hostage effect continuous batching
removes.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.serve.kvcache import TieredKVCache
from repro.serve.scheduler import Request, SlotScheduler
from repro.serve.sessions import Session, SessionStore
from repro.train.step import make_serve_steps, make_slot_decode_step


@dataclasses.dataclass
class ServeResult:
    outputs: Dict[str, List[int]]     # rid -> emitted token ids
    decode_ticks: int
    prefills: int
    emitted_tokens: int
    mode: str
    resumed_step: Optional[int] = None
    resumed_sessions: int = 0
    commits: int = 0


class ServeEngine:
    def __init__(self, bundle, params, *, n_slots: int = 4,
                 t_max: int = 96, ctx=None,
                 store: Optional[SessionStore] = None,
                 commit_every: int = 0,
                 restore_mode: str = "cache",
                 retire_done: bool = False):
        assert restore_mode in ("cache", "replay"), restore_mode
        if bundle.cfg.is_encdec:
            raise ValueError(
                "the serving subsystem is decoder-only (the slot-masked "
                "decode has no encoder-state plumbing); encoder-decoder "
                "archs are not servable — see serve.engine.servable_archs")
        self.bundle = bundle
        self.params = params
        self.n_slots = n_slots
        self.t_max = t_max
        self.store = store
        self.commit_every = commit_every if store is not None else 0
        self.restore_mode = restore_mode
        self.retire_done = retire_done

        prefill_step, decode_step = make_serve_steps(bundle, ctx)
        self._prefill = jax.jit(prefill_step)
        self._decode = jax.jit(decode_step)           # static baseline
        self._slot_decode = jax.jit(make_slot_decode_step(bundle, ctx),
                                    donate_argnums=(2,))

        self.kv = TieredKVCache(bundle, n_slots, t_max,
                                tiers=store.tiers if store else None,
                                placement=getattr(store, "placement", None))
        self._caches1 = bundle.init_caches(jax.random.PRNGKey(0), 1, t_max)
        self.sched = SlotScheduler(n_slots)
        self.sessions: Dict[str, Session] = {}
        self.results: Dict[str, List[int]] = {}
        self._resume_cache: Dict[str, Any] = {}
        # host-side slot state
        self.pos = np.zeros(n_slots, np.int32)
        self.last_token = np.zeros(n_slots, np.int32)
        self.active = np.zeros(n_slots, bool)
        self._tick = 0
        self._resumed_step: Optional[int] = None
        self._n_resumed = 0
        self._n_prefills = 0
        self._n_commits = 0

    # -- request intake ------------------------------------------------------
    def submit(self, requests: Sequence[Request]):
        fresh = []
        for r in requests:
            assert len(r.prompt) + r.max_new_tokens <= self.t_max, \
                (r.rid, len(r.prompt), r.max_new_tokens, self.t_max)
            if r.rid in self.sessions or r.rid in self.results:
                continue    # recovered, resuming, or retired-done — skip
            fresh.append(r)
        self.sched.submit(fresh)

    # -- crash recovery ------------------------------------------------------
    def resume(self) -> Optional[int]:
        """Recover the newest session commit from the pool.  Finished
        sessions become results; unfinished ones are queued for admission
        AHEAD of any fresh request (they were admitted first in the killed
        incarnation).  Returns the recovered tick or None (cold pool)."""
        if self.store is None:
            return None
        rec = self.store.recover(self.kv.template1)
        if rec is None:
            return None
        for rid, s in rec.sessions.items():
            self.sessions[rid] = s
            if s.done:
                self.results[rid] = list(s.emitted)
            else:
                self._resume_cache[rid] = rec.caches.get(rid)
                self._n_resumed += 1
                self.sched.submit([Request(rid, s.prompt,
                                           s.max_new_tokens)])
        self._resumed_step = rec.step
        self._tick = rec.step + 1
        return rec.step

    # -- the continuous-batching loop ---------------------------------------
    def run(self, requests: Optional[Sequence[Request]] = None
            ) -> ServeResult:
        if requests:
            self.submit(requests)
        ticks0 = self._tick
        while not self.sched.done:
            for slot, req in self.sched.admit():
                self._admit(slot, req)
            if self.sched.n_running:
                self._decode_tick()
            self._tick += 1
            if self.commit_every and self._tick % self.commit_every == 0:
                self._commit()
        if self.store is not None:
            self._commit()            # final table (all sessions done)
            self.store.drain()
        return ServeResult(
            outputs=dict(self.results),
            decode_ticks=self._tick - ticks0,
            prefills=self._n_prefills,
            emitted_tokens=sum(len(v) for v in self.results.values()),
            mode="continuous",
            resumed_step=self._resumed_step,
            resumed_sessions=self._n_resumed,
            commits=self._n_commits)

    def _admit(self, slot: int, req: Request):
        rid = req.rid
        s = self.sessions.get(rid)
        if s is not None and not s.done:
            cache1 = self._resume_cache.pop(rid, None)
            if (self.restore_mode == "cache" and cache1 is not None
                    and s.emitted):
                # fast-forward: committed cache bytes back into a lane
                self.kv.write_slot(slot, cache1)
                self.pos[slot] = s.pos
                self.last_token[slot] = s.emitted[-1]
                self.active[slot] = True
                return
            s.emitted = []            # replay: re-decode from the prompt
        else:
            s = Session(rid, tuple(req.prompt), req.max_new_tokens)
            self.sessions[rid] = s
        tokens = jnp.asarray(np.asarray(s.prompt, np.int32)[None])
        logits, st = self._prefill(self.params, {"tokens": tokens},
                                   self._caches1)
        self._n_prefills += 1
        tok0 = int(jnp.argmax(logits, -1)[0])
        self.kv.write_slot(slot, st.caches)
        self.pos[slot] = len(s.prompt)
        self.last_token[slot] = tok0
        self.active[slot] = True
        s.emitted.append(tok0)
        if len(s.emitted) >= s.max_new_tokens:
            self._finish(rid, slot)

    def _decode_tick(self):
        next_toks, _, new_caches, new_pos = self._slot_decode(
            self.params, jnp.asarray(self.last_token[:, None]),
            self.kv.caches, jnp.asarray(self.pos),
            jnp.asarray(self.active))
        self.kv.caches = new_caches
        self.pos = np.array(new_pos)      # copy: np.asarray of a jax
        #                                   array is a read-only view
        toks = np.asarray(next_toks)
        for rid, slot in list(self.sched.running.items()):
            s = self.sessions[rid]
            tok = int(toks[slot])
            s.emitted.append(tok)
            self.last_token[slot] = tok
            if len(s.emitted) >= s.max_new_tokens:
                self._finish(rid, slot)

    def _finish(self, rid: str, slot: int):
        self.sched.release(rid)
        self.active[slot] = False
        s = self.sessions[rid]
        s.done = True
        self.results[rid] = list(s.emitted)
        if self.store is not None:
            self.store.discard(rid)

    def _commit(self):
        assert self.store is not None
        for rid, slot in self.sched.running.items():
            self.store.stage(self.sessions[rid], self.kv.read_slot(slot))
        self.store.commit(self.sessions, self._tick)
        self._n_commits += 1
        if self.retire_done:
            # done sessions were durable in the table just committed;
            # retire them so commit cost stays O(live sessions) instead of
            # O(total request history).  Their outputs remain in
            # self.results (delivered to the caller) but a later restart
            # will no longer replay them — the long-lived-service policy.
            for rid in [r for r, s in self.sessions.items() if s.done]:
                del self.sessions[rid]

    # -- static baseline -----------------------------------------------------
    def run_static(self, requests: Sequence[Request]) -> ServeResult:
        """FIFO batches of ``n_slots``; each batch decodes until its
        LONGEST sequence finishes (the hostage effect)."""
        outputs: Dict[str, List[int]] = {}
        ticks = prefills = 0
        reqs = list(requests)
        for i in range(0, len(reqs), self.n_slots):
            batch = reqs[i:i + self.n_slots]
            lens = {len(r.prompt) for r in batch}
            assert len(lens) == 1, \
                "static baseline batches unpadded prompts (uniform length)"
            toks = jnp.asarray(np.asarray([r.prompt for r in batch],
                                          np.int32))
            caches = self.bundle.init_caches(jax.random.PRNGKey(0),
                                             len(batch), self.t_max)
            logits, st = self._prefill(self.params, {"tokens": toks},
                                       caches)
            prefills += 1
            tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
            emitted = [[int(t)] for t in np.asarray(tok[:, 0])]
            for _ in range(max(r.max_new_tokens for r in batch) - 1):
                logits, st = self._decode(self.params, tok, st)
                tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
                ticks += 1
                for row, t in enumerate(np.asarray(tok[:, 0])):
                    emitted[row].append(int(t))
            for r, row in zip(batch, emitted):
                outputs[r.rid] = row[:r.max_new_tokens]
        return ServeResult(
            outputs=outputs, decode_ticks=ticks, prefills=prefills,
            emitted_tokens=sum(len(v) for v in outputs.values()),
            mode="static")

    # -- utilities -----------------------------------------------------------
    def warmup(self, prompt_lens: Sequence[int]):
        """Compile prefill per distinct prompt length + the decode step,
        outside any timed region."""
        for L in sorted(set(int(l) for l in prompt_lens)):
            tokens = jnp.zeros((1, L), jnp.int32)
            logits, _ = self._prefill(self.params, {"tokens": tokens},
                                      self._caches1)
            jax.block_until_ready(logits)
        nt, _, self.kv.caches, _ = self._slot_decode(
            self.params, jnp.asarray(self.last_token[:, None]),
            self.kv.caches, jnp.asarray(self.pos),
            jnp.asarray(self.active))
        jax.block_until_ready(nt)

    def close(self):
        if self.store is not None:
            self.store.close()


def servable_archs():
    """Arch ids the serving subsystem supports (decoder-only — the
    slot-masked decode has no encoder-state plumbing).  Used by the CLI
    front-ends as argparse choices so encoder-decoder archs are rejected
    up front instead of deep in engine construction."""
    from repro.configs import ARCH_IDS, get_smoke_config
    return [a for a in ARCH_IDS if not get_smoke_config(a).is_encdec]


def build_serve_engine(arch: str = "olmo-1b", *, smoke: bool = True,
                       n_slots: int = 4, t_max: int = 96, ctx=None,
                       pool_path: Optional[str] = None,
                       commit_every: int = 0, commit_mode: str = "sync",
                       n_shards: Optional[int] = None, retention: int = 2,
                       fault_hook=None, restore_mode: str = "cache",
                       retire_done: bool = False, seed: int = 0,
                       topology: Optional[str] = None,
                       dsm: Optional["CXL0Config"] = None):
    """One-stop construction shared by the launcher, the example and the
    killable scenario worker: config -> bundle -> (sharded) params ->
    optional durable session store -> engine.  Returns (engine, cfg).

    The durable tier stack is wired from ONE ``CXL0Config``: pass it
    directly via ``dsm`` (the launchers do) or let the legacy kwargs
    (``pool_path``/``commit_mode``/``n_shards``/``retention``/``topology``)
    be folded into one here.  ``ctx`` is the parallelism context (mesh),
    not the DSM context.

    Params are initialized from ``seed`` deterministically, so two
    processes built with the same arguments hold bit-identical weights —
    the property crash-replay bit-identity rests on."""
    from repro.configs import get_config, get_smoke_config
    from repro.dsm.api import CXL0Config
    from repro.models.registry import build as build_model

    cfg = get_smoke_config(arch) if smoke else get_config(arch)
    bundle = build_model(cfg, dec_pos_len=t_max)
    key = jax.random.PRNGKey(seed)
    params = bundle.init_params(key)
    if ctx is not None and ctx.mesh is not None:
        from repro.train.elastic import shardings_for
        params = jax.tree_util.tree_map(
            jax.device_put, params, shardings_for(ctx, bundle.descs))
    store = None
    if dsm is None and pool_path is not None:
        # cost-driven shard count (and, with commit_mode="auto", the
        # schedule) come from the topology's placement policy, built by
        # the config at open time
        dsm = CXL0Config(path=pool_path, schedule=commit_mode,
                         n_shards=n_shards, retention=retention,
                         topology=topology, fault_hook=fault_hook)
    if dsm is not None:
        store = SessionStore(ctx=dsm.open())
    engine = ServeEngine(bundle, params, n_slots=n_slots, t_max=t_max,
                         ctx=ctx, store=store, commit_every=commit_every,
                         restore_mode=restore_mode, retire_done=retire_done)
    return engine, cfg
