"""ServeEngine: continuous batching + tiered KV caches + durable sessions.

The serving loop per decode tick (``tick()`` — ``run()`` just loops it,
and a fleet controller interleaves many engines' ticks over one pool):

1. **admit** — free slots refill FIFO from the scheduler; each admission
   prefills ONE sequence (B=1, compiled once per distinct prompt length),
   writes its cache into the slot lane and emits its first token — or,
   with prefix reuse enabled, restores the prompt's content-addressed
   pool blocks and skips the prefill entirely;
2. **decode** — one slot-masked batched decode step advances every
   running slot at its own position (``train.step.make_slot_decode_step``
   — a per-slot vmap, so slot contents never influence each other);
3. **retire** — sequences that hit their token budget free their slot in
   the same tick (the scheduler contract), their block frames return to
   the allocator and their staged blocks leave the host tier;
4. **commit** (every ``commit_every`` ticks, durable pools only) — the
   PAGED layout (serve.paging, the default): only the token blocks each
   session's position touched since the last commit are staged + flushed;
   the manifest carries every clean block by reference (serve.sessions).
   ``paged=False`` keeps the legacy whole-lane path for the equivalence
   tests.

Crash recovery: a restarted worker calls ``resume()`` — finished
sessions come back as results; running sessions re-enter the admission
queue AHEAD of fresh requests with their committed cache restored into a
lane (``restore_mode="cache"``) or replayed from the prompt
(``restore_mode="replay"``).  Both are bit-identical to the
uninterrupted run: the restored bytes ARE the committed HBM bytes, and a
replay re-executes the identical deterministic computation.

Live migration (driven by serve.fleet): ``begin_migration`` freezes a
session and frees its slot mid-flight, ``stage_migration`` RStores its
dirty blocks into the target's staging buffer, ``commit_handoff`` makes
the handoff durable, and the target's ``install_session`` re-admits it
at the FRONT of the queue — the token stream is bit-identical across
the handoff because the adopted cache bytes equal the frozen lane bytes.

``run_static`` is the old static-batch loop kept as the benchmark
baseline: batched prefill, then decode until the LONGEST sequence of the
batch finishes — the behaviour whose hostage effect continuous batching
removes.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.serve.kvcache import TieredKVCache
from repro.serve.paging import (BLOCK_TOKENS, BlockAllocator, BlockPager,
                                BlockRef, BlockTable, STATE_BLOCK)
from repro.serve.scheduler import Request, SlotScheduler
from repro.serve.sessions import Session, SessionStore
from repro.train.step import make_serve_steps, make_slot_decode_step


@dataclasses.dataclass
class ServeResult:
    outputs: Dict[str, List[int]]     # rid -> emitted token ids
    decode_ticks: int
    prefills: int
    emitted_tokens: int
    mode: str
    resumed_step: Optional[int] = None
    resumed_sessions: int = 0
    commits: int = 0
    prefix_hits: int = 0              # admissions served from shared blocks
    migrated_in: int = 0
    migrated_out: int = 0


class ServeEngine:
    def __init__(self, bundle, params, *, n_slots: int = 4,
                 t_max: int = 96, ctx=None,
                 store: Optional[SessionStore] = None,
                 commit_every: int = 0,
                 restore_mode: str = "cache",
                 retire_done: bool = False,
                 paged: bool = True,
                 block_tokens: int = BLOCK_TOKENS,
                 allocator: Optional[BlockAllocator] = None,
                 prefix_reuse: bool = False,
                 prefix_key: str = ""):
        assert restore_mode in ("cache", "replay"), restore_mode
        if bundle.cfg.is_encdec:
            raise ValueError(
                "the serving subsystem is decoder-only (the slot-masked "
                "decode has no encoder-state plumbing); encoder-decoder "
                "archs are not servable — see serve.engine.servable_archs")
        self.bundle = bundle
        self.params = params
        self.n_slots = n_slots
        self.t_max = t_max
        self.store = store
        self.engine_id = store.engine_id if store is not None else 0
        self.commit_every = commit_every if store is not None else 0
        self.restore_mode = restore_mode
        self.retire_done = retire_done
        self.paged = paged and store is not None
        self.block_tokens = block_tokens
        #: reuse is sound only within one model identity: ``prefix_key``
        #: must fold arch + params seed (build_serve_engine sets it)
        self.prefix_reuse = prefix_reuse and self.paged
        self.prefix_key = prefix_key

        prefill_step, decode_step = make_serve_steps(bundle, ctx)
        self._prefill = jax.jit(prefill_step)
        self._decode = jax.jit(decode_step)           # static baseline
        self._slot_decode = jax.jit(make_slot_decode_step(bundle, ctx),
                                    donate_argnums=(2,))

        self.kv = TieredKVCache(bundle, n_slots, t_max,
                                tiers=store.tiers if store else None,
                                placement=getattr(store, "placement", None),
                                parallel=ctx)
        self._caches1 = bundle.init_caches(jax.random.PRNGKey(0), 1, t_max)
        self.sched = SlotScheduler(n_slots)
        self.sessions: Dict[str, Session] = {}
        self.results: Dict[str, List[int]] = {}
        self._resume_cache: Dict[str, Any] = {}
        #: recovered handoff tables of sessions we migrated OUT whose
        #: target never committed its adoption — the fleet resume
        #: completes these (serve.fleet.FleetController.resume)
        self._handoffs: Dict[str, Optional[BlockTable]] = {}
        if self.paged:
            self.pager = BlockPager(bundle, t_max, block_tokens)
            frames = n_slots * (self.pager.n_blocks(t_max) + 1) + 8
            self.allocator = allocator or BlockAllocator(max(64, 4 * frames))
            self.tables: Dict[str, BlockTable] = {}
        # host-side slot state
        self.pos = np.zeros(n_slots, np.int32)
        self.last_token = np.zeros(n_slots, np.int32)
        self.active = np.zeros(n_slots, bool)
        self._tick = 0
        self._resumed_step: Optional[int] = None
        self._n_resumed = 0
        self._n_prefills = 0
        self._n_commits = 0
        self._n_prefix_hits = 0
        self._n_migrated_in = 0
        self._n_migrated_out = 0

    # -- request intake ------------------------------------------------------
    def submit(self, requests: Sequence[Request]):
        fresh = []
        for r in requests:
            assert len(r.prompt) + r.max_new_tokens <= self.t_max, \
                (r.rid, len(r.prompt), r.max_new_tokens, self.t_max)
            if r.rid in self.sessions or r.rid in self.results:
                continue    # recovered, resuming, migrated, or retired —
                #             this engine already accounts for the rid
            fresh.append(r)
        self.sched.submit(fresh)

    # -- crash recovery ------------------------------------------------------
    def resume(self) -> Optional[int]:
        """Recover the newest session commit from the pool.  Finished
        sessions become results; unfinished ones are queued for admission
        AHEAD of any fresh request (they were admitted first in the killed
        incarnation).  Sessions handed off to another engine stay as
        tombstones: ``submit`` skips them and the adopting engine (or the
        fleet resume) serves them.  Returns the recovered tick or None
        (cold pool)."""
        if self.store is None:
            return None
        rec = self.store.recover(self.kv.template1,
                                 pager=self.pager if self.paged else None)
        if rec is None:
            return None
        for rid, s in rec.sessions.items():
            self.sessions[rid] = s
            if s.migrated_to is not None:
                # owned by the target engine; keep the handoff table so
                # the fleet resume can finish an interrupted adoption
                self._handoffs[rid] = rec.tables.get(rid)
                continue
            if s.done:
                self.results[rid] = list(s.emitted)
            else:
                self._resume_cache[rid] = rec.caches.get(rid)
                if self.paged and rid in rec.tables:
                    self.tables[rid] = rec.tables[rid]
                    for bid in rec.tables[rid].bids():
                        self.allocator.adopt(bid)
                self._n_resumed += 1
                self.sched.submit([Request(rid, s.prompt,
                                           s.max_new_tokens)])
        self._resumed_step = rec.step
        self._tick = rec.step + 1
        return rec.step

    # -- the continuous-batching loop ---------------------------------------
    def tick(self):
        """One scheduler round: admit, decode, commit-on-cadence.  The
        unit a fleet controller interleaves across engines."""
        for slot, req in self.sched.admit():
            self._admit(slot, req)
        if self.sched.n_running:
            self._decode_tick()
        self._tick += 1
        if self.commit_every and self._tick % self.commit_every == 0:
            self._commit()

    def run(self, requests: Optional[Sequence[Request]] = None
            ) -> ServeResult:
        if requests:
            self.submit(requests)
        ticks0 = self._tick
        while not self.sched.done:
            self.tick()
        return self.finish(ticks0)

    def finish(self, ticks0: int = 0) -> ServeResult:
        """Final commit + drain, then the result record (split out of
        ``run`` so a fleet controller can drive ticks itself)."""
        if self.store is not None:
            self._commit()            # final table (all sessions done)
            self.store.drain()
        return ServeResult(
            outputs=dict(self.results),
            decode_ticks=self._tick - ticks0,
            prefills=self._n_prefills,
            emitted_tokens=sum(len(v) for v in self.results.values()),
            mode="continuous",
            resumed_step=self._resumed_step,
            resumed_sessions=self._n_resumed,
            commits=self._n_commits,
            prefix_hits=self._n_prefix_hits,
            migrated_in=self._n_migrated_in,
            migrated_out=self._n_migrated_out)

    def _admit(self, slot: int, req: Request):
        rid = req.rid
        s = self.sessions.get(rid)
        if s is not None and not s.done:
            cache1 = self._resume_cache.pop(rid, None)
            if (self.restore_mode == "cache" and cache1 is not None
                    and s.emitted):
                # fast-forward: committed cache bytes back into a lane
                self.kv.write_slot(slot, cache1)
                self.pos[slot] = s.pos
                self.last_token[slot] = s.emitted[-1]
                self.active[slot] = True
                return
            s.emitted = []            # replay: re-decode from the prompt
        else:
            s = Session(rid, tuple(req.prompt), req.max_new_tokens)
            self.sessions[rid] = s
            if self.prefix_reuse and self._admit_from_prefix(slot, s):
                return
        tokens = jnp.asarray(np.asarray(s.prompt, np.int32)[None])
        logits, st = self._prefill(self.params, {"tokens": tokens},
                                   self._caches1)
        self._n_prefills += 1
        tok0 = int(jnp.argmax(logits, -1)[0])
        self.kv.write_slot(slot, st.caches)
        self.pos[slot] = len(s.prompt)
        self.last_token[slot] = tok0
        self.active[slot] = True
        s.emitted.append(tok0)
        if self.prefix_reuse:
            self.store.publish_prefix(self.pager, self.prefix_key,
                                      s.prompt, st.caches, tok0)
        if len(s.emitted) >= s.max_new_tokens:
            self._finish(rid, slot)

    def _admit_from_prefix(self, slot: int, s: Session) -> bool:
        """Admission fast path: restore the prompt's shared blocks from
        the pool instead of prefilling.  Bit-identical to the prefill it
        replaces — the blocks were published from an identical-weights
        prefill of the identical prompt."""
        hit = self.store.load_prefix(self.pager, self.prefix_key, s.prompt)
        if hit is None:
            return False
        blocks, shared, tok0 = hit
        self.kv.write_slot(slot, self.pager.assemble(blocks))
        table = BlockTable()
        for k, (name, entry) in shared.items():
            # the table references the SHARED objects: carried by name
            # into this engine's manifests, no bytes copied
            table.refs[k] = BlockRef(blk=k, bid=self.allocator.alloc(),
                                     tokens=self.pager.block_tokens,
                                     name=name, entry=entry)
        self.tables[s.rid] = table
        self.pos[slot] = len(s.prompt)
        self.last_token[slot] = tok0
        self.active[slot] = True
        s.emitted.append(tok0)
        self._n_prefix_hits += 1
        if len(s.emitted) >= s.max_new_tokens:
            self._finish(s.rid, slot)
        return True

    def _decode_tick(self):
        next_toks, _, new_caches, new_pos = self._slot_decode(
            self.params, jnp.asarray(self.last_token[:, None]),
            self.kv.caches, jnp.asarray(self.pos),
            jnp.asarray(self.active))
        self.kv.caches = new_caches
        self.pos = np.array(new_pos)      # copy: np.asarray of a jax
        #                                   array is a read-only view
        toks = np.asarray(next_toks)
        for rid, slot in list(self.sched.running.items()):
            s = self.sessions[rid]
            tok = int(toks[slot])
            s.emitted.append(tok)
            self.last_token[slot] = tok
            if len(s.emitted) >= s.max_new_tokens:
                self._finish(rid, slot)

    def _finish(self, rid: str, slot: int):
        self.sched.release(rid)
        self.active[slot] = False
        s = self.sessions[rid]
        s.done = True
        self.results[rid] = list(s.emitted)
        if self.store is not None:
            if self.paged:
                t = self.tables.pop(rid, None)
                if t is not None:
                    for bid in t.bids():
                        self.allocator.free(bid)
                self.store.discard_session_blocks(rid)
            else:
                self.store.discard(rid)

    def _stage_paged(self, rid: str, cache1: Any):
        """Stage a running session's DIRTY blocks for the next commit —
        the O(blocks touched) replacement for whole-lane ``store.stage``."""
        s = self.sessions[rid]
        table = self.tables.setdefault(rid, BlockTable())
        for blk, leaves in self.pager.slice_dirty(cache1, s.pos,
                                                  table).items():
            ref = table.refs.get(blk)
            if ref is None:
                ref = BlockRef(blk=blk, bid=self.allocator.alloc(),
                               tokens=0,
                               name=self.store.block_name(rid, blk))
                table.refs[blk] = ref
            if blk != STATE_BLOCK:
                ref.tokens = self.pager.tokens_in_block(blk, s.pos)
            self.store.stage_block(s, ref, leaves)

    def _commit(self):
        assert self.store is not None
        if self.paged:
            for rid, slot in self.sched.running.items():
                self._stage_paged(rid, self.kv.read_slot(slot))
            self.store.commit_paged(self.sessions, self.tables,
                                    self._tick,
                                    block_tokens=self.block_tokens)
        else:
            for rid, slot in self.sched.running.items():
                self.store.stage(self.sessions[rid],
                                 self.kv.read_slot(slot))
            self.store.commit(self.sessions, self._tick)
        self._n_commits += 1
        if self.retire_done:
            # done sessions were durable in the table just committed;
            # retire them so commit cost stays O(live sessions) instead of
            # O(total request history).  Their outputs remain in
            # self.results (delivered to the caller) but a later restart
            # will no longer replay them — the long-lived-service policy.
            for rid in [r for r, s in self.sessions.items() if s.done]:
                del self.sessions[rid]

    # -- live migration mechanics (driven by serve.fleet) --------------------
    def begin_migration(self, rid: str):
        """Freeze an in-flight session: extract its lane and free the
        slot — freed via MIGRATION, not completion, so the scheduler
        refills it with the next pending request this very tick."""
        slot = self.sched.running[rid]
        cache1 = self.kv.read_slot(slot)
        self.active[slot] = False
        self.sched.release(rid)
        self._n_migrated_out += 1
        return self.sessions[rid], \
            self.tables.setdefault(rid, BlockTable()), cache1

    def stage_migration(self, rid: str, cache1: Any, proxy, tag: int
                        ) -> BlockTable:
        """mig_stage: LStore the session's dirty blocks (the handoff
        commit will flush them — the pool arm of staging-or-pool) and
        RStore each into the TARGET's staging buffer (the hot arm).
        Clean blocks move zero bytes: the target reads them from the pool
        entries the block table already carries."""
        s = self.sessions[rid]
        table = self.tables[rid]
        for blk, leaves in self.pager.slice_dirty(cache1, s.pos,
                                                  table).items():
            ref = table.refs.get(blk)
            if ref is None:
                ref = BlockRef(blk=blk, bid=self.allocator.alloc(),
                               tokens=0,
                               name=self.store.block_name(rid, blk))
                table.refs[blk] = ref
            if blk != STATE_BLOCK:
                ref.tokens = self.pager.tokens_in_block(blk, s.pos)
            self.store.stage_block(s, ref, leaves)
            self.store.tiers.rstore(ref.name, proxy, tag=tag)
        return table

    def commit_handoff(self, rid: str, target_id: int):
        """mig_commit: mark the session migrated and commit — ONE paged
        commit makes the marker, the block table and the staged dirty
        blocks durable atomically.  After this manifest lands the target
        owns the session, crash or no crash."""
        self.sessions[rid].migrated_to = target_id
        self._commit()

    def release_migrated(self, rid: str):
        """mig_release: the target's adoption commit landed — drop our
        copy.  Frame ids move WITH the table (same pool frames); staged
        payloads leave the host tier; the tombstone leaves the committed
        table at our next commit."""
        self.sessions.pop(rid, None)
        self.tables.pop(rid, None)
        self.store.discard_session_blocks(rid)

    def install_session(self, s: Session, table: BlockTable, cache1: Any,
                        *, claim_frames: bool = False):
        """Adopt a migrated-in session: re-admit it AHEAD of fresh
        requests with its cache ready to fast-forward into a lane.
        ``claim_frames`` re-asserts the table's frame ids in OUR
        allocator (restart recovery — a live in-process handoff moves
        already-owned frames of the shared fleet allocator)."""
        s.migrated_to = None
        self.sessions[s.rid] = s
        self.tables[s.rid] = table
        if claim_frames:
            for bid in table.bids():
                self.allocator.adopt(bid)
        self._resume_cache[s.rid] = cache1
        self._n_migrated_in += 1
        self.sched.submit_front(Request(s.rid, s.prompt, s.max_new_tokens))

    # -- static baseline -----------------------------------------------------
    def run_static(self, requests: Sequence[Request]) -> ServeResult:
        """FIFO batches of ``n_slots``; each batch decodes until its
        LONGEST sequence finishes (the hostage effect)."""
        outputs: Dict[str, List[int]] = {}
        ticks = prefills = 0
        reqs = list(requests)
        for i in range(0, len(reqs), self.n_slots):
            batch = reqs[i:i + self.n_slots]
            lens = {len(r.prompt) for r in batch}
            assert len(lens) == 1, \
                "static baseline batches unpadded prompts (uniform length)"
            toks = jnp.asarray(np.asarray([r.prompt for r in batch],
                                          np.int32))
            caches = self.bundle.init_caches(jax.random.PRNGKey(0),
                                             len(batch), self.t_max)
            logits, st = self._prefill(self.params, {"tokens": toks},
                                       caches)
            prefills += 1
            tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
            emitted = [[int(t)] for t in np.asarray(tok[:, 0])]
            for _ in range(max(r.max_new_tokens for r in batch) - 1):
                logits, st = self._decode(self.params, tok, st)
                tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
                ticks += 1
                for row, t in enumerate(np.asarray(tok[:, 0])):
                    emitted[row].append(int(t))
            for r, row in zip(batch, emitted):
                outputs[r.rid] = row[:r.max_new_tokens]
        return ServeResult(
            outputs=outputs, decode_ticks=ticks, prefills=prefills,
            emitted_tokens=sum(len(v) for v in outputs.values()),
            mode="static")

    # -- utilities -----------------------------------------------------------
    def warmup(self, prompt_lens: Sequence[int]):
        """Compile prefill per distinct prompt length + the decode step,
        outside any timed region."""
        for L in sorted(set(int(l) for l in prompt_lens)):
            tokens = jnp.zeros((1, L), jnp.int32)
            logits, _ = self._prefill(self.params, {"tokens": tokens},
                                      self._caches1)
            jax.block_until_ready(logits)
        nt, _, self.kv.caches, _ = self._slot_decode(
            self.params, jnp.asarray(self.last_token[:, None]),
            self.kv.caches, jnp.asarray(self.pos),
            jnp.asarray(self.active))
        jax.block_until_ready(nt)

    def close(self):
        if self.store is not None:
            self.store.close()


def servable_archs():
    """Arch ids the serving subsystem supports (decoder-only — the
    slot-masked decode has no encoder-state plumbing).  Used by the CLI
    front-ends as argparse choices so encoder-decoder archs are rejected
    up front instead of deep in engine construction."""
    from repro.configs import ARCH_IDS, get_smoke_config
    return [a for a in ARCH_IDS if not get_smoke_config(a).is_encdec]


def build_serve_engine(arch: str = "olmo-1b", *, smoke: bool = True,
                       n_slots: int = 4, t_max: int = 96, ctx=None,
                       pool_path: Optional[str] = None,
                       commit_every: int = 0, commit_mode: str = "sync",
                       n_shards: Optional[int] = None, retention: int = 2,
                       fault_hook=None, restore_mode: str = "cache",
                       retire_done: bool = False, seed: int = 0,
                       topology: Optional[str] = None,
                       dsm: Optional["CXL0Config"] = None,
                       engine_id: int = 0,
                       paged: bool = True,
                       block_tokens: int = BLOCK_TOKENS,
                       allocator: Optional[BlockAllocator] = None,
                       prefix_reuse: bool = False,
                       bundle=None, params=None):
    """One-stop construction shared by the launcher, the example, the
    fleet controller and the killable scenario worker: config -> bundle
    -> (sharded) params -> optional durable session store -> engine.
    Returns (engine, cfg).

    The durable tier stack is wired from ONE ``CXL0Config``: pass it
    directly via ``dsm`` (the launchers do) or let the legacy kwargs
    (``pool_path``/``commit_mode``/``n_shards``/``retention``/``topology``)
    be folded into one here.  ``ctx`` is the parallelism context (mesh),
    not the DSM context.

    Params are initialized from ``seed`` deterministically, so two
    processes built with the same arguments hold bit-identical weights —
    the property crash-replay bit-identity AND cross-engine prefix reuse
    rest on (the reuse key folds arch + smoke + seed).  Pass ``bundle``
    + ``params`` to share ONE weight pytree across engines (how the
    fleet controller hosts N engines of the same model)."""
    from repro.configs import get_config, get_smoke_config
    from repro.dsm.api import CXL0Config
    from repro.models.registry import build as build_model

    cfg = get_smoke_config(arch) if smoke else get_config(arch)
    if bundle is None:
        bundle = build_model(cfg, dec_pos_len=t_max)
    if params is None:
        params = bundle.init_params(jax.random.PRNGKey(seed))
    if ctx is not None and ctx.mesh is not None:
        from repro.train.elastic import shardings_for
        params = jax.tree_util.tree_map(
            jax.device_put, params, shardings_for(ctx, bundle.descs))
    store = None
    if dsm is None and pool_path is not None:
        # cost-driven shard count (and, with commit_mode="auto", the
        # schedule) come from the topology's placement policy, built by
        # the config at open time
        dsm = CXL0Config(path=pool_path, schedule=commit_mode,
                         n_shards=n_shards, retention=retention,
                         topology=topology, fault_hook=fault_hook)
    if dsm is not None:
        store = SessionStore(ctx=dsm.open(), engine_id=engine_id)
    engine = ServeEngine(
        bundle, params, n_slots=n_slots, t_max=t_max, ctx=ctx,
        store=store, commit_every=commit_every, restore_mode=restore_mode,
        retire_done=retire_done, paged=paged, block_tokens=block_tokens,
        allocator=allocator, prefix_reuse=prefix_reuse,
        prefix_key=f"{arch}|{'smoke' if smoke else 'full'}|s{seed}")
    return engine, cfg
