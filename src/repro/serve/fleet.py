"""Fleet controller: N serve engines sharing ONE CXL0 pool.

The paper's pooled-memory regime (CXL 2.0 switched pool and up) is N
compute hosts load/storing into one cache-coherent capacity substrate.
For serving, that substrate is the paged KV layout (serve.paging): every
engine ``open_cxl0``s the SAME pool directory under a per-engine
namespace (``e<i>/`` object names, ``engine: i`` manifests), and three
fleet mechanisms fall out of blocks-as-pool-objects:

* **cost-routed admission** — a new request goes to the engine with the
  lowest modelled time-to-first-token (``dsm.placement.choose_admission``:
  queue depth x decode tick + prefill replay vs pool block restore when
  the prompt's shared-prefix objects already exist).  Every decision is
  logged on the policy and assertable;
* **live session migration** — an in-flight session moves between
  engines without losing a token.  The four-phase protocol (each phase
  boundary is a kill point the scenario runner drives):

    1. ``mig_stage``   source freezes the session (slot freed — the
                       scheduler refills it the same tick), LStores its
                       dirty blocks and RStores them into the TARGET's
                       staging buffer (``FileStagingArea`` — the peer
                       host-memory arm).  Clean blocks move zero bytes:
                       the block table carries their pool entries;
    2. ``mig_commit``  source commits the handoff: ``migrated_to`` marker
                       + block table + dirty-block flushes in ONE
                       manifest.  From here the target owns the session,
                       crash or no crash;
    3. ``mig_adopt``   target assembles the cache staging-first-else-pool
                       (both arms hold identical bytes — the handoff
                       commit flushed exactly what was staged), re-admits
                       the session AHEAD of its queue, and commits the
                       adoption under its own namespace;
    4. ``mig_release`` source drops its copy; the tombstone leaves its
                       committed table at its next commit.

  A kill before phase 2's manifest lands leaves the source the owner (it
  resumes the session as usual; the orphaned staging copies are inert).
  A kill after phase 2 leaves a durable marker: ``resume()`` finds it via
  the source's recovered handoff table and completes the adoption —
  staging-or-pool, bit-identical either way;
* **cross-engine prefix reuse** — the content-addressed ``kvblk/``
  objects (serve.sessions) are unnamespaced on purpose: any engine's
  publish serves every engine's admissions.

Exactly-one-owner invariant: a session is served by the engine whose
newest manifest holds it WITHOUT a ``migrated_to`` marker; a marker
points at the adopter.  ``resume()`` re-establishes the invariant from
manifests alone.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Any, Callable, Dict, List, Optional, Sequence

from repro.dsm.cluster import FileStagingArea
from repro.dsm.placement import PlacementPolicy
from repro.serve.engine import ServeEngine, ServeResult, build_serve_engine
from repro.serve.paging import (BLOCK_TOKENS, BlockAllocator, BlockTable,
                                STATE_BLOCK, prefix_hash, shared_head_name)
from repro.serve.scheduler import Request

#: the four kill points of the migration protocol, in order — the hook
#: fires AFTER each phase's effects (same convention as the committer's
#: fault points: "pre_flush" fires before the flush, "mig_commit" fires
#: after the handoff manifest landed)
MIGRATION_POINTS = ("mig_stage", "mig_commit", "mig_adopt", "mig_release")

DEFAULT_TOPOLOGY = "cxl20-switched-pool"


@dataclasses.dataclass
class FleetResult:
    outputs: Dict[str, List[int]]         # rid -> tokens, fleet-wide
    per_engine: Dict[int, ServeResult]
    migrations: int
    prefix_hits: int
    emitted_tokens: int


class FleetController:
    """N engines, one pool, one shared frame allocator, one cost model.

    Engine ids are 1-based: id 0 is the single-engine legacy layout
    (unprefixed names), so a fleet pool and a single-engine pool can
    never alias each other's objects."""

    def __init__(self, arch: str = "olmo-1b", *, pool_path: str,
                 n_engines: int = 2, smoke: bool = True, n_slots: int = 2,
                 t_max: int = 48, commit_every: int = 2,
                 commit_mode: str = "sync",
                 topology: Optional[str] = None,
                 prefix_reuse: bool = True,
                 block_tokens: int = BLOCK_TOKENS, seed: int = 0,
                 restore_mode: str = "cache", retire_done: bool = False,
                 fault_hook=None,
                 mig_hook: Optional[Callable] = None,
                 bundle=None, params=None):
        assert n_engines >= 1, n_engines
        self.pool_path = pool_path
        self.topology = topology or DEFAULT_TOPOLOGY
        self.policy = PlacementPolicy(self.topology)
        self.mig_hook = mig_hook
        #: the migration staging arm lives INSIDE the pool directory
        #: (the pool only reads objects/ and manifests/) so one path
        #: names the whole shared substrate and staged handoffs survive
        #: process restarts like real peer host memory survives a
        #: SIBLING's crash
        self.staging = FileStagingArea(os.path.join(pool_path, "staging"))
        # ONE frame pool fleet-wide: migration moves a table's frames
        # between engines without alloc/free traffic
        frames = n_slots * (-(-t_max // block_tokens) + 1) + 8
        allocator = BlockAllocator(max(64, 4 * frames * n_engines))
        # everything a later add_engine() must replay to build an
        # identical serving front (bundle/params/allocator attach below)
        self._arch = arch
        self._build_kwargs = dict(
            smoke=smoke, n_slots=n_slots, t_max=t_max,
            pool_path=pool_path, commit_every=commit_every,
            commit_mode=commit_mode, topology=topology, seed=seed,
            restore_mode=restore_mode, retire_done=retire_done,
            fault_hook=fault_hook, paged=True,
            block_tokens=block_tokens, prefix_reuse=prefix_reuse)
        self._bundle, self._params = bundle, params
        self.engines: Dict[int, ServeEngine] = {}
        self.allocator = allocator
        for _ in range(n_engines):
            self.add_engine()
        self.n_migrations = 0
        self.migration_log: List[tuple] = []
        #: finished work of engines that have since been drained away —
        #: results outlive the engine that produced them
        self._retired: Dict[int, ServeResult] = {}

    # -- elastic membership --------------------------------------------------
    def add_engine(self) -> int:
        """Grow the fleet by one serving front (next free 1-based id —
        ids are never reused, so a re-added engine can't alias a closed
        one's pool namespace).  The new engine shares the fleet's weight
        pytree and frame allocator; it serves admissions from its first
        tick.  Returns the new engine id."""
        eid = max(self.engines, default=0) + 1
        eng, cfg = build_serve_engine(
            self._arch, engine_id=eid, allocator=self.allocator,
            bundle=self._bundle, params=self._params,
            **self._build_kwargs)
        self.engines[eid] = eng
        self._bundle, self._params = eng.bundle, eng.params
        self.cfg = cfg
        return eid

    def remove_engine(self, eid: int):
        """Shrink the fleet by draining one engine: every RUNNING session
        live-migrates (token-lossless, the four-phase protocol) to the
        least-loaded peer, every PENDING request re-routes through
        cost-priced admission, then the engine closes.  Its pool
        namespace stays durable — history is never rewritten."""
        assert len(self.engines) > 1, "cannot remove the last engine"
        e = self.engines[eid]
        for rid in [r for r in e.sched.admission_order
                    if r in e.sched.running]:
            depths = {i: d for i, d in self.queue_depths().items()
                      if i != eid}
            dst = min(sorted(depths), key=lambda i: depths[i])
            self.migrate(rid, eid, dst)
        pending = list(e.sched.pending)
        e.sched.pending.clear()
        del self.engines[eid]
        if pending:
            self.submit(pending)
        self._retired[eid] = e.finish()
        e.close()

    # -- routing -------------------------------------------------------------
    def queue_depths(self) -> Dict[int, int]:
        return {i: e.sched.n_running + len(e.sched.pending)
                for i, e in self.engines.items()}

    def _prefix_reusable(self, e: ServeEngine, prompt) -> bool:
        if not e.prefix_reuse:
            return False
        h = prefix_hash(e.prefix_key, prompt, e.block_tokens)
        return e.store.pool.max_version(shared_head_name(h)) > 0

    def submit(self, requests: Sequence[Request]):
        """Route each request to the engine the cost model picks.  The
        pool is shared, so prefix reusability is fleet-global — it
        lowers every engine's fill cost equally and the queue-depth term
        decides (logged per request as an ``admit`` decision)."""
        for r in requests:
            if any(r.rid in e.sessions or r.rid in e.results
                   for e in self.engines.values()):
                continue                      # recovered somewhere already
            first = next(iter(self.engines.values()))
            nbytes = len(r.prompt) * first.pager.token_nbytes
            hit = self._prefix_reusable(first, r.prompt)
            eid = self.policy.choose_admission(
                r.rid, self.queue_depths(), nbytes,
                {i: hit for i in self.engines})
            self.engines[eid].submit([r])

    # -- the fleet loop ------------------------------------------------------
    @property
    def done(self) -> bool:
        return all(e.sched.done for e in self.engines.values())

    def tick(self, *, rebalance: bool = True):
        """One lockstep round: every engine ticks, then at most one
        cost-approved rebalancing migration."""
        for e in self.engines.values():
            if not e.sched.done:
                e.tick()
        if rebalance:
            self.maybe_rebalance()

    def run(self, requests: Optional[Sequence[Request]] = None, *,
            rebalance: bool = True) -> FleetResult:
        if requests:
            self.submit(requests)
        ticks0 = {i: e._tick for i, e in self.engines.items()}
        while not self.done:
            self.tick(rebalance=rebalance)
        return self.finish(ticks0)

    def finish(self, ticks0: Optional[Dict[int, int]] = None) -> FleetResult:
        ticks0 = ticks0 or {}
        per = dict(self._retired)
        per.update({i: e.finish(ticks0.get(i, 0))
                    for i, e in self.engines.items()})
        outputs: Dict[str, List[int]] = {}
        for r in per.values():
            outputs.update(r.outputs)
        return FleetResult(
            outputs=outputs, per_engine=per,
            migrations=self.n_migrations,
            prefix_hits=sum(r.prefix_hits for r in per.values()),
            emitted_tokens=sum(r.emitted_tokens for r in per.values()))

    # -- rebalancing ---------------------------------------------------------
    def maybe_rebalance(self) -> Optional[str]:
        """Move one running session from an engine with a backlog to an
        idle engine IF the cost model approves: the freed slot admits the
        backlog next tick, the moved session keeps decoding on the
        target.  Deterministic: first (src, dst) pair in id order, the
        most recently admitted running session (least sunk cost)."""
        for si, src in sorted(self.engines.items()):
            if not src.sched.pending or not src.sched.running:
                continue
            for di, dst in sorted(self.engines.items()):
                if di == si or dst.sched.pending \
                        or not dst.sched.free_slots():
                    continue
                rid = next(r for r in reversed(src.sched.admission_order)
                           if r in src.sched.running)
                depths = self.queue_depths()
                # dirty payload ~ the partial tail block + state
                nbytes = src.pager.token_nbytes * src.pager.block_tokens
                if self.policy.choose_migration(
                        rid, nbytes, depths[si] - depths[di]):
                    self.migrate(rid, si, di)
                    return rid
        return None

    # -- live migration ------------------------------------------------------
    def _point(self, point: str, rid: str, src: int, dst: int):
        self.migration_log.append((point, rid, src, dst))
        if self.mig_hook is not None:
            self.mig_hook(point, rid=rid, src=src, dst=dst)

    def migrate(self, rid: str, src_id: int, dst_id: int):
        """The four-phase live handoff (docstring up top).  Bit-identical
        token stream: the adopted cache bytes equal the frozen lane
        bytes, whichever arm (staging or pool) they travelled."""
        src, dst = self.engines[src_id], self.engines[dst_id]
        session, table, cache1 = src.begin_migration(rid)
        src.stage_migration(rid, cache1, self.staging.proxy(dst_id),
                            tag=src._tick)
        self._point("mig_stage", rid, src_id, dst_id)
        src.commit_handoff(rid, dst_id)
        self._point("mig_commit", rid, src_id, dst_id)
        cache = self._read_migrated_cache(dst, dst_id, table)
        dst.install_session(session, table, cache)
        dst._commit()                     # adoption commit: dst owns rid
        self._point("mig_adopt", rid, src_id, dst_id)
        src.release_migrated(rid)
        self._point("mig_release", rid, src_id, dst_id)
        self.n_migrations += 1

    def _read_migrated_cache(self, dst: ServeEngine, dst_id: int,
                             table: BlockTable):
        """Assemble a handed-off cache with staging-or-pool precedence:
        the RStored copy in the TARGET's buffer if it validates (the hot
        arm — no pool read), else the pool entry the block table carries.
        The handoff commit flushed exactly the staged bytes, so the arms
        are interchangeable — which is what the kill-cell equivalence
        asserts."""
        pager = dst.pager
        tpl = {ref.name: (pager.state_template if blk == STATE_BLOCK
                          else pager.block_template)
               for blk, ref in table.refs.items()}
        view = self.staging.view(dst_id, tpl)
        blocks: Dict[int, Any] = {}
        for blk, ref in table.refs.items():
            hit = view.staging.get(ref.name)
            if hit is not None:
                blocks[blk] = hit[1]
            else:
                assert ref.entry is not None, \
                    f"block {ref.name} neither staged nor durable"
                blocks[blk] = dst.store.pool.read_entry(
                    ref.name, ref.entry, tpl[ref.name])
        return pager.assemble(blocks)

    # -- crash recovery ------------------------------------------------------
    def resume(self) -> Dict[int, Optional[int]]:
        """Every engine recovers its own newest manifest, then handoffs
        whose adoption never committed are completed: the source's
        recovered ``migrated_to`` tombstone carries the block table, the
        target adopts staging-or-pool and commits, the source's copy is
        dropped.  Idempotent — a tombstone whose target already owns the
        session (adoption committed before the crash) is just released."""
        steps = {i: e.resume() for i, e in self.engines.items()}
        for si, src in sorted(self.engines.items()):
            for rid, table in list(src._handoffs.items()):
                s = src.sessions.get(rid)
                if s is None or s.migrated_to is None:
                    src._handoffs.pop(rid, None)
                    continue
                di, dst = s.migrated_to, self.engines.get(s.migrated_to)
                if dst is None:
                    continue                  # target not in this fleet
                if rid not in dst.sessions and rid not in dst.results:
                    if table is None:
                        continue              # no table: nothing to adopt
                    cache = self._read_migrated_cache(dst, di, table)
                    dst.install_session(s, table, cache,
                                        claim_frames=True)
                    dst._commit()             # adoption commit
                    self._point("mig_adopt", rid, si, di)
                src.release_migrated(rid)
                src._handoffs.pop(rid, None)
                self._point("mig_release", rid, si, di)
        return steps

    def close(self):
        for e in self.engines.values():
            e.close()
