"""Tiered KV-cache manager: per-slot cache blocks in HBM, cold sessions
in the staging/pool tiers.

The decode batch's caches live as ONE batched pytree on device (the HBM
tier) with ``n_slots`` lanes on the per-leaf batch axis (layer-stacked
groups put batch at axis 1 — the axis map comes from the cache
descriptors via ``train.step.cache_batch_axes``).  Slot surgery is two
jitted primitives:

* ``write_slot(slot, cache1)`` — insert a single-sequence cache (fresh
  prefill, or a restored cold session) into a lane;
* ``read_slot(slot)``         — extract a lane as a single-sequence cache
  (for spilling, or for staging into a durable commit).

Cold sessions leave HBM through the CXL0 tiers (``dsm.tiers``):

* ``stage(name, cache1)``            — LStore into the worker's host
  object tier; from there the FliT committer RFlushes it durably as part
  of a session commit (serve.sessions);
* ``spill(name, cache1, peer=...)``  — additionally RStore the copy into
  a PEER worker's host buffer (survives OUR crash without pool I/O);
* ``spill_durable(name, cache1)``    — immediate sharded RFlush into the
  pool, leaves partitioned into byte-balanced blocks
  (``pool.partition_leaves`` under ``rflush_sharded``); returns the
  manifest entry needed to restore;
* ``spill_auto(name, cache1, peer=...)`` — cost-driven routing: the
  placement policy (``dsm.placement``) prices staging vs (sharded) pool
  for this cache's size under the active emulated topology and picks the
  cheaper tier — the decision is logged on the policy;
* ``restore(name, entry=...)``       — best tier first: HBM host object,
  then peer staging, then pool — byte-identical round-trip in all cases
  (streamed ``.cxl0`` frames store each leaf's raw bytes + dtype/shape
  header, so bf16 et al. survive exactly; see ``dsm.stream``).

This manager moves WHOLE single-sequence caches between tiers.  The
serving engine's durable path no longer uses that granularity: it
commits fixed-size token-axis blocks through ``serve.paging`` +
``SessionStore.commit_paged`` so cold-session state is O(blocks
touched).  Whole-lane spill/restore stays as the legacy layout
(``ServeEngine(paged=False)``, equivalence-tested) and as the
mid-decode HBM-pressure escape hatch.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.dsm.pool import manifest_entry, partition_leaves
from repro.dsm.tiers import TierManager
from repro.train.step import cache_batch_axes


class TieredKVCache:
    def __init__(self, bundle, n_slots: int, t_max: int,
                 tiers: Optional[TierManager] = None,
                 placement=None, parallel=None):
        self.n_slots = n_slots
        self.t_max = t_max
        self.tiers = tiers
        #: cost-driven spill routing (repro.dsm.placement.PlacementPolicy);
        #: when set, ``spill_auto`` replaces the caller-chosen tier.
        self.placement = placement
        #: ParallelCtx (parallel.sharding): when its mesh is live, the
        #: batched KV lanes are device-sharded per the cache descriptors'
        #: logical axes (heads on the model axis), spill block counts
        #: default to the mesh's device count, and durable spills run
        #: device-local (each block pipeline drains its devices' buffers
        #: — no host gather of the whole lane).
        self.parallel = parallel
        self.axes = cache_batch_axes(bundle)
        # zero-initialized batched cache (cache descs are init="zeros")
        self.caches = bundle.init_caches(jax.random.PRNGKey(0), n_slots,
                                         t_max)
        if parallel is not None and getattr(parallel, "mesh", None) \
                is not None:
            from repro.models.params import tree_map_descs
            from repro.parallel.sharding import spec_for
            shardings = tree_map_descs(
                lambda d: jax.sharding.NamedSharding(
                    parallel.mesh, spec_for(parallel, d)),
                bundle.cache_descs(n_slots, t_max))
            self.caches = jax.tree_util.tree_map(
                jax.device_put, self.caches, shardings)
        self._template1 = bundle.abstract_caches(1, t_max)
        tm = jax.tree_util.tree_map

        def _write(full, one, slot):
            return tm(lambda f, o, a: jax.lax.dynamic_update_slice_in_dim(
                f, o.astype(f.dtype), slot, axis=a), full, one, self.axes)

        def _read(full, slot):
            return tm(lambda f, a: jax.lax.dynamic_slice_in_dim(
                f, slot, 1, axis=a), full, self.axes)

        self._write = jax.jit(_write, donate_argnums=0)
        self._read = jax.jit(_read)

    # -- HBM slot surgery ----------------------------------------------------
    def write_slot(self, slot: int, cache1: Any):
        """Insert a single-sequence cache into lane ``slot``."""
        self.caches = self._write(self.caches, cache1,
                                  jnp.int32(slot))

    def read_slot(self, slot: int) -> Any:
        """Extract lane ``slot`` as a single-sequence cache."""
        return self._read(self.caches, jnp.int32(slot))

    @property
    def template1(self):
        """Single-sequence cache pytree prototype (for pool unflattening)."""
        return self._template1

    # -- tier movement -------------------------------------------------------
    def _need_tiers(self) -> TierManager:
        assert self.tiers is not None, "no TierManager configured"
        return self.tiers

    def stage(self, name: str, cache1: Any) -> int:
        """LStore a session cache into the host object tier; returns the
        version the next RFlush/commit of ``name`` will write."""
        t = self._need_tiers()
        t.lstore(name, cache1)
        return t.versions[name]

    def spill(self, name: str, cache1: Any, *,
              peer: Optional[TierManager] = None) -> int:
        """Evict to the host tier; optionally RStore-replicate to a peer's
        staging buffer (the cache then survives our crash without having
        been flushed)."""
        version = self.stage(name, cache1)
        if peer is not None:
            self._need_tiers().rstore(name, peer, tag=version)
        return version

    def spill_durable(self, name: str, cache1: Any,
                      n_blocks: Optional[int] = None) -> dict:
        """Evict straight to the pool: sharded RFlush over byte-balanced
        leaf blocks.  Returns the manifest entry for ``restore``."""
        t = self._need_tiers()
        self.stage(name, cache1)
        n = n_blocks or len(self.block_layout())
        obj = t.rflush_sharded(name, n,
                               device_local=self.parallel is not None)
        return manifest_entry(obj)

    def spill_auto(self, name: str, cache1: Any, *,
                   peer: Optional[TierManager] = None) -> dict:
        """Cost-driven eviction: the placement policy prices staging vs
        pool for THIS cache's size under the active topology and routes
        accordingly (decision logged on the policy).  Returns
        ``{"tier": ..., ...}`` — pass ``entry`` (pool spills) back into
        ``restore``.  A staging choice with no peer degrades to the host
        object tier alone (still restorable while we live)."""
        assert self.placement is not None, "no PlacementPolicy configured"
        from repro.dsm.emu import tree_nbytes
        nbytes = tree_nbytes(cache1)
        tier = self.placement.choose_spill(name, nbytes)
        if tier == "staging":
            return {"tier": "staging", "nbytes": nbytes,
                    "version": self.spill(name, cache1, peer=peer)}
        n = self.placement.choose_shards(nbytes, name)
        return {"tier": "pool", "nbytes": nbytes,
                "entry": self.spill_durable(name, cache1, n_blocks=n)}

    def restore(self, name: str, entry: Optional[dict] = None,
                *, drop_hot: bool = False) -> Optional[Any]:
        """Bring a session cache back, best tier first: the host object
        tier (still resident), then OUR staging buffer (a peer RStored it
        here), then the pool (needs the manifest ``entry`` from
        ``spill_durable`` or a session-commit manifest).  Returns None if
        no tier holds it."""
        t = self._need_tiers()
        if name in t.hbm:
            tree = t.hbm[name]
            if drop_hot:
                t.ldiscard(name)
            return tree
        staged = t.rload(name)
        if staged is not None:
            return staged
        if entry is not None:
            return t.pool.read_entry(name, entry, self._template1)
        return None

    def discard(self, name: str):
        """Drop a session cache from the host tier (session finished)."""
        self._need_tiers().ldiscard(name)

    # -- block layout --------------------------------------------------------
    def block_layout(self, n_blocks: Optional[int] = None) -> List[List[int]]:
        """Byte-balanced partition of the per-slot cache leaves into spill
        blocks (``pool.partition_leaves`` — the same layout
        ``rflush_sharded`` writes).  Default block count: one per device
        of the configured mesh (else one per local device), clamped by
        the leaf count."""
        leaves = jax.tree_util.tree_leaves(self._template1)
        nbytes = [int(np.prod(l.shape)) * l.dtype.itemsize for l in leaves]
        mesh = getattr(self.parallel, "mesh", None)
        if n_blocks:
            n = n_blocks
        elif mesh is not None:
            from repro.dsm.meshio import mesh_device_count
            n = mesh_device_count(mesh)
        else:
            n = max(jax.local_device_count(), 1)
        return partition_leaves(nbytes, n)
