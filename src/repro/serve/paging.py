"""Paged KV layout: fixed-size token-axis blocks instead of whole-lane
pytrees.

The legacy durable-serving path spilled ONE ``kv/<rid>`` object per
session — the whole per-slot cache pytree, re-flushed at every commit
even though a decode tick appends exactly one token.  The paged layout
splits every cache leaf that HAS a token axis (logical axis name
``seq_kv`` in the cache descriptors — attention K/V; recurrent
mamba/rwkv state has none and rides in a separate always-dirty STATE
block) into fixed-``block_tokens`` spans:

* block ``k`` of session ``rid`` covers decode positions
  ``[k*bt, (k+1)*bt)`` and lives in the pool as object
  ``kv/<rid>/b<k>`` — a LIST of the per-leaf token slices, written
  through the same LStore -> RFlush path as everything else, so it gets
  the PR-7 streamed ``.cxl0`` frames + ``SpillArena`` buffers for free;
* the decode cache is append-only along the token axis, so a block is
  IMMUTABLE once the session's position passes its upper edge — a
  session commit re-flushes only the blocks its position touched since
  the last commit (the partial tail + the recurrent STATE block), making
  cold state O(blocks touched) instead of O(whole cache);
* a per-session **block table** (ordinal -> ``BlockRef``) records each
  block's pool object name, version-entry and valid-token count.  The
  table rides in the session-commit manifest meta, and the manifest's
  object dict carries BOTH the freshly flushed blocks and the carried
  entries of every clean block (``SessionStore`` merges them in a
  delegated completeOp) — so any single manifest is a complete,
  self-contained description of every live session's cache.

**Free-list allocator.**  ``BlockAllocator`` models the pool's hot
block-frame budget: every materialized block holds one frame id
(``bid``), freed when its session retires.  Admission at fleet scale is
bounded by frames, not whole-lane caches — a million idle sessions cost
table entries, not HBM lanes.  ``alloc``/``free``/``adopt`` never
double-assign a frame (property-tested); ``adopt`` claims a specific id
recorded in a recovered or migrated-in block table.

**Content-addressed prefix blocks.**  A prompt-pure block (entirely
inside the prompt) is a deterministic function of (arch key, prompt
prefix up to its upper edge) — two sessions sharing a prompt prefix
share those block BYTES.  ``prefix_hash`` keys them as pool objects
``kvblk/<hash>`` published once (plus a ``kvhead/<hash-of-full-prompt>``
object holding the partial tail + recurrent state + first sampled
token), so a second engine serving the same prompt restores blocks and
skips the prefill entirely (serve.sessions ``publish_prefix`` /
``load_prefix``).
"""
from __future__ import annotations

import dataclasses
import zlib
from typing import Any, Dict, List, Optional, Sequence

import jax
import numpy as np

from repro.dsm.meshio import assemble_leaf

BLOCK_TOKENS = 16
#: ordinal of the recurrent-state pseudo-block (leaves with no token
#: axis — mamba conv/ssm state, rwkv state).  Always dirty while the
#: session runs: recurrent state genuinely changes every token.
STATE_BLOCK = -1


def cache_token_axes(bundle):
    """Per-leaf index of the TOKEN axis (logical name ``seq_kv``) in the
    decode-cache pytree, or -1 for leaves without one (recurrent state).
    Mirror of ``train.step.cache_batch_axes`` — slot caches are sliced
    into token blocks by descriptor axis names, never fixed positions."""
    from repro.models.params import tree_map_descs
    return tree_map_descs(
        lambda d: d.logical.index("seq_kv") if "seq_kv" in d.logical else -1,
        bundle.cache_descs(1, 2))


def block_object_name(rid: str, blk: int, ns: str = "") -> str:
    """Pool object name of session ``rid``'s block ``blk`` under an
    engine namespace (``e<i>/`` in a fleet, empty for engine 0)."""
    if blk == STATE_BLOCK:
        return f"{ns}kv/{rid}/state"
    return f"{ns}kv/{rid}/b{blk}"


def shared_block_name(h: int) -> str:
    """Content-addressed prompt-prefix block (cross-engine, unnamespaced
    on purpose: the pool is the shared substrate)."""
    return f"kvblk/{h:08x}"


def shared_head_name(h: int) -> str:
    """Content-addressed prefill head: partial tail block + recurrent
    state + the first sampled token, keyed by the FULL prompt hash."""
    return f"kvhead/{h:08x}"


def prefix_hash(key: str, tokens: Sequence[int], block_tokens: int) -> int:
    """Deterministic content address of a prompt prefix under one model
    identity (``key`` folds arch + params seed: reuse across engines is
    only sound when their weights are bit-identical)."""
    doc = f"{key}|bt{block_tokens}|".encode()
    return zlib.crc32(np.asarray(tokens, np.int32).tobytes(), zlib.crc32(doc))


class OutOfBlocksError(RuntimeError):
    """The pool's hot block-frame budget is exhausted — admission control
    should shed or migrate load instead of overcommitting frames."""


class BlockAllocator:
    """Free-list over ``n_blocks`` frame ids.  The invariant (property-
    tested in tests/test_paging.py): a frame is owned by at most one
    holder at any time — ``alloc``/``adopt`` never hand out an id that is
    already assigned, ``free`` rejects ids it does not own."""

    def __init__(self, n_blocks: int):
        assert n_blocks >= 1, n_blocks
        self.n_blocks = n_blocks
        self._free: List[int] = list(range(n_blocks - 1, -1, -1))
        self._owned: set = set()

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def allocated(self) -> frozenset:
        return frozenset(self._owned)

    def alloc(self) -> int:
        if not self._free:
            raise OutOfBlocksError(
                f"all {self.n_blocks} block frames are assigned")
        bid = self._free.pop()
        self._owned.add(bid)
        return bid

    def adopt(self, bid: int):
        """Claim a SPECIFIC frame id — a recovered or migrated-in block
        table re-asserts ownership of the frames it recorded."""
        if not (0 <= bid < self.n_blocks):
            raise ValueError(f"bid {bid} outside pool of {self.n_blocks}")
        if bid in self._owned:
            raise OutOfBlocksError(f"bid {bid} is already assigned")
        self._owned.add(bid)
        self._free.remove(bid)

    def free(self, bid: int):
        if bid not in self._owned:
            raise ValueError(f"bid {bid} is not assigned")
        self._owned.discard(bid)
        self._free.append(bid)


@dataclasses.dataclass
class BlockRef:
    """One block-table entry: where block ``blk`` of a session lives."""
    blk: int                      # ordinal (STATE_BLOCK for recurrent state)
    bid: int                      # allocator frame id
    tokens: int                   # valid tokens in the span (0 for STATE)
    name: str                     # pool object name (may be a shared kvblk/)
    entry: Optional[dict] = None  # manifest entry once durable

    def to_meta(self) -> dict:
        return {"blk": self.blk, "bid": self.bid, "tokens": self.tokens,
                "name": self.name, "entry": self.entry}

    @classmethod
    def from_meta(cls, d: dict) -> "BlockRef":
        return cls(blk=int(d["blk"]), bid=int(d["bid"]),
                   tokens=int(d["tokens"]), name=d["name"],
                   entry=d.get("entry"))


@dataclasses.dataclass
class BlockTable:
    """Per-session block map.  ``refs[k]`` covers tokens
    ``[k*bt, (k+1)*bt)``; ``refs[STATE_BLOCK]`` is the recurrent-state
    pseudo-block.  Round-trips bit-identically through manifest meta
    (property-tested)."""
    refs: Dict[int, BlockRef] = dataclasses.field(default_factory=dict)

    def to_meta(self) -> dict:
        return {"blocks": [self.refs[k].to_meta()
                           for k in sorted(self.refs)]}

    @classmethod
    def from_meta(cls, d: dict) -> "BlockTable":
        t = cls()
        for bd in d.get("blocks", ()):
            ref = BlockRef.from_meta(bd)
            t.refs[ref.blk] = ref
        return t

    def bids(self) -> List[int]:
        return [r.bid for r in self.refs.values()]

    def entries(self) -> Dict[str, dict]:
        """Manifest entries of every DURABLE block — what the session
        store carries forward into each completeOp so one manifest
        references the whole cache without re-flushing clean blocks."""
        return {r.name: r.entry for r in self.refs.values()
                if r.entry is not None}


class BlockPager:
    """Host-side slicing/assembly between whole slot caches and token
    blocks.  Pure numpy — blocks are spilled/restored on the host path
    anyway (LStore trees are host copies), and host slicing keeps the
    jitted slot surgery untouched, so the paged engine is bit-identical
    to the legacy whole-lane path by construction."""

    def __init__(self, bundle, t_max: int,
                 block_tokens: int = BLOCK_TOKENS):
        assert block_tokens >= 1, block_tokens
        self.t_max = t_max
        self.block_tokens = block_tokens
        template = bundle.abstract_caches(1, t_max)
        self._leaves, self._treedef = jax.tree_util.tree_flatten(template)
        axes = jax.tree_util.tree_leaves(cache_token_axes(bundle))
        assert len(axes) == len(self._leaves)
        self._axes = [int(a) for a in axes]
        self.tok_idx = [i for i, a in enumerate(self._axes) if a >= 0]
        self.state_idx = [i for i, a in enumerate(self._axes) if a < 0]

        def _blk_struct(i):
            l = self._leaves[i]
            shape = list(l.shape)
            shape[self._axes[i]] = block_tokens
            return jax.ShapeDtypeStruct(tuple(shape), l.dtype)

        #: pytree template of one block object (list of token slices) —
        #: independent of t_max, so blocks outlive lane-geometry changes
        self.block_template = [_blk_struct(i) for i in self.tok_idx]
        self.state_template = [self._leaves[i] for i in self.state_idx]
        #: head object = tail block slices + recurrent state + token0
        self.head_template = (self.block_template + self.state_template
                              + [jax.ShapeDtypeStruct((1,), np.int32)])

    # -- geometry ------------------------------------------------------------
    @property
    def token_nbytes(self) -> int:
        """Cache bytes per decode position across every token-axis leaf —
        the unit the fleet cost model prices admissions/migrations in."""
        per = sum(int(np.prod(s.shape)) * s.dtype.itemsize
                  for s in self.block_template)
        return max(1, per // self.block_tokens)

    def n_blocks(self, pos: int) -> int:
        return -(-pos // self.block_tokens) if pos > 0 else 0

    def tokens_in_block(self, blk: int, pos: int) -> int:
        return max(0, min(self.block_tokens, pos - blk * self.block_tokens))

    # -- slicing -------------------------------------------------------------
    def _host_leaves(self, cache1: Any) -> List[np.ndarray]:
        leaves = jax.tree_util.tree_leaves(cache1)
        assert len(leaves) == len(self._leaves), \
            (len(leaves), len(self._leaves))
        # assemble_leaf copies mesh-sharded lanes per device buffer (and
        # passes host/unsharded leaves through np.asarray-equivalently),
        # so paged spills of a device-sharded cache never demand one
        # monolithic transfer — bit-identical output either way
        return [assemble_leaf(l) for l in leaves]

    def slice_block(self, host: List[np.ndarray], blk: int
                    ) -> List[np.ndarray]:
        """Token slices of block ``blk`` over every token-axis leaf,
        zero-padded to ``block_tokens`` (uniform shape: one template fits
        every block incl. the partial tail, and a partial block's unseen
        positions are zeros in the source cache anyway)."""
        bt = self.block_tokens
        lo = blk * bt
        out = []
        for i in self.tok_idx:
            a, ax = host[i], self._axes[i]
            idx = tuple(slice(lo, lo + bt) if j == ax else slice(None)
                        for j in range(a.ndim))
            part = a[idx]
            if part.shape[ax] < bt:
                pad = [(0, bt - part.shape[ax]) if j == ax else (0, 0)
                       for j in range(a.ndim)]
                part = np.pad(part, pad)
            out.append(np.ascontiguousarray(part))
        return out

    def slice_state(self, host: List[np.ndarray]) -> List[np.ndarray]:
        return [np.ascontiguousarray(host[i]) for i in self.state_idx]

    def slice_dirty(self, cache1: Any, pos: int, table: BlockTable
                    ) -> Dict[int, List[np.ndarray]]:
        """Blocks needing (re)staging for a commit at position ``pos``:
        every span the position entered or grew inside since the block
        was last durable, plus the STATE pseudo-block.  Full durable
        blocks are skipped — the append-only token axis makes them
        immutable, which is the whole O(blocks touched) claim."""
        host = self._host_leaves(cache1)
        out: Dict[int, List[np.ndarray]] = {}
        for blk in range(self.n_blocks(pos)):
            want = self.tokens_in_block(blk, pos)
            ref = table.refs.get(blk)
            if ref is not None and ref.entry is not None \
                    and ref.tokens >= want:
                continue
            out[blk] = self.slice_block(host, blk)
        if self.state_idx:
            out[STATE_BLOCK] = self.slice_state(host)
        return out

    # -- assembly ------------------------------------------------------------
    def assemble(self, blocks: Dict[int, List[np.ndarray]]) -> Any:
        """Rebuild a single-slot cache pytree from block payloads.
        Unfilled positions are zeros — exactly what the source cache held
        beyond its decode position, so restore is bit-identical."""
        bt = self.block_tokens
        leaves = [np.zeros(l.shape, l.dtype) for l in self._leaves]
        for blk, parts in blocks.items():
            if blk == STATE_BLOCK:
                for i, part in zip(self.state_idx, parts):
                    leaves[i] = np.asarray(part).astype(
                        leaves[i].dtype, copy=False)
                continue
            lo = blk * bt
            for i, part in zip(self.tok_idx, parts):
                ax = self._axes[i]
                hi = min(lo + bt, leaves[i].shape[ax])
                if hi <= lo:
                    continue
                dst = tuple(slice(lo, hi) if j == ax else slice(None)
                            for j in range(leaves[i].ndim))
                src = tuple(slice(0, hi - lo) if j == ax else slice(None)
                            for j in range(np.asarray(part).ndim))
                leaves[i][dst] = np.asarray(part)[src]
        return jax.tree_util.tree_unflatten(self._treedef, leaves)

    # -- prefix-reuse payloads ----------------------------------------------
    def head_payload(self, host: List[np.ndarray], prompt_len: int,
                     tok0: int) -> List[np.ndarray]:
        """The ``kvhead`` object: the partial tail block of the prompt
        (possibly all-zero when the prompt length is block-aligned) + the
        recurrent state + the first sampled token."""
        tail = prompt_len // self.block_tokens
        return (self.slice_block(host, tail) + self.slice_state(host)
                + [np.asarray([tok0], np.int32)])

    def split_head(self, payload: List[np.ndarray]):
        """Inverse of ``head_payload`` -> (tail slices, state, tok0)."""
        nt = len(self.tok_idx)
        ns = len(self.state_idx)
        tail, state, tok0 = (payload[:nt], payload[nt:nt + ns],
                             int(np.asarray(payload[nt + ns])[0]))
        return tail, state, tok0

    def prompt_block_hashes(self, key: str, prompt: Sequence[int]
                            ) -> List[int]:
        """Content hashes of every FULL prompt-pure block: block k is
        keyed by the prompt prefix up to its upper edge, so two prompts
        sharing a prefix share the early block objects."""
        bt = self.block_tokens
        return [prefix_hash(key, prompt[:(k + 1) * bt], bt)
                for k in range(len(prompt) // bt)]
