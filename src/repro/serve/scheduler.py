"""Slot-based continuous-batching scheduler (pure state machine, no JAX).

The decode batch has ``n_slots`` fixed lanes.  A slot is either FREE or
RUNNING one request; the scheduler's contract (property-tested in
tests/test_serve.py):

* **admission never exceeds the slot count** — at most ``n_slots``
  requests run at once, everything else waits in the FIFO queue;
* **finished sequences free their slot within one step** — ``release``
  happens in the same scheduler tick that observes completion, so the
  next ``admit`` can refill the lane immediately (this is the whole
  throughput win over static batching: no lane idles behind the longest
  sequence of a batch);
* **FIFO fairness under oversubscription** — requests are admitted in
  arrival order; a request never overtakes an earlier one into a slot.

The scheduler owns WHICH request runs WHERE and nothing else: token
state lives with the engine, cache blocks with the KV manager.  That
keeps it a deterministic, millisecond-testable state machine.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Dict, List, Optional, Sequence, Tuple


@dataclasses.dataclass(frozen=True)
class Request:
    """One serving request: a prompt and a decode budget.

    ``arrival`` is the tick the request enters the system (0 = present
    from the start, the pre-arrival-time behaviour).  Only arrival-aware
    drivers (scale.traffic / scale.autoscaler) read it; the scheduler
    itself stays arrival-blind — whoever submits decides *when*."""
    rid: str
    prompt: Tuple[int, ...]              # prompt token ids
    max_new_tokens: int
    arrival: int = 0

    def __post_init__(self):
        assert len(self.prompt) > 0, "empty prompt"
        assert self.max_new_tokens >= 1, self.max_new_tokens
        assert self.arrival >= 0, self.arrival


class SlotScheduler:
    def __init__(self, n_slots: int):
        assert n_slots >= 1, n_slots
        self.n_slots = n_slots
        self.slots: List[Optional[str]] = [None] * n_slots
        self.pending: Deque[Request] = deque()
        self.running: Dict[str, int] = {}      # rid -> slot
        self._admitted: List[str] = []         # admission order (for tests)

    # -- queue side ----------------------------------------------------------
    def submit(self, requests: Sequence[Request]):
        for r in requests:
            assert r.rid not in self.running and all(
                p.rid != r.rid for p in self.pending), f"dup rid {r.rid}"
            self.pending.append(r)

    def submit_front(self, request: Request):
        """Queue a request AHEAD of everything pending.  Used for
        migrated-in and crash-resumed sessions: they were admitted first
        in their previous incarnation, so FIFO fairness (measured over
        the fleet's lifetime, not one engine's) puts them first here."""
        assert request.rid not in self.running and all(
            p.rid != request.rid for p in self.pending), \
            f"dup rid {request.rid}"
        self.pending.appendleft(request)

    # -- slot side -----------------------------------------------------------
    def free_slots(self) -> List[int]:
        return [i for i, sid in enumerate(self.slots) if sid is None]

    @property
    def n_running(self) -> int:
        return len(self.running)

    def admit(self) -> List[Tuple[int, Request]]:
        """Fill free slots from the FIFO queue; returns (slot, request)
        pairs for the engine to prefill.  Never exceeds ``n_slots``."""
        placed: List[Tuple[int, Request]] = []
        for slot in self.free_slots():
            if not self.pending:
                break
            req = self.pending.popleft()
            self.slots[slot] = req.rid
            self.running[req.rid] = slot
            self._admitted.append(req.rid)
            placed.append((slot, req))
        return placed

    def release(self, rid: str) -> int:
        """Finished sequence frees its slot (same tick as completion)."""
        slot = self.running.pop(rid)
        assert self.slots[slot] == rid, (rid, slot, self.slots[slot])
        self.slots[slot] = None
        return slot

    @property
    def done(self) -> bool:
        return not self.pending and not self.running

    @property
    def admission_order(self) -> List[str]:
        return list(self._admitted)
