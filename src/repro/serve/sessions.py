"""Durable session store: serving state committed through the FliT path.

One *session commit* at decode tick ``s`` is the paper's Alg. 2 over the
serving worker's live state, exactly as a training checkpoint commit but
with a DYNAMIC object set:

* objects — one KV-cache object ``kv/<rid>`` per RUNNING session (staged
  from the slot lanes by the engine just before the commit);
* meta    — the full session table: per session the prompt, every token
  emitted so far, done flag and the staged cache version.  The table
  rides in the manifest document, so it becomes durable by the SAME
  atomic rename (completeOp) that publishes the cache objects — a
  session's tokens and its cache can never be torn apart.

A killed serving worker restarts and calls ``recover()``: the newest
manifest whose every cache object CRC-validates wins
(``dsm.recovery.RecoveryManager.recover_latest``; torn commits fall back
exactly as in training recovery).  Finished sessions come back as
results; running sessions come back as (tokens emitted, restored cache)
and the engine resumes them — bit-identically, because the restored
cache bytes equal the committed HBM bytes and the slot-masked decode is
independent of batch composition (train.step.make_slot_decode_step).

Fault injection: the committer's ``fault_hook`` fires at the usual
pre_flush / mid_flush / post_completeOp points, which is what the
serve-worker kill scenario (repro.scenarios.serve_worker) drives.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

from repro.dsm.api import CXL0Context, open_cxl0
from repro.dsm.pool import DSMPool

KV_PREFIX = "kv/"


def kv_name(rid: str) -> str:
    return KV_PREFIX + rid


@dataclasses.dataclass
class Session:
    """One admitted request's serving state."""
    rid: str
    prompt: Tuple[int, ...]
    max_new_tokens: int
    emitted: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    cache_version: Optional[int] = None

    @property
    def pos(self) -> int:
        """Decode position the cache currently covers: the prompt plus
        every emitted token that has been FED BACK.  The newest emitted
        token is the next decode's input, so it is not in the cache yet —
        hence the ``- 1`` (emitted is never empty once admitted: prefill
        emits the first token)."""
        return len(self.prompt) + len(self.emitted) - 1

    def to_meta(self) -> dict:
        return {"prompt": list(self.prompt), "max_new": self.max_new_tokens,
                "emitted": list(self.emitted), "done": self.done,
                "cache_version": self.cache_version}

    @classmethod
    def from_meta(cls, rid: str, d: dict) -> "Session":
        return cls(rid=rid, prompt=tuple(int(t) for t in d["prompt"]),
                   max_new_tokens=int(d["max_new"]),
                   emitted=[int(t) for t in d["emitted"]],
                   done=bool(d["done"]),
                   cache_version=d.get("cache_version"))


@dataclasses.dataclass
class RecoveredState:
    sessions: Dict[str, Session]     # full table (done + running)
    caches: Dict[str, Any]           # rid -> restored cache (running only)
    step: int                        # decode tick of the commit
    seq: int                         # manifest sequence


class SessionStore:
    def __init__(self, pool: Optional[DSMPool] = None, *, worker_id: int = 0,
                 mode: str = "sync", n_shards: Optional[int] = None,
                 retention: Optional[int] = 2,
                 fault_hook=None, placement=None,
                 ctx: Optional[CXL0Context] = None):
        """Either hand in an already-open ``CXL0Context`` (the launchers'
        ``CXL0Config`` path) or a pool + the legacy kwargs — the latter are
        routed through ``open_cxl0`` so there is ONE wiring path."""
        if ctx is None:
            ctx = open_cxl0(pool, worker_id, schedule=mode,
                            n_shards=n_shards, retention=retention,
                            fault_hook=fault_hook, placement=placement)
        self.ctx = ctx
        self.pool = ctx.pool
        self.placement = ctx.placement  # cost-driven shard count/schedule
        self.recovery = ctx.recovery

    @property
    def tiers(self):
        return self.ctx.tiers

    @property
    def committer(self):
        return self.ctx.committer

    # -- commit side ---------------------------------------------------------
    def stage(self, session: Session, cache1: Any):
        """LStore a running session's slot cache for the next commit and
        record the version it will be durable at."""
        self.tiers.lstore(kv_name(session.rid), cache1)
        session.cache_version = self.tiers.versions[kv_name(session.rid)]

    def discard(self, rid: str):
        """Session finished (or evicted): its cache leaves the host tier so
        the next commit stops flushing it."""
        self.tiers.ldiscard(kv_name(rid))

    def commit(self, sessions: Dict[str, Session], step: int):
        """Alg. 2 commit as ONE commit region: RFlush every staged cache,
        then exactly one completeOp manifest carrying the session table."""
        meta = {"kind": "serve",
                "sessions": {rid: s.to_meta()
                             for rid, s in sessions.items()}}
        with self.ctx.commit(step, meta=meta) as txn:
            pass                # caches were staged via ``stage``
        return txn.stats

    def drain(self):
        return self.ctx.drain()

    def close(self):
        self.ctx.close()

    # -- recovery side -------------------------------------------------------
    def recover(self, cache_template) -> Optional[RecoveredState]:
        """Newest fully-valid session commit, or None on a cold pool."""
        got = self.recovery.recover_latest(lambda name, entry:
                                           cache_template)
        if got is None:
            return None
        objs, m = got
        meta = m.get("meta") or {}
        table = meta.get("sessions")
        if table is None:
            return None                       # not a serve-worker pool
        sessions = {rid: Session.from_meta(rid, d)
                    for rid, d in table.items()}
        caches = {rid: objs[kv_name(rid)] for rid in sessions
                  if kv_name(rid) in objs}
        return RecoveredState(sessions, caches, m["step"], m["seq"])
