"""Durable session store: serving state committed through the FliT path.

One *session commit* at decode tick ``s`` is the paper's Alg. 2 over the
serving worker's live state, exactly as a training checkpoint commit but
with a DYNAMIC object set.  Two layouts share the machinery:

* **paged** (the default engine path since the fleet refactor) — one
  pool object per token BLOCK, ``kv/<rid>/b<k>`` (serve.paging): the
  commit flushes only the blocks a session's position touched since the
  last commit, and the manifest's object dict is the union of those
  fresh flushes and the CARRIED entries of every clean block (merged in
  a delegated completeOp), so any single manifest still describes every
  live cache completely.  The per-session block tables ride in the
  manifest meta next to the session table — tokens, tables and block
  bytes become durable in ONE atomic rename;
* **legacy** (kept for the equivalence tests) — one whole-lane
  ``kv/<rid>`` object per running session, re-flushed every commit.

A killed serving worker restarts and calls ``recover()``: the newest
manifest for THIS engine (fleet manifests are tagged ``engine: i`` and
block objects live under an ``e<i>/`` namespace) whose every referenced
object CRC-validates wins; torn commits fall back to older manifests
exactly as in training recovery.  Finished sessions come back as
results; running sessions come back as (tokens emitted, restored cache)
and the engine resumes them — bit-identically, because the restored
bytes equal the committed HBM bytes and the slot-masked decode is
independent of batch composition (train.step.make_slot_decode_step).

Cross-engine prefix reuse: prompt-pure blocks are ALSO published as
content-addressed pool objects ``kvblk/<hash>`` + a ``kvhead/<hash>``
prefill head (serve.paging), written once via MStore; ``load_prefix``
restores them so a second engine serving the same prompt skips its
prefill.  A torn publish is invisible — the streamed frames self-
validate, and any read failure degrades to a normal prefill.

Fault injection: the committer's ``fault_hook`` fires at the usual
pre_flush / mid_flush / post_completeOp points, which is what the
serve-worker kill scenario (repro.scenarios.serve_worker) drives.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

from repro.dsm.api import CXL0Context, open_cxl0
from repro.dsm.pool import (CorruptObjectError, DSMPool, manifest_entry)
from repro.serve.paging import (BlockPager, BlockRef, BlockTable,
                                STATE_BLOCK, block_object_name,
                                prefix_hash, shared_block_name,
                                shared_head_name)

KV_PREFIX = "kv/"


def kv_name(rid: str) -> str:
    return KV_PREFIX + rid


def engine_ns(engine_id: int) -> str:
    """Per-engine object namespace in a fleet pool.  Engine 0 writes
    unprefixed names so single-engine pools look exactly as before."""
    return f"e{engine_id}/" if engine_id else ""


@dataclasses.dataclass
class Session:
    """One admitted request's serving state."""
    rid: str
    prompt: Tuple[int, ...]
    max_new_tokens: int
    emitted: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    cache_version: Optional[int] = None
    #: set by a migration handoff commit: this engine no longer owns the
    #: session — the target engine (or its restart) serves it
    migrated_to: Optional[int] = None

    @property
    def pos(self) -> int:
        """Decode position the cache currently covers: the prompt plus
        every emitted token that has been FED BACK.  The newest emitted
        token is the next decode's input, so it is not in the cache yet —
        hence the ``- 1`` (emitted is never empty once admitted: prefill
        emits the first token)."""
        return len(self.prompt) + len(self.emitted) - 1

    def to_meta(self) -> dict:
        d = {"prompt": list(self.prompt), "max_new": self.max_new_tokens,
             "emitted": list(self.emitted), "done": self.done,
             "cache_version": self.cache_version}
        if self.migrated_to is not None:
            d["migrated_to"] = self.migrated_to
        return d

    @classmethod
    def from_meta(cls, rid: str, d: dict) -> "Session":
        return cls(rid=rid, prompt=tuple(int(t) for t in d["prompt"]),
                   max_new_tokens=int(d["max_new"]),
                   emitted=[int(t) for t in d["emitted"]],
                   done=bool(d["done"]),
                   cache_version=d.get("cache_version"),
                   migrated_to=d.get("migrated_to"))


@dataclasses.dataclass
class RecoveredState:
    sessions: Dict[str, Session]     # full table (done + running)
    caches: Dict[str, Any]           # rid -> restored cache (running only)
    step: int                        # decode tick of the commit
    seq: int                         # manifest sequence
    #: paged commits only: the recovered per-session block tables (the
    #: engine re-adopts their frame ids into its allocator)
    tables: Dict[str, BlockTable] = dataclasses.field(default_factory=dict)


class SessionStore:
    def __init__(self, pool: Optional[DSMPool] = None, *, worker_id: int = 0,
                 mode: str = "sync", n_shards: Optional[int] = None,
                 retention: Optional[int] = 2,
                 fault_hook=None, placement=None,
                 ctx: Optional[CXL0Context] = None,
                 engine_id: int = 0):
        """Either hand in an already-open ``CXL0Context`` (the launchers'
        ``CXL0Config`` path) or a pool + the legacy kwargs — the latter are
        routed through ``open_cxl0`` so there is ONE wiring path.
        ``engine_id`` namespaces this store's objects and manifests inside
        a fleet pool (0 = the single-engine layout, unprefixed)."""
        if ctx is None:
            ctx = open_cxl0(pool, worker_id, schedule=mode,
                            n_shards=n_shards, retention=retention,
                            fault_hook=fault_hook, placement=placement)
        self.ctx = ctx
        self.pool = ctx.pool
        self.placement = ctx.placement  # cost-driven shard count/schedule
        self.recovery = ctx.recovery
        self.engine_id = engine_id
        self.ns = engine_ns(engine_id)
        #: clean-block manifest entries carried into the next completeOp
        #: (rebuilt from the live block tables at every paged commit)
        self._carried: Dict[str, dict] = {}
        #: entries of the most recent completeOp's fresh flushes —
        #: captured inside the delegated completeOp, absorbed into block
        #: tables right after the commit call returns
        self._last_written: Dict[str, dict] = {}

    @property
    def tiers(self):
        return self.ctx.tiers

    @property
    def committer(self):
        return self.ctx.committer

    def block_name(self, rid: str, blk: int) -> str:
        return block_object_name(rid, blk, self.ns)

    # -- legacy commit side (whole-lane kv/<rid> objects) --------------------
    def stage(self, session: Session, cache1: Any):
        """LStore a running session's slot cache for the next commit and
        record the version it will be durable at."""
        self.tiers.lstore(self.ns + kv_name(session.rid), cache1)
        session.cache_version = \
            self.tiers.versions[self.ns + kv_name(session.rid)]

    def discard(self, rid: str):
        """Session finished (or evicted): its cache leaves the host tier so
        the next commit stops flushing it."""
        self.tiers.ldiscard(self.ns + kv_name(rid))

    def commit(self, sessions: Dict[str, Session], step: int):
        """Alg. 2 commit as ONE commit region: RFlush every staged cache,
        then exactly one completeOp manifest carrying the session table."""
        meta = {"kind": "serve", "engine": self.engine_id,
                "sessions": {rid: s.to_meta()
                             for rid, s in sessions.items()}}
        if not self.engine_id:
            meta.pop("engine")        # single-engine meta unchanged
        with self.ctx.commit(step, meta=meta) as txn:
            pass                # caches were staged via ``stage``
        return txn.stats

    # -- paged commit side ---------------------------------------------------
    def stage_block(self, session: Session, ref: BlockRef, leaves):
        """LStore one dirty block payload; the next commit flushes it."""
        self.tiers.lstore(ref.name, leaves)
        ref.entry = None                      # durable entry now stale
        session.cache_version = self.tiers.versions[ref.name]

    def commit_paged(self, sessions: Dict[str, Session],
                     tables: Dict[str, BlockTable], step: int, *,
                     block_tokens: int):
        """Paged Alg. 2 commit: flush ONLY the staged dirty blocks, then
        one completeOp whose manifest carries (a) the session table and
        every block table in meta and (b) the union of fresh + carried
        block entries in the object dict.  The carried merge happens in a
        delegated completeOp (``complete_fn``), the cluster extension
        point — which also disables the committer's retention GC:
        multi-writer fleet pools must not drop a sibling's manifests
        (repro.dsm.flit_runtime)."""
        if self.committer.complete_fn is None:
            self.committer.complete_fn = self._complete_paged
        meta = {"kind": "serve", "paged": True, "engine": self.engine_id,
                "block_tokens": block_tokens,
                "sessions": {rid: s.to_meta()
                             for rid, s in sessions.items()},
                "tables": {rid: t.to_meta() for rid, t in tables.items()}}
        self._carried = {}
        for t in tables.values():
            self._carried.update(t.entries())
        with self.ctx.commit(step, meta=meta) as txn:
            pass                # dirty blocks were staged via stage_block
        self.absorb_written(tables)
        return txn.stats

    def _complete_paged(self, step: int, written: Dict[str, Any],
                        meta: Optional[dict]) -> int:
        """Delegated completeOp: ONE manifest referencing the fresh
        flushes AND every carried clean block, atomically with the
        session/block tables in ``meta``."""
        entries = {n: manifest_entry(o) for n, o in written.items()}
        merged = dict(self._carried)
        merged.update(entries)
        self._last_written = entries
        return self.pool.commit_manifest(step, merged, meta)

    def absorb_written(self, tables: Dict[str, BlockTable]):
        """Record the freshly published entries into their block refs and
        drop the flushed payloads from the host tier — a clean block is
        carried by name from here on, never re-flushed.  Async schedules
        publish one commit late; their entries are absorbed at the next
        call (double-buffering semantics unchanged)."""
        if not self._last_written:
            return
        for t in tables.values():
            for ref in t.refs.values():
                e = self._last_written.get(ref.name)
                if e is not None:
                    ref.entry = e
                    if ref.blk != STATE_BLOCK \
                            and ref.name in self.tiers.hbm:
                        self.tiers.ldiscard(ref.name)
        self._last_written = {}

    def discard_session_blocks(self, rid: str):
        """Drop a finished/migrated session's staged blocks from the host
        tier (its carried entries disappear with its table at the next
        commit)."""
        prefix = f"{self.ns}{KV_PREFIX}{rid}/"
        for name in [n for n in self.tiers.hbm if n.startswith(prefix)]:
            self.tiers.ldiscard(name)

    # -- cross-engine prefix reuse -------------------------------------------
    def publish_prefix(self, pager: BlockPager, key: str,
                       prompt: Tuple[int, ...], cache1: Any, tok0: int
                       ) -> int:
        """Publish the prompt-pure blocks of a freshly prefilled session
        as content-addressed shared objects (write-once: a block whose
        hash already exists in the pool is skipped).  Returns how many
        objects were newly written."""
        host = pager._host_leaves(cache1)
        wrote = 0
        for k, h in enumerate(pager.prompt_block_hashes(key, prompt)):
            name = shared_block_name(h)
            if self.pool.max_version(name) == 0:
                self.tiers.mstore(name, pager.slice_block(host, k))
                self.tiers.ldiscard(name)     # durable; keep out of commits
                wrote += 1
        hname = shared_head_name(
            prefix_hash(key, prompt, pager.block_tokens))
        if self.pool.max_version(hname) == 0:
            self.tiers.mstore(hname, pager.head_payload(host, len(prompt),
                                                        tok0))
            self.tiers.ldiscard(hname)
            wrote += 1
        return wrote

    def load_prefix(self, pager: BlockPager, key: str,
                    prompt: Tuple[int, ...]):
        """Restore a session's prefill state from shared prefix blocks:
        returns ``(blocks, shared_refs, tok0)`` on a full-prompt hit, or
        None (missing or torn objects — the frames self-validate, and any
        failure means 'prefill normally')."""
        names = [shared_block_name(h)
                 for h in pager.prompt_block_hashes(key, prompt)]
        hname = shared_head_name(
            prefix_hash(key, prompt, pager.block_tokens))
        blocks: Dict[int, Any] = {}
        shared: Dict[int, Tuple[str, dict]] = {}
        try:
            for k, name in enumerate(names):
                v = self.pool.max_version(name)
                if v == 0:
                    return None
                blocks[k] = self.pool.read_object(name, v,
                                                  pager.block_template)
                shared[k] = (name, {"name": name, "version": v,
                                    "crc": None})
            v = self.pool.max_version(hname)
            if v == 0:
                return None
            head = self.pool.read_object(hname, v, pager.head_template)
        except (CorruptObjectError, OSError, ValueError):
            return None
        tail, state, tok0 = pager.split_head(head)
        if tail:
            blocks[len(names)] = tail
        if state:
            blocks[STATE_BLOCK] = state
        return blocks, shared, tok0

    def drain(self):
        return self.ctx.drain()

    def close(self):
        self.ctx.close()

    # -- recovery side -------------------------------------------------------
    def _manifests_for_engine(self) -> List[dict]:
        out = []
        for m in self.pool.manifests_desc():
            meta = m.get("meta") or {}
            if "sessions" not in meta:
                continue                      # not a serve commit
            if int(meta.get("engine", 0)) != self.engine_id:
                continue                      # a fleet sibling's commit
            out.append(m)
        return out

    def recover(self, cache_template, *,
                pager: Optional[BlockPager] = None
                ) -> Optional[RecoveredState]:
        """Newest fully-valid session commit FOR THIS ENGINE, or None on
        a cold pool.  Handles both layouts: paged manifests restore each
        running session by assembling its block table's objects
        (``pager`` required); legacy manifests read whole-lane
        ``kv/<rid>`` objects against ``cache_template``.  Any torn or
        unreadable object fails the WHOLE manifest and recovery falls
        back to an older one — a session table can never pair with torn
        bytes."""
        for m in self._manifests_for_engine():
            meta = m.get("meta") or {}
            got = (self._read_paged(m, meta, pager) if meta.get("paged")
                   else self._read_legacy(m, meta, cache_template))
            if got is None:
                continue                      # torn commit: older manifest
            sessions, caches, tables = got
            return RecoveredState(sessions, caches, m["step"], m["seq"],
                                  tables=tables)
        return None

    def _read_legacy(self, m: dict, meta: dict, cache_template):
        sessions = {rid: Session.from_meta(rid, d)
                    for rid, d in meta["sessions"].items()}
        caches: Dict[str, Any] = {}
        try:
            for name, entry in m["objects"].items():
                caches[name] = self.pool.read_entry(name, entry,
                                                    cache_template)
        except (CorruptObjectError, KeyError, ValueError):
            return None
        caches = {rid: caches[self.ns + kv_name(rid)] for rid in sessions
                  if self.ns + kv_name(rid) in caches}
        return sessions, caches, {}

    def _read_paged(self, m: dict, meta: dict,
                    pager: Optional[BlockPager]):
        if pager is None:
            return None       # paged pool read without a pager: no match
        sessions = {rid: Session.from_meta(rid, d)
                    for rid, d in meta["sessions"].items()}
        tables = {rid: BlockTable.from_meta(d)
                  for rid, d in (meta.get("tables") or {}).items()}
        # backfill durable entries the tables were serialized WITHOUT:
        # a block staged for this very commit had entry=None at meta
        # capture time (its flush entry only exists post-completeOp), but
        # the manifest's object dict references it — so a recovered table
        # (including a migration-handoff tombstone's) always carries a
        # valid pool entry per block
        for t in tables.values():
            for ref in t.refs.values():
                e = m["objects"].get(ref.name)
                if e is not None:
                    ref.entry = e
        caches: Dict[str, Any] = {}
        for rid, s in sessions.items():
            if s.done or s.migrated_to is not None or rid not in tables:
                continue
            blocks: Dict[int, Any] = {}
            try:
                for blk, ref in tables[rid].refs.items():
                    entry = m["objects"].get(ref.name) or ref.entry
                    if entry is None:
                        return None           # table references a block
                        #                       the manifest does not carry
                    tpl = (pager.state_template if blk == STATE_BLOCK
                           else pager.block_template)
                    blocks[blk] = self.pool.read_entry(ref.name, entry,
                                                       tpl)
            except (CorruptObjectError, KeyError, ValueError):
                return None
            caches[rid] = pager.assemble(blocks)
        return sessions, caches, tables

    def peek_engine(self, engine_id: int) -> Optional[dict]:
        """Newest serve manifest of a SIBLING engine (its meta carries
        the session + block tables) — how a fleet restart discovers
        handoffs whose target never committed its adoption."""
        for m in self.pool.manifests_desc():
            meta = m.get("meta") or {}
            if "sessions" not in meta:
                continue
            if int(meta.get("engine", 0)) != engine_id:
                continue
            return m
        return None
