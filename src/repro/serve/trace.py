"""Deterministic synthetic request traces.

Benchmarks and the serve-worker kill scenario must agree on the request
stream across PROCESSES (a restarted worker regenerates the trace from
the seed), so everything here is a pure function of its arguments:
prompts come from a seeded generator, request lengths cycle through the
choice tuples (guaranteed mixed-length without sampling noise).
"""
from __future__ import annotations

from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.serve.scheduler import Request


def synthetic_trace(n_requests: int, *, seed: int = 0,
                    vocab_size: int = 256,
                    prompt_lens: Sequence[int] = (32,),
                    new_tokens: Sequence[int] = (4, 8, 16, 32, 48),
                    n_prompts: int = 0,
                    arrivals: Optional[Sequence[int]] = None,
                    ) -> List[Request]:
    """``n_requests`` deterministic requests.

    ``prompt_lens`` / ``new_tokens`` are cycled in order — a one-element
    ``prompt_lens`` gives the uniform-prompt trace the static baseline
    needs (it batches prompts unpadded), while the default ``new_tokens``
    mix is exactly the mixed-output-length workload where one long
    sequence holds a static batch hostage.

    ``n_prompts > 0`` draws only that many DISTINCT prompts (per prompt
    length) and cycles them — the shared-prefix serving workload where
    content-addressed prefix reuse (serve.paging) pays: request i and
    request i + n_prompts*len(prompt_lens) share their prompt exactly.

    ``arrivals`` stamps request i with arrival tick ``arrivals[i]``
    (cycled if shorter).  Omitted, every request arrives at tick 0 and
    the trace is byte-identical to the pre-arrival-time one: prompts
    come from the same RNG draws in the same order, and ``arrival=0``
    is the dataclass default."""
    rng = np.random.default_rng(seed)
    pool: dict = {}
    out: List[Request] = []
    for i in range(n_requests):
        L = int(prompt_lens[i % len(prompt_lens)])
        m = int(new_tokens[i % len(new_tokens)])
        if n_prompts > 0:
            slot = (i // len(prompt_lens)) % n_prompts
            if (L, slot) not in pool:
                pool[(L, slot)] = tuple(
                    int(t) for t in rng.integers(0, vocab_size, size=L))
            prompt = pool[(L, slot)]
        else:
            prompt = tuple(int(t)
                           for t in rng.integers(0, vocab_size, size=L))
        if arrivals is None:
            out.append(Request(rid=f"r{i:04d}", prompt=prompt,
                               max_new_tokens=m))
        else:
            out.append(Request(rid=f"r{i:04d}", prompt=prompt,
                               max_new_tokens=m,
                               arrival=int(arrivals[i % len(arrivals)])))
    return out


def trace_t_max(requests: Sequence[Request]) -> int:
    """Cache length covering every request in the trace."""
    return max(len(r.prompt) + r.max_new_tokens for r in requests)
