from repro.train.state import TrainState  # noqa: F401
from repro.train.step import make_train_step, make_serve_steps  # noqa: F401
