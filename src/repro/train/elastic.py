"""Elastic scaling: re-shard training state when the worker set changes.

On a worker-count change (scale-up, or shrink after a permanent failure)
the launcher:

1. recovers the newest durable state (``dsm.recovery``) — the pool is the
   rendezvous, so joiners need no peer that remembers the past;
2. builds the new mesh (possibly fewer/more hosts) and the new sharding
   tree from the SAME logical axes (sharding rules are mesh-shape-agnostic);
3. ``reshard``s every array onto the new mesh (jax.device_put handles the
   all-to-all re-layout; on real hardware this is the resharding transfer);
4. re-plans data shards (``data.shard_plan``) for the new rank count.

The dry-run proves step 2-3 lower for both the 256-chip and 512-chip
meshes; tests/test_elastic.py exercises a real 8→4 device shrink on CPU.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.params import tree_map_descs
from repro.parallel.sharding import ParallelCtx, ctx_for_mesh, param_specs


def shardings_for(ctx: ParallelCtx, descs):
    """NamedShardings on ctx.mesh from the logical-axis rules (works for any
    mesh shape — the same descs tree serves 1, 256 or 512 devices)."""
    specs = param_specs(ctx, descs)
    return jax.tree_util.tree_map(lambda s: NamedSharding(ctx.mesh, s),
                                  specs)


def reshard(tree: Any, new_shardings: Any) -> Any:
    """Move every array onto its new sharding (device_put = resharding
    transfer; cross-host on real clusters)."""
    return jax.tree_util.tree_map(
        lambda a, s: jax.device_put(a, s), tree, new_shardings)


def remesh(tree: Any, descs: Any, new_mesh: Mesh, *,
           ep: bool = True) -> Tuple[Any, ParallelCtx]:
    """Recovered state -> state sharded on ``new_mesh``."""
    ctx = ctx_for_mesh(new_mesh, ep=ep)
    return reshard(tree, shardings_for(ctx, descs)), ctx


def shrink_plan(old_ranks: int, new_ranks: int) -> dict:
    """Which old rank's data-shard responsibilities move where (documented
    plan consumed by the launcher; data reshuffling itself is free because
    the pipeline is deterministic — any rank can compute any shard)."""
    assert new_ranks > 0
    return {r: r % new_ranks for r in range(old_ranks)}


def grow_plan(old_ranks: int, new_ranks: int) -> dict:
    """``shrink_plan``'s inverse direction: which new rank inherits each
    old rank's data-shard responsibilities when the cluster GROWS.  Old
    ranks keep their identity (r -> r); the added ranks start fresh —
    the deterministic pipeline means a joiner can compute any shard, so
    the plan only documents continuity for the launcher."""
    assert new_ranks >= old_ranks > 0, (old_ranks, new_ranks)
    return {r: r for r in range(old_ranks)}


def plan_delta(old_plan: Dict[str, int], new_plan: Dict[str, int]
               ) -> Dict[str, Tuple[int, int]]:
    """The entries that change owner between two partition plans:
    ``{name: (old_owner, new_owner)}``.  This is exactly the transfer
    set of a grow (or shrink) by repartition — the survivors RStore each
    moving entry into its new owner's staging buffer, and everything not
    in the delta stays put."""
    assert set(old_plan) == set(new_plan), \
        (sorted(set(old_plan) ^ set(new_plan)))
    return {n: (old_plan[n], new_plan[n]) for n in sorted(old_plan)
            if old_plan[n] != new_plan[n]}


def partition_plan(names: Sequence[str], ranks: Sequence[int],
                   device_sets: Optional[Dict[int, Any]] = None
                   ) -> Dict[str, int]:
    """Stable ownership map of named state entries over a rank set — the
    FSDP-style state partition of the cluster protocol
    (``repro.dsm.cluster``): each data-parallel rank OWNS a disjoint slice
    of the model/optimizer state and commits it under its ``w<i>/``
    namespace.  Round-robin over the sorted names and the sorted live
    ranks, so every process (and a restarted one) derives the identical
    map from the same membership — no coordinator needed.  On a shrink the
    plan recomputed for the surviving ranks reassigns the victim's entries
    deterministically.

    ``device_sets`` maps each rank to its mesh-slice weight — a device
    count, or anything with a ``len`` (a device list, a ``Mesh``'s device
    array) — and expands the round-robin over per-device SLOTS: a rank
    owning twice the devices draws twice the entries, so partitions land
    proportionally on the actual sub-grids (``launch.mesh.rank_submesh``).
    Every process derives the same plan from the same (live, device_sets)
    pair; equal weights reduce to the classic per-rank round-robin."""
    ranks = sorted(ranks)
    assert ranks, "partition over an empty rank set"
    if device_sets:
        slots: list = []
        for r in ranks:
            w = device_sets.get(r, 1)
            try:
                w = len(w)
            except TypeError:
                w = int(w)
            slots.extend([r] * max(1, w))
    else:
        slots = ranks
    return {n: slots[i % len(slots)] for i, n in enumerate(sorted(names))}
