"""The durable training loop: train steps + FliT-protocol commits + crash
recovery, with fault-injection hooks and straggler statistics.

This is the single-process integration of the whole stack (model, optimizer,
data pipeline, DSM runtime); the multi-pod launch wraps exactly this loop
per worker (launch/train.py).  The loop guarantees:

* any step whose commit completed survives a crash (durable linearizability
  of the step history — the paper's §6 transformation at system scale);
* recovery resumes from the newest recoverable state — a peer's RStore-staged
  copy if fresher than the pool (CXL0 cache-to-cache propagation), else the
  newest CRC-valid manifest;
* the data pipeline resumes exactly where the recovered step left off
  (PipelineState is one of the committed objects) — no data loss or dupes.

The default commit schedule is ``sharded-async``: per-device byte-balanced
state shards flushed on parallel pipelines, double-buffered one commit
behind compute (see repro.dsm.flit_runtime for all four schedules).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.pipeline import DataPipeline, PipelineState
from repro.dsm.api import CXL0Context, open_cxl0
from repro.dsm.pool import DSMPool
from repro.dsm.recovery import CrashError, ColdStartError
from repro.train.state import TrainState


@dataclasses.dataclass
class StepTiming:
    """Per-step wall times — the straggler-mitigation signal: the launcher
    feeds these into ``data.shard_plan`` weights to shrink a slow worker's
    shard."""
    step: int
    compute_s: float
    commit_s: float


@dataclasses.dataclass
class LoopResult:
    state: TrainState
    pipeline_state: PipelineState
    losses: List[float]
    timings: List[StepTiming]
    recoveries: List[str]       # recovery sources used ("pool"/"peer-staging")
    crashes: int
    resumed_from: Optional[int] = None    # step recovered at startup
    #                                       (resume=True), None if cold


def _state_objects(state: TrainState, pipe_state: PipelineState):
    return {
        "params": state.params,
        "opt_mu": state.opt.mu,
        "opt_nu": state.opt.nu,
        "counters": {"opt_step": state.opt.step, "rng": state.rng},
        "pipeline": {"seed": np.int64(pipe_state.seed),
                     "step": np.int64(pipe_state.step)},
    }


def _restore_placement(objs, templates):
    """Put recovered (host-resident) leaves back onto the device layout the
    templates carry: a template leaf that is a device-sharded jax array
    donates its ``sharding``, so a mesh run resumes device-sharded and the
    NEXT commit can run device-local again.  Host templates pass through —
    the non-mesh loop is unchanged."""
    def place(r, t):
        sh = getattr(t, "sharding", None)
        if isinstance(t, jax.Array) and sh is not None:
            return jax.device_put(r, sh)
        return r
    return {name: jax.tree_util.tree_map(place, objs[name], templates[name])
            for name in objs}


def _objects_to_state(objs, template: TrainState):
    st = TrainState(
        params=objs["params"],
        opt=template.opt._replace(
            mu=objs["opt_mu"], nu=objs["opt_nu"],
            step=jnp.asarray(objs["counters"]["opt_step"])),
        rng=jnp.asarray(objs["counters"]["rng"]))
    ps = PipelineState(seed=int(objs["pipeline"]["seed"]),
                       step=int(objs["pipeline"]["step"]))
    return st, ps


def run_durable_loop(
    step_fn: Callable,
    init_state: TrainState,
    pipeline: DataPipeline,
    pool: DSMPool,
    *,
    n_steps: int,
    commit_every: int = 5,
    commit_mode: str = "sharded-async",   # the production default schedule
    n_shards: Optional[int] = None,      # sharded modes; None = per-device
    placement=None,         # PlacementPolicy: cost-driven shard count (and,
    #                         with commit_mode="auto", the schedule) under
    #                         an emulated topology — see repro.dsm.placement
    retention: Optional[int] = None,     # keep newest k manifests (GC)
    worker_id: int = 0,
    peer_tiers=None,            # one peer, or a sequence of peers: anything
    #                             with a .staging mapping (TierManager, or a
    #                             cross-process staging view).  Replication
    #                             targets the FIRST peer; recovery consults
    #                             them all.
    replicate: bool = False,
    crash_at: Optional[Dict[int, str]] = None,   # step -> "before_commit" |
    #                                              "after_commit" | "mid_write"
    fault_hook: Optional[Callable] = None,  # (point, step) inside the commit
    #                                         window — see flit_runtime
    resume: bool = False,   # recover from the pool before training (process
    #                         restart); skips the initial step -1 commit
    mesh=None,              # jax Mesh: device-sharded commits (each shard
    #                         pipeline drains its devices' buffers — no host
    #                         gather) and recovered leaves are put back onto
    #                         the template leaf's NamedSharding
    to_device: Callable = jnp.asarray,
) -> LoopResult:
    """Run ``n_steps`` with durable commits every ``commit_every`` steps.

    ``crash_at`` injects worker crashes at precise points (tests use this to
    prove prefix-consistency); after a crash the loop RECOVERS and continues
    — emulating the scheduler restarting the worker.  ``fault_hook`` is the
    harder variant: it fires INSIDE the commit window (pre-flush, mid-flush,
    post-completeOp) so the scenario runner can kill the whole process
    there; the restarted process passes ``resume=True`` to recover from the
    pool instead of re-committing a fresh step -1 (which would shadow newer
    manifests).

    ``pool`` may be a ``DSMPool`` (or pool path) — the loop then opens a
    ``CXL0Context`` from the wiring kwargs — or an already-open
    ``CXL0Context`` (e.g. from a launcher's ``CXL0Config``), in which case
    the context's own wiring wins and the kwargs above only drive the loop
    (cadence, crash injection, resume).
    """
    if isinstance(pool, CXL0Context):
        ctx = pool
    else:
        peers = (tuple(peer_tiers) if isinstance(peer_tiers, (tuple, list))
                 else (peer_tiers,) if peer_tiers is not None else ())
        ctx = open_cxl0(
            pool, worker_id, schedule=commit_mode, n_shards=n_shards,
            retention=retention, placement=placement, peers=peers,
            replicate_to=peers[0] if (replicate and peers) else None,
            mesh=mesh, fault_hook=fault_hook)
    mesh = mesh if mesh is not None else getattr(ctx.config, "mesh", None)
    templates = _state_objects(init_state, pipeline.state)

    state = init_state
    losses: List[float] = []
    timings: List[StepTiming] = []
    recoveries: List[str] = []
    crashes = 0
    resumed_from: Optional[int] = None
    crash_at = dict(crash_at or {})

    i = 0
    if resume:
        try:
            objs, rec_step, source = ctx.recover(templates)
            if mesh is not None:
                objs = _restore_placement(objs, templates)
            state, pipe_state = _objects_to_state(objs, state)
            pipeline.state = pipe_state
            recoveries.append(source)
            resumed_from = rec_step
            i = rec_step + 1
        except ColdStartError:
            pass                # cold pool: fall through to the fresh path
            # (any OTHER failure propagates — committing a fresh step -1
            #  over an existing history would shadow every newer manifest)
    if resumed_from is None:
        # initial durable state (step -1): a cold restart is always possible
        ctx.put(_state_objects(state, pipeline.state), step=-1)
        with ctx.commit(-1):
            pass
        ctx.drain()
    while i < n_steps:
        plan = crash_at.get(i)
        try:
            t0 = time.perf_counter()
            batch_np = pipeline.next_global()
            batch = {k: to_device(v) for k, v in batch_np.items()}
            new_state, metrics = step_fn(state, batch)
            state = new_state
            losses.append(float(metrics["loss"]))
            t1 = time.perf_counter()

            ctx.put(_state_objects(state, pipeline.state), step=i)

            if plan == "before_commit":
                raise CrashError(f"injected before commit of step {i}")

            commit_s = 0.0
            if (i + 1) % commit_every == 0:
                if plan == "mid_write":
                    # simulate dying midway through the durable write: some
                    # objects reach the pool, the manifest does NOT
                    for name in list(ctx.tiers.hbm)[:2]:
                        ctx.tiers.rflush(name)
                    raise CrashError(f"injected mid-write at step {i}")
                tc = time.perf_counter()
                with ctx.commit(i):
                    pass
                commit_s = time.perf_counter() - tc
                if plan == "after_commit":
                    raise CrashError(f"injected after commit of step {i}")

            timings.append(StepTiming(i, t1 - t0, commit_s))
            i += 1
        except CrashError:
            crashes += 1
            crash_at.pop(i, None)
            ctx.crash()       # f_i: abort in-flight flushes, volatile tiers
            #                   vanish
            # --- recovery (new worker incarnation) -------------------------
            objs, rec_step, source = ctx.recover(templates)
            if mesh is not None:
                objs = _restore_placement(objs, templates)
            state, pipe_state = _objects_to_state(objs, state)
            pipeline.state = pipe_state
            recoveries.append(source)
            i = rec_step + 1

    td = time.perf_counter()
    drained = ctx.drain()
    if drained is not None:
        # the tail flush join is real blocking commit time (it overlaps no
        # compute) — charge it so schedule comparisons stay honest
        timings.append(StepTiming(n_steps, 0.0, time.perf_counter() - td))
    ctx.close()
    return LoopResult(state, pipeline.state, losses, timings, recoveries,
                      crashes, resumed_from)
