"""Training state: params + optimizer + step counter + RNG.

The state tree is what the DSM runtime checkpoints: each top-level entry
(params / mu / nu / counters) is registered as a durable object with the
FliT-protocol commit (see ``repro.dsm``).
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.optim.adamw import AdamWState, adamw_init, adamw_abstract


class TrainState(NamedTuple):
    params: Any
    opt: AdamWState
    rng: jax.Array            # (2,) uint32


def init_train_state(params, key, moment_dtype: str = "float32") -> TrainState:
    return TrainState(params=params,
                      opt=adamw_init(params, moment_dtype),
                      rng=jax.random.key_data(key) if hasattr(
                          jax.random, "key_data") else key)


def abstract_train_state(params_abstract,
                         moment_dtype: str = "float32") -> TrainState:
    return TrainState(
        params=params_abstract,
        opt=adamw_abstract(params_abstract, moment_dtype),
        rng=jax.ShapeDtypeStruct((2,), jnp.uint32))
